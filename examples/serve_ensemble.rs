//! End-to-end serving driver (the brief's required E2E example):
//! train a real (small) lattice ensemble, jointly optimize order +
//! thresholds, start the sharded TCP coordinator (two engine shards
//! sharing one compiled plan) with dynamic batching, drive it with a
//! closed-loop batched client, and report latency/throughput for the
//! QWYC policy vs full evaluation. Results are recorded in
//! EXPERIMENTS.md §Serving.
//!
//! By default the engine is the native backend; pass `--backend pjrt` to
//! serve through the AOT-compiled HLO artifacts (requires
//! `make artifacts` and the demo geometry).
//!
//! Run: `cargo run --release --example serve_ensemble [-- --backend pjrt]`

use qwyc::coordinator::{BatchPolicy, Client, Server, ServerConfig};
use qwyc::data::synth::{generate, Which};
use qwyc::data::Dataset;
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::pipeline::PlanBuilder;
use qwyc::plan::{PlanArtifact, QwycPlan};
use qwyc::qwyc::{FastClassifier, QwycConfig};
use qwyc::util::pool::Pool;
#[cfg(feature = "pjrt")]
use qwyc::runtime::engine::{Engine, PjrtEngine};
use std::time::Duration;

fn main() {
    let backend = std::env::args()
        .skip_while(|a| a != "--backend")
        .nth(1)
        .unwrap_or_else(|| "native".into());
    if backend == "pjrt" && !cfg!(feature = "pjrt") {
        eprintln!("error: built without the 'pjrt' feature; rerun with --features pjrt");
        std::process::exit(2);
    }

    // --- model: demo geometry (D=4, T=4, d=3) so both backends serve the
    // same artifact-compatible ensemble.
    let (tr, te) = generate(Which::Rw2Like, 77, 0.05);
    let project = |ds: &Dataset| {
        let mut out = Dataset::new("demo4", 4);
        for i in 0..ds.n {
            let r = ds.row(i);
            out.push(&[r[0], r[7], r[14], r[21]], ds.y[i]);
        }
        out
    };
    let (tr, te) = (project(&tr), project(&te));
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 4, dim: 3, steps: 250, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = PlanBuilder::new("serve-demo")
        .with_scores(&ens, &sm)
        .expect("scores entry")
        .optimize(&QwycConfig { alpha: 0.005, ..Default::default() }, &Pool::from_env())
        .expect("optimize")
        .classifier()
        .clone();
    println!(
        "model: T={} lattices; QWYC order {:?}; backend={backend}",
        ens.len(),
        fc.order
    );

    // --- serve with QWYC policy, then with full evaluation, same load.
    // Two engine shards share ONE compiled plan (native path) — the
    // same flow as `qwyc serve --plan --shards 2`.
    let config = ServerConfig {
        shards: 2,
        queue_cap: 4096,
        policy: BatchPolicy { max_batch: 256, max_wait: Duration::from_micros(500) },
        default_deadline: None,
    };
    for (policy_name, fc_used) in [
        ("qwyc", fc.clone()),
        ("full", FastClassifier::no_early_stop(fc.order.clone(), fc.bias, fc.beta)),
    ] {
        let (ens2, backend2) = (ens.clone(), backend.clone());
        let server = if backend2 == "pjrt" {
            start_pjrt_server(ens2, fc_used, config)
        } else {
            // Native path: bundle into a plan artifact, compile ONCE,
            // and share the Arc across both shards — the same flow as
            // `qwyc compile-plan` + `qwyc serve --plan` (the artifact's
            // binary form is what a deployment would ship).
            let mut plan =
                QwycPlan::bundle(ens2, fc_used, "serve-demo", 0.005).expect("bundle plan");
            plan.meta.n_features = 4;
            let artifact = PlanArtifact::from_plan(plan).expect("compile plan");
            Server::start_with_plan("127.0.0.1:0", artifact.compiled(), config).expect("server")
        };

        // Closed-loop client with a pipeline window.
        let requests = 20_000usize;
        let window = 128usize;
        let mut client = Client::connect(&server.addr).expect("connect");
        let sw = std::time::Instant::now();
        let (mut sent, mut recv) = (0usize, 0usize);
        let mut lat_us: Vec<f64> = Vec::with_capacity(requests);
        let mut models_sum = 0u64;
        while recv < requests {
            while sent < requests && sent - recv < window {
                client.send_eval(te.row(sent % te.n)).expect("send");
                sent += 1;
            }
            let r = client.read_response().expect("recv");
            lat_us.push(r.latency_us as f64);
            models_sum += r.models as u64;
            recv += 1;
        }
        let secs = sw.elapsed().as_secs_f64();
        lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| qwyc::util::stats::percentile_sorted(&lat_us, p);
        println!(
            "[{policy_name:>4}] {requests} reqs in {secs:.2}s = {:>7.0} req/s | \
             latency p50/p95/p99 = {:>5.0}/{:>5.0}/{:>5.0} us | mean models {:.2}",
            requests as f64 / secs,
            pct(50.0),
            pct(95.0),
            pct(99.0),
            models_sum as f64 / requests as f64,
        );
        server.stop();
    }
    println!("\n(qwyc-vs-full throughput ratio above is the serving-path speedup)");
}

/// PJRT backend: each shard opens its own runtime and builds its engine
/// inside its worker thread — device handles are not `Send`.
#[cfg(feature = "pjrt")]
fn start_pjrt_server(
    ens: qwyc::ensemble::Ensemble,
    fc: FastClassifier,
    config: ServerConfig,
) -> Server {
    Server::start(
        "127.0.0.1:0",
        move |_shard| -> Box<dyn Engine> {
            let rt = qwyc::runtime::Runtime::open(std::path::Path::new("artifacts"))
                .expect("run `make artifacts` first");
            Box::new(PjrtEngine::new(rt, "demo_stage", &ens, &fc).expect("engine"))
        },
        config,
    )
    .expect("server")
}

#[cfg(not(feature = "pjrt"))]
fn start_pjrt_server(
    _ens: qwyc::ensemble::Ensemble,
    _fc: FastClassifier,
    _config: ServerConfig,
) -> Server {
    unreachable!("--backend pjrt is rejected earlier when the feature is absent")
}
