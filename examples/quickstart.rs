//! Quickstart: the whole QWYC story in ~60 seconds on a laptop.
//!
//! 1. Generate an Adult-like dataset and train a boosted-tree ensemble.
//! 2. Jointly optimize evaluation order + early-stop thresholds (QWYC*).
//! 3. Compare against full evaluation and a fixed-order baseline.
//!
//! Run: `cargo run --release --example quickstart`

use qwyc::data::synth::{generate, Which};
use qwyc::gbt::{train, GbtParams};
use qwyc::pipeline::PlanBuilder;
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{optimize_order, optimize_thresholds_for_order, simulate, QwycConfig};
use qwyc::util::pool::Pool;

fn main() {
    // 1. Data + ensemble (scaled down for a fast demo; geometry is real).
    let (train_ds, test_ds) = generate(Which::AdultLike, 42, 0.10);
    println!(
        "dataset: {} train / {} test examples, {} features, {:.1}% positive",
        train_ds.n,
        test_ds.n,
        train_ds.d,
        train_ds.positive_rate() * 100.0
    );
    let params = GbtParams { n_trees: 200, max_depth: 4, ..Default::default() };
    let (ensemble, _) = train(&train_ds, &params);
    println!(
        "trained {} trees; full-ensemble test accuracy {:.4}",
        ensemble.len(),
        ensemble.accuracy(&test_ds)
    );

    // 2. QWYC* joint optimization at a few faithfulness budgets.
    let sm_train = ensemble.score_matrix(&train_ds);
    let sm_test = ensemble.score_matrix(&test_ds);
    println!(
        "\n{:<10} {:>12} {:>10} {:>10} {:>10}",
        "alpha", "mean#models", "speedup", "%diff", "accuracy"
    );
    for alpha in [0.0, 0.005, 0.01, 0.02] {
        let cfg = QwycConfig { alpha, ..Default::default() };
        let fc = optimize_order(&sm_train, &cfg);
        let sim = simulate(&fc, &sm_test);
        println!(
            "{:<10} {:>12.1} {:>9.1}x {:>9.2}% {:>10.4}",
            alpha,
            sim.mean_models,
            sm_test.t as f64 / sim.mean_models,
            sim.pct_diff * 100.0,
            sim.accuracy(&test_ds.y)
        );
    }

    // 3. Joint optimization vs fixed GBT order (paper Figure 1's gap).
    // The QWYC* side goes through the typed pipeline builder and ships
    // as a qwyc-plan-v1 artifact (JSON round-trip), so this demo
    // evaluates exactly what `serve --plan` runs.
    let alpha = 0.005;
    let cfg = QwycConfig { alpha, ..Default::default() };
    let plan = PlanBuilder::new("quickstart")
        .with_scores(&ensemble, &sm_train)
        .expect("scores entry")
        .optimize(&cfg, &Pool::from_env())
        .expect("optimize")
        .into_plan()
        .expect("bundle plan");
    let plan = QwycPlan::from_json(&plan.to_json()).expect("plan roundtrip");
    let star = simulate(&plan.fc, &sm_test);
    let natural: Vec<usize> = (0..sm_train.t).collect();
    let fixed = simulate(
        &optimize_thresholds_for_order(&sm_train, &natural, alpha, false),
        &sm_test,
    );
    println!(
        "\nat alpha={alpha}: QWYC* needs {:.1} models/example, GBT-order thresholds need {:.1} \
         — joint ordering buys {:.0}% fewer evaluations",
        star.mean_models,
        fixed.mean_models,
        (1.0 - star.mean_models / fixed.mean_models) * 100.0
    );
}
