//! Filter-and-Score: the paper's production use case (Experiments 3-6).
//!
//! A candidate-recommendation pipeline must reject ~95% of candidates as
//! fast as possible while fully scoring the promising ones for downstream
//! ranking. Only early-NEGATIVE thresholds are optimized (ε⁺ ≡ +∞).
//!
//! Run: `cargo run --release --example filter_and_score`

use qwyc::coordinator::FilterPipeline;
use qwyc::data::synth::{generate, Which};
use qwyc::lattice::LatticeParams;
use qwyc::pipeline::{PlanBuilder, TrainSpec};
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{simulate, QwycConfig};
use qwyc::util::pool::Pool;

fn main() {
    // RW1 geometry: 5 jointly-trained lattices on 13-of-16 features,
    // heavy-negative prior (95% rejected by the full model).
    let (train_ds, test_ds) = generate(Which::Rw1Like, 7, 0.05);
    println!(
        "candidates: {} train / {} test, positive rate {:.1}%",
        train_ds.n,
        test_ds.n,
        test_ds.positive_rate() * 100.0
    );
    let params = LatticeParams { n_lattices: 5, dim: 13, steps: 300, ..Default::default() };
    // Train + optimize through the typed pipeline. Only rejection
    // thresholds are optimized (neg_only): any positive classification
    // falls through to the full score. Tight α: rejecting a
    // would-be-positive costs real recall here, so the budget is a
    // quarter of the positive prior.
    let cfg = QwycConfig { alpha: 0.001, neg_only: true, ..Default::default() };
    let optimized = PlanBuilder::new("filter-demo")
        .train(TrainSpec::lattice_joint(&train_ds, params))
        .expect("train lattice ensemble")
        .optimize(&cfg, &Pool::from_env())
        .expect("optimize");
    println!("trained T=5 lattice ensemble (2^13 = 8192 vertices each)");

    // The artifact the builder emits is what online serving deploys; the
    // filter consumes the same round-tripped qwyc-plan-v1 document (and
    // the same sweep kernel).
    let plan = optimized.into_plan().expect("bundle plan");
    let plan = QwycPlan::from_json(&plan.to_json()).expect("plan roundtrip");

    let sm_test = plan.ensemble.score_matrix(&test_ds);
    let sim = simulate(&plan.fc, &sm_test);
    println!(
        "QWYC (neg-only): mean {:.2}/5 models per candidate ({:.1}x speedup), \
         {:.2}% decisions differ from full ensemble",
        sim.mean_models,
        5.0 / sim.mean_models,
        sim.pct_diff * 100.0
    );

    // Run the actual pipeline: reject early, fully score survivors, rank.
    let pipeline = FilterPipeline::from_plan(&plan).expect("neg-only classifier");
    let (stats, ranked) = pipeline.run_batch(&test_ds.x, test_ds.n);
    println!(
        "\npipeline: {} candidates -> {} rejected early, {} fully scored",
        stats.total, stats.rejected, stats.scored
    );
    println!("mean models evaluated per candidate: {:.2}", stats.mean_models);
    println!("\ntop 5 ranked survivors (row, full score):");
    for (row, score) in ranked.iter().take(5) {
        println!("  #{row:<6} score {score:+.4}");
    }
}
