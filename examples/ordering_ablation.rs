//! Ordering ablation: how much of QWYC's win comes from the *joint*
//! ordering optimization vs just the early-stop thresholds?
//!
//! Reproduces the paper's Appendix B comparison on one dataset: QWYC*
//! against {GBT natural, Random x5, Individual-MSE, Greedy-MSE} orders,
//! all with Algorithm-2 thresholds at the same α, plus the Fan et al.
//! early-stop mechanism on its suggested Individual-MSE order (Fan*).
//!
//! Run: `cargo run --release --example ordering_ablation`

use qwyc::data::synth::{generate, Which};
use qwyc::fan::FanClassifier;
use qwyc::gbt::{train, GbtParams};
use qwyc::orderings;
use qwyc::pipeline::PlanBuilder;
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{optimize_thresholds_for_order, simulate, QwycConfig};
use qwyc::util::pool::Pool;

fn main() {
    let alpha = 0.005;
    let (tr, te) = generate(Which::NomaoLike, 13, 0.15);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 300, max_depth: 6, ..Default::default() });
    println!(
        "Nomao-like, T={} GBT, full-ensemble test acc {:.4}; target alpha {:.2}%\n",
        ens.len(),
        ens.accuracy(&te),
        alpha * 100.0
    );
    let sm_tr = ens.score_matrix(&tr);
    let sm_te = ens.score_matrix(&te);

    println!("{:<34} {:>12} {:>9} {:>9}", "method", "mean#models", "%diff", "acc");
    let mut show = |name: &str, sim: &qwyc::qwyc::SimResult| {
        println!(
            "{:<34} {:>12.1} {:>8.2}% {:>9.4}",
            name,
            sim.mean_models,
            sim.pct_diff * 100.0,
            sim.accuracy(&te.y)
        );
    };

    // QWYC*: joint optimization through the typed pipeline, shipped and
    // re-read as a qwyc-plan-v1 artifact so the ablation's headline row
    // uses the deployable path.
    let cfg = QwycConfig { alpha, max_opt_examples: 4000, ..Default::default() };
    let star_plan = PlanBuilder::new("ablation-star")
        .with_scores(&ens, &sm_tr)
        .expect("scores entry")
        .optimize(&cfg, &Pool::from_env())
        .expect("optimize")
        .into_plan()
        .expect("bundle plan");
    let star_plan = QwycPlan::from_json(&star_plan.to_json()).expect("plan roundtrip");
    let star = simulate(&star_plan.fc, &sm_te);
    show("QWYC* (joint order+thresholds)", &star);

    // Fixed orders + Algorithm 2 thresholds.
    let n_opt = 4000.min(sm_tr.n);
    let sm_sub = sm_tr.select_examples(&(0..n_opt).collect::<Vec<_>>());
    let fixed: Vec<(String, Vec<usize>)> = vec![
        ("GBT natural order".into(), orderings::natural(sm_tr.t)),
        ("Individual MSE order".into(), orderings::individual_mse(&sm_tr, &tr.y)),
        ("Greedy MSE order".into(), orderings::greedy_mse(&sm_sub, &tr.y[..n_opt])),
    ];
    for (name, order) in &fixed {
        let sim = simulate(&optimize_thresholds_for_order(&sm_tr, order, alpha, false), &sm_te);
        show(&format!("Alg2 thresholds ({name})"), &sim);
    }
    for seed in 1..=5u64 {
        let order = orderings::random(sm_tr.t, seed);
        let sim = simulate(&optimize_thresholds_for_order(&sm_tr, &order, alpha, false), &sm_te);
        show(&format!("Alg2 thresholds (random #{seed})"), &sim);
    }

    // Fan*: their early-stop mechanism on their suggested order.
    let ind = orderings::individual_mse(&sm_tr, &tr.y);
    let fan = FanClassifier::calibrate(&sm_tr, &ind, 0.01);
    // Pick gamma closest to the same %diff operating point as QWYC*.
    let mut best: Option<(f64, f64, qwyc::qwyc::SimResult)> = None;
    for gamma in [3.0, 2.5, 2.0, 1.5, 1.0, 0.7, 0.5] {
        let sim = fan.simulate(&sm_te, gamma, false);
        let d = (sim.pct_diff - star.pct_diff).abs();
        if best.as_ref().map(|(bd, ..)| d < *bd).unwrap_or(true) {
            best = Some((d, gamma, sim));
        }
    }
    let (_, gamma, sim) = best.unwrap();
    show(&format!("Fan* (Ind-MSE order, gamma={gamma})"), &sim);

    println!(
        "\nQWYC* evaluates {:.1}x fewer models than the best fixed ordering above.",
        fixed_best_models(&sm_tr, &sm_te, &fixed, alpha) / star.mean_models
    );
}

fn fixed_best_models(
    sm_tr: &qwyc::ensemble::ScoreMatrix,
    sm_te: &qwyc::ensemble::ScoreMatrix,
    fixed: &[(String, Vec<usize>)],
    alpha: f64,
) -> f64 {
    fixed
        .iter()
        .map(|(_, order)| {
            simulate(&optimize_thresholds_for_order(sm_tr, order, alpha, false), sm_te).mean_models
        })
        .fold(f64::INFINITY, f64::min)
}
