//! The typed pipeline in one sitting: exactly what an embedder writes.
//!
//! `PlanBuilder` walks the paper's whole flow through typed stages —
//! train → optimize → compile — and `EvalSession::decide_iter` streams
//! per-example `Decision`s without materializing a batch. The example
//! ends by checking the paper's constraint live: the streamed decisions
//! differ from the full ensemble on at most a fraction α of the
//! optimization set.
//!
//! Run: `cargo run --release --example pipeline_quickstart`

use qwyc::prelude::*;

fn main() -> Result<(), QwycError> {
    // 1. Data + typed pipeline: train an Adult-like GBT ensemble, then
    //    jointly optimize evaluation order and early-exit thresholds.
    let alpha = 0.005;
    let (train_ds, test_ds) = generate(Which::AdultLike, 42, 0.05);
    let spec = TrainSpec::gbt(
        &train_ds,
        GbtParams { n_trees: 120, max_depth: 4, ..Default::default() },
    );
    let optimized = PlanBuilder::new("pipeline-quickstart")
        .with_source("examples/pipeline_quickstart.rs")
        .train(spec)?
        .optimize(&QwycConfig { alpha, ..Default::default() }, &Pool::from_env())?;
    println!(
        "trained + optimized: T={} models, alpha={alpha}, order head {:?}",
        optimized.classifier().t(),
        &optimized.classifier().order[..5.min(optimized.classifier().t())]
    );

    // 2. Compile once and write the deployable artifact — the zero-copy
    //    binary plan is exactly what `qwyc serve --plan` would load.
    //    Reload it to show the round trip; serving continues from the
    //    reloaded copy.
    let plan_path = std::env::temp_dir().join("pipeline_quickstart.plan.bin");
    optimized.save(&plan_path, PlanFormat::Binary)?;
    let artifact = PlanArtifact::load(&plan_path)?;
    println!("saved + reloaded plan artifact -> {}", plan_path.display());
    let session = EvalSession::new(artifact.compiled());

    // 3. Stream decisions over the held-out set — pull-based, so early
    //    consumers never pay for the rest of the buffer.
    let mut exits = 0u64;
    let mut models = 0u64;
    let mut positives = 0usize;
    for d in session.decide_iter(&test_ds.x, test_ds.n)? {
        exits += u64::from(d.exited_early);
        models += u64::from(d.exit_position);
        positives += usize::from(d.label);
    }
    println!(
        "test: {} examples, {:.1}% early exits, mean models {:.2}/{}, {:.1}% positive",
        test_ds.n,
        exits as f64 / test_ds.n as f64 * 100.0,
        models as f64 / test_ds.n as f64,
        session.plan().t(),
        positives as f64 / test_ds.n as f64 * 100.0
    );

    // 4. The paper's constraint, live on the optimization set: streamed
    //    decisions differ from the full ensemble on ≤ α of examples.
    let full: Vec<bool> = (0..train_ds.n)
        .map(|i| session.plan().eval_full(train_ds.row(i)) >= session.plan().beta())
        .collect();
    let diffs = session
        .decide_iter(&train_ds.x, train_ds.n)?
        .enumerate()
        .filter(|(i, d)| d.label != full[*i])
        .count();
    let rate = diffs as f64 / train_ds.n as f64;
    println!("train diff rate {:.4}% (alpha {:.2}%)", rate * 100.0, alpha * 100.0);
    assert!(rate <= alpha + 1e-9, "diff rate {rate} exceeded alpha {alpha}");
    println!("OK: early-exit decisions stay within the faithfulness budget");
    let _ = std::fs::remove_file(&plan_path);
    Ok(())
}
