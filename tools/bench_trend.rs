//! Perf-trajectory regression gate over the committed `BENCH_*.json`
//! snapshots (ROADMAP item 5).
//!
//! Reads every `BENCH_<n>.json` at the repo root in PR order (plus a
//! freshly generated `BENCH.json`, if present, as the newest snapshot),
//! tracks each *paired* target — one carrying a non-null
//! `speedup_vs_serial`, i.e. the optimized half of a baseline/optimized
//! pair — and exits nonzero if the newest measured `mean_ns` regressed
//! more than the threshold against the most recent earlier measured
//! snapshot of the same target. Placeholder entries with `runs == 0`
//! (snapshots authored where no measurement was possible) are skipped,
//! so an all-placeholder trajectory passes vacuously. Every run also
//! prints a per-target delta table — the newest measured step of each
//! paired target — so the trajectory stays visible when the gate passes.
//!
//! Usage: `bench_trend [--dir <repo-root>] [--threshold <pct>]`
//! (defaults: the workspace root, 20%).

use qwyc::util::json::{self, Json};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// One bench target as trend tooling sees it.
#[derive(Clone, Debug, PartialEq)]
struct Target {
    name: String,
    mean_ns: f64,
    runs: u64,
    /// Non-null `speedup_vs_serial` → the optimized half of a pair.
    paired: bool,
}

/// A paired target whose newest measurement is worse than the previous
/// one by more than the threshold.
#[derive(Clone, Debug, PartialEq)]
struct Regression {
    name: String,
    from_label: String,
    from_ns: f64,
    to_label: String,
    to_ns: f64,
    pct: f64,
}

fn parse_snapshot(doc: &Json) -> Result<Vec<Target>, qwyc::error::QwycError> {
    let schema = doc.req("schema")?.as_str()?;
    if schema != "qwyc-bench-v1" {
        return Err(qwyc::error::QwycError::Schema(format!("unknown bench schema '{schema}'")));
    }
    doc.req("targets")?
        .as_arr()?
        .iter()
        .map(|t| {
            Ok(Target {
                name: t.req("name")?.as_str()?.to_string(),
                mean_ns: t.req("mean_ns")?.as_f64()?,
                runs: t.req("runs")?.as_f64()? as u64,
                paired: !matches!(t.req("speedup_vs_serial")?, Json::Null),
            })
        })
        .collect()
}

/// `BENCH_<n>.json` → n, for snapshot ordering.
fn snapshot_index(file_name: &str) -> Option<u64> {
    file_name.strip_prefix("BENCH_")?.strip_suffix(".json")?.parse().ok()
}

/// The trajectory files under `dir`, oldest first; a plain `BENCH.json`
/// (a fresh local/CI run, not a committed snapshot) sorts last.
fn bench_files(dir: &Path) -> Vec<PathBuf> {
    let mut numbered: Vec<(u64, PathBuf)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name();
            if let Some(n) = name.to_str().and_then(snapshot_index) {
                numbered.push((n, e.path()));
            }
        }
    }
    numbered.sort_by_key(|(n, _)| *n);
    let mut files: Vec<PathBuf> = numbered.into_iter().map(|(_, p)| p).collect();
    let fresh = dir.join("BENCH.json");
    if fresh.is_file() {
        files.push(fresh);
    }
    files
}

/// Every paired target name seen anywhere in the trajectory, in
/// first-appearance order.
fn paired_names(history: &[(String, Vec<Target>)]) -> Vec<&str> {
    let mut names: Vec<&str> = Vec::new();
    for (_, targets) in history {
        for t in targets {
            if t.paired && !names.contains(&t.name.as_str()) {
                names.push(&t.name);
            }
        }
    }
    names
}

/// The measured (`runs > 0`) trajectory of one paired target, oldest
/// first, as (snapshot label, mean_ns). Placeholder entries never
/// participate. Shared by the regression gate and the delta table so
/// the two views can't disagree about what was compared.
fn measured_series<'a>(
    history: &'a [(String, Vec<Target>)],
    name: &str,
) -> Vec<(&'a str, f64)> {
    history
        .iter()
        .filter_map(|(label, targets)| {
            let t = targets.iter().find(|t| t.name == name && t.paired && t.runs > 0)?;
            Some((label.as_str(), t.mean_ns))
        })
        .collect()
}

/// Human-readable per-target delta view of the newest measured step —
/// printed on every run (pass or fail) so the trajectory stays visible
/// even when the gate is green.
fn delta_table(history: &[(String, Vec<Target>)]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "per-target trend (newest measured step):");
    let names = paired_names(history);
    if names.is_empty() {
        let _ = writeln!(out, "  (no paired targets in any snapshot)");
        return out;
    }
    for name in names {
        let series = measured_series(history, name);
        match series.as_slice() {
            [] => {
                let _ = writeln!(out, "  {name:<52} unmeasured (placeholders only)");
            }
            [(label, ns)] => {
                let _ =
                    writeln!(out, "  {name:<52} {ns:>11.0}ns  (first measured: {label})");
            }
            [.., (from, from_ns), (to, to_ns)] => {
                let pct = (to_ns / from_ns - 1.0) * 100.0;
                let _ = writeln!(
                    out,
                    "  {name:<52} {from_ns:>11.0}ns -> {to_ns:>11.0}ns  \
                     {pct:>+7.1}%  ({from} -> {to})"
                );
            }
        }
    }
    out
}

/// Compare, per paired target, the newest measured snapshot against the
/// most recent earlier measured one. `runs == 0` entries never
/// participate on either side.
fn find_regressions(history: &[(String, Vec<Target>)], threshold_pct: f64) -> Vec<Regression> {
    let mut out = Vec::new();
    for name in paired_names(history) {
        let measured = measured_series(history, name);
        if measured.len() < 2 {
            continue;
        }
        let (prev_label, prev_ns) = measured[measured.len() - 2];
        let (last_label, last_ns) = measured[measured.len() - 1];
        if prev_ns > 0.0 && last_ns > prev_ns * (1.0 + threshold_pct / 100.0) {
            out.push(Regression {
                name: name.to_string(),
                from_label: prev_label.to_string(),
                from_ns: prev_ns,
                to_label: last_label.to_string(),
                to_ns: last_ns,
                pct: (last_ns / prev_ns - 1.0) * 100.0,
            });
        }
    }
    out
}

fn main() -> ExitCode {
    let mut dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."));
    let mut threshold = 20.0f64;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--dir" => {
                if let Some(p) = argv.next() {
                    dir = p.into();
                }
            }
            "--threshold" => {
                if let Some(t) = argv.next() {
                    threshold = t.parse().expect("--threshold takes a percentage");
                }
            }
            other => {
                eprintln!("bench_trend: unknown arg '{other}'");
                return ExitCode::from(2);
            }
        }
    }

    let files = bench_files(&dir);
    if files.is_empty() {
        eprintln!("bench_trend: no BENCH_*.json under {}", dir.display());
        return ExitCode::from(2);
    }
    let mut history: Vec<(String, Vec<Target>)> = Vec::new();
    for f in &files {
        let label = f.file_name().unwrap().to_string_lossy().into_owned();
        match json::read_file(f).and_then(|doc| parse_snapshot(&doc)) {
            Ok(targets) => {
                let measured = targets.iter().filter(|t| t.runs > 0).count();
                let paired = targets.iter().filter(|t| t.paired).count();
                println!(
                    "{label}: {} targets ({measured} measured, {paired} paired)",
                    targets.len()
                );
                history.push((label, targets));
            }
            Err(e) => {
                eprintln!("bench_trend: {label}: {e}");
                return ExitCode::from(2);
            }
        }
    }

    print!("{}", delta_table(&history));

    let regressions = find_regressions(&history, threshold);
    if regressions.is_empty() {
        println!("bench_trend: no paired target regressed >{threshold}%");
        return ExitCode::SUCCESS;
    }
    for r in &regressions {
        eprintln!(
            "REGRESSION {}: {} {:.0}ns -> {} {:.0}ns (+{:.1}%, threshold {threshold}%)",
            r.name, r.from_label, r.from_ns, r.to_label, r.to_ns, r.pct
        );
    }
    ExitCode::FAILURE
}

#[cfg(test)]
mod tests {
    use super::*;

    fn target(name: &str, mean_ns: f64, runs: u64, paired: bool) -> Target {
        Target { name: name.to_string(), mean_ns, runs, paired }
    }

    #[test]
    fn snapshot_names_sort_numerically_with_fresh_run_last() {
        assert_eq!(snapshot_index("BENCH_6.json"), Some(6));
        assert_eq!(snapshot_index("BENCH_10.json"), Some(10));
        assert_eq!(snapshot_index("BENCH.json"), None);
        assert_eq!(snapshot_index("BENCH_x.json"), None);
        let dir = std::env::temp_dir().join(format!("qwyc-trend-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["BENCH_10.json", "BENCH_2.json", "BENCH.json", "other.json"] {
            std::fs::write(dir.join(name), "{}").unwrap();
        }
        let names: Vec<String> = bench_files(&dir)
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, ["BENCH_2.json", "BENCH_10.json", "BENCH.json"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn parses_the_bench_report_schema() {
        let doc = Json::parse(
            r#"{"schema": "qwyc-bench-v1", "threads": 4, "targets": [
                {"name": "a", "mean_ns": 10.0, "p50_ns": 0, "p99_ns": 0, "std_ns": 0,
                 "runs": 5, "iters_per_run": 100, "speedup_vs_serial": null},
                {"name": "b", "mean_ns": 5.0, "p50_ns": 0, "p99_ns": 0, "std_ns": 0,
                 "runs": 5, "iters_per_run": 100, "speedup_vs_serial": 2.0}
            ]}"#,
        )
        .unwrap();
        let targets = parse_snapshot(&doc).unwrap();
        assert_eq!(targets.len(), 2);
        assert!(!targets[0].paired);
        assert!(targets[1].paired && targets[1].mean_ns == 5.0);
        let bad = Json::parse(r#"{"schema": "other", "targets": []}"#).unwrap();
        assert!(parse_snapshot(&bad).is_err());
    }

    #[test]
    fn regression_gate_compares_newest_measured_pair() {
        let history = vec![
            ("BENCH_1.json".to_string(), vec![target("k", 100.0, 5, true)]),
            ("BENCH_2.json".to_string(), vec![target("k", 115.0, 5, true)]),
            ("BENCH_3.json".to_string(), vec![target("k", 150.0, 5, true)]),
        ];
        // Newest vs previous: 150 vs 115 is a +30.4% regression...
        let r = find_regressions(&history, 20.0);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].from_label, "BENCH_2.json");
        assert_eq!(r[0].to_label, "BENCH_3.json");
        assert!((r[0].pct - 30.434).abs() < 0.01, "{}", r[0].pct);
        // ...but a looser threshold passes it.
        assert!(find_regressions(&history, 35.0).is_empty());
    }

    #[test]
    fn placeholders_and_unpaired_targets_are_skipped() {
        let history = vec![
            ("BENCH_1.json".to_string(), vec![target("k", 100.0, 5, true)]),
            // runs == 0: an unmeasured placeholder, never compared.
            ("BENCH_2.json".to_string(), vec![target("k", 0.0, 0, true)]),
            ("BENCH_3.json".to_string(), vec![target("k", 500.0, 5, false)]),
        ];
        // The only later entries are a placeholder and an unpaired
        // target, so nothing is comparable.
        assert!(find_regressions(&history, 20.0).is_empty());
        // A single measured snapshot has no baseline to regress from.
        let solo = vec![("BENCH_9.json".to_string(), vec![target("k", 9e9, 5, true)])];
        assert!(find_regressions(&solo, 20.0).is_empty());
    }

    #[test]
    fn delta_table_reports_every_paired_target() {
        let history = vec![
            (
                "BENCH_1.json".to_string(),
                vec![target("k", 100.0, 5, true), target("solo", 40.0, 5, true)],
            ),
            // k regresses; "fresh" appears only as a placeholder.
            (
                "BENCH_2.json".to_string(),
                vec![target("k", 150.0, 5, true), target("fresh", 0.0, 0, true)],
            ),
        ];
        let table = delta_table(&history);
        assert!(table.starts_with("per-target trend"), "{table}");
        // Newest measured step with labels and signed percent.
        assert!(table.contains("100ns ->"), "{table}");
        assert!(table.contains("150ns"), "{table}");
        assert!(table.contains("+50.0%"), "{table}");
        assert!(table.contains("(BENCH_1.json -> BENCH_2.json)"), "{table}");
        // Single measurement and placeholder-only rows are labeled, not
        // silently dropped.
        assert!(table.contains("first measured: BENCH_1.json"), "{table}");
        assert!(table.contains("unmeasured (placeholders only)"), "{table}");
        // The table and the gate agree on what was compared.
        let r = find_regressions(&history, 20.0);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].name, "k");
        // No paired targets at all is stated explicitly.
        let none = vec![("BENCH_1.json".to_string(), vec![target("u", 9.0, 5, false)])];
        assert!(delta_table(&none).contains("no paired targets"), "{}", delta_table(&none));
    }

    #[test]
    fn improvement_and_small_noise_pass() {
        let history = vec![
            ("BENCH_1.json".to_string(), vec![target("k", 100.0, 5, true)]),
            ("BENCH_2.json".to_string(), vec![target("k", 119.0, 5, true)]),
        ];
        assert!(find_regressions(&history, 20.0).is_empty(), "+19% is inside the gate");
        let better = vec![
            ("BENCH_1.json".to_string(), vec![target("k", 100.0, 5, true)]),
            ("BENCH_2.json".to_string(), vec![target("k", 40.0, 5, true)]),
        ];
        assert!(find_regressions(&better, 20.0).is_empty());
    }
}
