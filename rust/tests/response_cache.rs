//! Generation-keyed response cache + adaptive batching, end to end: a
//! cache hit is bitwise-identical to the cold eval it memoizes, RELOAD
//! (accepted or rejected) never lets a stale generation leak through,
//! NaN features bypass the cache entirely, and an adaptive-policy
//! server answers bitwise-identically to a fixed-policy one.

use qwyc::coordinator::{BatchPolicy, Client, Server, ServerConfig};
use qwyc::data::synth::{generate, Which};
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{optimize_order, QwycConfig};
use std::time::Duration;

fn tiny_model(
    seed: u64,
) -> (qwyc::data::Dataset, qwyc::ensemble::Ensemble, qwyc::qwyc::FastClassifier) {
    let (tr, te) = generate(Which::Rw2Like, seed, 0.005);
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 6, dim: 4, steps: 80, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    (te, ens, fc)
}

fn tiny_plan_shared(
    ens: &qwyc::ensemble::Ensemble,
    fc: &qwyc::qwyc::FastClassifier,
    d: usize,
    name: &str,
) -> std::sync::Arc<qwyc::plan::CompiledPlan> {
    QwycPlan::bundle_with_width(ens.clone(), fc.clone(), name, 0.01, d)
        .expect("bundle")
        .compile_shared()
        .expect("compile")
}

/// Score as the wire prints it (`%.6f`), so comparisons go through the
/// same rounding the protocol applies.
fn wire_bits(score: f32) -> u32 {
    format!("{score:.6}").parse::<f32>().unwrap().to_bits()
}

/// Pull `(hits, misses, evictions)` out of a STATS report's
/// `cache(hit/miss/evict)=h/m/e` field.
fn cache_counters(stats: &str) -> (u64, u64, u64) {
    let tail = stats
        .split("cache(hit/miss/evict)=")
        .nth(1)
        .unwrap_or_else(|| panic!("no cache field in: {stats}"));
    let field = tail.split_whitespace().next().unwrap();
    let mut parts = field.split('/').map(|p| p.parse::<u64>().unwrap());
    (parts.next().unwrap(), parts.next().unwrap(), parts.next().unwrap())
}

fn cached_config() -> ServerConfig {
    ServerConfig {
        shards: 1,
        queue_cap: 4096,
        policy: BatchPolicy::fixed(16, Duration::from_millis(1)),
        default_deadline: None,
        cache_bytes: 1 << 20,
    }
}

/// A repeated identical request is served from the cache (hit counters
/// move) and the hit is bitwise-identical — decision, printed score
/// bits, stop position — to the cold evaluation that populated it.
#[test]
fn cache_hit_is_bitwise_identical_to_cold_eval() {
    let (te, ens, fc) = tiny_model(55);
    let d = te.d;
    let plan = tiny_plan_shared(&ens, &fc, d, "cache-hit");
    let server =
        Server::start_with_plan("127.0.0.1:0", plan, cached_config()).expect("server start");
    let mut client = Client::connect(&server.addr).expect("connect");

    for i in 0..20 {
        let x = te.row(i);
        let want = fc.eval_single(&ens, x);
        let cold = client.eval(x).expect("cold eval");
        for pass in 0..3 {
            let hit = client.eval(x).expect("cached eval");
            assert_eq!(hit.positive, cold.positive, "row {i} pass {pass}");
            assert_eq!(hit.score.to_bits(), cold.score.to_bits(), "row {i} pass {pass}");
            assert_eq!(hit.models, cold.models, "row {i} pass {pass}");
        }
        // And the cold path itself matches the reference classifier.
        assert_eq!(cold.positive, want.positive, "row {i}");
        assert_eq!(cold.score.to_bits(), wire_bits(want.score), "row {i}");
        assert_eq!(cold.models as usize, want.models_evaluated, "row {i}");
    }
    let (hits, misses, _) = cache_counters(&client.stats().expect("stats"));
    assert!(hits >= 60, "expected ≥60 cache hits, got {hits}");
    assert!(misses >= 20, "expected ≥20 cache misses, got {misses}");
    server.stop();
}

/// RELOAD bumps the plan generation, which implicitly invalidates every
/// cached entry: post-swap replies match the NEW plan's cold path, and
/// a rejected reload (generation unchanged) keeps serving the current
/// plan — never a stale one.
#[test]
fn reload_and_rejected_rollback_never_serve_stale_generations() {
    let (te, ens_a, fc_a) = tiny_model(55);
    let d = te.d;
    // A genuinely different model (different training split) so stale
    // cache entries would be observable as wrong scores.
    let (_, ens_b, fc_b) = tiny_model(99);
    let mut plan_b = QwycPlan::bundle(ens_b.clone(), fc_b.clone(), "plan-b", 0.01).expect("bundle");
    plan_b.meta.n_features = d;
    let plan_b_path = std::env::temp_dir().join("qwyc_cache_reload_plan_b.json");
    plan_b.save(&plan_b_path).expect("save plan-b");

    let plan_a = tiny_plan_shared(&ens_a, &fc_a, d, "plan-a");
    let server =
        Server::start_with_plan("127.0.0.1:0", plan_a, cached_config()).expect("server start");
    let mut client = Client::connect(&server.addr).expect("connect");

    let n = 20usize;
    // Populate the cache under generation 0 and keep the gen-0 answers.
    let mut gen0 = Vec::new();
    for i in 0..n {
        client.eval(te.row(i)).expect("warm");
        let r = client.eval(te.row(i)).expect("hit");
        gen0.push((r.positive, r.score.to_bits(), r.models));
    }

    let mut ctl = Client::connect(&server.addr).expect("connect ctl");
    let reply = ctl.reload(plan_b_path.to_str().unwrap()).expect("reload");
    assert!(reply.starts_with("RELOADED plan-b gen=1"), "{reply}");

    // Same rows, new generation: every reply must be plan B's cold
    // answer, not the cached gen-0 one.
    let mut any_changed = false;
    for (i, g0) in gen0.iter().enumerate() {
        let r = client.eval(te.row(i)).expect("post-reload eval");
        let want = fc_b.eval_single(&ens_b, te.row(i));
        assert_eq!(r.positive, want.positive, "row {i} served stale decision");
        assert_eq!(r.score.to_bits(), wire_bits(want.score), "row {i} served stale score");
        assert_eq!(r.models as usize, want.models_evaluated, "row {i} served stale stop pos");
        any_changed |= (r.positive, r.score.to_bits(), r.models) != *g0;
    }
    assert!(any_changed, "plans A and B answered identically; stale reads would be invisible");

    // A rejected reload must not disturb the live generation: replies
    // still match plan B, and its cache keeps hitting.
    let (hits_before, _, _) = cache_counters(&client.stats().expect("stats"));
    let err = ctl.reload("/nonexistent/plan.json").expect("reload io");
    assert!(err.starts_with("RELOAD_REJECTED io:"), "{err}");
    for i in 0..n {
        let r = client.eval(te.row(i)).expect("post-reject eval");
        let want = fc_b.eval_single(&ens_b, te.row(i));
        assert_eq!(r.positive, want.positive, "row {i} after rejected reload");
        assert_eq!(r.score.to_bits(), wire_bits(want.score), "row {i} after rejected reload");
    }
    let (hits_after, _, _) = cache_counters(&client.stats().expect("stats"));
    assert!(hits_after > hits_before, "cache stopped hitting after a rejected reload");
    server.stop();
    std::fs::remove_file(&plan_b_path).ok();
}

/// NaN features are legal inputs but poison bytewise key comparison
/// (NaN != NaN), so they bypass the cache: neither hit nor miss
/// counters move for them and each request is evaluated fresh.
#[test]
fn nan_features_bypass_the_cache() {
    let (te, ens, fc) = tiny_model(55);
    let d = te.d;
    let plan = tiny_plan_shared(&ens, &fc, d, "cache-nan");
    let server =
        Server::start_with_plan("127.0.0.1:0", plan, cached_config()).expect("server start");
    let mut client = Client::connect(&server.addr).expect("connect");

    let mut x = te.row(0).to_vec();
    x[1] = f32::NAN;
    let (h0, m0, _) = cache_counters(&client.stats().expect("stats"));
    for _ in 0..4 {
        client.eval(&x).expect("nan eval");
    }
    let (h1, m1, _) = cache_counters(&client.stats().expect("stats"));
    assert_eq!(h1, h0, "NaN requests must not hit the cache");
    assert_eq!(m1, m0, "NaN requests must not count as cache misses");

    // A clean repeated request on the same connection still caches.
    client.eval(te.row(0)).expect("clean warm");
    client.eval(te.row(0)).expect("clean hit");
    let (h2, _, _) = cache_counters(&client.stats().expect("stats"));
    assert!(h2 > h1, "cache stopped working after NaN traffic");
    server.stop();
}

/// Batch composition must not perturb per-example outcomes: a server
/// under the adaptive flush policy answers bitwise-identically to one
/// under the fixed policy, and advertises `policy=adaptive` in STATS.
#[test]
fn adaptive_policy_is_bitwise_identical_to_fixed() {
    let (te, ens, fc) = tiny_model(55);
    let d = te.d;
    let plan = tiny_plan_shared(&ens, &fc, d, "adaptive-equiv");
    let n = 100.min(te.n);

    let run = |policy: BatchPolicy| -> Vec<(bool, u32, u32)> {
        let adaptive = policy.adaptive;
        let config = ServerConfig {
            shards: 2,
            queue_cap: 4096,
            policy,
            default_deadline: None,
            cache_bytes: 0,
        };
        let server =
            Server::start_with_plan("127.0.0.1:0", plan.clone(), config).expect("server start");
        let mut client = Client::connect(&server.addr).expect("connect");
        for i in 0..n {
            client.send_eval(te.row(i)).expect("send");
        }
        let mut by_id = vec![(false, 0u32, 0u32); n];
        for _ in 0..n {
            let r = client.read_response().expect("read");
            by_id[r.id as usize] = (r.positive, r.score.to_bits(), r.models);
        }
        let stats = client.stats().expect("stats");
        if adaptive {
            assert!(stats.contains(" policy=adaptive"), "{stats}");
        } else {
            assert!(stats.contains(" policy=fixed"), "{stats}");
        }
        server.stop();
        by_id
    };

    let fixed = run(BatchPolicy::fixed(16, Duration::from_millis(2)));
    let adaptive = run(BatchPolicy::adaptive(16, Duration::from_millis(2)));
    assert_eq!(fixed, adaptive, "adaptive flush policy changed scoring outcomes");
}
