//! End-to-end serving: TCP server + dynamic batcher + sharded early-exit
//! engines. Exercises the full coordinator with both backends (native
//! always; PJRT when artifacts are present), the 1-vs-N-shard bitwise
//! equivalence contract, RELOAD hot-swap, and BUSY load shedding.

use qwyc::coordinator::{BatchPolicy, Client, Reply, Server, ServerConfig};
use qwyc::data::synth::{generate, Which};
use qwyc::error::QwycError;
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::plan::{PlanArtifact, PlanFormat, QwycPlan};
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::runtime::engine::NativeEngine;
use qwyc::util::pool::Pool;
use std::collections::BTreeMap;
use std::time::Duration;

fn tiny_model() -> (qwyc::data::Dataset, qwyc::ensemble::Ensemble, qwyc::qwyc::FastClassifier) {
    let (tr, te) = generate(Which::Rw2Like, 55, 0.005);
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 6, dim: 4, steps: 80, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    (te, ens, fc)
}

fn tiny_plan_shared(
    ens: &qwyc::ensemble::Ensemble,
    fc: &qwyc::qwyc::FastClassifier,
    d: usize,
    name: &str,
) -> std::sync::Arc<qwyc::plan::CompiledPlan> {
    QwycPlan::bundle_with_width(ens.clone(), fc.clone(), name, 0.01, d)
        .expect("bundle")
        .compile_shared()
        .expect("compile")
}

/// The compiled-plan engine the removed loose-parts constructor used to
/// build on the fly (generic-factory servers still construct engines
/// per shard).
fn native_engine(
    ens: &qwyc::ensemble::Ensemble,
    fc: &qwyc::qwyc::FastClassifier,
    d: usize,
) -> NativeEngine {
    NativeEngine::from_shared(tiny_plan_shared(ens, fc, d, "e2e-engine"), Pool::from_env())
}

#[test]
fn server_answers_eval_requests_correctly() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let (ens2, fc2) = (ens.clone(), fc.clone());
    let server = Server::start(
        "127.0.0.1:0",
        move |_shard| Box::new(native_engine(&ens2, &fc2, d)),
        BatchPolicy::fixed(32, Duration::from_millis(1)),
    )
    .expect("server start");

    let mut client = Client::connect(&server.addr).expect("connect");
    for i in 0..50 {
        let x = te.row(i);
        let resp = client.eval(x).expect("eval");
        let want = fc.eval_single(&ens, x);
        assert_eq!(resp.positive, want.positive, "request {i}");
        assert_eq!(resp.models as usize, want.models_evaluated, "request {i}");
        assert!((resp.score - want.score).abs() < 1e-4);
    }
    let stats = client.stats().expect("stats");
    assert!(stats.starts_with("STATS"), "{stats}");
    assert!(stats.contains("requests=50"), "{stats}");
    server.stop();
}

#[test]
fn server_batches_pipelined_requests() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let server = Server::start(
        "127.0.0.1:0",
        move |_shard| Box::new(native_engine(&ens, &fc, d)),
        BatchPolicy::fixed(64, Duration::from_millis(5)),
    )
    .expect("server start");

    let mut client = Client::connect(&server.addr).expect("connect");
    // Pipeline 200 requests before reading any response.
    let n = 200.min(te.n);
    for i in 0..n {
        client.send_eval(te.row(i)).expect("send");
    }
    let mut got = 0;
    for _ in 0..n {
        let r = client.read_response().expect("read");
        assert!(r.models >= 1);
        got += 1;
    }
    assert_eq!(got, n);
    let snap = server.metrics.snapshot();
    assert!(snap.mean_batch > 1.5, "no batching happened: {}", snap.mean_batch);
    server.stop();
}

/// The sharding acceptance contract: per-request responses (decision,
/// score bits, stop position) are identical between a 1-shard and a
/// 4-shard server, across multiple concurrent pipelined connections —
/// each example's sweep is independent, so shard placement must not
/// perturb outcomes.
#[test]
fn responses_bitwise_identical_at_1_and_4_shards() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let plan = tiny_plan_shared(&ens, &fc, d, "shard-equiv");
    const CONNS: usize = 3;
    const PER_CONN: usize = 80;

    // id → (positive, score bits, models), per connection.
    let run = |shards: usize| -> Vec<BTreeMap<u64, (bool, u32, u32)>> {
        let config = ServerConfig {
            shards,
            queue_cap: 4096,
            policy: BatchPolicy::fixed(16, Duration::from_millis(1)),
            default_deadline: None,
            cache_bytes: 0,
        };
        let server =
            Server::start_with_plan("127.0.0.1:0", plan.clone(), config).expect("server start");
        let addr = server.addr;
        let results: Vec<BTreeMap<u64, (bool, u32, u32)>> = std::thread::scope(|s| {
            let te = &te;
            let handles: Vec<_> = (0..CONNS)
                .map(|c| {
                    s.spawn(move || {
                        let mut client = Client::connect(&addr).expect("connect");
                        let mut ids = Vec::new();
                        for i in 0..PER_CONN {
                            let row = te.row((c * PER_CONN + i) % te.n);
                            ids.push(client.send_eval(row).expect("send"));
                        }
                        let mut got = BTreeMap::new();
                        for _ in 0..PER_CONN {
                            let r = client.read_response().expect("read");
                            got.insert(r.id, (r.positive, r.score.to_bits(), r.models));
                        }
                        assert_eq!(got.len(), PER_CONN, "conn {c}: duplicate or lost ids");
                        for id in ids {
                            assert!(got.contains_key(&id), "conn {c}: id {id} unanswered");
                        }
                        got
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        server.stop();
        results
    };

    let one = run(1);
    let four = run(4);
    for (c, (a, b)) in one.iter().zip(four.iter()).enumerate() {
        assert_eq!(a, b, "conn {c}: 1-shard vs 4-shard responses differ");
    }
    // Cross-check against the reference single-example path.
    for (c, m) in one.iter().enumerate() {
        for (&id, &(positive, score_bits, models)) in m {
            let want = fc.eval_single(&ens, te.row((c * PER_CONN + id as usize) % te.n));
            assert_eq!(positive, want.positive, "conn {c} id {id}");
            assert_eq!(models as usize, want.models_evaluated, "conn {c} id {id}");
            // The protocol prints %.6f, so compare through the same
            // formatting, not raw bits of the f32.
            let printed: f32 = format!("{:.6}", want.score).parse().unwrap();
            assert_eq!(score_bits, printed.to_bits(), "conn {c} id {id}");
        }
    }
}

/// RELOAD swaps the shared plan at batch boundaries: nothing in flight
/// errors, the reply names the new plan, and subsequent requests still
/// match the reference path.
#[test]
fn reload_swaps_plan_without_erroring_inflight_requests() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let plan_a = tiny_plan_shared(&ens, &fc, d, "plan-a");
    // Same model, new artifact name — deployment's "re-optimized plan"
    // with identical geometry, so outcomes stay comparable.
    let mut plan_b = QwycPlan::bundle(ens.clone(), fc.clone(), "plan-b", 0.01).expect("bundle");
    plan_b.meta.n_features = d;
    let plan_b_path = std::env::temp_dir().join("qwyc_e2e_reload_plan_b.json");
    plan_b.save(&plan_b_path).expect("save plan-b");

    let config = ServerConfig {
        shards: 2,
        queue_cap: 4096,
        policy: BatchPolicy::fixed(8, Duration::from_millis(1)),
        default_deadline: None,
        cache_bytes: 0,
    };
    let server = Server::start_with_plan("127.0.0.1:0", plan_a, config).expect("server start");

    // Fill the pipe, then reload mid-stream from a second connection.
    let mut client = Client::connect(&server.addr).expect("connect");
    let n = 120.min(te.n);
    for i in 0..n {
        client.send_eval(te.row(i)).expect("send");
    }
    let mut ctl = Client::connect(&server.addr).expect("connect ctl");
    let reply = ctl.reload(plan_b_path.to_str().unwrap()).expect("reload");
    assert!(
        reply.starts_with("RELOADED plan-b gen=1"),
        "unexpected reload reply: {reply}"
    );

    // Every in-flight request answers OK and matches the reference path.
    for _ in 0..n {
        let r = client.read_response().expect("in-flight request errored");
        let want = fc.eval_single(&ens, te.row(r.id as usize));
        assert_eq!(r.positive, want.positive, "id {}", r.id);
        assert_eq!(r.models as usize, want.models_evaluated, "id {}", r.id);
    }
    // And so do fresh requests against the swapped plan.
    for i in 0..20 {
        let r = client.eval(te.row(i)).expect("post-reload eval");
        let want = fc.eval_single(&ens, te.row(i));
        assert_eq!(r.positive, want.positive, "post-reload {i}");
        assert_eq!(r.models as usize, want.models_evaluated, "post-reload {i}");
    }
    // A bogus path is refused loudly (validated-reload stage tag)
    // without killing the server.
    let err = ctl.reload("/nonexistent/plan.json").expect("reload io");
    assert!(err.starts_with("RELOAD_REJECTED io:"), "{err}");
    assert!(client.eval(te.row(0)).is_ok(), "server died after failed reload");

    // Reload once more from the zero-copy binary form — the server
    // sniffs the format from the magic bytes, so ops can switch artifact
    // formats without touching the protocol.
    let mut plan_c = QwycPlan::bundle(ens.clone(), fc.clone(), "plan-c", 0.01).expect("bundle c");
    plan_c.meta.n_features = d;
    let plan_c_path = std::env::temp_dir().join("qwyc_e2e_reload_plan_c.bin");
    PlanArtifact::from_plan(plan_c)
        .expect("compile plan-c")
        .save(&plan_c_path, PlanFormat::Binary)
        .expect("save plan-c");
    let reply = ctl.reload(plan_c_path.to_str().unwrap()).expect("reload bin");
    assert!(
        reply.starts_with("RELOADED plan-c gen=2"),
        "unexpected binary reload reply: {reply}"
    );
    for i in 0..20 {
        let r = client.eval(te.row(i)).expect("post-binary-reload eval");
        let want = fc.eval_single(&ens, te.row(i));
        assert_eq!(r.positive, want.positive, "post-binary-reload {i}");
        assert_eq!(r.models as usize, want.models_evaluated, "post-binary-reload {i}");
    }
    server.stop();
    std::fs::remove_file(&plan_b_path).ok();
    std::fs::remove_file(&plan_c_path).ok();
}

/// Generic-factory servers (PJRT/custom engines) have no plan slot and
/// must refuse RELOAD instead of hanging or crashing.
#[test]
fn reload_without_plan_slot_is_refused() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let server = Server::start(
        "127.0.0.1:0",
        move |_shard| Box::new(native_engine(&ens, &fc, d)),
        BatchPolicy::default(),
    )
    .expect("server start");
    let mut client = Client::connect(&server.addr).expect("connect");
    let reply = client.reload("whatever.json").expect("reload");
    assert!(reply.starts_with("ERR - reload unsupported"), "{reply}");
    server.stop();
}

/// A full shard queue sheds load with `BUSY <id>` instead of queueing
/// unbounded latency; every pipelined request still gets exactly one
/// id-correlated reply.
#[test]
fn full_queue_sheds_load_with_busy() {
    struct Slow;
    impl qwyc::runtime::engine::Engine for Slow {
        fn n_features(&self) -> usize {
            2
        }
        fn classify_batch(
            &mut self,
            _x: &[f32],
            n: usize,
        ) -> Result<Vec<qwyc::runtime::engine::Outcome>, QwycError> {
            std::thread::sleep(Duration::from_millis(30));
            Ok(vec![
                qwyc::runtime::engine::Outcome {
                    positive: false,
                    score: 0.0,
                    models_evaluated: 1,
                    early: true,
                };
                n
            ])
        }
        fn backend(&self) -> &'static str {
            "slow"
        }
    }
    let config = ServerConfig {
        shards: 1,
        queue_cap: 1,
        policy: BatchPolicy::fixed(1, Duration::from_millis(0)),
        default_deadline: None,
        cache_bytes: 0,
    };
    let server =
        Server::start("127.0.0.1:0", |_shard| Box::new(Slow), config).expect("server start");
    let mut client = Client::connect(&server.addr).expect("connect");
    let n = 20u64;
    for _ in 0..n {
        client.send_eval(&[0.1, 0.2]).expect("send");
    }
    let (mut ok, mut busy) = (0u64, 0u64);
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..n {
        match client.read_reply().expect("reply") {
            Reply::Ok(r) => {
                ok += 1;
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
            }
            Reply::Busy { id } => {
                busy += 1;
                assert!(seen.insert(id), "duplicate id {id}");
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    assert_eq!(ok + busy, n);
    assert!(ok >= 1, "nothing was served");
    assert!(busy >= 1, "bounded queue never shed load (ok={ok})");
    assert_eq!(seen.len() as u64, n, "ids lost or duplicated");
    server.stop();
}

#[test]
fn server_rejects_malformed_requests() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let server = Server::start(
        "127.0.0.1:0",
        move |_shard| Box::new(native_engine(&ens, &fc, d)),
        BatchPolicy::default(),
    )
    .expect("server start");
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect(server.addr).unwrap();
    writeln!(s, "EVAL notanumber 1,2").unwrap();
    writeln!(s, "BOGUS").unwrap();
    writeln!(s, "EVAL 7 1.0,2.0").unwrap(); // wrong feature count
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut lines = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");
        lines.push(line.trim().to_string());
    }
    // Unparseable requests carry the `-` placeholder id; the
    // wrong-feature-count ERR must echo the request's own id.
    assert!(lines[0].starts_with("ERR - "), "{}", lines[0]);
    assert!(lines[1].starts_with("ERR - "), "{}", lines[1]);
    assert!(lines[2].starts_with("ERR 7 "), "{}", lines[2]);
    server.stop();
}

#[test]
fn failing_engine_reports_id_correlated_errors() {
    // Failure injection: an engine that always errors must surface ERR
    // responses carrying each request's id (not hangs, not dropped
    // connections), so pipelined clients can correlate.
    struct Broken;
    impl qwyc::runtime::engine::Engine for Broken {
        fn n_features(&self) -> usize {
            2
        }
        fn classify_batch(
            &mut self,
            _x: &[f32],
            _n: usize,
        ) -> Result<Vec<qwyc::runtime::engine::Outcome>, QwycError> {
            Err(QwycError::Io("injected failure".into()))
        }
        fn backend(&self) -> &'static str {
            "broken"
        }
    }
    let server = Server::start("127.0.0.1:0", |_shard| Box::new(Broken), BatchPolicy::default())
        .expect("server start");
    let mut client = Client::connect(&server.addr).expect("connect");
    client.send_eval(&[0.5, 0.5]).expect("send"); // id 0
    client.send_eval(&[0.5, 0.5]).expect("send"); // id 1
    let mut ids = std::collections::BTreeSet::new();
    for _ in 0..2 {
        match client.read_reply().expect("reply") {
            Reply::Err { id, message } => {
                assert!(message.contains("injected failure"), "{message}");
                ids.insert(id.expect("engine ERR must carry the request id"));
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    assert_eq!(ids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
    server.stop();
}

/// Protocol robustness: an oversized line, binary garbage, and a
/// half-written final line each get a clean per-line reply on the same
/// connection — neither the connection thread nor the acceptor dies,
/// and fresh connections still work afterwards.
#[test]
fn garbage_oversized_and_partial_lines_get_per_line_errors() {
    use qwyc::coordinator::MAX_LINE_BYTES;
    use std::io::{BufRead, BufReader, Write};
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let (ens2, fc2) = (ens.clone(), fc.clone());
    let server = Server::start(
        "127.0.0.1:0",
        move |_shard| Box::new(native_engine(&ens2, &fc2, d)),
        BatchPolicy::fixed(8, Duration::from_millis(1)),
    )
    .expect("server start");

    let mut s = std::net::TcpStream::connect(server.addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();

    // An oversized line (past the cap) is discarded as it streams in —
    // one clean ERR, no unbounded buffering, connection stays up.
    let mut big = vec![b'z'; MAX_LINE_BYTES + 1024];
    big.push(b'\n');
    s.write_all(&big).unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR - line too long"), "{line}");

    // Binary garbage is an unknown command, not a crash.
    line.clear();
    s.write_all(b"\xde\xad\xbe\xef garbage\n").unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR"), "{line}");

    // The same connection still serves real requests after both.
    line.clear();
    let feats: Vec<String> = te.row(0).iter().map(|v| format!("{v}")).collect();
    writeln!(s, "EVAL 5 {}", feats.join(",")).unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK 5 "), "{line}");

    // A half-written final line (no newline before the client shuts its
    // write side) is parsed at EOF and answered before close.
    line.clear();
    write!(s, "EVAL 9 {}", feats.join(",")).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK 9 "), "{line}");
    line.clear();
    assert_eq!(r.read_line(&mut line).unwrap(), 0, "expected close, got {line}");

    // The acceptor survived it all: a fresh connection works.
    let mut client = Client::connect(&server.addr).expect("reconnect");
    client.eval(te.row(1)).expect("eval after garbage");
    server.stop();
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_serves_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    // Demo geometry: D=4, T=4, d=3.
    let (tr, te) = generate(Which::Rw2Like, 77, 0.01);
    let project = |ds: &qwyc::data::Dataset| {
        let mut out = qwyc::data::Dataset::new("demo4", 4);
        for i in 0..ds.n {
            let r = ds.row(i);
            out.push(&[r[0], r[7], r[14], r[21]], ds.y[i]);
        }
        out
    };
    let (tr, te) = (project(&tr), project(&te));
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 4, dim: 3, steps: 80, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    let (ens2, fc2) = (ens.clone(), fc.clone());

    let server = Server::start(
        "127.0.0.1:0",
        move |_shard| {
            let rt = qwyc::runtime::Runtime::open(std::path::Path::new("artifacts")).unwrap();
            Box::new(
                qwyc::runtime::engine::PjrtEngine::new(rt, "demo_stage", &ens2, &fc2).unwrap(),
            )
        },
        BatchPolicy::fixed(8, Duration::from_millis(2)),
    )
    .expect("server start");

    let mut client = Client::connect(&server.addr).expect("connect");
    for i in 0..30 {
        let resp = client.eval(te.row(i)).expect("eval");
        let want = fc.eval_single(&ens, te.row(i));
        assert_eq!(resp.positive, want.positive, "request {i}");
        assert_eq!(resp.models as usize, want.models_evaluated, "request {i}");
    }
    server.stop();
}
