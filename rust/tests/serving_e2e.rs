//! End-to-end serving: TCP server + dynamic batcher + early-exit engine.
//! Exercises the full coordinator with both backends (native always; PJRT
//! when artifacts are present).

use qwyc::coordinator::{BatchPolicy, Client, Server};
use qwyc::data::synth::{generate, Which};
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::runtime::engine::NativeEngine;
use std::time::Duration;

fn tiny_model() -> (qwyc::data::Dataset, qwyc::ensemble::Ensemble, qwyc::qwyc::FastClassifier) {
    let (tr, te) = generate(Which::Rw2Like, 55, 0.005);
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 6, dim: 4, steps: 80, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    (te, ens, fc)
}

#[test]
fn server_answers_eval_requests_correctly() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let (ens2, fc2) = (ens.clone(), fc.clone());
    let server = Server::start(
        "127.0.0.1:0",
        move || Box::new(NativeEngine::new(ens2, fc2, d)),
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
    )
    .expect("server start");

    let mut client = Client::connect(&server.addr).expect("connect");
    for i in 0..50 {
        let x = te.row(i);
        let resp = client.eval(x).expect("eval");
        let want = fc.eval_single(&ens, x);
        assert_eq!(resp.positive, want.positive, "request {i}");
        assert_eq!(resp.models as usize, want.models_evaluated, "request {i}");
        assert!((resp.score - want.score).abs() < 1e-4);
    }
    let stats = client.stats().expect("stats");
    assert!(stats.starts_with("STATS"), "{stats}");
    assert!(stats.contains("requests=50"), "{stats}");
    server.stop();
}

#[test]
fn server_batches_pipelined_requests() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let server = Server::start(
        "127.0.0.1:0",
        move || Box::new(NativeEngine::new(ens, fc, d)),
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(5) },
    )
    .expect("server start");

    let mut client = Client::connect(&server.addr).expect("connect");
    // Pipeline 200 requests before reading any response.
    let n = 200.min(te.n);
    for i in 0..n {
        client.send_eval(te.row(i)).expect("send");
    }
    let mut got = 0;
    for _ in 0..n {
        let r = client.read_response().expect("read");
        assert!(r.models >= 1);
        got += 1;
    }
    assert_eq!(got, n);
    let snap = server.metrics.snapshot();
    assert!(snap.mean_batch > 1.5, "no batching happened: {}", snap.mean_batch);
    server.stop();
}

#[test]
fn server_rejects_malformed_requests() {
    let (te, ens, fc) = tiny_model();
    let d = te.d;
    let server = Server::start(
        "127.0.0.1:0",
        move || Box::new(NativeEngine::new(ens, fc, d)),
        BatchPolicy::default(),
    )
    .expect("server start");
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect(server.addr).unwrap();
    writeln!(s, "EVAL notanumber 1,2").unwrap();
    writeln!(s, "BOGUS").unwrap();
    writeln!(s, "EVAL 1 1.0,2.0").unwrap(); // wrong feature count
    let mut r = BufReader::new(s.try_clone().unwrap());
    for _ in 0..3 {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR"), "{line}");
    }
    server.stop();
}

#[test]
fn failing_engine_reports_errors_to_clients() {
    // Failure injection: an engine that always errors must surface ERR
    // responses (not hangs, not dropped connections).
    struct Broken;
    impl qwyc::runtime::engine::Engine for Broken {
        fn n_features(&self) -> usize {
            2
        }
        fn classify_batch(
            &mut self,
            _x: &[f32],
            _n: usize,
        ) -> Result<Vec<qwyc::runtime::engine::Outcome>, String> {
            Err("injected failure".into())
        }
        fn backend(&self) -> &'static str {
            "broken"
        }
    }
    let server = Server::start("127.0.0.1:0", || Box::new(Broken), BatchPolicy::default())
        .expect("server start");
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect(server.addr).unwrap();
    writeln!(s, "EVAL 0 0.5,0.5").unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR"), "{line}");
    assert!(line.contains("injected failure"), "{line}");
    server.stop();
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_serves_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    // Demo geometry: D=4, T=4, d=3.
    let (tr, te) = generate(Which::Rw2Like, 77, 0.01);
    let project = |ds: &qwyc::data::Dataset| {
        let mut out = qwyc::data::Dataset::new("demo4", 4);
        for i in 0..ds.n {
            let r = ds.row(i);
            out.push(&[r[0], r[7], r[14], r[21]], ds.y[i]);
        }
        out
    };
    let (tr, te) = (project(&tr), project(&te));
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 4, dim: 3, steps: 80, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    let (ens2, fc2) = (ens.clone(), fc.clone());

    let server = Server::start(
        "127.0.0.1:0",
        move || {
            let rt = qwyc::runtime::Runtime::open(std::path::Path::new("artifacts")).unwrap();
            Box::new(
                qwyc::runtime::engine::PjrtEngine::new(rt, "demo_stage", &ens2, &fc2).unwrap(),
            )
        },
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    )
    .expect("server start");

    let mut client = Client::connect(&server.addr).expect("connect");
    for i in 0..30 {
        let resp = client.eval(te.row(i)).expect("eval");
        let want = fc.eval_single(&ens, te.row(i));
        assert_eq!(resp.positive, want.positive, "request {i}");
        assert_eq!(resp.models as usize, want.models_evaluated, "request {i}");
    }
    server.stop();
}
