//! The feature-quantized sweep kernel against the raw f32 path: the
//! two must be **bitwise identical** — same exit positions, same score
//! bits — on adversarial inputs (feature values exactly equal to split
//! thresholds, NaN, ±∞, subnormals, both zeros) at 1 and 4 threads,
//! through every serving entry point (pooled sweep, the engine's
//! allocation-free `classify_into`, `eval_single`). Also covers the
//! runtime-dispatched SIMD kernels against their scalar twins and the
//! binary artifact's quantization sections (round-trip + corruption).

use qwyc::data::synth::{generate, Which};
use qwyc::gbt::{train, GbtParams};
use qwyc::plan::{CompiledPlan, PlanArtifact, PlanFormat, QwycPlan};
use qwyc::qwyc::sweep::SweepOutcome;
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::runtime::engine::{Engine, NativeEngine, ENGINE_BLOCK};
use qwyc::util::pool::Pool;
use qwyc::util::simd;
use std::path::PathBuf;

/// A small but real GBT plan — trees are what quantization rewrites.
fn gbt_plan() -> QwycPlan {
    let (tr, _) = generate(Which::AdultLike, 77, 0.02);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 10, max_depth: 3, ..Default::default() });
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    QwycPlan::bundle_with_width(ens, fc, "quant-equiv", 0.01, tr.d).expect("bundle plan")
}

/// Rows engineered against the plan's own edge tables: every feature
/// cycles through values *exactly equal* to its split thresholds (the
/// `x <= t` boundary the bin mapping must preserve), between-edge
/// midpoints, ±∞, NaN, subnormals, and both zeros.
fn adversarial_rows(cp: &CompiledPlan, n: usize) -> Vec<f32> {
    let q = cp.quant().expect("tree plan should quantize");
    let d = cp.n_features();
    let specials = [
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::from_bits(1), // smallest positive subnormal
        f32::MIN_POSITIVE / 2.0,
        -0.0,
        0.0,
        1.0e30,
        -1.0e30,
    ];
    let mut x = vec![0f32; n * d];
    for i in 0..n {
        for f in 0..d {
            let edges = q.edges(f);
            let pick = i.wrapping_mul(31).wrapping_add(f * 7);
            x[i * d + f] = if !edges.is_empty() && pick % 3 == 0 {
                // Exactly a threshold: the hardest case for any binning.
                edges[pick / 3 % edges.len()]
            } else if !edges.is_empty() && pick % 3 == 1 {
                // Just above an edge (midpoint to the next, or +1).
                let k = pick / 3 % edges.len();
                let e = edges[k];
                edges.get(k + 1).map_or(e + 1.0, |&hi| e + (hi - e) / 2.0)
            } else {
                specials[pick % specials.len()]
            };
        }
    }
    x
}

fn assert_outcomes_bitwise(a: &[SweepOutcome], b: &[SweepOutcome], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (oa, ob)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(oa.positive, ob.positive, "{what}: example {i}: positive");
        assert_eq!(oa.stop, ob.stop, "{what}: example {i}: stop position");
        assert_eq!(oa.early, ob.early, "{what}: example {i}: early flag");
        assert_eq!(
            oa.score.to_bits(),
            ob.score.to_bits(),
            "{what}: example {i}: score bits diverge ({} vs {})",
            oa.score,
            ob.score
        );
    }
}

/// The tentpole contract: quantized sweep ≡ raw f32 sweep, bit for bit,
/// on adversarial inputs, at 1 and 4 threads.
#[test]
fn quantized_sweep_matches_raw_sweep_bitwise() {
    let cp = gbt_plan().compile().expect("compile");
    assert!(cp.quant().is_some(), "GBT plan must quantize");
    let d = cp.n_features();
    let n = 403; // odd, spans many blocks and a ragged 16-lane tail
    let x = adversarial_rows(&cp, n);
    for threads in [1, 4] {
        let pool = Pool::new(threads);
        let quantized = cp.sweep_features(&x, n, d, 64, &pool);
        let raw = cp.sweep_features_raw(&x, n, d, 64, &pool);
        assert_outcomes_bitwise(&quantized, &raw, &format!("{threads} threads"));
    }
    // eval_single (the raw reference walk) agrees with both.
    let pool = Pool::new(1);
    let quantized = cp.sweep_features(&x, n, d, 1, &pool);
    for (i, o) in quantized.iter().enumerate().take(64) {
        let r = cp.eval_single(&x[i * d..(i + 1) * d]);
        assert_eq!(o.score.to_bits(), r.score.to_bits(), "eval_single {i}");
        assert_eq!(o.stop as usize, r.models_evaluated, "eval_single {i}");
    }
}

/// NaN features must not change the exit behaviour: a NaN-laden row
/// takes the same path (NaN routes right in both walks, and the keep
/// mask's ordered compares keep NaN scores active) in both kernels.
#[test]
fn nan_rows_quantize_to_the_same_path() {
    let cp = gbt_plan().compile().expect("compile");
    let d = cp.n_features();
    // Rows 0..d: one NaN feature each; last row all NaN.
    let n = d + 1;
    let mut x = vec![0.25f32; n * d];
    for i in 0..d {
        x[i * d + i] = f32::NAN;
    }
    for v in x[d * d..].iter_mut() {
        *v = f32::NAN;
    }
    let pool = Pool::new(1);
    let quantized = cp.sweep_features(&x, n, d, 64, &pool);
    let raw = cp.sweep_features_raw(&x, n, d, 64, &pool);
    assert_outcomes_bitwise(&quantized, &raw, "nan rows");
}

/// The engine's allocation-free path (`classify_into`, which quantizes
/// the block once into its recycled `qx`) agrees bitwise with the raw
/// pooled sweep.
#[test]
fn engine_classify_into_matches_raw_sweep_bitwise() {
    let plan = gbt_plan();
    let cp = plan.clone().compile().expect("compile");
    let d = cp.n_features();
    let n = ENGINE_BLOCK.min(197);
    let x = adversarial_rows(&cp, n);
    for threads in [1, 4] {
        let mut engine =
            NativeEngine::from_plan_with_pool(plan.clone().compile().unwrap(), Pool::new(threads));
        let mut out = Vec::new();
        engine.classify_into(&x, n, &mut out).expect("classify_into");
        let raw = cp.sweep_features_raw(&x, n, d, ENGINE_BLOCK, &Pool::new(threads));
        assert_eq!(out.len(), raw.len());
        for (i, (o, r)) in out.iter().zip(raw.iter()).enumerate() {
            assert_eq!(o.positive, r.positive, "example {i} ({threads} threads)");
            assert_eq!(o.models_evaluated, r.stop, "example {i} ({threads} threads)");
            assert_eq!(o.early, r.early, "example {i} ({threads} threads)");
            assert_eq!(
                o.score.to_bits(),
                r.score.to_bits(),
                "example {i} ({threads} threads): score bits"
            );
        }
    }
}

/// The runtime-dispatched SIMD kernels against their scalar twins on
/// the same adversarial values, in-process (CI additionally re-runs the
/// whole suite with `QWYC_FORCE_SCALAR=1`, exercising the scalar tier
/// through the dispatcher itself).
#[test]
fn dispatched_simd_kernels_match_scalar_twins() {
    // accumulate + keep mask over every length with a ragged tail.
    let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.5, -0.5, 0.0, -0.0, 1.0e-40];
    for m in [1usize, 3, 7, 8, 15, 16, 31, 97] {
        let scores: Vec<f32> = (0..m).map(|i| specials[i % specials.len()]).collect();
        let (ep, en) = (0.5f32, -0.5f32);
        let mut g_simd: Vec<f32> = (0..m).map(|i| (i as f32) * 0.125 - 2.0).collect();
        let mut g_scalar = g_simd.clone();
        let mut keep_simd = vec![0u8; m];
        let mut keep_scalar = vec![0u8; m];
        simd::accumulate_keep_mask(&mut g_simd, &scores, &mut keep_simd, ep, en);
        simd::accumulate_keep_mask_scalar(&mut g_scalar, &scores, &mut keep_scalar, ep, en);
        assert_eq!(keep_simd, keep_scalar, "m={m}");
        for (i, (a, b)) in g_simd.iter().zip(g_scalar.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "m={m} lane {i}");
        }
    }
    // 16-lane select on sentinel and threshold-equal bins.
    let qv: [u32; 16] =
        [0, 1, 2, 3, 65533, 65534, 65535, 7, 8, 9, 10, 11, 65535, 13, 0, 65534];
    let qt: [u32; 16] = [0, 0, 2, 4, 65533, 65533, 65533, 7, 7, 9, 9, 12, 0, 13, 1, 65533];
    let left: [u32; 16] = std::array::from_fn(|i| 100 + i as u32);
    let right: [u32; 16] = std::array::from_fn(|i| 200 + i as u32);
    let mut idx_simd = [0u32; 16];
    let mut idx_scalar = [0u32; 16];
    simd::select16(&qv, &qt, &left, &right, &mut idx_simd);
    simd::select16_scalar(&qv, &qt, &left, &right, &mut idx_scalar);
    assert_eq!(idx_simd, idx_scalar, "tier {:?}", simd::tier());
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qwyc-quant-equiv-{}-{name}", std::process::id()))
}

/// The binary artifact's quantization sections: preserved through a
/// round-trip (rebuilt tables bitwise-equal), and any corruption of the
/// stored sections is rejected by the decode-time verification with a
/// schema error naming the section.
#[test]
fn binary_artifact_preserves_and_verifies_quantization() {
    let cp = gbt_plan().compile().expect("compile");
    let dir = tmp("roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("plan.bin");
    PlanArtifact::from_plan(gbt_plan()).unwrap().save(&p, PlanFormat::Binary).unwrap();

    let loaded = PlanArtifact::load(&p).expect("load bin");
    let (qa, qb) = (cp.quant().unwrap(), loaded.compiled().quant().expect("still quantized"));
    assert_eq!(qa.n_features(), qb.n_features());
    for f in 0..qa.n_features() {
        let (ea, eb) = (qa.edges(f), qb.edges(f));
        assert_eq!(ea.len(), eb.len(), "feature {f}");
        for (a, b) in ea.iter().zip(eb.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "feature {f} edge bits");
        }
    }

    // plan-info sees the edge tables without compiling.
    let info = PlanArtifact::info(&p).expect("info").render("plan.bin");
    assert!(info.contains("quantization: "), "{info}");
    assert!(info.contains("bin_edges"), "{info}");
    assert!(info.contains("quant_nodes"), "{info}");
    assert!(!info.contains("quantization: none"), "{info}");

    // Corrupt one byte inside each quantization section payload: the
    // decoder's rebuild-and-compare must name the section.
    let good = std::fs::read(&p).unwrap();
    for (k, name) in [(8usize, "bin_edges"), (9usize, "quant_nodes")] {
        let entry = 64 + 24 * k;
        let off = u64::from_ne_bytes(good[entry + 8..entry + 16].try_into().unwrap()) as usize;
        let len = u64::from_ne_bytes(good[entry + 16..entry + 24].try_into().unwrap()) as usize;
        assert!(len > 0, "{name} must be populated for a quantized plan");
        let mut bad = good.clone();
        bad[off + len / 2] ^= 0x40;
        let bp = dir.join(format!("bad-{name}.bin"));
        std::fs::write(&bp, &bad).unwrap();
        let e = PlanArtifact::load(&bp).expect_err("corrupted quant section must not load");
        assert_eq!(e.stage(), "schema", "{e}");
        assert!(e.message().contains(name), "expected '{name}' in: {e}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
