//! Paper Appendix A.1: the worked PIPELINE instance. QWYC's greedy must
//! recover the optimal order π = [3, 2, 1] and the optimal evaluation cost
//! OPT = OPT* = (8c₃ + 4c₂ + 2c₁)/8 = 7/4, and the 4-approximation bound
//! must hold by a wide margin (here: exactly optimal).

use qwyc::ensemble::ScoreMatrix;
use qwyc::qwyc::{optimize_order, simulate, QwycConfig};

/// Build the Appendix A.1 instance: 8 examples, 3 base models, β = 0,
/// c_t = 1.
fn appendix_a1() -> ScoreMatrix {
    let n = 8;
    let mut cols = vec![0f32; n * 3];
    // f1: e1 → +1, e2 → −1.
    cols[0] = 1.0;
    cols[1] = -1.0;
    // f2: e3, e4 → +1; e5 → −1.
    cols[n + 2] = 1.0;
    cols[n + 3] = 1.0;
    cols[n + 4] = -1.0;
    // f3: e5, e7, e8 → −1; e6 → +1.
    cols[2 * n + 4] = -1.0;
    cols[2 * n + 5] = 1.0;
    cols[2 * n + 6] = -1.0;
    cols[2 * n + 7] = -1.0;
    ScoreMatrix::new(n, 3, cols, 0.0, 0.0, vec![1.0; 3])
}

#[test]
fn full_classifier_decisions_match_paper() {
    let sm = appendix_a1();
    // f = f1+f2+f3: e1..e8 = [1, -1, 1, 1, -2, 1, -1, -1]; β=0, f≥β ⇒ P.
    let expect = [true, false, true, true, false, true, false, false];
    for (i, &e) in expect.iter().enumerate() {
        assert_eq!(sm.full_positive(i), e, "example e{}", i + 1);
    }
}

#[test]
fn qwyc_recovers_optimal_order_and_cost() {
    let sm = appendix_a1();
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.0, ..Default::default() });
    fc.validate().unwrap();
    // Optimal order [3, 2, 1] (1-based) = [2, 1, 0] (0-based).
    assert_eq!(fc.order, vec![2, 1, 0]);
    let sim = simulate(&fc, &sm);
    assert_eq!(sim.pct_diff, 0.0);
    assert!((sim.mean_models - 1.75).abs() < 1e-12, "cost {}", sim.mean_models);
}

#[test]
fn greedy_cost_within_4x_of_opt_over_random_instances() {
    // Theorem 1 (sanity form): on random small instances where we can
    // brute-force all T! orders with exhaustive zero-budget thresholds,
    // greedy cost ≤ 4·OPT. (Random instances should sit far below the
    // bound — usually at exactly OPT.)
    use qwyc::util::rng::Rng;
    let mut rng = Rng::new(99);
    let mut exact_hits = 0;
    for trial in 0..30 {
        let n = 24;
        let t = 4;
        let mut cols = vec![0f32; n * t];
        for c in cols.iter_mut() {
            // Sparse ±1 votes, like the appendix instance.
            let r = rng.f64();
            *c = if r < 0.15 {
                1.0
            } else if r < 0.3 {
                -1.0
            } else {
                0.0
            };
        }
        let sm = ScoreMatrix::new(n, t, cols, 0.0, 0.0, vec![1.0; t]);
        let fc = optimize_order(&sm, &QwycConfig { alpha: 0.0, ..Default::default() });
        let greedy_cost = simulate(&fc, &sm).mean_models;

        // Brute force over all 24 permutations of 4 models.
        let mut best = f64::INFINITY;
        for p in &permutations(t) {
            let fc_p = qwyc::qwyc::optimize_thresholds_for_order(&sm, p, 0.0, false);
            let sim = simulate(&fc_p, &sm);
            assert_eq!(sim.pct_diff, 0.0, "alpha=0 violated by fixed order");
            best = best.min(sim.mean_models);
        }
        assert!(
            greedy_cost <= 4.0 * best + 1e-9,
            "trial {trial}: greedy {greedy_cost} > 4x opt {best}"
        );
        if (greedy_cost - best).abs() < 1e-9 {
            exact_hits += 1;
        }
    }
    assert!(exact_hits >= 20, "greedy exactly optimal only {exact_hits}/30 times");
}

fn permutations(t: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur: Vec<usize> = (0..t).collect();
    heap(&mut cur, t, &mut out);
    out
}

fn heap(cur: &mut Vec<usize>, k: usize, out: &mut Vec<Vec<usize>>) {
    if k == 1 {
        out.push(cur.clone());
        return;
    }
    for i in 0..k {
        heap(cur, k - 1, out);
        if k % 2 == 0 {
            cur.swap(i, k - 1);
        } else {
            cur.swap(0, k - 1);
        }
    }
}
