//! Integration: the AOT PJRT path must agree with the native rust path.
//!
//! Requires `make artifacts` (skipped with a loud message otherwise).
//! This is the cross-layer correctness seam of the whole system: L1
//! (Pallas lattice kernel) + L2 (gather/scan graph) compiled to HLO and
//! executed through the rust runtime must produce exactly the decisions,
//! stop positions, and scores of the pure-rust evaluator.

// The whole suite needs the PJRT runtime; the default build has no
// `qwyc::runtime::Runtime` at all.
#![cfg(feature = "pjrt")]

use qwyc::data::synth::{generate, Which};
use qwyc::ensemble::Ensemble;
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::runtime::engine::{Engine, NativeEngine, PjrtEngine};
use qwyc::runtime::Runtime;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        None
    }
}

/// Train a tiny ensemble matching the `demo` artifact geometry
/// (D=4, T=4, d=3).
fn demo_setup() -> (qwyc::data::Dataset, Ensemble, qwyc::qwyc::FastClassifier) {
    let (mut tr, te) = generate(Which::Rw2Like, 77, 0.01);
    // Project the rw2-like features down to D=4.
    let project = |ds: &qwyc::data::Dataset| {
        let mut out = qwyc::data::Dataset::new("demo4", 4);
        for i in 0..ds.n {
            let r = ds.row(i);
            out.push(&[r[0], r[7], r[14], r[21]], ds.y[i]);
        }
        out
    };
    tr = project(&tr);
    let te = project(&te);
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 4, dim: 3, steps: 120, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    (te, ens, fc)
}

#[test]
fn pjrt_stage_engine_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let (te, ens, fc) = demo_setup();
    let rt = Runtime::open(dir).expect("open runtime");
    let mut pjrt = PjrtEngine::new(rt, "demo_stage", &ens, &fc).expect("pjrt engine");
    let nplan =
        qwyc::plan::QwycPlan::bundle_with_width(ens.clone(), fc.clone(), "pjrt-native", 0.01, 4)
            .expect("bundle plan");
    let mut native = NativeEngine::from_plan(nplan.compile().expect("compile plan"));

    // Several batch sizes, including non-multiples of the compiled B=8.
    for n in [1usize, 7, 8, 9, 300] {
        let n = n.min(te.n);
        let x = &te.x[..n * 4];
        let got = pjrt.classify_batch(x, n).expect("pjrt classify");
        let want = native.classify_batch(x, n).expect("native classify");
        for i in 0..n {
            assert_eq!(got[i].positive, want[i].positive, "n={n} example {i} decision");
            assert_eq!(
                got[i].models_evaluated, want[i].models_evaluated,
                "n={n} example {i} models"
            );
            assert!(
                (got[i].score - want[i].score).abs() < 1e-4,
                "n={n} example {i}: score {} vs {}",
                got[i].score,
                want[i].score
            );
            assert_eq!(got[i].early, want[i].early, "n={n} example {i} early");
        }
    }
}

#[test]
fn pjrt_full_artifact_matches_ensemble_eval() {
    let Some(dir) = artifacts_dir() else { return };
    let (te, ens, _) = demo_setup();
    let mut rt = Runtime::open(dir).expect("open runtime");
    let art = rt.get("demo_full").expect("compile demo_full");
    let cfg = art.spec.config.clone();
    assert_eq!(cfg.t, 4);
    let b = cfg.b;

    // Pack subsets/theta in natural order.
    let v = 1 << cfg.dim;
    let mut subsets = vec![0i32; cfg.t * cfg.dim];
    let mut theta = vec![0f32; cfg.t * v];
    for (t, m) in ens.models.iter().enumerate() {
        let qwyc::ensemble::BaseModel::Lattice(l) = m else { panic!("lattice expected") };
        for (j, &f) in l.features.iter().enumerate() {
            subsets[t * cfg.dim + j] = f as i32;
        }
        theta[t * v..(t + 1) * v].copy_from_slice(&l.theta);
    }
    let mut xbuf = vec![0f32; b * cfg.d_features];
    for (slot, i) in (0..b.min(te.n)).enumerate() {
        xbuf[slot * cfg.d_features..(slot + 1) * cfg.d_features].copy_from_slice(te.row(i));
    }
    let out = art
        .execute(&[
            qwyc::runtime::Input::F32(&xbuf),
            qwyc::runtime::Input::I32(&subsets),
            qwyc::runtime::Input::F32(&theta),
        ])
        .expect("execute");
    let scores = out[0].as_f32();
    for i in 0..b.min(te.n) {
        let want = ens.eval_full(te.row(i)) - ens.bias; // artifact excludes bias
        assert!(
            (scores[i] - want).abs() < 1e-4,
            "example {i}: {} vs {}",
            scores[i],
            want
        );
    }
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(dir).expect("open runtime");
    let art = rt.get("demo_full").expect("compile");
    // Wrong element count.
    let err = art.execute(&[
        qwyc::runtime::Input::F32(&[0.0; 3]),
        qwyc::runtime::Input::I32(&[0; 12]),
        qwyc::runtime::Input::F32(&[0.0; 32]),
    ]);
    assert!(err.is_err());
    // Wrong input arity.
    let err = art.execute(&[qwyc::runtime::Input::F32(&[0.0; 32])]);
    assert!(err.is_err());
}

#[test]
fn manifest_names_present() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(dir).expect("open runtime");
    let names = rt.names();
    for want in ["demo_stage", "demo_full", "rw1_stage", "rw2_stage"] {
        assert!(names.iter().any(|n| n == want), "missing artifact {want}: {names:?}");
    }
}
