//! Compiled-plan equivalence: the unified sweep core, driven by a
//! round-tripped `qwyc-plan-v1` artifact, must reproduce the pre-refactor
//! outcomes bit-for-bit at 1 and N threads.
//!
//! PR 3 deleted three bespoke position-major active-list loops (offline
//! `simulate`, `NativeEngine::classify_batch`, `FilterPipeline`) in favor
//! of one shared core (`qwyc::sweep`) consuming a `CompiledPlan`. The
//! reference implementations below are test-local reimplementations of
//! the deleted arithmetic — per-example f32 accumulation in π order with
//! positive-first threshold checks — so a regression in the shared core
//! or in plan compilation (permutation, SoA banks, prefix costs) fails
//! here, not in production.

use qwyc::coordinator::{FilterOutcome, FilterPipeline};
use qwyc::data::synth::{generate, Which};
use qwyc::ensemble::{Ensemble, ScoreMatrix};
use qwyc::gbt::{train, GbtParams};
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{
    optimize_order_with_pool, simulate_with_pool, FastClassifier, QwycConfig,
};
use qwyc::runtime::engine::{Engine, NativeEngine};
use qwyc::util::pool::Pool;

/// Round-trip a plan through its JSON artifact, failing the test on any
/// serialization drift.
fn roundtrip(plan: QwycPlan) -> QwycPlan {
    QwycPlan::from_json(&plan.to_json()).expect("qwyc-plan-v1 roundtrip")
}

/// Pre-refactor `simulate` semantics: serial per-example accumulation
/// over score-matrix columns in π order.
fn reference_simulate(
    fc: &FastClassifier,
    sm: &ScoreMatrix,
) -> (Vec<bool>, Vec<u32>, Vec<bool>) {
    let t = fc.order.len();
    let mut decisions = vec![false; sm.n];
    let mut stops = vec![t as u32; sm.n];
    let mut early = vec![false; sm.n];
    for i in 0..sm.n {
        let mut g = fc.bias;
        let mut decided = false;
        for r in 0..t {
            g += sm.col(fc.order[r])[i];
            if g > fc.eps_pos[r] || g < fc.eps_neg[r] {
                decisions[i] = g > fc.eps_pos[r];
                stops[i] = (r + 1) as u32;
                early[i] = true;
                decided = true;
                break;
            }
        }
        if !decided {
            decisions[i] = g >= sm.beta;
        }
    }
    (decisions, stops, early)
}

/// Pre-refactor aggregate reduction (bit-exact f64 accumulation order).
fn reference_aggregates(
    fc: &FastClassifier,
    sm: &ScoreMatrix,
    stops: &[u32],
    early: &[bool],
    decisions: &[bool],
) -> (f64, f64, f64) {
    let t = fc.order.len();
    let mut cum = vec![0f64; t + 1];
    for r in 0..t {
        cum[r + 1] = cum[r] + sm.costs[fc.order[r]] as f64;
    }
    let total_cost = sm.total_cost();
    let (mut models_sum, mut cost_sum) = (0f64, 0f64);
    let mut diffs = 0usize;
    for i in 0..sm.n {
        models_sum += stops[i] as f64;
        if early[i] {
            cost_sum += cum[stops[i] as usize];
        } else {
            cost_sum += total_cost;
        }
        if decisions[i] != sm.full_positive(i) {
            diffs += 1;
        }
    }
    let n = sm.n.max(1) as f64;
    (models_sum / n, cost_sum / n, diffs as f64 / n)
}

fn gbt_fixture() -> (qwyc::data::Dataset, qwyc::data::Dataset, Ensemble, FastClassifier) {
    let (tr, te) = generate(Which::AdultLike, 61, 0.03);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 28, max_depth: 3, ..Default::default() });
    let sm = ens.score_matrix_par(&tr, &Pool::new(1));
    let fc = optimize_order_with_pool(
        &sm,
        &QwycConfig { alpha: 0.01, ..Default::default() },
        &Pool::new(1),
    );
    (tr, te, ens, fc)
}

#[test]
fn simulate_from_roundtripped_plan_is_bit_identical_at_1_and_n_threads() {
    let (tr, te, ens, fc) = gbt_fixture();
    let plan = roundtrip(QwycPlan::bundle(ens, fc, "sim-equiv", 0.01).unwrap());
    for ds in [&tr, &te] {
        let sm = plan.ensemble.score_matrix_par(ds, &Pool::new(1));
        let (rd, rs, re) = reference_simulate(&plan.fc, &sm);
        let (r_models, r_cost, r_diff) =
            reference_aggregates(&plan.fc, &sm, &rs, &re, &rd);
        for threads in [1, 4] {
            let sim = simulate_with_pool(&plan.fc, &sm, &Pool::new(threads));
            assert_eq!(sim.decisions, rd, "{threads} threads");
            assert_eq!(sim.stops, rs, "{threads} threads");
            assert_eq!(sim.n_early, re.iter().filter(|&&e| e).count(), "{threads} threads");
            assert_eq!(sim.mean_models.to_bits(), r_models.to_bits(), "{threads} threads");
            assert_eq!(sim.mean_cost.to_bits(), r_cost.to_bits(), "{threads} threads");
            assert_eq!(sim.pct_diff.to_bits(), r_diff.to_bits(), "{threads} threads");
        }
    }
}

#[test]
fn native_engine_from_roundtripped_plan_matches_eval_single_reference() {
    let (tr, te, ens, fc) = gbt_fixture();
    let mut plan = QwycPlan::bundle(ens.clone(), fc.clone(), "engine-equiv", 0.01).unwrap();
    plan.meta.n_features = tr.d;
    let plan = roundtrip(plan);
    let n = te.n.min(600);
    for threads in [1, 4] {
        let mut engine = NativeEngine::from_plan_with_pool(
            plan.compile().expect("compile plan"),
            Pool::new(threads),
        );
        assert_eq!(engine.n_features(), tr.d);
        let got = engine.classify_batch(&te.x[..n * te.d], n).expect("classify");
        assert_eq!(got.len(), n);
        for (i, o) in got.iter().enumerate() {
            // eval_single is the pre-refactor per-example contract the
            // old blocked engine was pinned to.
            let want = fc.eval_single(&ens, te.row(i));
            assert_eq!(o.positive, want.positive, "example {i} ({threads} threads)");
            assert_eq!(
                o.models_evaluated as usize, want.models_evaluated,
                "example {i} ({threads} threads)"
            );
            assert_eq!(o.early, want.early, "example {i} ({threads} threads)");
            assert_eq!(
                o.score.to_bits(),
                want.score.to_bits(),
                "example {i} ({threads} threads)"
            );
        }
    }
}

#[test]
fn filter_pipeline_from_roundtripped_plan_matches_eval_single_reference() {
    let (tr, te) = generate(Which::Rw1Like, 62, 0.004);
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 5, dim: 5, steps: 120, ..Default::default() },
    );
    let sm = ens.score_matrix_par(&tr, &Pool::new(1));
    let fc = optimize_order_with_pool(
        &sm,
        &QwycConfig { alpha: 0.005, neg_only: true, ..Default::default() },
        &Pool::new(1),
    );
    let plan = roundtrip(QwycPlan::bundle(ens.clone(), fc.clone(), "filter-equiv", 0.005).unwrap());

    // Reference outcomes straight from the pre-refactor per-example path.
    let mut want_scored: Vec<(usize, f32)> = Vec::new();
    let mut want_rejected_stops = vec![0u32; te.n];
    for i in 0..te.n {
        let r = fc.eval_single(&ens, te.row(i));
        if !r.early && r.positive {
            want_scored.push((i, r.score));
        } else {
            want_rejected_stops[i] = r.models_evaluated as u32;
        }
    }
    want_scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let bits = |v: &[(usize, f32)]| v.iter().map(|&(i, s)| (i, s.to_bits())).collect::<Vec<_>>();

    for threads in [1, 4] {
        let pipe = FilterPipeline::from_plan_with_pool(&plan, Pool::new(threads)).unwrap();
        let (stats, scored) = pipe.run_batch(&te.x, te.n);
        assert_eq!(stats.total, te.n, "{threads} threads");
        assert_eq!(stats.scored, want_scored.len(), "{threads} threads");
        assert_eq!(stats.rejected, te.n - want_scored.len(), "{threads} threads");
        assert_eq!(bits(&scored), bits(&want_scored), "{threads} threads");
        // Rejected candidates stop exactly where eval_single stopped.
        for i in 0..te.n.min(300) {
            if let FilterOutcome::Rejected { models } = pipe.run_one(te.row(i)) {
                assert_eq!(models, want_rejected_stops[i], "example {i}");
            } else {
                assert!(want_scored.iter().any(|&(j, _)| j == i), "example {i}");
            }
        }
    }
}
