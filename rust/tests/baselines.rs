//! Cross-method integration: on a mid-size GBT ensemble, the paper's
//! qualitative ordering of methods must hold — QWYC* dominates fixed
//! orderings with Algorithm-2 thresholds at matched α, and every method
//! trades #models against %diff monotonically.

use qwyc::data::synth::{generate, Which};
use qwyc::fan::FanClassifier;
use qwyc::gbt::{train, GbtParams};
use qwyc::orderings;
use qwyc::qwyc::{optimize_order, optimize_thresholds_for_order, simulate, QwycConfig};

struct Setup {
    sm_tr: qwyc::ensemble::ScoreMatrix,
    sm_te: qwyc::ensemble::ScoreMatrix,
    labels_tr: Vec<f32>,
}

fn setup() -> Setup {
    let (tr, te) = generate(Which::AdultLike, 7, 0.06);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 60, max_depth: 4, ..Default::default() });
    Setup {
        sm_tr: ens.score_matrix(&tr),
        sm_te: ens.score_matrix(&te),
        labels_tr: tr.y.clone(),
    }
}

#[test]
fn qwyc_star_dominates_fixed_orderings_on_train() {
    let s = setup();
    let alpha = 0.01;
    let cfg = QwycConfig { alpha, ..Default::default() };
    let star = simulate(&optimize_order(&s.sm_tr, &cfg), &s.sm_tr);

    let orders: Vec<(&str, Vec<usize>)> = vec![
        ("natural", orderings::natural(s.sm_tr.t)),
        ("random", orderings::random(s.sm_tr.t, 3)),
        ("ind_mse", orderings::individual_mse(&s.sm_tr, &s.labels_tr)),
        ("greedy_mse", orderings::greedy_mse(&s.sm_tr, &s.labels_tr)),
    ];
    for (name, ord) in orders {
        let sim = simulate(&optimize_thresholds_for_order(&s.sm_tr, &ord, alpha, false), &s.sm_tr);
        assert!(
            star.mean_models <= sim.mean_models + 1e-9,
            "QWYC* ({:.2}) worse than {name} ({:.2}) on the optimization set",
            star.mean_models,
            sim.mean_models
        );
    }
}

#[test]
fn all_methods_generalize_to_test_set() {
    let s = setup();
    let alpha = 0.01;
    let cfg = QwycConfig { alpha, ..Default::default() };
    let fc = optimize_order(&s.sm_tr, &cfg);
    let sim_te = simulate(&fc, &s.sm_te);
    // Held-out diff can exceed alpha but must stay small, and the speedup
    // must carry over.
    assert!(sim_te.pct_diff < 0.05, "test diff {}", sim_te.pct_diff);
    assert!(
        sim_te.mean_models < 0.8 * s.sm_te.t as f64,
        "no test-time speedup: {}",
        sim_te.mean_models
    );
}

#[test]
fn fan_baseline_is_slower_than_qwyc_at_matched_diff() {
    // The paper's headline comparison: at ≈matched %diff, QWYC* evaluates
    // fewer base models than Fan (Individual MSE order).
    let s = setup();
    let fan_order = orderings::individual_mse(&s.sm_tr, &s.labels_tr);
    let fan = FanClassifier::calibrate(&s.sm_tr, &fan_order, 0.01);

    // Sweep γ to find the Fan point with test diff closest to target.
    let target = 0.01;
    let mut fan_best: Option<(f64, f64)> = None; // (|diff-target|, models)
    for gamma in [0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0] {
        let sim = fan.simulate(&s.sm_te, gamma, false);
        let d = (sim.pct_diff - target).abs();
        if fan_best.map(|(bd, _)| d < bd).unwrap_or(true) {
            fan_best = Some((d, sim.mean_models));
        }
    }
    let (_, fan_models) = fan_best.unwrap();

    let mut qwyc_best: Option<(f64, f64)> = None;
    for alpha in [0.002, 0.005, 0.01, 0.02] {
        let cfg = QwycConfig { alpha, ..Default::default() };
        let sim = simulate(&optimize_order(&s.sm_tr, &cfg), &s.sm_te);
        let d = (sim.pct_diff - target).abs();
        if qwyc_best.map(|(bd, _)| d < bd).unwrap_or(true) {
            qwyc_best = Some((d, sim.mean_models));
        }
    }
    let (_, qwyc_models) = qwyc_best.unwrap();
    assert!(
        qwyc_models < fan_models,
        "QWYC* {qwyc_models:.1} models not faster than Fan {fan_models:.1}"
    );
}

#[test]
fn training_bigger_and_pruning_beats_small_ensemble() {
    // Figure 1's "GBT alone" comparison: a 60-tree ensemble QWYC-pruned
    // to ~k models should be at least as accurate as training a k-tree
    // ensemble outright (compared at the pruned ensemble's mean #models).
    let (tr, te) = generate(Which::AdultLike, 7, 0.06);
    let (big, _) = train(&tr, &GbtParams { n_trees: 60, max_depth: 4, ..Default::default() });
    let sm_tr = big.score_matrix(&tr);
    let sm_te = big.score_matrix(&te);
    let fc = optimize_order(&sm_tr, &QwycConfig { alpha: 0.01, ..Default::default() });
    let sim = simulate(&fc, &sm_te);
    let k = sim.mean_models.ceil() as usize;

    let (small, _) = train(&tr, &GbtParams { n_trees: k, max_depth: 4, ..Default::default() });
    let small_acc = small.accuracy(&te);
    let pruned_acc = sim.accuracy(&te.y);
    assert!(
        pruned_acc + 0.005 >= small_acc,
        "pruned-60-trees acc {pruned_acc:.4} (at {k} mean models) much worse than \
         {k}-tree ensemble acc {small_acc:.4}"
    );
}
