//! Property tests over the QWYC optimizer's contract, on randomly
//! generated score matrices (proptest substrate: util::proptest).

use qwyc::ensemble::ScoreMatrix;
use qwyc::qwyc::{optimize_order, optimize_thresholds_for_order, simulate, QwycConfig};
use qwyc::util::proptest::{check, Gen};

/// Random score matrix: n examples, t models, mixture of informative and
/// noisy columns, random bias/β.
fn random_matrix(g: &mut Gen) -> ScoreMatrix {
    let n = g.usize_in(10, 250);
    let t = g.usize_in(2, 12);
    // Latent per-example difficulty drives correlated columns.
    let latent: Vec<f32> = (0..n).map(|_| g.rng.normal() as f32).collect();
    let mut cols = vec![0f32; n * t];
    for ti in 0..t {
        let informativeness = g.rng.f64() as f32;
        for i in 0..n {
            cols[ti * n + i] =
                informativeness * latent[i] + (1.0 - informativeness) * g.rng.normal() as f32;
        }
    }
    let bias = (g.rng.normal() * 0.3) as f32;
    let beta = (g.rng.normal() * 0.3) as f32;
    ScoreMatrix::new(n, t, cols, bias, beta, vec![1.0; t])
}

#[test]
fn alpha_constraint_always_holds_on_optimization_set() {
    check("diff<=alpha", 120, |g| {
        let sm = random_matrix(g);
        let alpha = [0.0, 0.01, 0.05, 0.2][g.usize_in(0, 3)];
        let neg_only = g.rng.bool(0.3);
        let cfg = QwycConfig { alpha, neg_only, max_opt_examples: 0, seed: g.seed };
        let fc = optimize_order(&sm, &cfg);
        fc.validate().map_err(|e| format!("invalid classifier: {e}"))?;
        let sim = simulate(&fc, &sm);
        if sim.pct_diff > alpha + 1e-9 {
            return Err(format!("pct_diff {} > alpha {alpha}", sim.pct_diff).into());
        }
        Ok(())
    });
}

#[test]
fn joint_optimization_never_worse_than_natural_order() {
    // QWYC* (order + thresholds) must beat-or-match Algorithm 2 on the
    // natural order, measured on the optimization set itself. Both spend
    // the same budget; QWYC* additionally chooses the order greedily —
    // greedy choice includes "keep the natural next model", so it can
    // only improve the greedy-step J. (Global non-inferiority is not
    // guaranteed in theory, but holds overwhelmingly; allow tiny slack.)
    check("qwyc*<=natural", 60, |g| {
        let sm = random_matrix(g);
        let alpha = 0.02;
        let cfg = QwycConfig { alpha, neg_only: false, max_opt_examples: 0, seed: g.seed };
        let star = simulate(&optimize_order(&sm, &cfg), &sm);
        let natural: Vec<usize> = (0..sm.t).collect();
        let fixed = simulate(&optimize_thresholds_for_order(&sm, &natural, alpha, false), &sm);
        if star.mean_models > fixed.mean_models * 1.10 + 0.5 {
            return Err(format!(
                "qwyc* {} models vs natural-order {} models",
                star.mean_models, fixed.mean_models
            )
            .into());
        }
        Ok(())
    });
}

#[test]
fn neg_only_classifiers_never_exit_positive() {
    check("neg_only no early positives", 80, |g| {
        let sm = random_matrix(g);
        let cfg = QwycConfig { alpha: 0.05, neg_only: true, max_opt_examples: 0, seed: g.seed };
        let fc = optimize_order(&sm, &cfg);
        if fc.eps_pos.iter().any(|&e| e != f32::INFINITY) {
            return Err("finite eps_pos in neg_only mode".into());
        }
        let sim = simulate(&fc, &sm);
        for i in 0..sm.n {
            if sim.stops[i] < sm.t as u32 && sim.decisions[i] {
                return Err(format!("example {i} exited early positive").into());
            }
        }
        Ok(())
    });
}

#[test]
fn stops_and_cost_accounting_consistent() {
    check("cost accounting", 80, |g| {
        let sm = random_matrix(g);
        let cfg = QwycConfig { alpha: 0.05, neg_only: false, max_opt_examples: 0, seed: g.seed };
        let fc = optimize_order(&sm, &cfg);
        let sim = simulate(&fc, &sm);
        let mean_stops =
            sim.stops.iter().map(|&s| s as f64).sum::<f64>() / sm.n as f64;
        if (mean_stops - sim.mean_models).abs() > 1e-9 {
            let m = format!("mean stops {mean_stops} != mean models {}", sim.mean_models);
            return Err(m.into());
        }
        // Unit costs: mean cost == mean models.
        if (sim.mean_cost - sim.mean_models).abs() > 1e-9 {
            return Err("mean_cost != mean_models under unit costs".into());
        }
        if sim.stops.iter().any(|&s| s == 0 || s > sm.t as u32) {
            return Err("stop position out of range".into());
        }
        Ok(())
    });
}

#[test]
fn costs_influence_greedy_choice() {
    // Duplicate an informative column with a much cheaper cost: the
    // greedy must prefer the cheap copy first.
    check("cost-aware ordering", 40, |g| {
        let n = g.usize_in(30, 120);
        let latent: Vec<f32> = (0..n).map(|_| g.rng.normal() as f32).collect();
        let mut cols = Vec::with_capacity(n * 3);
        cols.extend(latent.iter().map(|&v| v)); // model 0: expensive copy
        cols.extend(latent.iter().map(|&v| v)); // model 1: cheap copy
        cols.extend((0..n).map(|_| g.rng.normal() as f32 * 0.1)); // noise
        let sm = ScoreMatrix::new(n, 3, cols, 0.0, 0.0, vec![10.0, 1.0, 1.0]);
        let cfg = QwycConfig { alpha: 0.05, neg_only: false, max_opt_examples: 0, seed: g.seed };
        let fc = optimize_order(&sm, &cfg);
        if fc.order[0] == 0 {
            return Err(format!("picked expensive duplicate first: {:?}", fc.order).into());
        }
        Ok(())
    });
}

#[test]
fn simulate_is_deterministic() {
    check("determinism", 30, |g| {
        let sm = random_matrix(g);
        let cfg = QwycConfig { alpha: 0.01, neg_only: false, max_opt_examples: 0, seed: 7 };
        let a = optimize_order(&sm, &cfg);
        let b = optimize_order(&sm, &cfg);
        if a.order != b.order || a.eps_pos != b.eps_pos || a.eps_neg != b.eps_neg {
            return Err("optimizer not deterministic".into());
        }
        Ok(())
    });
}
