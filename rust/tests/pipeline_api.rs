//! Typed pipeline API contracts.
//!
//! The `qwyc::pipeline` facade must be a veneer, not a fork: plans built
//! through `PlanBuilder` are pinned **bitwise** against the loose
//! function path (`score_matrix_par` → `optimize_order_with_pool` →
//! `QwycPlan::bundle` → `compile`) at 1 and 4 threads, and every
//! `EvalSession` surface (`decide`, `decide_batch`, `decide_iter`) must
//! agree bitwise with `CompiledPlan::eval_single`. The typed-state
//! machine itself is checked two ways: a static trait-bound assertion
//! that only the Optimized stage is `CompileReady`, plus the
//! `compile_fail` doctest on `qwyc::pipeline::CompileReady` (an
//! un-optimized builder has no `compile` method at all).

use qwyc::data::synth::{generate, Which};
use qwyc::data::Dataset;
use qwyc::ensemble::Ensemble;
use qwyc::gbt::{train, GbtParams};
use qwyc::pipeline::{CompileReady, EvalSession, Optimized, PlanBuilder, TrainSpec};
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{optimize_order_with_pool, QwycConfig};
use qwyc::util::pool::Pool;

fn setup() -> (Dataset, Dataset, Ensemble) {
    let (tr, te) = generate(Which::AdultLike, 61, 0.02);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 20, max_depth: 3, ..Default::default() });
    (tr, te, ens)
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// The acceptance pin: builder-produced plans are bitwise identical to
/// the loose-function path, at 1 and 4 threads, through both the
/// `with_ensemble` (dataset) and `with_scores` (precomputed matrix)
/// entries.
#[test]
fn builder_plans_bitwise_match_loose_functions_at_1_and_4_threads() {
    let (tr, te, ens) = setup();
    let cfg = QwycConfig { alpha: 0.01, ..Default::default() };
    for threads in [1usize, 4] {
        let pool = Pool::new(threads);

        // Loose-function reference path.
        let sm = ens.score_matrix_par(&tr, &pool);
        let fc_loose = optimize_order_with_pool(&sm, &cfg, &pool);
        let mut plan_loose =
            QwycPlan::bundle(ens.clone(), fc_loose.clone(), "loose", cfg.alpha).expect("bundle");
        plan_loose.meta.n_features = tr.d;
        let cp_loose = plan_loose.compile().expect("compile");

        for entry in ["data", "scores"] {
            let builder = PlanBuilder::new("built");
            let opt = match entry {
                "data" => builder.with_ensemble(&ens, &tr),
                _ => builder.with_scores(&ens, &sm).expect("scores entry"),
            }
            .optimize(&cfg, &pool)
            .expect("optimize");

            // Classifier: identical order, bit-identical thresholds.
            let fc = opt.classifier();
            assert_eq!(fc.order, fc_loose.order, "{entry}@{threads}t: order");
            assert_eq!(bits(&fc.eps_pos), bits(&fc_loose.eps_pos), "{entry}@{threads}t");
            assert_eq!(bits(&fc.eps_neg), bits(&fc_loose.eps_neg), "{entry}@{threads}t");
            assert_eq!(fc.bias.to_bits(), fc_loose.bias.to_bits());
            assert_eq!(fc.beta.to_bits(), fc_loose.beta.to_bits());

            // Compiled plan: same geometry, bit-identical sweeps.
            let cp = opt.with_n_features(tr.d).compile().expect("compile");
            assert_eq!(cp.t(), cp_loose.t());
            assert_eq!(cp.n_features(), cp_loose.n_features());
            assert_eq!(cp.order(), cp_loose.order());
            for r in 0..=cp.t() {
                assert_eq!(cp.prefix_cost(r).to_bits(), cp_loose.prefix_cost(r).to_bits());
            }
            let n = te.n.min(300);
            let a = cp.sweep_features(&te.x[..n * te.d], n, te.d, 64, &pool);
            let b = cp_loose.sweep_features(&te.x[..n * te.d], n, te.d, 64, &pool);
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.positive, y.positive, "{entry}@{threads}t ex {i}");
                assert_eq!(x.stop, y.stop, "{entry}@{threads}t ex {i}");
                assert_eq!(x.score.to_bits(), y.score.to_bits(), "{entry}@{threads}t ex {i}");
            }
        }
    }
}

/// The round-tripped artifact a builder emits equals the one the loose
/// path emits (schema, meta, thresholds).
#[test]
fn builder_artifact_roundtrips_like_the_loose_one() {
    let (tr, _, ens) = setup();
    let cfg = QwycConfig { alpha: 0.005, ..Default::default() };
    let pool = Pool::new(1);
    let plan = PlanBuilder::new("rt")
        .with_source("pipeline_api test")
        .with_ensemble(&ens, &tr)
        .optimize(&cfg, &pool)
        .expect("optimize")
        .into_plan()
        .expect("plan");
    assert_eq!(plan.meta.name, "rt");
    assert_eq!(plan.meta.alpha, 0.005);
    assert_eq!(plan.meta.n_features, tr.d, "dataset width recorded automatically");
    assert_eq!(plan.meta.source, "pipeline_api test");
    let back = QwycPlan::from_json(&plan.to_json()).expect("roundtrip");
    assert_eq!(back.fc.order, plan.fc.order);
    assert_eq!(bits(&back.fc.eps_neg), bits(&plan.fc.eps_neg));
}

/// decide ≡ decide_batch ≡ decide_iter ≡ CompiledPlan::eval_single,
/// bitwise, at 1 and 4 session threads.
#[test]
fn session_surfaces_agree_bitwise_with_eval_single() {
    let (tr, te, _) = setup();
    let spec = TrainSpec::gbt(&tr, GbtParams { n_trees: 18, max_depth: 3, ..Default::default() });
    let opt = PlanBuilder::new("session")
        .train(spec)
        .expect("train")
        .optimize(&QwycConfig { alpha: 0.01, ..Default::default() }, &Pool::new(1))
        .expect("optimize");
    let cp = opt.compile().expect("compile");
    let n = te.n.min(600); // spans several streaming blocks
    let x = &te.x[..n * te.d];

    for threads in [1usize, 4] {
        let session = EvalSession::with_pool(cp.clone(), Pool::new(threads));
        let batch = session.decide_batch(x, n).expect("decide_batch");
        let streamed: Vec<_> = session.decide_iter(x, n).expect("decide_iter").collect();
        assert_eq!(batch.len(), n);
        assert_eq!(streamed.len(), n);
        for i in 0..n {
            let single = cp.eval_single(te.row(i));
            let one = session.decide(te.row(i)).expect("decide");
            for (surface, d) in [("batch", &batch[i]), ("iter", &streamed[i]), ("one", &one)] {
                assert_eq!(d.label, single.positive, "{surface}@{threads}t ex {i}");
                assert_eq!(
                    d.exit_position as usize, single.models_evaluated,
                    "{surface}@{threads}t ex {i}"
                );
                assert_eq!(d.exited_early, single.early, "{surface}@{threads}t ex {i}");
                assert_eq!(
                    d.score.to_bits(),
                    single.score.to_bits(),
                    "{surface}@{threads}t ex {i}"
                );
                assert_eq!(
                    d.cost.to_bits(),
                    cp.prefix_cost(single.models_evaluated).to_bits(),
                    "{surface}@{threads}t ex {i}"
                );
            }
        }
    }
}

/// Streaming honors the paper's constraint end to end: the fraction of
/// decisions differing from the full ensemble is ≤ α on the
/// optimization set.
#[test]
fn streamed_decisions_respect_alpha_on_the_optimization_set() {
    let (tr, _, ens) = setup();
    let alpha = 0.01;
    let opt = PlanBuilder::new("alpha")
        .with_ensemble(&ens, &tr)
        .optimize(&QwycConfig { alpha, ..Default::default() }, &Pool::new(1))
        .expect("optimize");
    let session = opt.session().expect("session");
    let diffs = session
        .decide_iter(&tr.x, tr.n)
        .expect("decide_iter")
        .enumerate()
        .filter(|(i, d)| d.label != (ens.eval_full(tr.row(*i)) >= ens.beta))
        .count();
    assert!(
        diffs as f64 / tr.n as f64 <= alpha + 1e-9,
        "diff rate {} exceeds alpha {alpha}",
        diffs as f64 / tr.n as f64
    );
}

/// Static trait-bound check: `CompileReady` (the capability behind
/// `.compile()`/`.into_plan()`/`.session()`) is implemented by the
/// Optimized stage — and, per the sealed hierarchy plus the
/// `compile_fail` doctest on the trait, by nothing else.
#[test]
fn only_the_optimized_stage_is_compile_ready() {
    fn assert_compile_ready<S: CompileReady>() {}
    assert_compile_ready::<Optimized<'static>>();
}
