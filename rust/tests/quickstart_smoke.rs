//! CI smoke test over the quickstart example's path: synthetic dataset →
//! GBT training → QWYC* joint optimization → simulation, with a fixed
//! `util::rng` seed. Exercises the paper's core invariant end to end —
//! the fraction of examples whose fast decision differs from the full
//! ensemble's is ≤ α on the optimization set (problem (2)) — so CI
//! checks behavior, not just compilation.

use qwyc::data::synth::{generate, Which};
use qwyc::gbt::{train, GbtParams};
use qwyc::qwyc::{optimize_order, simulate, QwycConfig};

#[test]
fn quickstart_path_respects_alpha_end_to_end() {
    // Same seed/dataset family as examples/quickstart.rs, scaled for CI.
    let (tr, te) = generate(Which::AdultLike, 42, 0.03);
    let params = GbtParams { n_trees: 40, max_depth: 4, ..Default::default() };
    let (ens, losses) = train(&tr, &params);
    assert_eq!(ens.len(), 40);
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "boosting did not reduce the train loss: {:?}",
        (losses.first(), losses.last())
    );

    let sm_tr = ens.score_matrix(&tr);
    let sm_te = ens.score_matrix(&te);
    let mut prev_models = f64::INFINITY;
    for alpha in [0.0, 0.005, 0.02] {
        let cfg = QwycConfig { alpha, seed: 17, ..Default::default() };
        let fc = optimize_order(&sm_tr, &cfg);
        fc.validate().expect("optimizer must emit a structurally valid classifier");

        // The paper's constraint: disagreement ≤ α on the optimization set.
        let sim = simulate(&fc, &sm_tr);
        assert!(
            sim.pct_diff <= alpha + 1e-9,
            "alpha={alpha}: train disagreement {} exceeds the budget",
            sim.pct_diff
        );
        // Larger budgets buy earlier exits (small slack: the greedy order
        // itself may differ between alphas).
        assert!(
            sim.mean_models <= prev_models * 1.05 + 0.5,
            "alpha={alpha}: {} mean models > {prev_models} at a smaller alpha",
            sim.mean_models
        );
        prev_models = sim.mean_models;

        // Held-out: thresholds generalize (diff can exceed alpha but must
        // stay small) and the early-exit machinery stays consistent.
        let sim_te = simulate(&fc, &sm_te);
        assert!(
            sim_te.pct_diff < 0.05,
            "alpha={alpha}: test disagreement {} is out of family",
            sim_te.pct_diff
        );
        assert!(sim_te.mean_models >= 1.0 && sim_te.mean_models <= sm_te.t as f64);
    }
}
