//! End-to-end tests for the HTTP/1.1 front-end (`qwyc::http`) served by
//! `Server::attach_http` — the SECOND protocol surface over the same
//! shard set as the line protocol, not a parallel serving path.
//!
//! The headline pin: a `POST /v1/score` response carries the score
//! token BITWISE-identical to the line protocol's `EVAL` reply and to
//! `CompiledPlan::eval_single` through the same `%.6f` formatting, at 1
//! and 4 shards. The robustness pins: framing-lost errors (bad request
//! line, oversized header, truncated body) answer once and close, while
//! framing-safe errors (bad body, unknown route, wrong method) fail
//! alone and the pipelined connection survives.
//!
//! Failpoint state is process-global, so every test takes the same
//! serializing guard the chaos harness uses.

use qwyc::coordinator::{BatchPolicy, Server, ServerConfig};
use qwyc::ensemble::{BaseModel, Ensemble};
use qwyc::http::{read_response_from, HttpClient, HttpResponse};
use qwyc::lattice::Lattice;
use qwyc::plan::{PlanArtifact, PlanFormat, QwycPlan};
use qwyc::qwyc::FastClassifier;
use qwyc::util::failpoints;
use qwyc::util::json::Json;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

static FP_LOCK: Mutex<()> = Mutex::new(());

struct FpGuard<'a>(#[allow(dead_code)] MutexGuard<'a, ()>);

impl Drop for FpGuard<'_> {
    fn drop(&mut self) {
        failpoints::configure("").expect("clear failpoints");
    }
}

fn failpoints_guard(spec: &str) -> FpGuard<'static> {
    let g = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoints::configure(spec).expect("configure failpoints");
    FpGuard(g)
}

/// Tiny deterministic 2-feature plan (f0 = x0, f1 = 1 - x1; neg-only ε)
/// — the same shape the chaos harness uses.
fn toy_plan(name: &str) -> QwycPlan {
    let l0 = Lattice::from_params(vec![0], vec![0.0, 1.0]);
    let l1 = Lattice::from_params(vec![1], vec![1.0, 0.0]);
    let ens =
        Ensemble::new("toy", vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1)], 0.25, 1.0);
    let fc = FastClassifier {
        order: vec![1, 0],
        eps_pos: vec![f32::INFINITY, f32::INFINITY],
        eps_neg: vec![-0.5, f32::NEG_INFINITY],
        bias: 0.25,
        beta: 1.0,
    };
    QwycPlan::bundle_with_width(ens, fc, name, 0.01, 2).unwrap()
}

fn rows(n: usize) -> Vec<[f32; 2]> {
    (0..n).map(|i| [(i as f32 * 0.137) % 1.0, (i as f32 * 0.291) % 1.0]).collect()
}

fn config(shards: usize, queue_cap: usize, max_batch: usize) -> ServerConfig {
    ServerConfig {
        shards,
        queue_cap,
        policy: BatchPolicy::fixed(max_batch, Duration::from_millis(1)),
        default_deadline: None,
        cache_bytes: 0,
    }
}

/// Start a dual-protocol server from the toy artifact; returns the
/// server (line-protocol addr in `server.addr`) and the HTTP address.
fn start_http(name: &str, cfg: ServerConfig) -> (Server, SocketAddr, PlanArtifact) {
    let artifact = PlanArtifact::from_plan(toy_plan(name)).unwrap();
    let mut server = Server::start_with_artifact("127.0.0.1:0", &artifact, cfg).unwrap();
    let http = server.attach_http("127.0.0.1:0").unwrap();
    (server, http, artifact)
}

/// Raw TCP connection + buffered reader, for driving malformed bytes.
fn raw(addr: &SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).ok();
    let r = BufReader::new(s.try_clone().unwrap());
    (s, r)
}

/// The verbatim score token of a single-row `/v1/score` response body.
fn http_score_token(body: &str) -> &str {
    let start = body.find("\"score\":").expect("score field") + "\"score\":".len();
    let len = body[start..].find(",\"models\"").expect("models field");
    &body[start..start + len]
}

/// All score tokens of a `/v1/score-batch` response body, in row order.
fn batch_score_tokens(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = body;
    while let Some(i) = rest.find("\"score\":") {
        rest = &rest[i + "\"score\":".len()..];
        let end = rest.find(",\"models\"").expect("models field");
        out.push(rest[..end].to_string());
        rest = &rest[end..];
    }
    out
}

fn post_score(client: &mut HttpClient, row: &[f32]) -> HttpResponse {
    let body = format!("[{}]", row.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(","));
    client.request("POST", "/v1/score", &[], body.as_bytes()).expect("POST /v1/score")
}

/// Headline acceptance: `/v1/score` ≡ line-protocol `EVAL` ≡
/// `eval_single`, token-for-token, at 1 and 4 shards.
#[test]
fn score_matches_line_protocol_and_eval_single_bitwise() {
    let _fp = failpoints_guard("");
    for shards in [1usize, 4] {
        let (server, http, artifact) = start_http("http-equiv", config(shards, 4096, 8));
        let compiled = artifact.compiled();
        let mut hc = HttpClient::connect(&http).unwrap();
        let (mut line_wr, mut line_rd) = raw(&server.addr);
        let mut line = String::new();
        for (k, row) in rows(24).iter().enumerate() {
            // Line protocol: "OK <id> <pos|neg> <score> <models> <latency_us>".
            writeln!(line_wr, "EVAL {k} {},{}", row[0], row[1]).unwrap();
            line.clear();
            std::io::BufRead::read_line(&mut line_rd, &mut line).unwrap();
            let line_token = line.trim().split(' ').nth(3).expect("score token").to_string();

            let resp = post_score(&mut hc, row);
            assert_eq!(resp.status, 200, "{}", resp.body);
            let http_token = http_score_token(&resp.body);

            let reference = format!("{:.6}", compiled.eval_single(row).score);
            assert_eq!(http_token, line_token, "shards={shards} row={k}");
            assert_eq!(http_token, reference, "shards={shards} row={k}");
        }
        server.stop();
    }
}

/// A framing-safe bad request (well-framed body that fails to parse)
/// fails alone: the pipelined good requests around it still answer on
/// the SAME connection, in order.
#[test]
fn pipelined_connection_survives_a_bad_request_mid_stream() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-pipeline", config(1, 4096, 8));
    let mut hc = HttpClient::connect(&http).unwrap();
    // Three requests on the wire before any response is read.
    hc.send("POST", "/v1/score", &[], b"[0.25,0.5]").unwrap();
    hc.send("POST", "/v1/score", &[], b"[0.25").unwrap();
    hc.send("POST", "/v1/score", &[], b"[0.25,0.5]").unwrap();
    let first = hc.read_response().unwrap();
    let bad = hc.read_response().unwrap();
    let third = hc.read_response().unwrap();
    assert_eq!(first.status, 200, "{}", first.body);
    assert_eq!(bad.status, 400, "{}", bad.body);
    assert!(bad.body.contains("error"), "{}", bad.body);
    assert_eq!(third.status, 200, "{}", third.body);
    assert_eq!(
        http_score_token(&first.body),
        http_score_token(&third.body),
        "same row, same score"
    );
    // And the connection still serves the admin plane afterwards.
    let health = hc.request("GET", "/healthz", &[], b"").unwrap();
    assert_eq!(health.status, 200);
    server.stop();
}

/// A request line that is not HTTP answers 400 once, then the
/// connection closes (the request boundary is lost).
#[test]
fn malformed_request_line_answers_400_then_closes() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-badline", config(1, 4096, 8));
    let (mut wr, mut rd) = raw(&http);
    wr.write_all(b"NOT-AN-HTTP-LINE\r\n\r\n").unwrap();
    let resp = read_response_from(&mut rd).unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body);
    assert_eq!(resp.header("Connection"), Some("close"));
    assert!(read_response_from(&mut rd).is_err(), "connection must be closed");
    server.stop();
}

/// A header line past the cap answers 431 and closes.
#[test]
fn oversized_header_line_answers_431_then_closes() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-bighdr", config(1, 4096, 8));
    let (mut wr, mut rd) = raw(&http);
    let big = "a".repeat(9 * 1024);
    write!(wr, "GET /healthz HTTP/1.1\r\nX-Big: {big}\r\n\r\n").unwrap();
    let resp = read_response_from(&mut rd).unwrap();
    assert_eq!(resp.status, 431, "{}", resp.body);
    assert!(read_response_from(&mut rd).is_err(), "connection must be closed");
    server.stop();
}

/// A body shorter than its declared `Content-Length` answers 400 and
/// closes — the framing is unrecoverable.
#[test]
fn truncated_body_answers_400_then_closes() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-trunc", config(1, 4096, 8));
    let (mut wr, mut rd) = raw(&http);
    wr.write_all(b"POST /v1/score HTTP/1.1\r\nContent-Length: 50\r\n\r\n[0.1,").unwrap();
    wr.shutdown(std::net::Shutdown::Write).unwrap();
    let resp = read_response_from(&mut rd).unwrap();
    assert_eq!(resp.status, 400, "{}", resp.body);
    assert!(resp.body.contains("truncated body"), "{}", resp.body);
    assert!(read_response_from(&mut rd).is_err(), "connection must be closed");
    server.stop();
}

/// Unknown routes (404) and known routes with the wrong method (405)
/// are framing-safe: the keep-alive connection keeps serving.
#[test]
fn unknown_route_and_wrong_method_keep_the_connection_alive() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-routes", config(1, 4096, 8));
    let mut hc = HttpClient::connect(&http).unwrap();
    let resp = hc.request("GET", "/nope", &[], b"").unwrap();
    assert_eq!(resp.status, 404, "{}", resp.body);
    let resp = hc.request("POST", "/healthz", &[], b"").unwrap();
    assert_eq!(resp.status, 405, "{}", resp.body);
    let resp = hc.request("GET", "/v1/score", &[], b"").unwrap();
    assert_eq!(resp.status, 405, "{}", resp.body);
    let resp = hc.request("GET", "/healthz", &[], b"").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    server.stop();
}

/// The same rows through `/v1/score-batch` as a JSON array-of-arrays
/// and as a CSV body yield token-identical scores, and the batch
/// summary counts every row as ok.
#[test]
fn csv_and_json_batch_bodies_agree() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-csv", config(2, 4096, 8));
    let mut hc = HttpClient::connect(&http).unwrap();
    let rows = rows(6);
    let json_body = format!(
        "[{}]",
        rows.iter().map(|r| format!("[{},{}]", r[0], r[1])).collect::<Vec<_>>().join(",")
    );
    let csv_body =
        rows.iter().map(|r| format!("{},{}", r[0], r[1])).collect::<Vec<_>>().join("\n");
    let from_json = hc.request("POST", "/v1/score-batch", &[], json_body.as_bytes()).unwrap();
    assert_eq!(from_json.status, 200, "{}", from_json.body);
    assert!(from_json.body.contains("\"ok\":6"), "{}", from_json.body);
    let from_csv = hc
        .request("POST", "/v1/score-batch", &[("Content-Type", "text/csv")], csv_body.as_bytes())
        .unwrap();
    assert_eq!(from_csv.status, 200, "{}", from_csv.body);
    let json_tokens = batch_score_tokens(&from_json.body);
    let csv_tokens = batch_score_tokens(&from_csv.body);
    assert_eq!(json_tokens.len(), 6);
    assert_eq!(json_tokens, csv_tokens);
    server.stop();
}

/// The `X-Deadline-Ms` header carries the line protocol's deadline
/// semantics: a short deadline under an injected batch stall maps to
/// 504, and `X-Deadline-Ms: 0` opts out and rides the stall to a 200.
#[test]
fn deadline_header_maps_timeout_to_504_and_zero_opts_out() {
    let _fp = failpoints_guard("slow_batch@ms=60");
    let (server, http, _) = start_http("http-deadline", config(1, 4096, 4));
    let mut hc = HttpClient::connect(&http).unwrap();
    let resp = hc.request("POST", "/v1/score", &[("X-Deadline-Ms", "15")], b"[0.3,0.7]").unwrap();
    assert_eq!(resp.status, 504, "{}", resp.body);
    assert!(resp.body.contains("\"status\":\"timeout\""), "{}", resp.body);
    let resp = hc.request("POST", "/v1/score", &[("X-Deadline-Ms", "0")], b"[0.3,0.7]").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    server.stop();
}

/// With a one-deep queue, a one-row batch policy, and a stalled shard,
/// most rows of a batch are refused at admission: BUSY dominates the
/// batch status (503) and the refused rows are itemized as busy.
#[test]
fn full_queue_maps_busy_to_503() {
    let _fp = failpoints_guard("slow_batch@ms=80");
    let (server, http, _) = start_http("http-busy", config(1, 1, 1));
    let mut hc = HttpClient::connect(&http).unwrap();
    let body = format!(
        "[{}]",
        (0..16).map(|i| format!("[0.{},0.5]", i % 10)).collect::<Vec<_>>().join(",")
    );
    let resp = hc.request("POST", "/v1/score-batch", &[], body.as_bytes()).unwrap();
    assert_eq!(resp.status, 503, "{}", resp.body);
    assert!(resp.body.contains("\"status\":\"busy\""), "{}", resp.body);
    // The batch summary accounts for every row exactly once.
    let j = Json::parse(&resp.body).unwrap();
    let total = ["ok", "busy", "timeout", "error"]
        .iter()
        .map(|k| j.req(k).unwrap().as_usize().unwrap())
        .sum::<usize>();
    assert_eq!(total, 16);
    assert!(j.req("busy").unwrap().as_usize().unwrap() >= 1);
    server.stop();
}

/// The admin plane, end to end on one server: healthz, stats, metrics,
/// plan, a rejected and a successful reload (generation bump visible in
/// `GET /plan`), then drain — after which healthz flips to 503 and
/// scoring reports the drain.
#[test]
fn admin_surface_round_trip() {
    let _fp = failpoints_guard("");
    let (server, http, _) = start_http("http-admin", config(2, 4096, 8));
    let mut hc = HttpClient::connect(&http).unwrap();

    let health = hc.request("GET", "/healthz", &[], b"").unwrap();
    assert_eq!(health.status, 200, "{}", health.body);
    assert!(health.body.contains("\"shards\":2"), "{}", health.body);

    for row in rows(4) {
        assert_eq!(post_score(&mut hc, &row).status, 200);
    }

    // /stats: one JSON document — the serving snapshot plus the HTTP
    // middleware's own per-route latencies (it has seen itself? no:
    // recording happens after the response is written, so /stats sees
    // every EARLIER request).
    let stats = hc.request("GET", "/stats", &[], b"").unwrap();
    assert_eq!(stats.status, 200);
    let j = Json::parse(&stats.body).unwrap();
    assert_eq!(j.req("serving").unwrap().req("requests").unwrap().as_usize().unwrap(), 4);
    let score_route = j.req("http").unwrap().req("/v1/score").unwrap();
    assert_eq!(score_route.req("requests").unwrap().as_usize().unwrap(), 4);
    assert_eq!(score_route.req("status").unwrap().req("200").unwrap().as_usize().unwrap(), 4);

    // /metrics: engine families and HTTP families in one exposition.
    let metrics = hc.request("GET", "/metrics", &[], b"").unwrap();
    assert_eq!(metrics.status, 200);
    assert!(metrics.header("Content-Type").unwrap().starts_with("text/plain"), "{metrics:?}");
    assert!(metrics.body.contains("qwyc_requests_total 4"), "{}", metrics.body);
    assert!(metrics.body.contains("qwyc_shard_requests_total{shard=\"0\"}"), "{}", metrics.body);
    assert!(
        metrics.body.contains("qwyc_http_requests_total{route=\"/v1/score\",status=\"200\"} 4"),
        "{}",
        metrics.body
    );

    // /plan: the live artifact at generation 0.
    let plan = hc.request("GET", "/plan", &[], b"").unwrap();
    assert_eq!(plan.status, 200, "{}", plan.body);
    let j = Json::parse(&plan.body).unwrap();
    assert_eq!(j.req("generation").unwrap().as_usize().unwrap(), 0);
    let info = j.req("plan").unwrap();
    assert_eq!(info.req("format").unwrap().as_str().unwrap(), "qwyc-plan-bin-v1");
    assert_eq!(info.req("name").unwrap().as_str().unwrap(), "http-admin");

    // Rejected reloads: unreadable path (staged io reason), then a
    // truncated artifact — both 409, last-known-good keeps serving.
    let resp = hc.request("POST", "/reload", &[], b"/nonexistent/plan.bin").unwrap();
    assert_eq!(resp.status, 409, "{}", resp.body);
    let j = Json::parse(&resp.body).unwrap();
    assert_eq!(j.req("status").unwrap().as_str().unwrap(), "rejected");
    assert_eq!(j.req("stage").unwrap().as_str().unwrap(), "io");

    let tmp = std::env::temp_dir();
    let good_path = tmp.join("qwyc_http_reload.bin");
    PlanArtifact::from_plan(toy_plan("http-v2"))
        .unwrap()
        .save(&good_path, PlanFormat::Binary)
        .unwrap();
    let bytes = std::fs::read(&good_path).unwrap();
    let trunc_path = tmp.join("qwyc_http_trunc.bin");
    std::fs::write(&trunc_path, &bytes[..128.min(bytes.len())]).unwrap();
    let resp = hc.request("POST", "/reload", &[], trunc_path.to_str().unwrap().as_bytes()).unwrap();
    assert_eq!(resp.status, 409, "{}", resp.body);
    assert!(resp.body.contains("\"status\":\"rejected\""), "{}", resp.body);
    assert_eq!(post_score(&mut hc, &[0.3, 0.7]).status, 200, "LKG must keep serving");

    // Successful reload via the JSON body form; generation bumps.
    let body = format!("{{\"path\": \"{}\"}}", good_path.to_str().unwrap().replace('\\', "/"));
    let resp = hc.request("POST", "/reload", &[], body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    let j = Json::parse(&resp.body).unwrap();
    assert_eq!(j.req("status").unwrap().as_str().unwrap(), "reloaded");
    assert_eq!(j.req("plan").unwrap().as_str().unwrap(), "http-v2");
    assert_eq!(j.req("generation").unwrap().as_usize().unwrap(), 1);
    let plan = hc.request("GET", "/plan", &[], b"").unwrap();
    let j = Json::parse(&plan.body).unwrap();
    assert_eq!(j.req("generation").unwrap().as_usize().unwrap(), 1);
    assert_eq!(j.req("plan").unwrap().req("name").unwrap().as_str().unwrap(), "http-v2");

    // Drain: queues empty, admission closed, health flips to 503.
    let resp = hc.request("POST", "/drain", &[], b"").unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert!(resp.body.contains("\"queued\":0"), "{}", resp.body);
    let health = hc.request("GET", "/healthz", &[], b"").unwrap();
    assert_eq!(health.status, 503, "{}", health.body);
    assert!(health.body.contains("draining"), "{}", health.body);
    let resp = post_score(&mut hc, &[0.3, 0.7]);
    assert_eq!(resp.status, 422, "{}", resp.body);
    assert!(resp.body.contains("draining"), "{}", resp.body);

    server.stop();
    std::fs::remove_file(&good_path).ok();
    std::fs::remove_file(&trunc_path).ok();
}
