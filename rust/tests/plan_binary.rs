//! The zero-copy binary plan artifact against the JSON path: bitwise
//! round-trip equivalence (same sweep outcomes, same bits, at 1 and N
//! threads) on a real GBT plan, plus loud rejection of corrupted,
//! truncated, and wrong-format files — every failure a staged `Schema`
//! error naming the bad section.

use qwyc::data::synth::{generate, Which};
use qwyc::error::QwycError;
use qwyc::gbt::{train, GbtParams};
use qwyc::plan::{PlanArtifact, PlanFormat, QwycPlan};
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::util::pool::Pool;
use std::path::PathBuf;

/// A small but real GBT plan (trees exercise the SoA walk paths) plus
/// its held-out feature matrix.
fn gbt_plan() -> (QwycPlan, qwyc::data::Dataset) {
    let (tr, te) = generate(Which::AdultLike, 1234, 0.02);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 12, max_depth: 3, ..Default::default() });
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    let d = tr.d;
    let plan =
        QwycPlan::bundle_with_width(ens, fc, "bin-roundtrip", 0.01, d).expect("bundle plan");
    (plan, te)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("qwyc-plan-binary-{}-{name}", std::process::id()))
}

/// Save the same plan as JSON and as binary, load both through
/// `PlanArtifact`, and demand bitwise-identical sweep outcomes at one
/// and four threads — the artifact format must be invisible to serving.
#[test]
fn binary_and_json_artifacts_sweep_bitwise_identically() {
    let (plan, te) = gbt_plan();
    let dir = tmp("sweep");
    std::fs::create_dir_all(&dir).unwrap();
    let json_path = dir.join("plan.json");
    let bin_path = dir.join("plan.bin");
    let art = PlanArtifact::from_plan(plan).expect("compile");
    art.save(&json_path, PlanFormat::Json).expect("save json");
    art.save(&bin_path, PlanFormat::Binary).expect("save bin");

    let from_json = PlanArtifact::load(&json_path).expect("load json");
    let from_bin = PlanArtifact::load(&bin_path).expect("load bin");
    assert_eq!(from_json.format(), PlanFormat::Json);
    assert_eq!(from_bin.format(), PlanFormat::Binary);

    let (cj, cb) = (from_json.compiled(), from_bin.compiled());
    let (n, d) = (te.n, te.d);
    for threads in [1, 4] {
        let pool = Pool::new(threads);
        let a = cj.sweep_features(&te.x, n, d, 64, &pool);
        let b = cb.sweep_features(&te.x, n, d, 64, &pool);
        assert_eq!(a.len(), b.len());
        for (i, (oa, ob)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(oa.positive, ob.positive, "example {i} ({threads} threads)");
            assert_eq!(oa.stop, ob.stop, "example {i} ({threads} threads)");
            assert_eq!(oa.early, ob.early, "example {i} ({threads} threads)");
            assert_eq!(
                oa.score.to_bits(),
                ob.score.to_bits(),
                "example {i} ({threads} threads): score bits diverge"
            );
        }
    }
    // The single-example path agrees too (first 50 rows is plenty).
    for i in 0..50.min(n) {
        let (a, b) = (cj.eval_single(te.row(i)), cb.eval_single(te.row(i)));
        assert_eq!(a.positive, b.positive, "eval_single {i}");
        assert_eq!(a.models_evaluated, b.models_evaluated, "eval_single {i}");
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "eval_single {i}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A binary artifact reconstructs the uncompiled plan exactly: JSON
/// re-export of a binary load is accepted by the strict JSON loader and
/// compiles to the same thresholds/order.
#[test]
fn binary_artifact_reconstructs_plan_for_json_reexport() {
    let (plan, _) = gbt_plan();
    let dir = tmp("reexport");
    std::fs::create_dir_all(&dir).unwrap();
    let bin_path = dir.join("plan.bin");
    let json_path = dir.join("reexport.json");
    PlanArtifact::from_plan(plan.clone())
        .expect("compile")
        .save(&bin_path, PlanFormat::Binary)
        .expect("save bin");

    let from_bin = PlanArtifact::load(&bin_path).expect("load bin");
    from_bin.save(&json_path, PlanFormat::Json).expect("reexport json");
    let back = PlanArtifact::load(&json_path).expect("reload json");
    assert_eq!(back.name(), plan.meta.name);
    let (a, b) = (back.compiled(), from_bin.compiled());
    assert_eq!(a.order(), b.order());
    assert_eq!(a.bias().to_bits(), b.bias().to_bits());
    for r in 0..a.t() {
        assert_eq!(a.eps_pos()[r].to_bits(), b.eps_pos()[r].to_bits(), "eps_pos[{r}]");
        assert_eq!(a.eps_neg()[r].to_bits(), b.eps_neg()[r].to_bits(), "eps_neg[{r}]");
        assert_eq!(a.prefix_cost(r).to_bits(), b.prefix_cost(r).to_bits(), "prefix_cost[{r}]");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Write a valid binary plan and return its bytes for corruption tests.
fn valid_bytes() -> Vec<u8> {
    let (plan, _) = gbt_plan();
    let dir = tmp("bytes");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("plan.bin");
    PlanArtifact::from_plan(plan).unwrap().save(&p, PlanFormat::Binary).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    bytes
}

/// Load `bytes` through the public artifact API and return the error.
fn load_err(bytes: &[u8], name: &str) -> QwycError {
    let dir = tmp(name);
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.bin");
    std::fs::write(&p, bytes).unwrap();
    let err = PlanArtifact::load(&p).expect_err("corrupted artifact must not load");
    std::fs::remove_dir_all(&dir).ok();
    err
}

#[test]
fn corrupted_binary_artifacts_are_rejected_with_staged_schema_errors() {
    let good = valid_bytes();
    // Sanity: the pristine bytes do load.
    {
        let dir = tmp("good");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("good.bin");
        std::fs::write(&p, &good).unwrap();
        PlanArtifact::load(&p).expect("pristine bytes load");
        std::fs::remove_dir_all(&dir).ok();
    }

    // Wrong magic, but still not JSON: rejected as a schema error that
    // names the format.
    let mut bad = good.clone();
    bad[0] = b'X';
    let e = load_err(&bad, "magic");
    assert_eq!(e.stage(), "schema", "{e}");
    assert!(e.message().contains("qwyc-plan-bin-v1") || e.message().contains("parse"), "{e}");

    // Unsupported version.
    let mut bad = good.clone();
    bad[8..12].copy_from_slice(&99u32.to_ne_bytes());
    let e = load_err(&bad, "version");
    assert_eq!(e.stage(), "schema", "{e}");
    assert!(e.message().contains("unsupported version 99"), "{e}");

    // Truncation at several depths: mid-header, mid-table, mid-payload.
    for (keep, name) in [(32usize, "hdr"), (100, "table"), (good.len() - 7, "payload")] {
        let e = load_err(&good[..keep], name);
        assert_eq!(e.stage(), "schema", "truncated to {keep}: {e}");
    }

    // A flipped section kind is named in the message.
    let hdr_len = 64usize;
    let mut bad = good.clone();
    bad[hdr_len..hdr_len + 4].copy_from_slice(&7u32.to_ne_bytes());
    let e = load_err(&bad, "kind");
    assert_eq!(e.stage(), "schema", "{e}");
    assert!(e.message().contains("section 0 (scalars)"), "{e}");

    // A section length running past end-of-file is named too. Entry 7
    // (model_data) starts at hdr + 7*24; its `len` field is at +16.
    let len_off = hdr_len + 7 * 24 + 16;
    let mut bad = good.clone();
    bad[len_off..len_off + 8].copy_from_slice(&(u64::MAX / 2).to_ne_bytes());
    let e = load_err(&bad, "len");
    assert_eq!(e.stage(), "schema", "{e}");
    assert!(e.message().contains("model_data"), "{e}");

    // Appending junk makes the header's file_len disagree.
    let mut bad = good.clone();
    bad.extend_from_slice(&[0u8; 16]);
    let e = load_err(&bad, "padded");
    assert_eq!(e.stage(), "schema", "{e}");
    assert!(e.message().contains("truncated or padded"), "{e}");
}

/// Files that are neither binary plans nor valid JSON fail as schema
/// errors through the same single entry point.
#[test]
fn non_plan_files_fail_loudly() {
    let e = load_err(b"not a plan at all", "garbage");
    assert_eq!(e.stage(), "schema", "{e}");
    let e = load_err(&[0xFFu8, 0xFE, 0x00, 0x01, 0x02], "binary-garbage");
    assert_eq!(e.stage(), "schema", "{e}");
}
