//! Allocation accounting for the serving hot path: after warmup, one
//! steady-state `EVAL` round trip — line parse, cache lookup, classify,
//! reply format, batch handoff — performs ZERO heap allocations on the
//! measured thread. The TCP loop itself is excluded by design (std's
//! mpsc channel allocates internal node blocks), so the harness drives
//! the exact component functions the server composes, each with the
//! same recycled buffers the server recycles through its pools.
//!
//! The counter is a thread-local wrapped around the system allocator,
//! so allocator traffic on other test threads (the harness runs tests
//! concurrently) cannot pollute a measurement.

use qwyc::coordinator::{
    batch_channel_with_cap, format_ok_reply, parse_eval, BatchPolicy, ResponseCache,
};
use qwyc::data::synth::{generate, Which};
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::plan::QwycPlan;
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::runtime::engine::{Engine, NativeEngine, Outcome};
use qwyc::util::pool::Pool;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::time::Duration;

thread_local! {
    // const-initialized so reading the counter inside the allocator
    // never triggers a lazy TLS init (which could itself allocate).
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator with a thread-local allocation counter. Frees are
/// not counted: the contract under test is "no NEW heap memory on the
/// steady-state path", and a free without a matching alloc would
/// already imply an alloc we counted earlier.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Run `f` and return how many heap allocations it performed on this
/// thread.
fn allocations(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.with(|c| c.get());
    f();
    ALLOCS.with(|c| c.get()) - before
}

fn tiny_engine() -> (qwyc::data::Dataset, NativeEngine) {
    let (tr, te) = generate(Which::Rw2Like, 55, 0.005);
    let (ens, _) = train_joint(
        &tr,
        &LatticeParams { n_lattices: 6, dim: 4, steps: 80, batch: 64, ..Default::default() },
    );
    let sm = ens.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.01, ..Default::default() });
    let plan = QwycPlan::bundle_with_width(ens, fc, "alloc-free", 0.01, te.d)
        .expect("bundle")
        .compile_shared()
        .expect("compile");
    // One worker: the per-request path never fans out, and the pool
    // must not be part of the measurement.
    (te, NativeEngine::from_shared(plan, Pool::new(1)))
}

#[test]
fn steady_state_eval_components_do_not_allocate() {
    let (te, mut engine) = tiny_engine();

    // --- EVAL line parse into a recycled feature buffer ---
    let line = {
        let row = te.row(0);
        let feats: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        format!("17 DEADLINE_MS=250 {}", feats.join(","))
    };
    let mut features: Vec<f32> = Vec::new();
    parse_eval(&line, &mut features).expect("warmup parse");
    let n_parse = allocations(|| {
        parse_eval(&line, &mut features).expect("parse");
    });
    assert_eq!(n_parse, 0, "parse_eval allocated {n_parse} times after warmup");

    // --- classify a small batch into recycled outcome scratch ---
    let batch_n = 4usize;
    let mut xbuf: Vec<f32> = Vec::new();
    for i in 0..batch_n {
        xbuf.extend_from_slice(te.row(i));
    }
    let mut outcomes: Vec<Outcome> = Vec::new();
    engine.classify_into(&xbuf, batch_n, &mut outcomes).expect("warmup classify");
    engine.classify_into(&xbuf, batch_n, &mut outcomes).expect("warmup classify 2");
    let n_classify = allocations(|| {
        engine.classify_into(&xbuf, batch_n, &mut outcomes).expect("classify");
    });
    assert_eq!(n_classify, 0, "classify_into allocated {n_classify} times after warmup");
    let outcome = outcomes[0];

    // --- response-cache hit ---
    let mut cache = ResponseCache::new(1 << 16, 0xfeed);
    cache.insert(3, &features, outcome);
    assert!(cache.lookup(3, &features).is_some(), "warmup lookup must hit");
    let n_lookup = allocations(|| {
        let hit = cache.lookup(3, &features);
        assert!(hit.is_some());
    });
    assert_eq!(n_lookup, 0, "cache lookup allocated {n_lookup} times");

    // --- OK reply formatting into a recycled string ---
    let mut reply = String::new();
    format_ok_reply(&mut reply, 17, &outcome, 133);
    let n_format = allocations(|| {
        format_ok_reply(&mut reply, 17, &outcome, 133);
    });
    assert_eq!(n_format, 0, "format_ok_reply allocated {n_format} times after warmup");

    // --- batch handoff through a recycled batch buffer ---
    let (tx, queue) = batch_channel_with_cap::<u64>(64);
    let policy = BatchPolicy::fixed(8, Duration::ZERO);
    let mut batch: Vec<u64> = Vec::new();
    for i in 0..8u64 {
        tx.try_send(i).expect("warmup send");
    }
    queue.next_batch_into(policy, &mut batch).expect("warmup batch");
    assert_eq!(batch.len(), 8);
    let n_queue = allocations(|| {
        for i in 0..8u64 {
            tx.try_send(i).expect("send");
        }
        queue.next_batch_into(policy, &mut batch).expect("batch");
    });
    assert_eq!(n_queue, 0, "batch queue round trip allocated {n_queue} times after warmup");
}

/// The cold path obviously allocates (buffers are born somewhere); the
/// harness itself must be able to see that, or the zero assertions
/// above would be vacuous.
#[test]
fn harness_counts_allocations_at_all() {
    let n = allocations(|| {
        let v: Vec<u64> = Vec::with_capacity(32);
        std::hint::black_box(&v);
    });
    assert!(n >= 1, "counting allocator saw nothing");
}
