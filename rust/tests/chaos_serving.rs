//! Deterministic chaos harness for the supervised serving runtime.
//!
//! Each test drives the real TCP coordinator with a failpoint spec
//! (`qwyc::util::failpoints`) injected through `configure()` — the same
//! hooks `QWYC_FAILPOINTS` reaches in production — and asserts the
//! failure-semantics contract: every request gets exactly one terminal
//! reply, a panicked shard restarts and serves bitwise-identically, and
//! a rejected RELOAD leaves last-known-good serving untouched.
//!
//! Failpoint state is process-global, so the tests serialize on a lock
//! and clear the table on drop (even when an assertion panics).

use qwyc::coordinator::{BatchPolicy, Client, Reply, Server, ServerConfig};
use qwyc::ensemble::{BaseModel, Ensemble};
use qwyc::lattice::Lattice;
use qwyc::plan::{CompiledPlan, PlanArtifact, PlanFormat, QwycPlan};
use qwyc::qwyc::FastClassifier;
use qwyc::util::failpoints;
use std::collections::BTreeSet;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static FP_LOCK: Mutex<()> = Mutex::new(());

/// Holds the failpoint lock for the test's duration and guarantees the
/// global table is cleared on the way out, pass or fail.
struct FpGuard<'a>(#[allow(dead_code)] MutexGuard<'a, ()>);

impl Drop for FpGuard<'_> {
    fn drop(&mut self) {
        failpoints::configure("").expect("clear failpoints");
    }
}

fn failpoints_guard(spec: &str) -> FpGuard<'static> {
    let g = FP_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    failpoints::configure(spec).expect("configure failpoints");
    FpGuard(g)
}

/// Tiny deterministic 2-feature plan (f0 = x0, f1 = 1 - x1; neg-only ε) —
/// the same shape the plan-layer canary tests use.
fn toy_plan(name: &str) -> QwycPlan {
    let l0 = Lattice::from_params(vec![0], vec![0.0, 1.0]);
    let l1 = Lattice::from_params(vec![1], vec![1.0, 0.0]);
    let ens =
        Ensemble::new("toy", vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1)], 0.25, 1.0);
    let fc = FastClassifier {
        order: vec![1, 0],
        eps_pos: vec![f32::INFINITY, f32::INFINITY],
        eps_neg: vec![-0.5, f32::NEG_INFINITY],
        bias: 0.25,
        beta: 1.0,
    };
    QwycPlan::bundle_with_width(ens, fc, name, 0.01, 2).unwrap()
}

fn toy_shared(name: &str) -> Arc<CompiledPlan> {
    toy_plan(name).compile_shared().unwrap()
}

/// Same construction, one feature wider — compiles fine, but a live
/// 2-feature server must refuse it at the canary's width check.
fn three_feature_plan(name: &str) -> QwycPlan {
    let ls: Vec<BaseModel> = (0..3)
        .map(|f| BaseModel::Lattice(Lattice::from_params(vec![f], vec![0.0, 1.0])))
        .collect();
    let ens = Ensemble::new("toy3", ls, 0.25, 1.0);
    let fc = FastClassifier {
        order: vec![0, 1, 2],
        eps_pos: vec![f32::INFINITY; 3],
        eps_neg: vec![f32::NEG_INFINITY; 3],
        bias: 0.25,
        beta: 1.0,
    };
    QwycPlan::bundle_with_width(ens, fc, name, 0.01, 3).unwrap()
}

/// Structurally valid but numerically poisoned: f32::MAX corner values
/// overflow the running sum to +inf on every probe row — the shape of
/// corruption that loads and compiles fine but must fail the canary.
fn overflowing_plan(name: &str) -> QwycPlan {
    let l0 = Lattice::from_params(vec![0], vec![f32::MAX, f32::MAX]);
    let l1 = Lattice::from_params(vec![1], vec![f32::MAX, f32::MAX]);
    let ens =
        Ensemble::new("hot", vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1)], 0.25, 1.0);
    let fc = FastClassifier {
        order: vec![0, 1],
        eps_pos: vec![f32::INFINITY; 2],
        eps_neg: vec![f32::NEG_INFINITY; 2],
        bias: 0.25,
        beta: 1.0,
    };
    QwycPlan::bundle_with_width(ens, fc, name, 0.01, 2).unwrap()
}

fn rows(n: usize) -> Vec<[f32; 2]> {
    (0..n).map(|i| [(i as f32 * 0.137) % 1.0, (i as f32 * 0.291) % 1.0]).collect()
}

/// Score a reply bitwise against the reference single-example path,
/// through the protocol's %.6f formatting.
fn assert_matches_reference(plan: &CompiledPlan, row: &[f32], r: &qwyc::coordinator::EvalResponse) {
    let want = plan.eval_single(row);
    assert_eq!(r.positive, want.positive, "id {}", r.id);
    assert_eq!(r.models as usize, want.models_evaluated, "id {}", r.id);
    let printed: f32 = format!("{:.6}", want.score).parse().unwrap();
    assert_eq!(r.score.to_bits(), printed.to_bits(), "id {}", r.id);
}

/// Tentpole acceptance #1: a shard panic mid-stream yields exactly one
/// terminal reply per outstanding id (`ERR <id> shard_panic`, never a
/// hang, never a duplicate), the supervisor restarts the shard, and the
/// recovered shard serves bitwise-identically to the reference path.
#[test]
fn shard_panic_gets_terminal_errs_and_shard_recovers_bitwise() {
    let _fp = failpoints_guard("shard_panic@at=1");
    let plan = toy_shared("chaos-a");
    let config = ServerConfig {
        shards: 1,
        queue_cap: 4096,
        policy: BatchPolicy::fixed(8, Duration::from_millis(1)),
        default_deadline: None,
        cache_bytes: 0,
    };
    let server = Server::start_with_plan("127.0.0.1:0", plan.clone(), config).expect("start");
    let mut client = Client::connect(&server.addr).expect("connect");

    let rows = rows(40);
    let mut ids = Vec::new();
    for row in &rows {
        ids.push(client.send_eval(row).expect("send"));
    }
    let (mut ok, mut panicked) = (0u64, 0u64);
    let mut seen = BTreeSet::new();
    for _ in 0..rows.len() {
        match client.read_reply().expect("reply") {
            Reply::Ok(r) => {
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
                ok += 1;
            }
            Reply::Err { id: Some(id), message } => {
                assert!(message.contains("shard_panic"), "{message}");
                assert!(seen.insert(id), "duplicate id {id}");
                panicked += 1;
            }
            other => panic!("unexpected reply: {other:?}"),
        }
    }
    for id in &ids {
        assert!(seen.contains(id), "id {id} never answered");
    }
    assert!(panicked >= 1, "the shard_panic failpoint never fired");
    assert!(ok >= 1, "the shard never recovered (ok={ok}, panicked={panicked})");
    assert!(
        server.metrics.ops().snapshot().shard_restarts >= 1,
        "restart counter never moved"
    );

    // The recovered shard answers bitwise-identically to eval_single —
    // restart must not perturb scoring.
    for row in &rows {
        let r = client.eval(row).expect("post-recovery eval");
        assert_matches_reference(&plan, row, &r);
    }
    server.stop();
}

/// Tentpole acceptance #2: with every batch stalled past the default
/// deadline (slow_batch failpoint), queued requests are shed with
/// `TIMEOUT <id>` at the batch boundary; `DEADLINE_MS=0` opts a request
/// out of the default and it rides out the stall to an OK.
#[test]
fn queued_past_deadline_requests_are_shed_with_timeout() {
    let _fp = failpoints_guard("slow_batch@ms=60");
    let plan = toy_shared("chaos-deadline");
    let config = ServerConfig {
        shards: 1,
        queue_cap: 4096,
        policy: BatchPolicy::fixed(4, Duration::from_millis(1)),
        // Far below the 60ms injected stall: every defaulted request
        // expires while queued.
        default_deadline: Some(Duration::from_millis(15)),
        cache_bytes: 0,
    };
    let server = Server::start_with_plan("127.0.0.1:0", plan, config).expect("start");
    let mut client = Client::connect(&server.addr).expect("connect");

    let n = 6usize;
    let mut ids = Vec::new();
    for i in 0..n {
        ids.push(client.send_eval(&[0.1 * i as f32, 0.5]).expect("send"));
    }
    let mut seen = BTreeSet::new();
    for _ in 0..n {
        match client.read_reply().expect("reply") {
            Reply::Timeout { id } => {
                assert!(seen.insert(id), "duplicate id {id}");
            }
            other => panic!("expected TIMEOUT, got {other:?}"),
        }
    }
    for id in &ids {
        assert!(seen.contains(id), "id {id} never answered");
    }
    assert_eq!(server.metrics.ops().snapshot().timeouts, n as u64);

    // Explicit opt-out overrides the server default: the request waits
    // out the stall and still answers OK.
    let id = client.send_eval_with_deadline(&[0.3, 0.7], 0).expect("send opt-out");
    match client.read_reply().expect("reply") {
        Reply::Ok(r) => assert_eq!(r.id, id),
        other => panic!("opt-out request should survive the stall: {other:?}"),
    }
    server.stop();
}

/// Tentpole acceptance #3: every rejected RELOAD — unreadable artifact,
/// width change, numerically poisoned candidate, or the reload_corrupt
/// failpoint — keeps last-known-good serving bitwise-identically, and a
/// clean retry of the same valid artifact then swaps in.
#[test]
fn rejected_reload_keeps_last_known_good_serving() {
    let _fp = failpoints_guard("");
    let plan = toy_shared("chaos-lkg");
    let config = ServerConfig {
        shards: 1,
        queue_cap: 4096,
        policy: BatchPolicy::fixed(8, Duration::from_millis(1)),
        default_deadline: None,
        cache_bytes: 0,
    };
    let server = Server::start_with_plan("127.0.0.1:0", plan.clone(), config).expect("start");
    let mut client = Client::connect(&server.addr).expect("connect");
    let mut ctl = Client::connect(&server.addr).expect("connect ctl");

    let rows = rows(16);
    let reference: Vec<(bool, u32)> = rows
        .iter()
        .map(|row| {
            let r = client.eval(row).expect("reference eval");
            (r.positive, r.score.to_bits())
        })
        .collect();
    let assert_still_reference = |client: &mut Client| {
        for (row, &(positive, bits)) in rows.iter().zip(reference.iter()) {
            let r = client.eval(row).expect("eval");
            assert_eq!(r.positive, positive, "decision drifted after a rejected reload");
            assert_eq!(r.score.to_bits(), bits, "score drifted after a rejected reload");
        }
    };

    let tmp = std::env::temp_dir();
    // (io) Unreadable artifact.
    let reply = ctl.reload("/nonexistent/chaos_plan.bin").expect("reload io");
    assert!(reply.starts_with("RELOAD_REJECTED io:"), "{reply}");
    // (canary: width) Loadable plan serving a different feature space.
    let wide_path = tmp.join("qwyc_chaos_wide.json");
    three_feature_plan("chaos-wide").save(&wide_path).expect("save wide");
    let reply = ctl.reload(wide_path.to_str().unwrap()).expect("reload wide");
    assert!(reply.starts_with("RELOAD_REJECTED canary:"), "{reply}");
    assert!(reply.contains("feature width"), "{reply}");
    // (canary: scores) Structurally valid, numerically poisoned.
    let hot_path = tmp.join("qwyc_chaos_hot.bin");
    PlanArtifact::from_plan(overflowing_plan("chaos-hot"))
        .expect("compile hot")
        .save(&hot_path, PlanFormat::Binary)
        .expect("save hot");
    let reply = ctl.reload(hot_path.to_str().unwrap()).expect("reload hot");
    assert!(reply.starts_with("RELOAD_REJECTED canary:"), "{reply}");
    assert!(reply.contains("non-finite"), "{reply}");
    // (canary: injected) The reload_corrupt failpoint rejects even a
    // perfectly valid artifact — the harness's forced-verdict hook.
    let good_path = tmp.join("qwyc_chaos_good.bin");
    PlanArtifact::from_plan(toy_plan("chaos-good"))
        .expect("compile good")
        .save(&good_path, PlanFormat::Binary)
        .expect("save good");
    failpoints::configure("reload_corrupt").expect("arm reload_corrupt");
    let reply = ctl.reload(good_path.to_str().unwrap()).expect("reload corrupt");
    assert!(
        reply.starts_with("RELOAD_REJECTED canary: injected failpoint"),
        "{reply}"
    );
    failpoints::configure("").expect("disarm");

    // Four rejections, zero swaps — and the surviving generation still
    // serves the exact reference bits.
    let ops = server.metrics.ops().snapshot();
    assert_eq!(ops.reload_rejected, 4);
    assert_eq!(ops.reload_ok, 0);
    assert_still_reference(&mut client);

    // With the failpoint cleared the same artifact swaps in cleanly,
    // and (same geometry) the replies stay bitwise identical.
    let reply = ctl.reload(good_path.to_str().unwrap()).expect("reload good");
    assert!(reply.starts_with("RELOADED chaos-good gen=1"), "{reply}");
    assert_eq!(server.metrics.ops().snapshot().reload_ok, 1);
    assert_still_reference(&mut client);

    server.stop();
    std::fs::remove_file(&wide_path).ok();
    std::fs::remove_file(&hot_path).ok();
    std::fs::remove_file(&good_path).ok();
}

/// DRAIN empties the shard queues, then admission stays closed: new
/// EVALs get a terminal `ERR <id> draining` instead of queueing.
#[test]
fn drain_stops_admission_after_emptying_queues() {
    let _fp = failpoints_guard("");
    let plan = toy_shared("chaos-drain");
    let config = ServerConfig {
        shards: 2,
        queue_cap: 4096,
        policy: BatchPolicy::fixed(8, Duration::from_millis(1)),
        default_deadline: None,
        cache_bytes: 0,
    };
    let server = Server::start_with_plan("127.0.0.1:0", plan, config).expect("start");
    let mut client = Client::connect(&server.addr).expect("connect");
    client.eval(&[0.2, 0.8]).expect("pre-drain eval");

    let mut ctl = Client::connect(&server.addr).expect("connect ctl");
    let reply = ctl.drain().expect("drain");
    assert_eq!(reply, "DRAINED queued=0");

    let id = client.send_eval(&[0.2, 0.8]).expect("send post-drain");
    match client.read_reply().expect("reply") {
        Reply::Err { id: got, message } => {
            assert_eq!(got, Some(id));
            assert!(message.contains("draining"), "{message}");
        }
        other => panic!("expected a draining ERR: {other:?}"),
    }
    server.stop();
}
