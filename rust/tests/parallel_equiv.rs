//! Parallel/serial equivalence: the perf work in the pool-backed hot
//! paths must never change results. Every assertion here is exact
//! (bitwise for floats) — the contract is bit-identical output at every
//! thread count, not "close enough".

use qwyc::data::synth::{generate, Which};
use qwyc::ensemble::BaseModel;
use qwyc::gbt::{train, GbtParams};
use qwyc::qwyc::{optimize_order_with_pool, simulate_with_pool, QwycConfig};
use qwyc::runtime::engine::{Engine, NativeEngine};
use qwyc::util::pool::Pool;
use qwyc::util::rng::Rng;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn optimize_order_bit_identical_across_thread_counts() {
    let (tr, _) = generate(Which::AdultLike, 31, 0.03);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 40, max_depth: 3, ..Default::default() });
    let sm = ens.score_matrix_par(&tr, &Pool::new(1));
    for cfg in [
        QwycConfig { alpha: 0.01, ..Default::default() },
        QwycConfig { alpha: 0.0, neg_only: true, ..Default::default() },
        // Subsampled search exercises the refit path too.
        QwycConfig { alpha: 0.02, max_opt_examples: 300, ..Default::default() },
    ] {
        let fc1 = optimize_order_with_pool(&sm, &cfg, &Pool::new(1));
        for threads in [2, 4] {
            let fcn = optimize_order_with_pool(&sm, &cfg, &Pool::new(threads));
            assert_eq!(fc1.order, fcn.order, "order diverged at {threads} threads ({cfg:?})");
            assert_eq!(
                bits(&fc1.eps_pos),
                bits(&fcn.eps_pos),
                "eps_pos diverged at {threads} threads ({cfg:?})"
            );
            assert_eq!(
                bits(&fc1.eps_neg),
                bits(&fcn.eps_neg),
                "eps_neg diverged at {threads} threads ({cfg:?})"
            );
        }
    }
}

#[test]
fn simulate_bit_identical_across_thread_counts() {
    let (tr, te) = generate(Which::NomaoLike, 32, 0.05);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 30, max_depth: 3, ..Default::default() });
    let sm_tr = ens.score_matrix_par(&tr, &Pool::new(1));
    let sm_te = ens.score_matrix_par(&te, &Pool::new(1));
    let cfg = QwycConfig { alpha: 0.005, ..Default::default() };
    let fc = optimize_order_with_pool(&sm_tr, &cfg, &Pool::new(1));
    for sm in [&sm_tr, &sm_te] {
        let s1 = simulate_with_pool(&fc, sm, &Pool::new(1));
        for threads in [2, 4] {
            let sn = simulate_with_pool(&fc, sm, &Pool::new(threads));
            assert_eq!(s1.decisions, sn.decisions, "{threads} threads");
            assert_eq!(s1.stops, sn.stops, "{threads} threads");
            assert_eq!(s1.n_early, sn.n_early, "{threads} threads");
            assert_eq!(
                s1.mean_models.to_bits(),
                sn.mean_models.to_bits(),
                "mean_models diverged at {threads} threads"
            );
            assert_eq!(
                s1.mean_cost.to_bits(),
                sn.mean_cost.to_bits(),
                "mean_cost diverged at {threads} threads"
            );
            assert_eq!(s1.pct_diff.to_bits(), sn.pct_diff.to_bits(), "{threads} threads");
        }
    }
}

#[test]
fn score_matrix_bit_identical_across_thread_counts() {
    let (tr, _) = generate(Which::AdultLike, 33, 0.03);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 20, max_depth: 4, ..Default::default() });
    let sm1 = ens.score_matrix_par(&tr, &Pool::new(1));
    let sm4 = ens.score_matrix_par(&tr, &Pool::new(4));
    assert_eq!(sm1.n, sm4.n);
    assert_eq!(sm1.t, sm4.t);
    for t in 0..sm1.t {
        assert_eq!(bits(sm1.col(t)), bits(sm4.col(t)), "column {t} diverged");
    }
    assert_eq!(bits(sm1.full_scores()), bits(sm4.full_scores()));
}

#[test]
fn eval_batch_agrees_with_scalar_eval_on_random_trees() {
    // Trained trees over random query points, plus out-of-range values.
    let (tr, _) = generate(Which::Rw2Like, 34, 0.005);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 12, max_depth: 5, ..Default::default() });
    let mut rng = Rng::new(99);
    let n = 301; // not a multiple of the lane width
    let d = tr.d;
    let mut x = Vec::with_capacity(n * d);
    for _ in 0..n * d {
        x.push((rng.normal() as f32) * 3.0);
    }
    for m in &ens.models {
        let BaseModel::Tree(t) = m else { panic!("gbt trains trees") };
        let soa = t.to_soa();
        let mut out = vec![0f32; n];
        soa.eval_batch(&x, d, &mut out);
        for i in 0..n {
            let want = t.eval(&x[i * d..(i + 1) * d]);
            assert_eq!(out[i].to_bits(), want.to_bits(), "row {i}");
        }
        // Gathered (active-set shaped) variant: random scattered rows.
        let rows: Vec<u32> = (0..97).map(|_| rng.below(n) as u32).collect();
        let mut out2 = vec![0f32; rows.len()];
        soa.eval_indexed(&x, d, &rows, &mut out2);
        for (j, &i) in rows.iter().enumerate() {
            let i = i as usize;
            let want = t.eval(&x[i * d..(i + 1) * d]);
            assert_eq!(out2[j].to_bits(), want.to_bits(), "gathered row {i}");
        }
    }
}

#[test]
fn classify_batch_matches_eval_single() {
    let (tr, te) = generate(Which::AdultLike, 35, 0.03);
    let (ens, _) = train(&tr, &GbtParams { n_trees: 25, max_depth: 4, ..Default::default() });
    let sm = ens.score_matrix_par(&tr, &Pool::new(1));
    let cfg = QwycConfig { alpha: 0.01, ..Default::default() };
    let fc = optimize_order_with_pool(&sm, &cfg, &Pool::new(1));
    let plan = qwyc::plan::QwycPlan::bundle_with_width(ens.clone(), fc.clone(), "equiv", 0.01, tr.d)
        .expect("bundle plan");
    let mut engine = NativeEngine::from_plan(plan.compile().expect("compile plan"));
    // A batch spanning several engine blocks (te.n > 256 at this scale).
    let n = te.n.min(700);
    let got = engine.classify_batch(&te.x[..n * te.d], n).expect("native classify");
    assert_eq!(got.len(), n);
    for (i, o) in got.iter().enumerate() {
        let want = fc.eval_single(&ens, te.row(i));
        assert_eq!(o.positive, want.positive, "example {i}");
        assert_eq!(o.models_evaluated as usize, want.models_evaluated, "example {i}");
        assert_eq!(o.early, want.early, "example {i}");
        assert_eq!(o.score.to_bits(), want.score.to_bits(), "example {i}");
    }
}
