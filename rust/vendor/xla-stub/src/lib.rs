//! Offline stub of the `xla` PJRT bindings.
//!
//! The container this repo builds in has no XLA/PJRT runtime, so the real
//! `xla` crate cannot be linked. This stub mirrors exactly the API surface
//! `qwyc::runtime` consumes — enough for `cargo build --features pjrt` and
//! `cargo clippy` to typecheck the whole PJRT path — while every
//! constructor fails at runtime with a clear message. Swapping this path
//! dependency for a real PJRT binding (same method names) turns the
//! feature on for real; no call-site changes are needed.
//!
//! Only the entry points (`PjRtClient::cpu`, `HloModuleProto::from_text_file`)
//! can ever be reached at runtime: they return `Err`, so values of the other
//! types are never constructed and their methods are unreachable by
//! construction (they still return `Err` defensively rather than panic).

use std::path::Path;

/// Error type; the runtime layer formats it with `{:?}`.
#[derive(Debug)]
pub struct Error(pub String);

fn unavailable(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} is unavailable in this offline build — link a real \
         PJRT binding in rust/vendor to enable the pjrt feature at runtime"
    ))
}

/// Sealed marker for element types the stub understands.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host/device tensor value.
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable("Literal::reshape"))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Synchronously copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host literals as arguments.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with pre-staged device buffers as arguments.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// The PJRT client; the real binding owns a device here.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub — this is the message
    /// users see when running a `--features pjrt` binary offline.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Upload a host tensor to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}
