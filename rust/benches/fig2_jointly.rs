//! Bench target: Figure 2 — real-world jointly-trained lattice ensembles,
//! % classification differences vs mean #base models (Experiments 3-4).
use qwyc::experiments::{figures, FigConfig};

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let cfg = FigConfig { scale, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    figures::fig2_or_fig4(&cfg, true);
}
