//! Bench target: Figure 3 — Adult/Nomao %diff vs mean #models. Shares its
//! computation with Figure 1 (both views are emitted by fig1_fig3).
use qwyc::experiments::{figures, FigConfig};

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let cfg = FigConfig { scale, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    figures::fig1_fig3(&cfg);
}
