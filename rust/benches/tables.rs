//! Bench target: Table 1 (dataset/ensemble summary). `cargo bench --bench tables`
use qwyc::experiments::tables;

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0);
    tables::table1(scale);
}
