//! Bench target: Figures 5-6 — per-example stop-position histograms on
//! Adult/Nomao at the ≈0.5%-diff operating point.
use qwyc::experiments::{figures, FigConfig};

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let cfg = FigConfig { scale, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    figures::fig5_fig6(&cfg);
}
