//! Bench target: Figure 1 — Adult/Nomao test accuracy vs mean #base models
//! (QWYC*, Fan*, fixed orderings, GBT-alone). Also emits the Figure 3 view.
//! Scale via QWYC_BENCH_SCALE (default 0.1; 1.0 = paper-size datasets).
use qwyc::experiments::{figures, FigConfig};

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let cfg = FigConfig { scale, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    figures::fig1_fig3(&cfg);
}
