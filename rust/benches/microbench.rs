//! Microbenchmarks for the hot paths (the §Perf profiling targets):
//!   - lattice single-eval contraction (d = 8 and 13)
//!   - GBT tree walk
//!   - QWYC early-exit eval_single vs full evaluation
//!   - Algorithm-2 threshold search (the inner loop of Algorithm 1)
//!   - PJRT stage execution (per-batch and per-example amortized)

use qwyc::data::synth::{generate, Which};
use qwyc::ensemble::BaseModel;
use qwyc::gbt::{train as gbt_train, GbtParams};
#[cfg(feature = "pjrt")]
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::qwyc::thresholds::{optimize_position, Search};
use qwyc::qwyc::{optimize_order, QwycConfig};
use qwyc::util::rng::Rng;
use qwyc::util::timer::{bench_auto, black_box};
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(200);
    let runs = 5;
    println!("== microbench (1 core, {runs} runs each) ==\n");

    // ---- lattice contraction --------------------------------------
    for d in [8usize, 13] {
        let mut rng = Rng::new(1);
        let feats: Vec<usize> = (0..d).collect();
        let theta: Vec<f32> = (0..1 << d).map(|_| rng.normal() as f32).collect();
        let lat = qwyc::lattice::Lattice::from_params(feats, theta);
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let mut buf = vec![0f32; 1 << d];
        let r = bench_auto(&format!("lattice eval d={d} (2^{d} vertices)"), budget, runs, || {
            black_box(lat.eval_with_scratch(black_box(&x), &mut buf));
        });
        println!("{}", r.report());
    }

    // ---- GBT tree walk ---------------------------------------------
    let (tr, _) = generate(Which::AdultLike, 2, 0.05);
    let (gbt, _) = gbt_train(&tr, &GbtParams { n_trees: 50, max_depth: 5, ..Default::default() });
    let x = tr.row(17).to_vec();
    if let BaseModel::Tree(t0) = &gbt.models[0] {
        let r = bench_auto("gbt tree walk (depth 5)", budget, runs, || {
            black_box(t0.eval(black_box(&x)));
        });
        println!("{}", r.report());
    }

    // ---- early-exit vs full evaluation ------------------------------
    let sm = gbt.score_matrix(&tr);
    let fc = optimize_order(&sm, &QwycConfig { alpha: 0.005, ..Default::default() });
    let full = qwyc::qwyc::FastClassifier::no_early_stop(fc.order.clone(), fc.bias, fc.beta);
    let mut i = 0usize;
    let r = bench_auto("qwyc eval_single (T=50 gbt)", budget, runs, || {
        i = (i + 1) % tr.n;
        black_box(fc.eval_single(&gbt, tr.row(i)));
    });
    println!("{}", r.report());
    let r2 = bench_auto("full eval_single (T=50 gbt)", budget, runs, || {
        i = (i + 1) % tr.n;
        black_box(full.eval_single(&gbt, tr.row(i)));
    });
    println!("{}", r2.report());
    println!("  -> early-exit speedup: {:.2}x\n", r2.mean_ns / r.mean_ns);

    // ---- threshold search (Algorithm 1 inner loop) -------------------
    let mut rng = Rng::new(3);
    for n in [1_000usize, 10_000, 100_000] {
        let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let fp: Vec<bool> = (0..n).map(|_| rng.bool(0.4)).collect();
        let mut scratch = Vec::with_capacity(n);
        let r = bench_auto(&format!("alg2 threshold search n={n}"), budget, runs, || {
            black_box(optimize_position(
                black_box(&g),
                &fp,
                n / 200,
                false,
                Search::Exact,
                &mut scratch,
            ));
        });
        println!("{}", r.report());
    }

    // ---- PJRT stage (needs --features pjrt and artifacts) ------------
    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use qwyc::runtime::engine::Engine;
        let (tr2, _) = generate(Which::Rw2Like, 77, 0.01);
        let project = |ds: &qwyc::data::Dataset| {
            let mut out = qwyc::data::Dataset::new("demo4", 4);
            for i in 0..ds.n {
                let r = ds.row(i);
                out.push(&[r[0], r[7], r[14], r[21]], ds.y[i]);
            }
            out
        };
        let tr2 = project(&tr2);
        let (ens, _) = train_joint(
            &tr2,
            &LatticeParams { n_lattices: 4, dim: 3, steps: 60, ..Default::default() },
        );
        let smd = ens.score_matrix(&tr2);
        let fcd = optimize_order(&smd, &QwycConfig { alpha: 0.01, ..Default::default() });
        let rt = qwyc::runtime::Runtime::open(std::path::Path::new("artifacts")).unwrap();
        let mut engine =
            qwyc::runtime::engine::PjrtEngine::new(rt, "demo_stage", &ens, &fcd).unwrap();
        let b = 8 * 4; // compiled B=8, D=4
        let xb: Vec<f32> = tr2.x[..b].to_vec();
        let r = bench_auto("pjrt demo_stage batch (B=8,T=4,d=3)", budget, runs, || {
            black_box(engine.classify_batch(black_box(&xb), 8).unwrap());
        });
        println!("{}", r.report());
        println!("  -> per-example amortized: {:.3} us", r.mean_us() / 8.0);
    } else {
        println!("(skipping pjrt stage bench: run `make artifacts`)");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(skipping pjrt stage bench: rebuild with --features pjrt and run `make artifacts`)");
}
