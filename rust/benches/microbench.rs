//! Microbenchmarks for the hot paths (the §Perf profiling targets):
//!   - lattice single-eval contraction (d = 8 and 13)
//!   - GBT tree walk: scalar vs the SoA `eval_batch` kernel
//!   - QWYC early-exit eval_single vs full evaluation
//!   - Algorithm-2 threshold search (the inner loop of Algorithm 1)
//!   - Algorithm-1 candidate search: serial vs `QWYC_THREADS` pool
//!   - batch scoring (`score_matrix`) and `simulate`: serial vs pool
//!   - NativeEngine blocked classify_batch
//!   - pipeline_api: typed PlanBuilder optimize+compile vs the loose
//!     optimize_order_with_pool + bundle + compile path
//!   - plan_load: JSON parse+compile vs zero-copy binary artifact load
//!   - sweep_branchless: branchy reference sweep vs the mask-and-compact
//!     kernel on an alternating-exit workload
//!   - sweep_quantized: the raw-f32 sweep vs the feature-quantized
//!     integer kernel (bitwise-identical outputs; the pair measures the
//!     win of binning features once per block)
//!   - walk16_select: the 16-lane compare+select step, runtime-dispatched
//!     SIMD vs the forced-scalar twin
//!   - quantize_features: re-binning the block at every tree position vs
//!     binning once per block (what the amortization is worth)
//!   - serve_path: per-request fresh-buffer allocation vs the
//!     zero-allocation scratch-reuse hot path (parse+classify+format)
//!   - response_cache: cold classify (miss path) vs seeded-hash lookup
//!   - serve policy: fixed vs adaptive batch flush at low/high load,
//!     end-to-end through the TCP coordinator
//!   - http_vs_line: the HTTP/1.1 front-end vs the line protocol over
//!     the SAME 2-shard server (attach_http) — the pair is the wire
//!     tax of head parsing + JSON rendering
//!   - PJRT stage execution (per-batch and per-example amortized)
//!
//! Every target lands in `BENCH.json` (schema `qwyc-bench-v1`, see
//! `util::timer::BenchReport`) with mean/p50/p99 ns, the thread count,
//! and — for the parallelized targets — the measured speedup vs the
//! single-thread pool, so the perf trajectory is tracked across PRs.
//!
//! Flags: `--quick` (tiny datasets + budget; the CI smoke path),
//! `--out <path>` (default: `BENCH.json` at the workspace root).

use qwyc::data::synth::{generate, Which};
use qwyc::ensemble::BaseModel;
use qwyc::gbt::{train as gbt_train, GbtParams};
#[cfg(feature = "pjrt")]
use qwyc::lattice::{train_joint, LatticeParams};
use qwyc::qwyc::thresholds::{optimize_position, Search};
use qwyc::qwyc::{optimize_order_with_pool, simulate_with_pool, QwycConfig};
use qwyc::runtime::engine::Engine;
use qwyc::util::pool::{threads_from_env, Pool};
use qwyc::util::rng::Rng;
use qwyc::util::timer::{bench_auto, black_box, BenchReport};
use std::time::Duration;

fn main() {
    let mut quick = false;
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor the default output at the workspace root where the README
    // and CI expect it; `--out` still accepts any path.
    let mut out_path =
        std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH.json"));
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--quick" => quick = true,
            "--out" => {
                if let Some(p) = argv.next() {
                    out_path = p.into();
                }
            }
            // `cargo bench` passes --bench to harness=false targets.
            "--bench" => {}
            other => eprintln!("microbench: ignoring unknown arg '{other}'"),
        }
    }

    let budget = Duration::from_millis(if quick { 20 } else { 200 });
    let runs = if quick { 2 } else { 5 };
    let threads = threads_from_env();
    let serial = Pool::new(1);
    let pool = Pool::new(threads);
    let mut report = BenchReport::new(threads);
    let mode = if quick { ", --quick" } else { "" };
    println!("== microbench ({threads} threads, {runs} runs each{mode}) ==\n");

    // ---- lattice contraction --------------------------------------
    let lattice_dims: &[usize] = if quick { &[8] } else { &[8, 13] };
    for &d in lattice_dims {
        let mut rng = Rng::new(1);
        let feats: Vec<usize> = (0..d).collect();
        let theta: Vec<f32> = (0..1 << d).map(|_| rng.normal() as f32).collect();
        let lat = qwyc::lattice::Lattice::from_params(feats, theta);
        let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let mut buf = vec![0f32; 1 << d];
        let r = bench_auto(&format!("lattice eval d={d} (2^{d} vertices)"), budget, runs, || {
            black_box(lat.eval_with_scratch(black_box(&x), &mut buf));
        });
        println!("{}", r.report());
        report.push(&r);
    }

    // ---- GBT tree walk: scalar vs SoA batch kernel -------------------
    let scale = if quick { 0.01 } else { 0.05 };
    let n_trees = if quick { 15 } else { 50 };
    let (tr, _) = generate(Which::AdultLike, 2, scale);
    let (gbt, _) = gbt_train(&tr, &GbtParams { n_trees, max_depth: 5, ..Default::default() });
    let x = tr.row(17).to_vec();
    if let BaseModel::Tree(t0) = &gbt.models[0] {
        let r = bench_auto("gbt tree walk (depth 5)", budget, runs, || {
            black_box(t0.eval(black_box(&x)));
        });
        println!("{}", r.report());
        report.push(&r);

        let nb = tr.n.min(2048);
        let soa = t0.to_soa();
        let mut out = vec![0f32; nb];
        let rs = bench_auto(&format!("gbt batch scalar loop (B={nb})"), budget, runs, || {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = t0.eval(tr.row(i));
            }
            black_box(&out);
        });
        println!("{}", rs.report());
        let rb = bench_auto(&format!("gbt eval_batch soa (B={nb})"), budget, runs, || {
            soa.eval_batch(&tr.x, tr.d, &mut out[..nb]);
            black_box(&out);
        });
        println!("{}", rb.report());
        println!("  -> soa kernel speedup: {:.2}x\n", rs.mean_ns / rb.mean_ns);
        report.push_pair(&rs, &rb);
    }

    // ---- early-exit vs full evaluation ------------------------------
    let sm = gbt.score_matrix(&tr);
    let cfg = QwycConfig { alpha: 0.005, ..Default::default() };
    let fc = optimize_order_with_pool(&sm, &cfg, &pool);
    let full = qwyc::qwyc::FastClassifier::no_early_stop(fc.order.clone(), fc.bias, fc.beta);
    let mut i = 0usize;
    let r = bench_auto(&format!("qwyc eval_single (T={n_trees} gbt)"), budget, runs, || {
        i = (i + 1) % tr.n;
        black_box(fc.eval_single(&gbt, tr.row(i)));
    });
    println!("{}", r.report());
    report.push(&r);
    let r2 = bench_auto(&format!("full eval_single (T={n_trees} gbt)"), budget, runs, || {
        i = (i + 1) % tr.n;
        black_box(full.eval_single(&gbt, tr.row(i)));
    });
    println!("{}", r2.report());
    report.push(&r2);
    println!("  -> early-exit speedup: {:.2}x\n", r2.mean_ns / r.mean_ns);

    // ---- threshold search (Algorithm 2, inner loop of Algorithm 1) ---
    let mut rng = Rng::new(3);
    let search_sizes: &[usize] = if quick { &[1_000] } else { &[1_000, 10_000, 100_000] };
    for &n in search_sizes {
        let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let fp: Vec<bool> = (0..n).map(|_| rng.bool(0.4)).collect();
        let mut scratch = Vec::with_capacity(n);
        let r = bench_auto(&format!("alg2 threshold search n={n}"), budget, runs, || {
            black_box(optimize_position(
                black_box(&g),
                &fp,
                n / 200,
                false,
                Search::Exact,
                &mut scratch,
            ));
        });
        println!("{}", r.report());
        report.push(&r);
    }

    // ---- Algorithm-1 candidate search: serial vs pool ----------------
    let rs = bench_auto(
        &format!("alg1 optimize_order T={n_trees} n={} (serial)", sm.n),
        budget,
        runs,
        || {
            black_box(optimize_order_with_pool(black_box(&sm), &cfg, &serial));
        },
    );
    println!("{}", rs.report());
    let rp = bench_auto(
        &format!("alg1 optimize_order T={n_trees} n={} (threads={threads})", sm.n),
        budget,
        runs,
        || {
            black_box(optimize_order_with_pool(black_box(&sm), &cfg, &pool));
        },
    );
    println!("{}", rp.report());
    println!("  -> alg1 candidate-search speedup: {:.2}x\n", rs.mean_ns / rp.mean_ns);
    report.push_pair(&rs, &rp);

    // ---- batch scoring (score_matrix): serial vs pool ----------------
    let (big, _) = generate(Which::AdultLike, 4, if quick { 0.02 } else { 0.2 });
    let rs = bench_auto(
        &format!("score_matrix T={n_trees} n={} (serial)", big.n),
        budget,
        runs,
        || {
            black_box(gbt.score_matrix_par(black_box(&big), &serial));
        },
    );
    println!("{}", rs.report());
    let rp = bench_auto(
        &format!("score_matrix T={n_trees} n={} (threads={threads})", big.n),
        budget,
        runs,
        || {
            black_box(gbt.score_matrix_par(black_box(&big), &pool));
        },
    );
    println!("{}", rp.report());
    println!("  -> batch-scoring speedup: {:.2}x\n", rs.mean_ns / rp.mean_ns);
    report.push_pair(&rs, &rp);

    // ---- simulate sweep: serial vs pool ------------------------------
    let sm_big = gbt.score_matrix_par(&big, &pool);
    let rs = bench_auto(&format!("simulate n={} (serial)", big.n), budget, runs, || {
        black_box(simulate_with_pool(black_box(&fc), &sm_big, &serial));
    });
    println!("{}", rs.report());
    let rp = bench_auto(&format!("simulate n={} (threads={threads})", big.n), budget, runs, || {
        black_box(simulate_with_pool(black_box(&fc), &sm_big, &pool));
    });
    println!("{}", rp.report());
    println!("  -> simulate speedup: {:.2}x\n", rs.mean_ns / rp.mean_ns);
    report.push_pair(&rs, &rp);

    // ---- NativeEngine blocked classify_batch -------------------------
    let bench_plan =
        qwyc::plan::QwycPlan::bundle_with_width(gbt.clone(), fc.clone(), "bench-serve", 0.005, tr.d)
            .expect("bundle plan");
    let compiled = bench_plan.compile_shared().expect("compile plan");
    let mut engine =
        qwyc::runtime::engine::NativeEngine::from_shared(compiled.clone(), Pool::from_env());
    let nb = big.n.min(1024);
    let xb = &big.x[..nb * big.d];
    let r = bench_auto(&format!("native classify_batch (B={nb})"), budget, runs, || {
        black_box(engine.classify_batch(black_box(xb), nb).unwrap());
    });
    println!("{}", r.report());
    println!("  -> per-example amortized: {:.3} us\n", r.mean_us() / nb as f64);
    report.push(&r);

    // ---- typed pipeline builder vs the loose-function path -----------
    // Same computation both ways (score matrix precomputed outside the
    // loop); the pair records what the PlanBuilder facade costs on top
    // of optimize_order_with_pool + QwycPlan::bundle + compile.
    {
        use qwyc::pipeline::PlanBuilder;
        let rl = bench_auto("pipeline loose optimize+bundle+compile", budget, runs, || {
            let fc = optimize_order_with_pool(black_box(&sm), &cfg, &pool);
            let plan =
                qwyc::plan::QwycPlan::bundle_with_width(gbt.clone(), fc, "loose", cfg.alpha, tr.d)
                    .expect("bundle");
            black_box(plan.compile_shared().expect("compile"));
        });
        println!("{}", rl.report());
        let rb = bench_auto("pipeline_api builder optimize+compile", budget, runs, || {
            let opt = PlanBuilder::new("builder")
                .with_scores(&gbt, black_box(&sm))
                .expect("scores")
                .optimize(&cfg, &pool)
                .expect("optimize");
            black_box(opt.with_n_features(tr.d).compile().expect("compile"));
        });
        println!("{}", rb.report());
        println!("  -> builder/loose mean ratio: {:.3}x\n", rb.mean_ns / rl.mean_ns);
        report.push_pair(&rl, &rb);
    }

    // ---- plan artifact load: JSON parse+compile vs zero-copy binary --
    // The pair behind the RELOAD story: a JSON load pays parse +
    // validate + permute + SoA rebuild; a binary load is one read plus
    // validated casts over the already-compiled layout.
    {
        use qwyc::plan::{PlanArtifact, PlanFormat};
        let dir = std::env::temp_dir().join(format!("qwyc-bench-plan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("bench tmp dir");
        let json_path = dir.join("plan.json");
        let bin_path = dir.join("plan.bin");
        let art = PlanArtifact::from_plan(bench_plan.clone()).expect("artifact");
        art.save(&json_path, PlanFormat::Json).expect("save json");
        art.save(&bin_path, PlanFormat::Binary).expect("save bin");
        let rj = bench_auto("plan_load json parse+compile", budget, runs, || {
            black_box(PlanArtifact::load(black_box(&json_path)).expect("load json"));
        });
        println!("{}", rj.report());
        let rb = bench_auto("plan_load binary zero-copy", budget, runs, || {
            black_box(PlanArtifact::load(black_box(&bin_path)).expect("load bin"));
        });
        println!("{}", rb.report());
        println!("  -> binary load speedup: {:.2}x\n", rj.mean_ns / rb.mean_ns);
        report.push_pair(&rj, &rb);
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- early-exit sweep kernel: branchy reference vs branchless ----
    // Same alternating-threshold workload (about half the actives retire
    // at every position) through the pre-rework per-example branchy
    // sweep and the production mask-and-compact kernel.
    {
        use qwyc::qwyc::sweep::{sweep_block, SweepParams};
        let t = 32usize;
        let nb = if quick { 1024 } else { 8192 };
        let cols: Vec<Vec<f32>> = (0..t)
            .map(|r| {
                let mut rng = Rng::new(r as u64 + 11);
                (0..nb).map(|_| rng.normal() as f32 * 0.25).collect()
            })
            .collect();
        let eps_pos: Vec<f32> =
            (0..t).map(|r| if r % 2 == 0 { 0.4 } else { f32::INFINITY }).collect();
        let eps_neg: Vec<f32> =
            (0..t).map(|r| if r % 2 == 1 { -0.4 } else { f32::NEG_INFINITY }).collect();
        let params = SweepParams { eps_pos: &eps_pos, eps_neg: &eps_neg, bias: 0.0, beta: 0.0 };
        let scorer = || {
            let cols = &cols;
            move |r: usize, active: &[u32], out: &mut [f32]| {
                for (slot, &i) in out.iter_mut().zip(active.iter()) {
                    *slot = cols[r][i as usize];
                }
            }
        };
        let rr = bench_auto(&format!("sweep branchy reference (T={t}, B={nb})"), budget, runs, || {
            black_box(reference_sweep(&params, nb, scorer()));
        });
        println!("{}", rr.report());
        let rb = bench_auto(&format!("sweep_branchless kernel (T={t}, B={nb})"), budget, runs, || {
            black_box(sweep_block(&params, nb, scorer()));
        });
        println!("{}", rb.report());
        println!("  -> branchless sweep speedup: {:.2}x\n", rr.mean_ns / rb.mean_ns);
        report.push_pair(&rr, &rb);
    }

    // ---- feature-quantized sweep vs the raw f32 path ------------------
    // Same compiled GBT plan, same rows, bitwise-identical outcomes
    // (rust/tests/quantized_equiv.rs pins that); the pair is purely the
    // kernel cost: one u16 binning pass per block, then integer
    // compare+select tree walks instead of f32 compares. Serial pool so
    // the delta is the kernel, not scheduling.
    {
        assert!(compiled.quant().is_some(), "GBT bench plan should quantize");
        let nq = big.n.min(if quick { 1024 } else { 4096 });
        let xq = &big.x[..nq * big.d];
        let rr = bench_auto(
            &format!("sweep_quantized raw f32 baseline (T={n_trees}, B={nq})"),
            budget,
            runs,
            || {
                black_box(compiled.sweep_features_raw(black_box(xq), nq, big.d, 256, &serial));
            },
        );
        println!("{}", rr.report());
        let rq = bench_auto(
            &format!("sweep_quantized u16 kernel (T={n_trees}, B={nq})"),
            budget,
            runs,
            || {
                black_box(compiled.sweep_features(black_box(xq), nq, big.d, 256, &serial));
            },
        );
        println!("{}", rq.report());
        println!("  -> quantized sweep speedup: {:.2}x\n", rr.mean_ns / rq.mean_ns);
        report.push_pair(&rr, &rq);
    }

    // ---- 16-lane compare+select: dispatched SIMD vs scalar twin -------
    // The inner step of the quantized tree walk, isolated. Both produce
    // identical indices; the pair records what the AVX2/SSE2 tier buys
    // on this host (and collapses to ~1.0x under QWYC_FORCE_SCALAR=1).
    {
        use qwyc::util::simd;
        let mut rng = Rng::new(9);
        let mut mk = |hi: u32| -> [u32; 16] {
            let mut a = [0u32; 16];
            for v in a.iter_mut() {
                *v = rng.next_u32() % hi;
            }
            a
        };
        let (qv, qt, lf, rt) = (mk(65536), mk(65534), mk(1 << 20), mk(1 << 20));
        let mut idx = [0u32; 16];
        let rs = bench_auto("walk16_select scalar twin (16 lanes)", budget, runs, || {
            simd::select16_scalar(black_box(&qv), &qt, &lf, &rt, &mut idx);
            black_box(&idx);
        });
        println!("{}", rs.report());
        let rv = bench_auto("walk16_select simd dispatched (16 lanes)", budget, runs, || {
            simd::select16(black_box(&qv), &qt, &lf, &rt, &mut idx);
            black_box(&idx);
        });
        println!("{}", rv.report());
        println!(
            "  -> select16 simd speedup ({}): {:.2}x\n",
            simd::tier().name(),
            rs.mean_ns / rv.mean_ns
        );
        report.push_pair(&rs, &rv);
    }

    // ---- feature binning: per-position vs once per block --------------
    // The quantized sweep bins each block exactly once; re-binning at
    // every tree position (the naive placement inside the position
    // loop) multiplies that cost by T. The pair documents why the
    // binning lives outside the sweep.
    {
        let q = compiled.quant().expect("GBT bench plan should quantize");
        let nq = big.n.min(if quick { 256 } else { 1024 });
        let xq = &big.x[..nq * big.d];
        let mut qx: Vec<u16> = Vec::new();
        let reps = n_trees;
        let rp = bench_auto(
            &format!("quantize_features per position (T={reps}×, B={nq})"),
            budget,
            runs,
            || {
                for _ in 0..reps {
                    q.quantize_block(black_box(xq), big.d, &mut qx);
                }
                black_box(&qx);
            },
        );
        println!("{}", rp.report());
        let ro = bench_auto(
            &format!("quantize_features once per block (B={nq})"),
            budget,
            runs,
            || {
                q.quantize_block(black_box(xq), big.d, &mut qx);
                black_box(&qx);
            },
        );
        println!("{}", ro.report());
        println!("  -> once-per-block amortization: {:.2}x\n", rp.mean_ns / ro.mean_ns);
        report.push_pair(&rp, &ro);
    }

    // ---- sharded serving throughput (1/2/4 shards) -------------------
    // End-to-end requests/sec through the TCP coordinator: one shared
    // compiled plan, N engine shards, 4 pipelined closed-loop
    // connections. mean_ns is wall-clock per request (1e9/rps);
    // p50/p99 are the server-reported per-request latencies.
    {
        use qwyc::coordinator::{BatchPolicy, Client, Server, ServerConfig};
        let conns = 4usize;
        let per_conn = if quick { 200 } else { 5_000 };
        let total = conns * per_conn;
        for shards in [1usize, 2, 4] {
            let config = ServerConfig {
                shards,
                queue_cap: 0, // unbounded: measure throughput, not shedding
                policy: BatchPolicy::fixed(64, Duration::from_micros(200)),
                default_deadline: None,
                cache_bytes: 0,
            };
            let server = Server::start_with_plan("127.0.0.1:0", compiled.clone(), config)
                .expect("bench server");
            let addr = server.addr;
            let sw = qwyc::util::timer::Stopwatch::new();
            let mut lat_ns: Vec<f64> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..conns)
                    .map(|c| {
                        let tr = &tr;
                        s.spawn(move || {
                            let mut client = Client::connect(&addr).expect("connect");
                            let window = 64usize;
                            let (mut sent, mut recv) = (0usize, 0usize);
                            let mut lat = Vec::with_capacity(per_conn);
                            while recv < per_conn {
                                while sent < per_conn && sent - recv < window {
                                    let row = tr.row((c * per_conn + sent) % tr.n);
                                    client.send_eval(row).expect("send");
                                    sent += 1;
                                }
                                let resp = client.read_response().expect("read");
                                lat.push(resp.latency_us as f64 * 1e3);
                                recv += 1;
                            }
                            lat
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            let el = sw.elapsed_s();
            server.stop();
            lat_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let rps = total as f64 / el;
            let rs = qwyc::util::timer::BenchResult {
                name: format!("serve_shards shards={shards} (reqs={total}, conns={conns})"),
                mean_ns: el * 1e9 / total as f64,
                std_ns: 0.0,
                p50_ns: qwyc::util::stats::percentile_sorted(&lat_ns, 50.0),
                p99_ns: qwyc::util::stats::percentile_sorted(&lat_ns, 99.0),
                runs: 1,
                iters_per_run: total as u64,
            };
            println!("{}   -> {rps:.0} req/s", rs.report());
            report.push(&rs);
        }
        println!();
    }

    // ---- failpoint disabled-path overhead ----------------------------
    // Chaos hooks sit on the serving batch loop; with QWYC_FAILPOINTS
    // unset they must cost one relaxed atomic load and nothing else.
    // Paired against a bare counter bump so the delta IS the hook cost.
    {
        use qwyc::util::failpoints;
        let mut acc = 0u64;
        let rr = bench_auto("failpoint baseline (counter bump)", budget, runs, || {
            acc = acc.wrapping_add(1);
            black_box(acc);
        });
        println!("{}", rr.report());
        let rb = bench_auto("failpoint disabled check (enabled() gate)", budget, runs, || {
            if failpoints::enabled() {
                black_box(failpoints::fire("bench_nop"));
            }
            acc = acc.wrapping_add(1);
            black_box(acc);
        });
        println!("{}", rb.report());
        println!("  -> disabled-failpoint overhead: {:.2} ns/check\n", rb.mean_ns - rr.mean_ns);
        report.push_pair(&rr, &rb);
    }

    // ---- request hot path: fresh buffers vs scratch reuse ------------
    // The same component chain the server runs per request (EVAL parse →
    // classify → OK format), once allocating every buffer per request
    // (the pre-overhaul shape) and once reusing warmed scratch (the
    // production shape the alloc_free test pins at zero allocations).
    {
        use qwyc::coordinator::{format_ok_reply, parse_eval};
        use qwyc::runtime::engine::Outcome;
        let line = {
            let feats: Vec<String> = tr.row(17).iter().map(|v| format!("{v}")).collect();
            format!("17 DEADLINE_MS=250 {}", feats.join(","))
        };
        let ra = bench_auto("serve_path per-request alloc", budget, runs, || {
            let mut feats: Vec<f32> = Vec::new();
            let (id, _) = parse_eval(black_box(line.as_str()), &mut feats).unwrap();
            let mut outs: Vec<Outcome> = Vec::new();
            engine.classify_into(&feats, 1, &mut outs).unwrap();
            let mut reply = String::new();
            format_ok_reply(&mut reply, id, &outs[0], 100);
            black_box(&reply);
        });
        println!("{}", ra.report());
        let mut feats: Vec<f32> = Vec::new();
        let mut outs: Vec<Outcome> = Vec::new();
        let mut reply = String::new();
        let rz = bench_auto("serve_path zero-alloc scratch reuse", budget, runs, || {
            let (id, _) = parse_eval(black_box(line.as_str()), &mut feats).unwrap();
            engine.classify_into(&feats, 1, &mut outs).unwrap();
            format_ok_reply(&mut reply, id, &outs[0], 100);
            black_box(&reply);
        });
        println!("{}", rz.report());
        println!("  -> scratch-reuse speedup: {:.2}x\n", ra.mean_ns / rz.mean_ns);
        report.push_pair(&ra, &rz);
    }

    // ---- response cache: cold classify (miss) vs lookup (hit) --------
    // The pair quantifies what a hit saves: a miss pays the full sweep,
    // a hit pays one seeded hash + bytewise key compare.
    {
        use qwyc::coordinator::ResponseCache;
        use qwyc::runtime::engine::Outcome;
        let feats = tr.row(17).to_vec();
        let mut outs: Vec<Outcome> = Vec::new();
        let rm = bench_auto("response_cache cold classify (miss path)", budget, runs, || {
            engine.classify_into(black_box(&feats), 1, &mut outs).unwrap();
            black_box(&outs);
        });
        println!("{}", rm.report());
        let mut cache = ResponseCache::new(1 << 20, 42);
        engine.classify_into(&feats, 1, &mut outs).unwrap();
        cache.insert(0, &feats, outs[0]);
        let rh = bench_auto("response_cache lookup (hit path)", budget, runs, || {
            black_box(cache.lookup(0, black_box(&feats)));
        });
        println!("{}", rh.report());
        println!("  -> cache-hit speedup: {:.2}x\n", rm.mean_ns / rh.mean_ns);
        report.push_pair(&rm, &rh);
    }

    // ---- fixed vs adaptive batch flush at low and high load ----------
    // Low load = one in-flight request per connection (idle shards; the
    // adaptive policy should flush immediately). High load = deep
    // pipelining (the adaptive policy should stretch toward full
    // batches). End-to-end through the TCP coordinator, 2 shards.
    {
        use qwyc::coordinator::BatchPolicy;
        let conns = 4usize;
        let per_conn = if quick { 150 } else { 2_000 };
        let fixed = BatchPolicy::fixed(64, Duration::from_micros(200));
        let adaptive = BatchPolicy::adaptive(64, Duration::from_micros(200));
        for (load, window) in [("low", 1usize), ("high", 64usize)] {
            let rf = serve_e2e(
                &compiled,
                &tr,
                fixed,
                &format!("serve fixed policy ({load} load)"),
                conns,
                per_conn,
                window,
            );
            println!("{}", rf.report());
            let ra = serve_e2e(
                &compiled,
                &tr,
                adaptive,
                &format!("serve adaptive policy ({load} load)"),
                conns,
                per_conn,
                window,
            );
            println!("{}", ra.report());
            println!("  -> adaptive/fixed mean ratio: {:.3}x\n", ra.mean_ns / rf.mean_ns);
            report.push_pair(&rf, &ra);
        }
    }

    // ---- HTTP front-end vs line protocol on one shard set ------------
    // Both listeners attached to the SAME 2-shard server (attach_http),
    // driven with identical windowed closed loops, so the pair is
    // purely the wire tax: request-line + header parse + JSON render
    // on the HTTP side vs the line codec. p50/p99 are the
    // server-reported per-request latencies either way.
    {
        use qwyc::coordinator::{BatchPolicy, Client, Server, ServerConfig};
        use qwyc::http::HttpClient;
        let conns = 4usize;
        let per_conn = if quick { 150 } else { 2_000 };
        let window = 16usize;
        let total = conns * per_conn;
        let config = ServerConfig {
            shards: 2,
            queue_cap: 0, // unbounded: measure the codecs, not shedding
            policy: BatchPolicy::fixed(64, Duration::from_micros(200)),
            default_deadline: None,
            cache_bytes: 0,
        };
        let mut server =
            Server::start_with_plan("127.0.0.1:0", compiled.clone(), config).expect("bench server");
        let http_addr = server.attach_http("127.0.0.1:0").expect("attach http");
        let addr = server.addr;

        let sw = qwyc::util::timer::Stopwatch::new();
        let mut line_lat: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..conns)
                .map(|c| {
                    let tr = &tr;
                    s.spawn(move || {
                        let mut client = Client::connect(&addr).expect("connect");
                        let (mut sent, mut recv) = (0usize, 0usize);
                        let mut lat = Vec::with_capacity(per_conn);
                        while recv < per_conn {
                            while sent < per_conn && sent - recv < window {
                                let row = tr.row((c * per_conn + sent) % tr.n);
                                client.send_eval(row).expect("send");
                                sent += 1;
                            }
                            let resp = client.read_response().expect("read");
                            lat.push(resp.latency_us as f64 * 1e3);
                            recv += 1;
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let line_el = sw.elapsed_s();

        let sw = qwyc::util::timer::Stopwatch::new();
        let mut http_lat: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..conns)
                .map(|c| {
                    let tr = &tr;
                    s.spawn(move || {
                        use std::fmt::Write as _;
                        let mut client = HttpClient::connect(&http_addr).expect("connect");
                        let mut body = String::new();
                        let (mut sent, mut recv) = (0usize, 0usize);
                        let mut lat = Vec::with_capacity(per_conn);
                        while recv < per_conn {
                            while sent < per_conn && sent - recv < window {
                                let row = tr.row((c * per_conn + sent) % tr.n);
                                body.clear();
                                body.push('[');
                                for (j, v) in row.iter().enumerate() {
                                    if j > 0 {
                                        body.push(',');
                                    }
                                    let _ = write!(body, "{v}");
                                }
                                body.push(']');
                                client
                                    .send("POST", "/v1/score", &[], body.as_bytes())
                                    .expect("send");
                                sent += 1;
                            }
                            let resp = client.read_response().expect("read");
                            assert_eq!(resp.status, 200, "score reply: {}", resp.body);
                            lat.push(latency_us_from_body(&resp.body) * 1e3);
                            recv += 1;
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let http_el = sw.elapsed_s();
        server.stop();

        line_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        http_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mk = |name: &str, el: f64, lat: &[f64]| qwyc::util::timer::BenchResult {
            name: name.to_string(),
            mean_ns: el * 1e9 / total as f64,
            std_ns: 0.0,
            p50_ns: qwyc::util::stats::percentile_sorted(lat, 50.0),
            p99_ns: qwyc::util::stats::percentile_sorted(lat, 99.0),
            runs: 1,
            iters_per_run: total as u64,
        };
        let rl = mk(
            &format!("http_vs_line line EVAL (reqs={total}, conns={conns})"),
            line_el,
            &line_lat,
        );
        let rh = mk(
            &format!("http_vs_line http POST /v1/score (reqs={total}, conns={conns})"),
            http_el,
            &http_lat,
        );
        println!("{}", rl.report());
        println!("{}", rh.report());
        println!("  -> http/line mean ratio: {:.3}x\n", rh.mean_ns / rl.mean_ns);
        report.push_pair(&rl, &rh);
    }

    // ---- PJRT stage (needs --features pjrt and artifacts) ------------
    #[cfg(feature = "pjrt")]
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let (tr2, _) = generate(Which::Rw2Like, 77, 0.01);
        let project = |ds: &qwyc::data::Dataset| {
            let mut out = qwyc::data::Dataset::new("demo4", 4);
            for i in 0..ds.n {
                let r = ds.row(i);
                out.push(&[r[0], r[7], r[14], r[21]], ds.y[i]);
            }
            out
        };
        let tr2 = project(&tr2);
        let (ens, _) = train_joint(
            &tr2,
            &LatticeParams { n_lattices: 4, dim: 3, steps: 60, ..Default::default() },
        );
        let smd = ens.score_matrix(&tr2);
        let cfg2 = QwycConfig { alpha: 0.01, ..Default::default() };
        let fcd = optimize_order_with_pool(&smd, &cfg2, &pool);
        let rt = qwyc::runtime::Runtime::open(std::path::Path::new("artifacts")).unwrap();
        let mut engine =
            qwyc::runtime::engine::PjrtEngine::new(rt, "demo_stage", &ens, &fcd).unwrap();
        let b = 8 * 4; // compiled B=8, D=4
        let xb: Vec<f32> = tr2.x[..b].to_vec();
        let r = bench_auto("pjrt demo_stage batch (B=8,T=4,d=3)", budget, runs, || {
            black_box(engine.classify_batch(black_box(&xb), 8).unwrap());
        });
        println!("{}", r.report());
        println!("  -> per-example amortized: {:.3} us", r.mean_us() / 8.0);
        report.push(&r);
    } else {
        println!("(skipping pjrt stage bench: run `make artifacts`)");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(skipping pjrt stage bench: rebuild with --features pjrt and run `make artifacts`)");

    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {}", out_path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out_path.display()),
    }
}

/// One closed-loop end-to-end serving run (the `serve_shards` shape,
/// parameterized by flush policy and pipeline depth) reported as a
/// single BenchResult: mean_ns is wall-clock per request, p50/p99 are
/// the server-reported per-request latencies.
fn serve_e2e(
    compiled: &std::sync::Arc<qwyc::plan::CompiledPlan>,
    tr: &qwyc::data::Dataset,
    policy: qwyc::coordinator::BatchPolicy,
    name: &str,
    conns: usize,
    per_conn: usize,
    window: usize,
) -> qwyc::util::timer::BenchResult {
    use qwyc::coordinator::{Client, Server, ServerConfig};
    let total = conns * per_conn;
    let config = ServerConfig {
        shards: 2,
        queue_cap: 0, // unbounded: measure the policy, not shedding
        policy,
        default_deadline: None,
        cache_bytes: 0,
    };
    let server =
        Server::start_with_plan("127.0.0.1:0", compiled.clone(), config).expect("bench server");
    let addr = server.addr;
    let sw = qwyc::util::timer::Stopwatch::new();
    let mut lat_ns: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                s.spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let (mut sent, mut recv) = (0usize, 0usize);
                    let mut lat = Vec::with_capacity(per_conn);
                    while recv < per_conn {
                        while sent < per_conn && sent - recv < window {
                            let row = tr.row((c * per_conn + sent) % tr.n);
                            client.send_eval(row).expect("send");
                            sent += 1;
                        }
                        let resp = client.read_response().expect("read");
                        lat.push(resp.latency_us as f64 * 1e3);
                        recv += 1;
                    }
                    lat
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let el = sw.elapsed_s();
    server.stop();
    lat_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    qwyc::util::timer::BenchResult {
        name: name.to_string(),
        mean_ns: el * 1e9 / total as f64,
        std_ns: 0.0,
        p50_ns: qwyc::util::stats::percentile_sorted(&lat_ns, 50.0),
        p99_ns: qwyc::util::stats::percentile_sorted(&lat_ns, 99.0),
        runs: 1,
        iters_per_run: total as u64,
    }
}

/// Pull the server-reported `latency_us` out of a `/v1/score` JSON
/// reply without a full parse (the bench loop is the hot path).
fn latency_us_from_body(body: &str) -> f64 {
    body.rsplit_once("\"latency_us\":")
        .and_then(|(_, tail)| tail.trim_end().trim_end_matches('}').parse::<f64>().ok())
        .unwrap_or(0.0)
}

/// The per-example branchy sweep `qwyc::sweep` used before the
/// branchless rework — the baseline half of the `sweep_branchless`
/// pair (same copy the kernel's equivalence tests pin against).
fn reference_sweep<S>(
    params: &qwyc::qwyc::SweepParams<'_>,
    nb: usize,
    mut score_position: S,
) -> Vec<qwyc::qwyc::SweepOutcome>
where
    S: FnMut(usize, &[u32], &mut [f32]),
{
    use qwyc::qwyc::SweepOutcome;
    let t = params.t();
    let mut out =
        vec![SweepOutcome { positive: false, score: 0.0, stop: t as u32, early: false }; nb];
    let mut g = vec![params.bias; nb];
    let mut scores = vec![0f32; nb];
    let mut active: Vec<u32> = (0..nb as u32).collect();
    for r in 0..t {
        if active.is_empty() {
            break;
        }
        let scores = &mut scores[..active.len()];
        score_position(r, &active, scores);
        let (ep, en) = (params.eps_pos[r], params.eps_neg[r]);
        let mut w = 0usize;
        for j in 0..active.len() {
            let i = active[j] as usize;
            let gi = g[i] + scores[j];
            g[i] = gi;
            if gi > ep || gi < en {
                let stop = (r + 1) as u32;
                out[i] = SweepOutcome { positive: gi > ep, score: gi, stop, early: true };
            } else {
                active[w] = i as u32;
                w += 1;
            }
        }
        active.truncate(w);
    }
    for &i in &active {
        let i = i as usize;
        out[i] = SweepOutcome {
            positive: g[i] >= params.beta,
            score: g[i],
            stop: t as u32,
            early: false,
        };
    }
    out
}
