//! Bench target: Figure 4 — real-world independently-trained lattice
//! ensembles (Experiments 5-6), incl. the paper's "random beats clever
//! orderings at T=500-independent" observation.
use qwyc::experiments::{figures, FigConfig};

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let cfg = FigConfig { scale, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    figures::fig2_or_fig4(&cfg, false);
}
