//! Bench target: Tables 2-5 — wall-clock evaluation-time comparison
//! (Full vs QWYC vs Fan) at ~0.5% classification differences for the four
//! real-world experiments. QWYC_BENCH_RUNS controls timing repeats
//! (paper: 100; default here 5).
use qwyc::experiments::{tables, FigConfig};

fn main() {
    let scale = std::env::var("QWYC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let runs = std::env::var("QWYC_BENCH_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let cfg = FigConfig { scale, ..Default::default() };
    std::fs::create_dir_all(&cfg.out_dir).ok();
    tables::tables_2_to_5(&cfg, runs, 2000);
}
