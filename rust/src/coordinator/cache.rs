//! Generation-keyed response cache for the serving hot path.
//!
//! A per-shard, bounded map from *feature-vector bytes* to the engine
//! [`Outcome`] they evaluate to, keyed **jointly** on the
//! [`PlanSlot`](crate::plan::PlanSlot) generation that produced the
//! outcome. A `RELOAD` bumps the slot generation, so every cached entry
//! from the old plan stops matching the moment the shard worker observes
//! the swap — invalidation needs no flush message, no epoch fence, no
//! coordination of any kind. A *rejected* reload never bumps the
//! generation, so the cache keeps serving the surviving plan's entries,
//! which is exactly right: the plan did not change.
//!
//! Shape and invariants:
//!
//! - Each shard worker **owns** its cache outright — no locks, no
//!   sharing. The cache deliberately lives here and not inside
//!   `NativeEngine`: the engine's `reusable_after_panic` contract is a
//!   compile-time `UnwindSafe` assertion that a shared mutable cache
//!   would break (see `runtime/engine.rs`).
//! - Keys compare the **bit patterns** of the features
//!   ([`f32::to_bits`]), not float equality, so `-0.0` vs `0.0` are
//!   distinct keys and the cache can never conflate two requests the
//!   engine could score differently. Hash collisions are resolved by a
//!   full bitwise key comparison — a hit is always exact.
//! - The hash is seeded per shard (a splitmix64-mixed FNV over the key
//!   bits), so a hostile or degenerate request stream cannot aim at one
//!   global bucket layout shared by every process.
//! - Bounded by an approximate **byte** budget, evicted FIFO. Lookups
//!   are allocation-free; only inserts (a miss that just got evaluated)
//!   allocate, so a steady state of repeated queries does no heap work.
//! - `NaN` handling is the caller's contract: feature vectors containing
//!   NaN must bypass the cache entirely ([`ResponseCache::cacheable`]),
//!   because NaN's bit pattern is not canonical and equal-scoring
//!   requests could miss each other while subtly different ones match.

use crate::runtime::engine::Outcome;
use std::collections::VecDeque;

/// Fixed per-entry overhead charged against the byte budget on top of
/// the feature payload: boxed-slice header, outcome, hash, sequence
/// number, FIFO slot, and bucket bookkeeping, rounded up.
const ENTRY_OVERHEAD_BYTES: usize = 96;

struct Entry {
    hash: u64,
    generation: u64,
    /// Feature bit patterns — the exact key material.
    key: Box<[u32]>,
    outcome: Outcome,
    /// Insertion sequence number, linking the entry to its FIFO slot.
    seq: u64,
}

impl Entry {
    fn cost(&self) -> usize {
        self.key.len() * 4 + ENTRY_OVERHEAD_BYTES
    }
}

/// Per-shard bounded response cache. See the module docs for the
/// invariants; see `coordinator::server` for the serving integration
/// (`serve --cache-bytes`).
pub struct ResponseCache {
    /// Power-of-two bucket array; each bucket is a short probe list.
    buckets: Vec<Vec<Entry>>,
    /// FIFO of (bucket index, sequence number) in insertion order.
    fifo: VecDeque<(u32, u64)>,
    mask: u64,
    seed: u64,
    next_seq: u64,
    max_bytes: usize,
    used_bytes: usize,
}

impl ResponseCache {
    /// A cache bounded to roughly `max_bytes` of entry storage, with a
    /// per-shard `seed` perturbing the bucket layout.
    pub fn new(max_bytes: usize, seed: u64) -> ResponseCache {
        // Size the bucket array for ~8 entries per bucket at the byte
        // budget, assuming small feature vectors; collisions only cost a
        // short linear scan, never a wrong answer.
        let est_entries = (max_bytes / ENTRY_OVERHEAD_BYTES).max(1);
        let n_buckets = (est_entries / 8 + 1).next_power_of_two();
        ResponseCache {
            buckets: (0..n_buckets).map(|_| Vec::new()).collect(),
            fifo: VecDeque::new(),
            mask: n_buckets as u64 - 1,
            seed,
            next_seq: 0,
            max_bytes,
            used_bytes: 0,
        }
    }

    /// May this feature vector use the cache at all? NaN bit patterns
    /// are not canonical, so NaN-bearing requests always go to the
    /// engine (module docs).
    pub fn cacheable(features: &[f32]) -> bool {
        !features.iter().any(|f| f.is_nan())
    }

    /// Seeded FNV-1a over the generation and feature bits, finished with
    /// a splitmix64 mix so low-entropy feature patterns still spread
    /// across buckets.
    fn hash(&self, generation: u64, features: &[f32]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ self.seed;
        let mut step = |word: u64| {
            h ^= word;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        step(generation);
        for f in features {
            step(f.to_bits() as u64);
        }
        // splitmix64 finisher.
        let mut z = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    fn key_matches(entry: &Entry, hash: u64, generation: u64, features: &[f32]) -> bool {
        entry.hash == hash
            && entry.generation == generation
            && entry.key.len() == features.len()
            && entry.key.iter().zip(features.iter()).all(|(&k, f)| k == f.to_bits())
    }

    /// Allocation-free exact lookup under the given plan generation.
    pub fn lookup(&self, generation: u64, features: &[f32]) -> Option<Outcome> {
        let h = self.hash(generation, features);
        let bucket = &self.buckets[(h & self.mask) as usize];
        bucket
            .iter()
            .find(|e| Self::key_matches(e, h, generation, features))
            .map(|e| e.outcome)
    }

    /// Insert a freshly evaluated outcome, evicting FIFO until the byte
    /// budget holds. Returns the number of entries evicted. A duplicate
    /// key (another request in the same batch raced the same features)
    /// is left in place — both outcomes are bitwise-identical anyway.
    pub fn insert(&mut self, generation: u64, features: &[f32], outcome: Outcome) -> u64 {
        let h = self.hash(generation, features);
        let bi = (h & self.mask) as usize;
        if self.buckets[bi].iter().any(|e| Self::key_matches(e, h, generation, features)) {
            return 0;
        }
        let entry = Entry {
            hash: h,
            generation,
            key: features.iter().map(|f| f.to_bits()).collect(),
            outcome,
            seq: self.next_seq,
        };
        let cost = entry.cost();
        if cost > self.max_bytes {
            return 0; // one oversized entry can never fit
        }
        self.next_seq += 1;
        let mut evicted = 0u64;
        while self.used_bytes + cost > self.max_bytes {
            if !self.evict_oldest() {
                break;
            }
            evicted += 1;
        }
        self.used_bytes += cost;
        self.fifo.push_back((bi as u32, entry.seq));
        self.buckets[bi].push(entry);
        evicted
    }

    fn evict_oldest(&mut self) -> bool {
        let Some((bi, seq)) = self.fifo.pop_front() else {
            return false;
        };
        let bucket = &mut self.buckets[bi as usize];
        if let Some(pos) = bucket.iter().position(|e| e.seq == seq) {
            let cost = bucket[pos].cost();
            bucket.swap_remove(pos);
            self.used_bytes -= cost;
            return true;
        }
        // Unreachable by construction (every FIFO slot has its entry),
        // but degrade to "nothing evicted" rather than loop forever.
        false
    }

    /// Drop every entry, keeping the allocated structure. The shard
    /// worker calls this when it observes a generation swap (stale
    /// entries can no longer match, this just returns their bytes
    /// early) and after a batch panic (paranoia: inserts are atomic,
    /// but a wedged shard restarting from scratch should not trust
    /// anything it half-built).
    pub fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.fifo.clear();
        self.used_bytes = 0;
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    /// No live entries?
    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }

    /// Approximate bytes charged against the budget.
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// The configured byte budget.
    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(score: f32) -> Outcome {
        Outcome { positive: score >= 0.0, score, models_evaluated: 3, early: true }
    }

    #[test]
    fn hit_returns_the_exact_stored_outcome() {
        let mut c = ResponseCache::new(1 << 16, 7);
        let feats = [1.0f32, -2.5, 0.0];
        assert!(c.lookup(1, &feats).is_none());
        c.insert(1, &feats, outcome(0.75));
        let got = c.lookup(1, &feats).expect("hit");
        assert_eq!(got.score.to_bits(), 0.75f32.to_bits());
        assert_eq!(got.models_evaluated, 3);
        assert!(got.positive && got.early);
    }

    #[test]
    fn generation_is_part_of_the_key() {
        let mut c = ResponseCache::new(1 << 16, 7);
        let feats = [4.0f32, 5.0];
        c.insert(1, &feats, outcome(1.0));
        assert!(c.lookup(1, &feats).is_some());
        // Same bytes under a new generation: a miss, never a stale hit.
        assert!(c.lookup(2, &feats).is_none());
        c.insert(2, &feats, outcome(-1.0));
        assert!(c.lookup(2, &feats).unwrap().score < 0.0);
        assert!(c.lookup(1, &feats).unwrap().score > 0.0, "old gen entry untouched");
    }

    #[test]
    fn bit_patterns_not_float_equality() {
        let mut c = ResponseCache::new(1 << 16, 7);
        c.insert(1, &[0.0f32], outcome(1.0));
        // -0.0 == 0.0 as floats but is a different bit pattern ⇒ miss.
        assert!(c.lookup(1, &[-0.0f32]).is_none());
        assert!(c.lookup(1, &[0.0f32]).is_some());
    }

    #[test]
    fn eviction_respects_the_byte_budget_in_fifo_order() {
        // Budget for roughly 4 entries of 2 features each.
        let per = 2 * 4 + ENTRY_OVERHEAD_BYTES;
        let mut c = ResponseCache::new(per * 4, 0);
        let mut evicted = 0u64;
        for i in 0..10 {
            evicted += c.insert(1, &[i as f32, 0.5], outcome(i as f32));
            assert!(c.used_bytes() <= c.max_bytes(), "budget exceeded at insert {i}");
        }
        assert_eq!(evicted, 6, "10 inserts into a 4-entry budget evict 6");
        assert_eq!(c.len(), 4);
        // FIFO: the oldest entries are gone, the newest 4 remain.
        for i in 0..6 {
            assert!(c.lookup(1, &[i as f32, 0.5]).is_none(), "entry {i} should be evicted");
        }
        for i in 6..10 {
            assert!(c.lookup(1, &[i as f32, 0.5]).is_some(), "entry {i} should survive");
        }
    }

    #[test]
    fn oversized_entry_is_refused_not_looped() {
        let mut c = ResponseCache::new(64, 0); // smaller than any entry
        let feats: Vec<f32> = (0..32).map(|i| i as f32).collect();
        assert_eq!(c.insert(1, &feats, outcome(1.0)), 0);
        assert_eq!(c.len(), 0);
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn nan_vectors_are_not_cacheable() {
        assert!(ResponseCache::cacheable(&[1.0, 2.0]));
        assert!(!ResponseCache::cacheable(&[1.0, f32::NAN]));
        assert!(ResponseCache::cacheable(&[]));
    }

    #[test]
    fn duplicate_insert_is_a_noop() {
        let mut c = ResponseCache::new(1 << 16, 3);
        c.insert(1, &[1.0f32], outcome(0.5));
        let used = c.used_bytes();
        c.insert(1, &[1.0f32], outcome(0.5));
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), used);
    }

    #[test]
    fn clear_returns_all_bytes() {
        let mut c = ResponseCache::new(1 << 16, 3);
        for i in 0..8 {
            c.insert(1, &[i as f32], outcome(0.0));
        }
        assert!(c.len() == 8 && c.used_bytes() > 0);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
        assert!(c.lookup(1, &[0.0f32]).is_none());
        // Still usable after a clear.
        c.insert(2, &[9.0f32], outcome(1.0));
        assert!(c.lookup(2, &[9.0f32]).is_some());
    }

    #[test]
    fn seeds_change_the_layout_not_the_answers() {
        let mut a = ResponseCache::new(1 << 12, 0x1111);
        let mut b = ResponseCache::new(1 << 12, 0x2222);
        for i in 0..64 {
            let feats = [i as f32, (i * 7) as f32];
            a.insert(5, &feats, outcome(i as f32));
            b.insert(5, &feats, outcome(i as f32));
        }
        for i in 0..64 {
            let feats = [i as f32, (i * 7) as f32];
            // Differently-seeded FIFOs may evict different victims; what
            // both caches still hold must agree bit for bit.
            if let (Some(x), Some(y)) = (a.lookup(5, &feats), b.lookup(5, &feats)) {
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
    }
}
