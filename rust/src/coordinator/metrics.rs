//! Serving metrics: latency histogram (fixed log-bucketed bins → p50/p99),
//! models-evaluated accounting, per-position exit counts (where in π do
//! requests actually stop — the serving-side view of Figures 5-6),
//! early-exit ratio, throughput.
//!
//! The sharded server gives every engine shard its own [`Metrics`] sink
//! (no cross-shard lock contention on the hot path) and aggregates them
//! in [`ShardedMetrics::snapshot`]; the aggregated [`Snapshot`] also
//! carries per-shard request counts so the `STATS` line shows how the
//! dispatcher balanced load.

use crate::util::stats::LatencyHist;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-position exit counts are tracked exactly up to this position;
/// later exits clamp into the last slot (T beyond this is off the
/// design map — the paper's largest ensembles are T = 500).
const STOP_POS_CAP: usize = 512;

/// Fixed bin count for the compact exit-position histogram in `report()`.
const STOP_REPORT_BINS: usize = 8;

#[derive(Debug, Default)]
struct Inner {
    latency: LatencyHist,
    /// Batch accounting as (Σ sizes, count): O(1) state and O(1) merge,
    /// so a long-lived server's snapshot cost never grows.
    batch_sum: u64,
    batch_count: u64,
    models_sum: u64,
    early: u64,
    requests: u64,
    /// `stop_counts[p]` = requests that stopped after exactly p base
    /// models (index 0 only for degenerate zero-model plans). Grown on
    /// demand, capped at [`STOP_POS_CAP`].
    stop_counts: Vec<u64>,
}

impl Inner {
    /// Fold another shard's counters into this aggregate.
    fn merge(&mut self, other: &Inner) {
        self.latency.merge(&other.latency);
        self.batch_sum += other.batch_sum;
        self.batch_count += other.batch_count;
        self.models_sum += other.models_sum;
        self.early += other.early;
        self.requests += other.requests;
        if self.stop_counts.len() < other.stop_counts.len() {
            self.stop_counts.resize(other.stop_counts.len(), 0);
        }
        for (a, &b) in self.stop_counts.iter_mut().zip(other.stop_counts.iter()) {
            *a += b;
        }
    }

    fn to_snapshot(&self, elapsed_s: f64, shard_requests: Vec<u64>, ops: OpsSnapshot) -> Snapshot {
        let n = self.requests.max(1) as f64;
        Snapshot {
            requests: self.requests,
            mean_latency_us: self.latency.mean_ns() / 1e3,
            p50_latency_us: self.latency.percentile_ns(50.0) / 1e3,
            p99_latency_us: self.latency.percentile_ns(99.0) / 1e3,
            mean_models: self.models_sum as f64 / n,
            early_frac: self.early as f64 / n,
            mean_batch: if self.batch_count == 0 {
                0.0
            } else {
                self.batch_sum as f64 / self.batch_count as f64
            },
            throughput_rps: self.requests as f64 / elapsed_s.max(1e-9),
            stop_counts: self.stop_counts.clone(),
            shard_requests,
            ops,
        }
    }
}

/// Monotonic counters for the serving runtime's failure paths: load shed
/// at admission (`busy_shed`), deadline expiries (`timeouts`), shard
/// supervisor restarts (`shard_restarts`), and reload outcomes. Lock-free
/// atomics so the admission path and supervisor never contend with the
/// latency sinks.
#[derive(Debug, Default)]
pub struct OpsCounters {
    /// Requests refused with `BUSY` because every shard queue was full.
    pub busy_shed: AtomicU64,
    /// Requests shed with `TIMEOUT` because their deadline expired while
    /// queued.
    pub timeouts: AtomicU64,
    /// Shard worker restarts after a caught panic (engine rebuilds).
    pub shard_restarts: AtomicU64,
    /// `RELOAD` commands that passed canary validation and swapped.
    pub reload_ok: AtomicU64,
    /// `RELOAD` commands rejected (load failure or canary mismatch).
    pub reload_rejected: AtomicU64,
}

impl OpsCounters {
    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> OpsSnapshot {
        OpsSnapshot {
            busy_shed: self.busy_shed.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            shard_restarts: self.shard_restarts.load(Ordering::Relaxed),
            reload_ok: self.reload_ok.load(Ordering::Relaxed),
            reload_rejected: self.reload_rejected.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time values of [`OpsCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpsSnapshot {
    pub busy_shed: u64,
    pub timeouts: u64,
    pub shard_restarts: u64,
    pub reload_ok: u64,
    pub reload_rejected: u64,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { inner: Mutex::new(Inner::default()), started: Instant::now() }
    }

    pub fn record_request(&self, latency_ns: u64, models: u32, early: bool) {
        let mut m = self.inner.lock().unwrap();
        m.latency.record_ns(latency_ns);
        m.models_sum += models as u64;
        m.early += early as u64;
        m.requests += 1;
        let pos = (models as usize).min(STOP_POS_CAP);
        if m.stop_counts.len() <= pos {
            m.stop_counts.resize(pos + 1, 0);
        }
        m.stop_counts[pos] += 1;
    }

    pub fn record_batch(&self, size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batch_sum += size as u64;
        m.batch_count += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        m.to_snapshot(self.started.elapsed().as_secs_f64(), Vec::new(), OpsSnapshot::default())
    }
}

/// One [`Metrics`] sink per engine shard plus cross-shard aggregation —
/// the serving-metrics view the sharded coordinator exposes. Shard
/// workers record into their own sink (uncontended mutex); `snapshot()`
/// merges all shards into one [`Snapshot`] whose `shard_requests`
/// records the dispatcher's per-shard balance.
pub struct ShardedMetrics {
    shards: Vec<Arc<Metrics>>,
    ops: Arc<OpsCounters>,
    started: Instant,
}

impl ShardedMetrics {
    pub fn new(n_shards: usize) -> ShardedMetrics {
        ShardedMetrics {
            shards: (0..n_shards.max(1)).map(|_| Arc::new(Metrics::new())).collect(),
            ops: Arc::new(OpsCounters::default()),
            started: Instant::now(),
        }
    }

    /// The sink for one shard (handed to that shard's worker thread).
    pub fn shard(&self, i: usize) -> Arc<Metrics> {
        self.shards[i].clone()
    }

    /// The server-wide operational counters (shared by the admission
    /// path, the shard supervisors, and the reload handler).
    pub fn ops(&self) -> &Arc<OpsCounters> {
        &self.ops
    }

    /// Aggregate snapshot across every shard.
    pub fn snapshot(&self) -> Snapshot {
        let mut agg = Inner::default();
        let mut shard_requests = Vec::with_capacity(self.shards.len());
        for m in &self.shards {
            let inner = m.inner.lock().unwrap();
            shard_requests.push(inner.requests);
            agg.merge(&inner);
        }
        agg.to_snapshot(self.started.elapsed().as_secs_f64(), shard_requests, self.ops.snapshot())
    }

    /// Per-shard snapshots (same order as the shard workers).
    pub fn shard_snapshots(&self) -> Vec<Snapshot> {
        self.shards.iter().map(|m| m.snapshot()).collect()
    }
}

/// Smallest position whose cumulative count reaches the p-th percentile.
fn stop_percentile(counts: &[u64], p: f64) -> usize {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((p / 100.0 * total as f64).ceil() as u64).max(1);
    let mut acc = 0u64;
    for (pos, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return pos;
        }
    }
    counts.len().saturating_sub(1)
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub mean_models: f64,
    pub early_frac: f64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    /// Per-position exit counts (`stop_counts[p]` = requests stopping
    /// after exactly p models); empty until the first request.
    pub stop_counts: Vec<u64>,
    /// Requests handled per shard (aggregated snapshots only; empty for
    /// a single [`Metrics`] sink).
    pub shard_requests: Vec<u64>,
    /// Operational counters (all zero for a single [`Metrics`] sink,
    /// which has no admission/supervision machinery).
    pub ops: OpsSnapshot,
}

impl Snapshot {
    /// Exit position below which p% of requests stop.
    pub fn stop_percentile(&self, p: f64) -> usize {
        stop_percentile(&self.stop_counts, p)
    }

    /// The per-position exit counts compacted into `bins` fixed-width
    /// buckets over positions [1, max recorded position].
    pub fn stop_histogram(&self, bins: usize) -> Vec<u64> {
        let bins = bins.max(1);
        let mut out = vec![0u64; bins];
        let hi = self.stop_counts.len().saturating_sub(1).max(1);
        for (pos, &c) in self.stop_counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let b = pos.saturating_sub(1) * bins / hi;
            out[b.min(bins - 1)] += c;
        }
        out
    }

    pub fn report(&self) -> String {
        let hist = self
            .stop_histogram(STOP_REPORT_BINS)
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let shards = if self.shard_requests.len() > 1 {
            let per = self
                .shard_requests
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join(",");
            format!(" shard_requests=[{per}]")
        } else {
            String::new()
        };
        let o = &self.ops;
        format!(
            "requests={} throughput={:.0}/s latency(mean/p50/p99)={:.1}/{:.1}/{:.1}us \
             mean_models={:.2} early={:.1}% exit_pos(p50/p99)={}/{} exit_hist=[{hist}] \
             mean_batch={:.1} busy_shed={} timeouts={} shard_restarts={} reload_ok={} \
             reload_rejected={}{shards}",
            self.requests,
            self.throughput_rps,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.mean_models,
            self.early_frac * 100.0,
            self.stop_percentile(50.0),
            self.stop_percentile(99.0),
            self.mean_batch,
            o.busy_shed,
            o.timeouts,
            o.shard_restarts,
            o.reload_ok,
            o.reload_rejected
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(1_000, 3, true);
        m.record_request(3_000, 5, false);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert!((s.mean_models - 4.0).abs() < 1e-9);
        assert!((s.early_frac - 0.5).abs() < 1e-9);
        assert!((s.mean_latency_us - 2.0).abs() < 0.1);
        assert!((s.mean_batch - 2.0).abs() < 1e-9);
        assert!(!s.report().is_empty());
    }

    #[test]
    fn tracks_per_position_exits() {
        let m = Metrics::new();
        // 8 requests stopping at position 1, one at 4, one at 10.
        for _ in 0..8 {
            m.record_request(1_000, 1, true);
        }
        m.record_request(1_000, 4, true);
        m.record_request(1_000, 10, false);
        let s = m.snapshot();
        assert_eq!(s.stop_counts[1], 8);
        assert_eq!(s.stop_counts[4], 1);
        assert_eq!(s.stop_counts[10], 1);
        assert_eq!(s.stop_counts.iter().sum::<u64>(), 10);
        assert_eq!(s.stop_percentile(50.0), 1);
        assert_eq!(s.stop_percentile(99.0), 10);
        // Fixed-bin compaction preserves mass and lands the tail last.
        let h = s.stop_histogram(5);
        assert_eq!(h.iter().sum::<u64>(), 10);
        assert_eq!(h[0], 8);
        assert_eq!(h[4], 1);
        // The STATS line surfaces the new fields.
        let rep = s.report();
        assert!(rep.contains("exit_pos(p50/p99)=1/10"), "{rep}");
        assert!(rep.contains("exit_hist=["), "{rep}");
    }

    #[test]
    fn sharded_metrics_aggregate_across_shards() {
        let sm = ShardedMetrics::new(3);
        // Shard 0: two early exits at position 2; shard 1: one full stop
        // at 10; shard 2: idle.
        sm.shard(0).record_request(1_000, 2, true);
        sm.shard(0).record_request(3_000, 2, true);
        sm.shard(1).record_request(5_000, 10, false);
        sm.shard(0).record_batch(2);
        sm.shard(1).record_batch(1);
        let s = sm.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.shard_requests, vec![2, 1, 0]);
        assert!((s.mean_models - 14.0 / 3.0).abs() < 1e-9);
        assert!((s.early_frac - 2.0 / 3.0).abs() < 1e-9);
        assert!((s.mean_latency_us - 3.0).abs() < 0.1);
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
        // Merged stop counts span both shards' positions.
        assert_eq!(s.stop_counts[2], 2);
        assert_eq!(s.stop_counts[10], 1);
        let rep = s.report();
        assert!(rep.contains("shard_requests=[2,1,0]"), "{rep}");
        // Per-shard views stay independent.
        let per = sm.shard_snapshots();
        assert_eq!(per[0].requests, 2);
        assert_eq!(per[1].requests, 1);
        assert_eq!(per[2].requests, 0);
        assert!(per[0].shard_requests.is_empty());
        assert!(!per[0].report().contains("shard_requests"), "{}", per[0].report());
    }

    #[test]
    fn ops_counters_surface_in_the_aggregated_report() {
        let sm = ShardedMetrics::new(2);
        sm.ops().busy_shed.fetch_add(3, Ordering::Relaxed);
        sm.ops().timeouts.fetch_add(2, Ordering::Relaxed);
        sm.ops().shard_restarts.fetch_add(1, Ordering::Relaxed);
        sm.ops().reload_ok.fetch_add(4, Ordering::Relaxed);
        sm.ops().reload_rejected.fetch_add(5, Ordering::Relaxed);
        let s = sm.snapshot();
        assert_eq!(
            s.ops,
            OpsSnapshot {
                busy_shed: 3,
                timeouts: 2,
                shard_restarts: 1,
                reload_ok: 4,
                reload_rejected: 5
            }
        );
        let rep = s.report();
        for needle in [
            "busy_shed=3",
            "timeouts=2",
            "shard_restarts=1",
            "reload_ok=4",
            "reload_rejected=5",
        ] {
            assert!(rep.contains(needle), "{rep}");
        }
        // A bare per-shard sink reports zeros (no admission machinery).
        assert_eq!(sm.shard_snapshots()[0].ops, OpsSnapshot::default());
    }

    #[test]
    fn positions_beyond_cap_clamp() {
        let m = Metrics::new();
        m.record_request(1_000, 100_000, false);
        let s = m.snapshot();
        assert_eq!(s.stop_counts.len(), STOP_POS_CAP + 1);
        assert_eq!(s.stop_counts[STOP_POS_CAP], 1);
        assert_eq!(s.stop_percentile(50.0), STOP_POS_CAP);
    }
}
