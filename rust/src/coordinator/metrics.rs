//! Serving metrics: latency histogram, models-evaluated accounting,
//! early-exit ratio, throughput. Shared across worker/connection threads.

use crate::util::stats::LatencyHist;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Default)]
struct Inner {
    latency: LatencyHist,
    batch_sizes: Vec<u64>,
    models_sum: u64,
    early: u64,
    requests: u64,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { inner: Mutex::new(Inner::default()), started: Instant::now() }
    }

    pub fn record_request(&self, latency_ns: u64, models: u32, early: bool) {
        let mut m = self.inner.lock().unwrap();
        m.latency.record_ns(latency_ns);
        m.models_sum += models as u64;
        m.early += early as u64;
        m.requests += 1;
    }

    pub fn record_batch(&self, size: usize) {
        self.inner.lock().unwrap().batch_sizes.push(size as u64);
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let n = m.requests.max(1) as f64;
        Snapshot {
            requests: m.requests,
            mean_latency_us: m.latency.mean_ns() / 1e3,
            p50_latency_us: m.latency.percentile_ns(50.0) / 1e3,
            p99_latency_us: m.latency.percentile_ns(99.0) / 1e3,
            mean_models: m.models_sum as f64 / n,
            early_frac: m.early as f64 / n,
            mean_batch: if m.batch_sizes.is_empty() {
                0.0
            } else {
                m.batch_sizes.iter().sum::<u64>() as f64 / m.batch_sizes.len() as f64
            },
            throughput_rps: m.requests as f64 / self.started.elapsed().as_secs_f64().max(1e-9),
        }
    }
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub mean_models: f64,
    pub early_frac: f64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
}

impl Snapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} throughput={:.0}/s latency(mean/p50/p99)={:.1}/{:.1}/{:.1}us \
             mean_models={:.2} early={:.1}% mean_batch={:.1}",
            self.requests,
            self.throughput_rps,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.mean_models,
            self.early_frac * 100.0,
            self.mean_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(1_000, 3, true);
        m.record_request(3_000, 5, false);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert!((s.mean_models - 4.0).abs() < 1e-9);
        assert!((s.early_frac - 0.5).abs() < 1e-9);
        assert!((s.mean_latency_us - 2.0).abs() < 0.1);
        assert!((s.mean_batch - 2.0).abs() < 1e-9);
        assert!(!s.report().is_empty());
    }
}
