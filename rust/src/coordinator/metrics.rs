//! Serving metrics: latency histogram (fixed log-bucketed bins → p50/p99),
//! models-evaluated accounting, per-position exit counts (where in π do
//! requests actually stop — the serving-side view of Figures 5-6),
//! early-exit ratio, throughput.
//!
//! The sharded server gives every engine shard its own [`Metrics`] sink
//! (no cross-shard lock contention on the hot path) and aggregates them
//! in [`ShardedMetrics::snapshot`]; the aggregated [`Snapshot`] also
//! carries per-shard request counts so the `STATS` line shows how the
//! dispatcher balanced load.

use super::batcher::FlushReason;
use crate::util::json::Json;
use crate::util::stats::LatencyHist;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-position exit counts are tracked exactly up to this position;
/// later exits clamp into the last slot (T beyond this is off the
/// design map — the paper's largest ensembles are T = 500).
const STOP_POS_CAP: usize = 512;

/// Fixed bin count for the compact exit-position histogram in `report()`.
const STOP_REPORT_BINS: usize = 8;

#[derive(Debug, Default)]
struct Inner {
    latency: LatencyHist,
    /// Batch accounting as (Σ sizes, count): O(1) state and O(1) merge,
    /// so a long-lived server's snapshot cost never grows.
    batch_sum: u64,
    batch_count: u64,
    models_sum: u64,
    early: u64,
    requests: u64,
    /// Batch flush decisions by [`FlushReason`] (the adaptive batcher's
    /// observable choices): immediate idle flushes, full batches, and
    /// deadline expiries. `Closed` flushes are shutdown noise and fold
    /// into `flush_deadline`.
    flush_idle: u64,
    flush_full: u64,
    flush_deadline: u64,
    /// Monotonic change counter: bumped by every record call, so the
    /// cached `STATS` report can detect "nothing changed" without
    /// rebuilding the string (see [`ShardedMetrics::report_cached`]).
    version: u64,
    /// `stop_counts[p]` = requests that stopped after exactly p base
    /// models (index 0 only for degenerate zero-model plans). Grown on
    /// demand, capped at [`STOP_POS_CAP`].
    stop_counts: Vec<u64>,
}

impl Inner {
    /// Fold another shard's counters into this aggregate.
    fn merge(&mut self, other: &Inner) {
        self.latency.merge(&other.latency);
        self.batch_sum += other.batch_sum;
        self.batch_count += other.batch_count;
        self.models_sum += other.models_sum;
        self.early += other.early;
        self.requests += other.requests;
        self.flush_idle += other.flush_idle;
        self.flush_full += other.flush_full;
        self.flush_deadline += other.flush_deadline;
        if self.stop_counts.len() < other.stop_counts.len() {
            self.stop_counts.resize(other.stop_counts.len(), 0);
        }
        for (a, &b) in self.stop_counts.iter_mut().zip(other.stop_counts.iter()) {
            *a += b;
        }
    }

    /// Snapshot body shared by the borrowing and consuming paths;
    /// `stop_counts` is passed in so the aggregate path can *move* its
    /// (potentially STOP_POS_CAP-long) vector instead of cloning it.
    fn snapshot_with(
        &self,
        elapsed_s: f64,
        shard_requests: Vec<u64>,
        ops: OpsSnapshot,
        stop_counts: Vec<u64>,
    ) -> Snapshot {
        let n = self.requests.max(1) as f64;
        Snapshot {
            requests: self.requests,
            mean_latency_us: self.latency.mean_ns() / 1e3,
            p50_latency_us: self.latency.percentile_ns(50.0) / 1e3,
            p99_latency_us: self.latency.percentile_ns(99.0) / 1e3,
            mean_models: self.models_sum as f64 / n,
            early_frac: self.early as f64 / n,
            mean_batch: if self.batch_count == 0 {
                0.0
            } else {
                self.batch_sum as f64 / self.batch_count as f64
            },
            throughput_rps: self.requests as f64 / elapsed_s.max(1e-9),
            flush_idle: self.flush_idle,
            flush_full: self.flush_full,
            flush_deadline: self.flush_deadline,
            policy: String::new(),
            stop_counts,
            shard_requests,
            ops,
        }
    }

    fn to_snapshot(&self, elapsed_s: f64, shard_requests: Vec<u64>, ops: OpsSnapshot) -> Snapshot {
        let stop_counts = self.stop_counts.clone();
        self.snapshot_with(elapsed_s, shard_requests, ops, stop_counts)
    }

    /// Consuming variant for aggregates: the merged `Inner` is a
    /// temporary, so its `stop_counts` moves into the [`Snapshot`]
    /// instead of being cloned on every `STATS` request.
    fn into_snapshot(
        mut self,
        elapsed_s: f64,
        shard_requests: Vec<u64>,
        ops: OpsSnapshot,
    ) -> Snapshot {
        let stop_counts = std::mem::take(&mut self.stop_counts);
        self.snapshot_with(elapsed_s, shard_requests, ops, stop_counts)
    }
}

/// Monotonic counters for the serving runtime's failure paths: load shed
/// at admission (`busy_shed`), deadline expiries (`timeouts`), shard
/// supervisor restarts (`shard_restarts`), and reload outcomes. Lock-free
/// atomics so the admission path and supervisor never contend with the
/// latency sinks.
#[derive(Debug, Default)]
pub struct OpsCounters {
    /// Requests refused with `BUSY` because every shard queue was full.
    pub busy_shed: AtomicU64,
    /// Requests shed with `TIMEOUT` because their deadline expired while
    /// queued.
    pub timeouts: AtomicU64,
    /// Shard worker restarts after a caught panic (engine rebuilds).
    pub shard_restarts: AtomicU64,
    /// `RELOAD` commands that passed canary validation and swapped.
    pub reload_ok: AtomicU64,
    /// `RELOAD` commands rejected (load failure or canary mismatch).
    pub reload_rejected: AtomicU64,
    /// Response-cache lookups answered without touching the engine.
    pub cache_hits: AtomicU64,
    /// Response-cache lookups that fell through to the engine (NaN
    /// bypasses are neither hits nor misses — they never consult the
    /// cache).
    pub cache_misses: AtomicU64,
    /// Response-cache entries evicted to hold the byte budget.
    pub cache_evictions: AtomicU64,
}

impl OpsCounters {
    /// Point-in-time copy of every counter.
    pub fn snapshot(&self) -> OpsSnapshot {
        OpsSnapshot {
            busy_shed: self.busy_shed.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            shard_restarts: self.shard_restarts.load(Ordering::Relaxed),
            reload_ok: self.reload_ok.load(Ordering::Relaxed),
            reload_rejected: self.reload_rejected.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time values of [`OpsCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpsSnapshot {
    pub busy_shed: u64,
    pub timeouts: u64,
    pub shard_restarts: u64,
    pub reload_ok: u64,
    pub reload_rejected: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
}

/// Thread-safe metrics sink.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { inner: Mutex::new(Inner::default()), started: Instant::now() }
    }

    pub fn record_request(&self, latency_ns: u64, models: u32, early: bool) {
        let mut m = self.inner.lock().unwrap();
        m.latency.record_ns(latency_ns);
        m.models_sum += models as u64;
        m.early += early as u64;
        m.requests += 1;
        m.version += 1;
        let pos = (models as usize).min(STOP_POS_CAP);
        if m.stop_counts.len() <= pos {
            m.stop_counts.resize(pos + 1, 0);
        }
        m.stop_counts[pos] += 1;
    }

    pub fn record_batch(&self, size: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batch_sum += size as u64;
        m.batch_count += 1;
        m.version += 1;
    }

    /// Count one batch-flush decision (see [`FlushReason`]).
    pub fn record_flush(&self, reason: FlushReason) {
        let mut m = self.inner.lock().unwrap();
        match reason {
            FlushReason::Idle => m.flush_idle += 1,
            FlushReason::Full => m.flush_full += 1,
            FlushReason::Deadline | FlushReason::Closed => m.flush_deadline += 1,
        }
        m.version += 1;
    }

    fn version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        m.to_snapshot(self.started.elapsed().as_secs_f64(), Vec::new(), OpsSnapshot::default())
    }
}

/// One [`Metrics`] sink per engine shard plus cross-shard aggregation —
/// the serving-metrics view the sharded coordinator exposes. Shard
/// workers record into their own sink (uncontended mutex); `snapshot()`
/// merges all shards into one [`Snapshot`] whose `shard_requests`
/// records the dispatcher's per-shard balance.
pub struct ShardedMetrics {
    shards: Vec<Arc<Metrics>>,
    ops: Arc<OpsCounters>,
    started: Instant,
    /// Batch-policy label surfaced as `policy=` in `STATS` (set once at
    /// server start; empty = omitted).
    policy: Mutex<String>,
    /// Cached `STATS` report keyed on (Σ shard versions, ops snapshot):
    /// a `STATS` storm against an idle server re-serves one string
    /// instead of re-merging every shard and re-formatting the report.
    report_cache: Mutex<ReportCache>,
}

#[derive(Default)]
struct ReportCache {
    version: u64,
    ops: OpsSnapshot,
    /// Empty = nothing cached yet (a real report is never empty).
    text: String,
}

impl ShardedMetrics {
    pub fn new(n_shards: usize) -> ShardedMetrics {
        ShardedMetrics {
            shards: (0..n_shards.max(1)).map(|_| Arc::new(Metrics::new())).collect(),
            ops: Arc::new(OpsCounters::default()),
            started: Instant::now(),
            policy: Mutex::new(String::new()),
            report_cache: Mutex::new(ReportCache::default()),
        }
    }

    /// Record the serving batch policy's label for `STATS` lines.
    pub fn set_policy_label(&self, label: &str) {
        *self.policy.lock().unwrap() = label.to_string();
    }

    /// The sink for one shard (handed to that shard's worker thread).
    pub fn shard(&self, i: usize) -> Arc<Metrics> {
        self.shards[i].clone()
    }

    /// The server-wide operational counters (shared by the admission
    /// path, the shard supervisors, and the reload handler).
    pub fn ops(&self) -> &Arc<OpsCounters> {
        &self.ops
    }

    /// Aggregate snapshot across every shard.
    pub fn snapshot(&self) -> Snapshot {
        let mut agg = Inner::default();
        let mut shard_requests = Vec::with_capacity(self.shards.len());
        for m in &self.shards {
            let inner = m.inner.lock().unwrap();
            shard_requests.push(inner.requests);
            agg.merge(&inner);
        }
        let mut snap = agg.into_snapshot(
            self.started.elapsed().as_secs_f64(),
            shard_requests,
            self.ops.snapshot(),
        );
        snap.policy = self.policy.lock().unwrap().clone();
        snap
    }

    /// The assembled `STATS` report, rebuilt only when a counter has
    /// changed since the last call. Change detection is (Σ per-shard
    /// record versions, [`OpsSnapshot`]): any record call bumps a
    /// version and any ops event changes the snapshot, so a stale string
    /// can never be served — but while nothing changes, repeated `STATS`
    /// requests cost one short lock per shard plus a string clone
    /// instead of a full merge + format. (Elapsed-time-derived fields
    /// like `throughput=` freeze with the string until the next counter
    /// change; a serving system at zero traffic has nothing new to
    /// report.)
    pub fn report_cached(&self) -> String {
        let mut version = 0u64;
        for m in &self.shards {
            version = version.wrapping_add(m.version());
        }
        let ops = self.ops.snapshot();
        {
            let c = self.report_cache.lock().unwrap();
            if c.version == version && c.ops == ops && !c.text.is_empty() {
                return c.text.clone();
            }
        }
        // Rebuild outside the cache lock: STATS is off the hot path, a
        // racing rebuild at worst writes the same fresh content twice.
        let text = self.snapshot().report();
        let mut c = self.report_cache.lock().unwrap();
        c.version = version;
        c.ops = ops;
        c.text.clone_from(&text);
        text
    }

    /// Per-shard snapshots (same order as the shard workers).
    pub fn shard_snapshots(&self) -> Vec<Snapshot> {
        self.shards.iter().map(|m| m.snapshot()).collect()
    }
}

/// Smallest position whose cumulative count reaches the p-th percentile.
fn stop_percentile(counts: &[u64], p: f64) -> usize {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((p / 100.0 * total as f64).ceil() as u64).max(1);
    let mut acc = 0u64;
    for (pos, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return pos;
        }
    }
    counts.len().saturating_sub(1)
}

/// Point-in-time metrics view.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub mean_models: f64,
    pub early_frac: f64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    /// Batches flushed immediately because the shard was idle (adaptive
    /// policy's latency-greedy path).
    pub flush_idle: u64,
    /// Batches flushed at `max_batch`.
    pub flush_full: u64,
    /// Batches flushed by deadline expiry (or queue close).
    pub flush_deadline: u64,
    /// Serving batch-policy label (`fixed`/`adaptive`); empty for a bare
    /// per-shard sink, which has no policy to report.
    pub policy: String,
    /// Per-position exit counts (`stop_counts[p]` = requests stopping
    /// after exactly p models); empty until the first request.
    pub stop_counts: Vec<u64>,
    /// Requests handled per shard (aggregated snapshots only; empty for
    /// a single [`Metrics`] sink).
    pub shard_requests: Vec<u64>,
    /// Operational counters (all zero for a single [`Metrics`] sink,
    /// which has no admission/supervision machinery).
    pub ops: OpsSnapshot,
}

impl Snapshot {
    /// Exit position below which p% of requests stop.
    pub fn stop_percentile(&self, p: f64) -> usize {
        stop_percentile(&self.stop_counts, p)
    }

    /// The per-position exit counts compacted into `bins` fixed-width
    /// buckets over positions [1, max recorded position].
    pub fn stop_histogram(&self, bins: usize) -> Vec<u64> {
        let bins = bins.max(1);
        let mut out = vec![0u64; bins];
        let hi = self.stop_counts.len().saturating_sub(1).max(1);
        for (pos, &c) in self.stop_counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let b = pos.saturating_sub(1) * bins / hi;
            out[b.min(bins - 1)] += c;
        }
        out
    }

    /// Structured rendering of the full snapshot — the single
    /// formatting authority for serving metrics. Both human surfaces
    /// derive from this document: the line protocol's `STATS` text
    /// ([`Snapshot::report`] formats these values) and the HTTP admin
    /// plane's `GET /stats` (serves it verbatim as JSON), so the two
    /// cannot drift. u64 counters fit `f64` exactly up to 2^53 —
    /// unreachable for per-process request counts.
    pub fn to_json(&self) -> Json {
        let o = &self.ops;
        let arr_u64 = |xs: &[u64]| Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect());
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
            (
                "latency_us",
                Json::obj(vec![
                    ("mean", Json::Num(self.mean_latency_us)),
                    ("p50", Json::Num(self.p50_latency_us)),
                    ("p99", Json::Num(self.p99_latency_us)),
                ]),
            ),
            ("mean_models", Json::Num(self.mean_models)),
            ("early_frac", Json::Num(self.early_frac)),
            (
                "exit_pos",
                Json::obj(vec![
                    ("p50", Json::Num(self.stop_percentile(50.0) as f64)),
                    ("p99", Json::Num(self.stop_percentile(99.0) as f64)),
                ]),
            ),
            ("exit_hist", arr_u64(&self.stop_histogram(STOP_REPORT_BINS))),
            ("mean_batch", Json::Num(self.mean_batch)),
            (
                "flush",
                Json::obj(vec![
                    ("idle", Json::Num(self.flush_idle as f64)),
                    ("full", Json::Num(self.flush_full as f64)),
                    ("deadline", Json::Num(self.flush_deadline as f64)),
                ]),
            ),
            (
                "policy",
                if self.policy.is_empty() { Json::Null } else { Json::str(&self.policy) },
            ),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::Num(o.cache_hits as f64)),
                    ("misses", Json::Num(o.cache_misses as f64)),
                    ("evictions", Json::Num(o.cache_evictions as f64)),
                ]),
            ),
            ("busy_shed", Json::Num(o.busy_shed as f64)),
            ("timeouts", Json::Num(o.timeouts as f64)),
            ("shard_restarts", Json::Num(o.shard_restarts as f64)),
            ("reload_ok", Json::Num(o.reload_ok as f64)),
            ("reload_rejected", Json::Num(o.reload_rejected as f64)),
            ("shard_requests", arr_u64(&self.shard_requests)),
            ("stop_counts", arr_u64(&self.stop_counts)),
        ])
    }

    /// The `STATS` line, formatted from [`Snapshot::to_json`] so the
    /// text report and the JSON document read the same values by
    /// construction. The wire shape is pinned by tests and grepped by
    /// CI — it must not change. Field lookups `expect`: `to_json`
    /// constructs every field this reads.
    pub fn report(&self) -> String {
        let j = self.to_json();
        let num = |v: &Json, k: &str| v.req(k).and_then(Json::as_f64).expect("to_json field");
        let int = |v: &Json, k: &str| num(v, k) as u64;
        let list = |v: &Json, k: &str| -> Vec<u64> {
            v.req(k)
                .and_then(Json::as_arr)
                .expect("to_json field")
                .iter()
                .map(|e| e.as_f64().expect("to_json element") as u64)
                .collect()
        };
        let join = |xs: &[u64]| xs.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",");
        let lat = j.req("latency_us").expect("to_json field");
        let exit = j.req("exit_pos").expect("to_json field");
        let flush = j.req("flush").expect("to_json field");
        let cache = j.req("cache").expect("to_json field");
        let hist = join(&list(&j, "exit_hist"));
        let shard_requests = list(&j, "shard_requests");
        let shards = if shard_requests.len() > 1 {
            format!(" shard_requests=[{}]", join(&shard_requests))
        } else {
            String::new()
        };
        let policy = match j.req("policy").expect("to_json field") {
            Json::Null => String::new(),
            p => format!(" policy={}", p.as_str().expect("to_json field")),
        };
        format!(
            "requests={} throughput={:.0}/s latency(mean/p50/p99)={:.1}/{:.1}/{:.1}us \
             mean_models={:.2} early={:.1}% exit_pos(p50/p99)={}/{} exit_hist=[{hist}] \
             mean_batch={:.1} flush(idle/full/deadline)={}/{}/{}{policy} \
             cache(hit/miss/evict)={}/{}/{} busy_shed={} timeouts={} shard_restarts={} \
             reload_ok={} reload_rejected={}{shards}",
            int(&j, "requests"),
            num(&j, "throughput_rps"),
            num(lat, "mean"),
            num(lat, "p50"),
            num(lat, "p99"),
            num(&j, "mean_models"),
            num(&j, "early_frac") * 100.0,
            int(exit, "p50"),
            int(exit, "p99"),
            num(&j, "mean_batch"),
            int(flush, "idle"),
            int(flush, "full"),
            int(flush, "deadline"),
            int(cache, "hits"),
            int(cache, "misses"),
            int(cache, "evictions"),
            int(&j, "busy_shed"),
            int(&j, "timeouts"),
            int(&j, "shard_restarts"),
            int(&j, "reload_ok"),
            int(&j, "reload_rejected")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_request(1_000, 3, true);
        m.record_request(3_000, 5, false);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert!((s.mean_models - 4.0).abs() < 1e-9);
        assert!((s.early_frac - 0.5).abs() < 1e-9);
        assert!((s.mean_latency_us - 2.0).abs() < 0.1);
        assert!((s.mean_batch - 2.0).abs() < 1e-9);
        assert!(!s.report().is_empty());
    }

    #[test]
    fn tracks_per_position_exits() {
        let m = Metrics::new();
        // 8 requests stopping at position 1, one at 4, one at 10.
        for _ in 0..8 {
            m.record_request(1_000, 1, true);
        }
        m.record_request(1_000, 4, true);
        m.record_request(1_000, 10, false);
        let s = m.snapshot();
        assert_eq!(s.stop_counts[1], 8);
        assert_eq!(s.stop_counts[4], 1);
        assert_eq!(s.stop_counts[10], 1);
        assert_eq!(s.stop_counts.iter().sum::<u64>(), 10);
        assert_eq!(s.stop_percentile(50.0), 1);
        assert_eq!(s.stop_percentile(99.0), 10);
        // Fixed-bin compaction preserves mass and lands the tail last.
        let h = s.stop_histogram(5);
        assert_eq!(h.iter().sum::<u64>(), 10);
        assert_eq!(h[0], 8);
        assert_eq!(h[4], 1);
        // The STATS line surfaces the new fields.
        let rep = s.report();
        assert!(rep.contains("exit_pos(p50/p99)=1/10"), "{rep}");
        assert!(rep.contains("exit_hist=["), "{rep}");
    }

    #[test]
    fn sharded_metrics_aggregate_across_shards() {
        let sm = ShardedMetrics::new(3);
        // Shard 0: two early exits at position 2; shard 1: one full stop
        // at 10; shard 2: idle.
        sm.shard(0).record_request(1_000, 2, true);
        sm.shard(0).record_request(3_000, 2, true);
        sm.shard(1).record_request(5_000, 10, false);
        sm.shard(0).record_batch(2);
        sm.shard(1).record_batch(1);
        let s = sm.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.shard_requests, vec![2, 1, 0]);
        assert!((s.mean_models - 14.0 / 3.0).abs() < 1e-9);
        assert!((s.early_frac - 2.0 / 3.0).abs() < 1e-9);
        assert!((s.mean_latency_us - 3.0).abs() < 0.1);
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
        // Merged stop counts span both shards' positions.
        assert_eq!(s.stop_counts[2], 2);
        assert_eq!(s.stop_counts[10], 1);
        let rep = s.report();
        assert!(rep.contains("shard_requests=[2,1,0]"), "{rep}");
        // Per-shard views stay independent.
        let per = sm.shard_snapshots();
        assert_eq!(per[0].requests, 2);
        assert_eq!(per[1].requests, 1);
        assert_eq!(per[2].requests, 0);
        assert!(per[0].shard_requests.is_empty());
        assert!(!per[0].report().contains("shard_requests"), "{}", per[0].report());
    }

    #[test]
    fn ops_counters_surface_in_the_aggregated_report() {
        let sm = ShardedMetrics::new(2);
        sm.ops().busy_shed.fetch_add(3, Ordering::Relaxed);
        sm.ops().timeouts.fetch_add(2, Ordering::Relaxed);
        sm.ops().shard_restarts.fetch_add(1, Ordering::Relaxed);
        sm.ops().reload_ok.fetch_add(4, Ordering::Relaxed);
        sm.ops().reload_rejected.fetch_add(5, Ordering::Relaxed);
        let s = sm.snapshot();
        assert_eq!(
            s.ops,
            OpsSnapshot {
                busy_shed: 3,
                timeouts: 2,
                shard_restarts: 1,
                reload_ok: 4,
                reload_rejected: 5,
                ..OpsSnapshot::default()
            }
        );
        let rep = s.report();
        for needle in [
            "busy_shed=3",
            "timeouts=2",
            "shard_restarts=1",
            "reload_ok=4",
            "reload_rejected=5",
        ] {
            assert!(rep.contains(needle), "{rep}");
        }
        // A bare per-shard sink reports zeros (no admission machinery).
        assert_eq!(sm.shard_snapshots()[0].ops, OpsSnapshot::default());
    }

    #[test]
    fn flush_reasons_and_policy_surface_in_the_report() {
        let sm = ShardedMetrics::new(2);
        sm.set_policy_label("adaptive");
        sm.shard(0).record_flush(FlushReason::Idle);
        sm.shard(0).record_flush(FlushReason::Idle);
        sm.shard(1).record_flush(FlushReason::Full);
        sm.shard(1).record_flush(FlushReason::Deadline);
        sm.shard(1).record_flush(FlushReason::Closed); // folds into deadline
        let s = sm.snapshot();
        assert_eq!((s.flush_idle, s.flush_full, s.flush_deadline), (2, 1, 2));
        assert_eq!(s.policy, "adaptive");
        let rep = s.report();
        assert!(rep.contains("flush(idle/full/deadline)=2/1/2"), "{rep}");
        assert!(rep.contains(" policy=adaptive"), "{rep}");
        // A bare per-shard sink has no policy to report.
        let bare = sm.shard_snapshots()[0].report();
        assert!(!bare.contains("policy="), "{bare}");
    }

    #[test]
    fn cache_counters_surface_in_the_report() {
        let sm = ShardedMetrics::new(1);
        sm.ops().cache_hits.fetch_add(7, Ordering::Relaxed);
        sm.ops().cache_misses.fetch_add(9, Ordering::Relaxed);
        sm.ops().cache_evictions.fetch_add(2, Ordering::Relaxed);
        let rep = sm.snapshot().report();
        assert!(rep.contains("cache(hit/miss/evict)=7/9/2"), "{rep}");
    }

    #[test]
    fn report_cache_invalidates_on_any_counter_change() {
        let sm = ShardedMetrics::new(2);
        sm.set_policy_label("fixed");
        sm.shard(0).record_request(1_000, 2, true);
        let first = sm.report_cached();
        // Unchanged counters: the exact same string comes back (the
        // elapsed-derived throughput field would differ in a rebuilt
        // report after enough wall time, so identity means "cached").
        assert_eq!(sm.report_cached(), first);
        // A per-shard record invalidates...
        sm.shard(1).record_request(2_000, 3, false);
        let second = sm.report_cached();
        assert!(second.contains("requests=2"), "{second}");
        // ...and so does a lock-free ops event (cache hit).
        sm.ops().cache_hits.fetch_add(1, Ordering::Relaxed);
        let third = sm.report_cached();
        assert!(third.contains("cache(hit/miss/evict)=1/0/0"), "{third}");
        assert_eq!(sm.report_cached(), third);
        // The cached report always matches a fresh snapshot's fields.
        assert!(third.contains("requests=2"), "{third}");
        assert!(third.contains(" policy=fixed"), "{third}");
    }

    #[test]
    fn report_and_json_read_the_same_values() {
        let sm = ShardedMetrics::new(2);
        sm.set_policy_label("adaptive");
        sm.shard(0).record_request(1_000, 2, true);
        sm.shard(1).record_request(2_000, 5, false);
        sm.ops().cache_hits.fetch_add(3, Ordering::Relaxed);
        let s = sm.snapshot();
        let j = s.to_json();
        assert_eq!(j.req("requests").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.req("policy").unwrap().as_str().unwrap(), "adaptive");
        assert_eq!(j.req("cache").unwrap().req("hits").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("shard_requests").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.req("exit_pos").unwrap().req("p99").unwrap().as_usize().unwrap(), 5);
        // The document round-trips through the crate's parser — it is
        // exactly what the HTTP admin plane serves from GET /stats.
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req("requests").unwrap().as_usize().unwrap(), 2);
        // A snapshot with no policy renders JSON null and drops the
        // `policy=` token from the text form.
        let bare = sm.shard_snapshots()[0].to_json();
        assert!(matches!(bare.req("policy").unwrap(), Json::Null));
        // The text report is formatted from the same document.
        let rep = s.report();
        assert!(rep.contains("requests=2"), "{rep}");
        assert!(rep.contains(" policy=adaptive"), "{rep}");
        assert!(rep.contains("cache(hit/miss/evict)=3/0/0"), "{rep}");
    }

    #[test]
    fn positions_beyond_cap_clamp() {
        let m = Metrics::new();
        m.record_request(1_000, 100_000, false);
        let s = m.snapshot();
        assert_eq!(s.stop_counts.len(), STOP_POS_CAP + 1);
        assert_eq!(s.stop_counts[STOP_POS_CAP], 1);
        assert_eq!(s.stop_percentile(50.0), STOP_POS_CAP);
    }
}
