//! Dynamic batcher: collects requests from the router queue into batches
//! bounded by `max_batch` size and `max_wait` latency (the standard
//! serving tradeoff — larger batches amortize per-call overhead on the
//! PJRT path, smaller ones bound tail latency).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait: Duration::from_millis(2) }
    }
}

/// Collect the next batch from `rx`. Blocks for the first item, then
/// drains until the batch is full or `max_wait` has elapsed since the
/// first item arrived. Returns `None` when the channel is closed and
/// empty (shutdown).
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = Vec::with_capacity(policy.max_batch.min(64));
    batch.push(first);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn returns_partial_batch_after_wait() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(5) };
        let start = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(start.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_on_closed_channel() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn blocks_for_first_then_batches_stragglers() {
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(7).unwrap();
            tx.send(8).unwrap();
        });
        let policy = BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(20) };
        let b = next_batch(&rx, policy).unwrap();
        assert!(!b.is_empty() && b[0] == 7);
        handle.join().unwrap();
    }
}
