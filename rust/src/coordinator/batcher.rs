//! Dynamic batcher: collects requests from the router into batches
//! bounded by `max_batch` size and `max_wait` latency (the standard
//! serving tradeoff — larger batches amortize per-call overhead on the
//! PJRT path, smaller ones bound tail latency).
//!
//! The queue is a mutex + condvar pair rather than an mpsc channel: the
//! consumer parks on the condvar with a deadline and is woken by every
//! push, so a batch flushes the moment it fills instead of waiting out a
//! fixed poll interval, and a burst that arrives together is drained in
//! one wakeup. Producer handles ([`BatchSender`]) are counted; when the
//! last one drops the queue closes and [`BatchQueue::next_batch`] drains
//! whatever is left before returning `None` (mpsc disconnect semantics).
//!
//! Queues may be **bounded** ([`batch_channel_with_cap`]): a full queue
//! makes [`BatchSender::try_send`] return [`TrySendError::Full`] so the
//! serving dispatcher can shed load with a protocol-level `BUSY` instead
//! of letting an overloaded shard's backlog (and every queued request's
//! latency) grow without bound. Blocking [`BatchSender::send`] parks on
//! a second condvar until the consumer drains space.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a [`BatchSender::try_send`] could not enqueue; carries the item
/// back to the caller.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// The queue is at capacity — shed load or retry later.
    Full(T),
    /// The queue was closed (consumer gone / shutdown).
    Closed(T),
}

/// Batching policy.
///
/// With `adaptive` off, every batch waits up to `max_wait` for
/// stragglers regardless of load. With it on, the flush deadline scales
/// with the queue depth observed when the first request of the batch is
/// admitted: an idle shard (nothing queued behind the first request)
/// flushes immediately — latency-greedy, the lone request never pays
/// `max_wait` — while a backlog of `k` requests waits
/// `max_wait · (k+1)/max_batch`, approaching the full `max_wait` (and a
/// full batch) as depth approaches `max_batch` — throughput-greedy under
/// load. Batch composition never changes per-example scores (each
/// example's sweep is independent), so the two policies are
/// bitwise-identical in what they answer and differ only in when.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Scale the flush deadline with instantaneous queue depth.
    pub adaptive: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 256, max_wait: Duration::from_millis(2), adaptive: false }
    }
}

impl BatchPolicy {
    /// Fixed-deadline policy (the PR 7 behavior).
    pub fn fixed(max_batch: usize, max_wait: Duration) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait, adaptive: false }
    }

    /// Depth-adaptive policy: same bounds, load-scaled deadline.
    pub fn adaptive(max_batch: usize, max_wait: Duration) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait, adaptive: true }
    }

    /// Policy name surfaced in `STATS` (`policy=fixed|adaptive`).
    pub fn label(&self) -> &'static str {
        if self.adaptive {
            "adaptive"
        } else {
            "fixed"
        }
    }

    /// Flush deadline for a batch whose first item found `depth` more
    /// items already queued behind it.
    fn effective_wait(&self, depth: usize) -> Duration {
        if !self.adaptive {
            return self.max_wait;
        }
        let max = self.max_batch.max(1);
        if depth == 0 || depth + 1 >= max {
            // Idle (flush now) or the backlog alone fills the batch
            // (waiting buys nothing).
            return Duration::ZERO;
        }
        self.max_wait.mul_f64((depth + 1) as f64 / max as f64)
    }
}

/// Why [`BatchQueue::next_batch_into`] handed back a batch — the
/// adaptive policy's observable decision, counted per shard in `STATS`
/// (`flush(idle/full/deadline)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// Nothing was queued behind the batch: flushed immediately without
    /// waiting (adaptive policies only).
    Idle,
    /// The batch reached `max_batch`.
    Full,
    /// The flush deadline expired with a partial batch.
    Deadline,
    /// The queue closed while the batch was filling.
    Closed,
}

struct QueueState<T> {
    items: VecDeque<T>,
    senders: usize,
    closed: bool,
    /// Maximum queued items; 0 = unbounded.
    cap: usize,
}

impl<T> QueueState<T> {
    fn full(&self) -> bool {
        self.cap > 0 && self.items.len() >= self.cap
    }
}

/// Condvar-backed request queue consumed in batches.
pub struct BatchQueue<T> {
    state: Mutex<QueueState<T>>,
    /// Consumers wait here for items (or close).
    cv: Condvar,
    /// Blocking producers wait here for space (bounded queues only).
    cv_space: Condvar,
}

/// Counted producer handle; cloning registers another producer, dropping
/// the last one closes the queue.
pub struct BatchSender<T> {
    q: Arc<BatchQueue<T>>,
}

/// Create a connected (sender, queue) pair — the batching analogue of
/// `mpsc::channel`. Unbounded.
pub fn batch_channel<T>() -> (BatchSender<T>, Arc<BatchQueue<T>>) {
    batch_channel_with_cap(0)
}

/// Bounded variant: at most `cap` items may be queued (0 = unbounded).
/// `try_send` on a full queue returns [`TrySendError::Full`]; blocking
/// `send` waits for the consumer to drain space.
pub fn batch_channel_with_cap<T>(cap: usize) -> (BatchSender<T>, Arc<BatchQueue<T>>) {
    let q = Arc::new(BatchQueue {
        state: Mutex::new(QueueState { items: VecDeque::new(), senders: 1, closed: false, cap }),
        cv: Condvar::new(),
        cv_space: Condvar::new(),
    });
    (BatchSender { q: q.clone() }, q)
}

impl<T> Clone for BatchSender<T> {
    fn clone(&self) -> Self {
        self.q.state.lock().unwrap().senders += 1;
        BatchSender { q: self.q.clone() }
    }
}

impl<T> Drop for BatchSender<T> {
    fn drop(&mut self) {
        let mut st = self.q.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            st.closed = true;
            drop(st);
            self.q.cv.notify_all();
            self.q.cv_space.notify_all();
        }
    }
}

impl<T> BatchSender<T> {
    /// Enqueue one item, waiting for space if the queue is bounded and
    /// full; `Err` returns the item if the queue was closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.q.state.lock().unwrap();
        while st.full() && !st.closed {
            st = self.q.cv_space.wait(st).unwrap();
        }
        if st.closed {
            return Err(item);
        }
        st.items.push_back(item);
        drop(st);
        self.q.cv.notify_one();
        Ok(())
    }

    /// Non-blocking enqueue: a full bounded queue rejects immediately
    /// (the dispatcher turns this into a `BUSY` response) instead of
    /// queueing unbounded latency.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.q.state.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.full() {
            return Err(TrySendError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.q.cv.notify_one();
        Ok(())
    }

}

impl<T> BatchQueue<T> {
    /// Force-close the queue (normally closing happens when the last
    /// sender drops); pending items remain drainable. Wakes blocked
    /// consumers *and* producers parked on a full bounded queue.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
        self.cv_space.notify_all();
    }

    /// Currently queued (not yet batched) item count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Collect the next batch. Blocks (no deadline) for the first item,
    /// then waits on the condvar until the batch is full or the policy's
    /// flush deadline has elapsed since the first item arrived — a full
    /// batch returns immediately on the push that filled it. Returns
    /// `None` when the queue is closed and empty (shutdown).
    pub fn next_batch(&self, policy: BatchPolicy) -> Option<Vec<T>> {
        let mut batch = Vec::with_capacity(policy.max_batch.max(1).min(64));
        self.next_batch_into(policy, &mut batch).map(|_| batch)
    }

    /// [`next_batch`](Self::next_batch) into a caller-owned buffer — the
    /// serving hot path's batch-arena recycling seam: the shard worker
    /// hands the same `Vec` back every iteration, so a warmed worker
    /// performs no per-batch allocation. `batch` is cleared first and
    /// holds the new batch on `Some`; the return value reports why the
    /// batch flushed.
    pub fn next_batch_into(&self, policy: BatchPolicy, batch: &mut Vec<T>) -> Option<FlushReason> {
        batch.clear();
        let max = policy.max_batch.max(1);
        let mut st = self.state.lock().unwrap();
        // Phase 1: block for the first item.
        loop {
            if let Some(first) = st.items.pop_front() {
                batch.push(first);
                break;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        // Queue depth behind the first item, observed at admission time:
        // the adaptive policy's instantaneous load signal.
        let wait = policy.effective_wait(st.items.len());
        let reason = if wait.is_zero() {
            // Latency-greedy: drain whatever is already queued and flush
            // without parking on the condvar at all.
            while batch.len() < max {
                match st.items.pop_front() {
                    Some(item) => batch.push(item),
                    None => break,
                }
            }
            if batch.len() >= max {
                FlushReason::Full
            } else {
                FlushReason::Idle
            }
        } else {
            // Phase 2: deadline-bounded fill.
            let deadline = Instant::now() + wait;
            loop {
                while batch.len() < max {
                    match st.items.pop_front() {
                        Some(item) => batch.push(item),
                        None => break,
                    }
                }
                if batch.len() >= max {
                    break FlushReason::Full;
                }
                if st.closed {
                    break FlushReason::Closed;
                }
                let now = Instant::now();
                if now >= deadline {
                    break FlushReason::Deadline;
                }
                let (guard, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if timeout.timed_out() {
                    // Grab anything that raced in with the timeout.
                    while batch.len() < max {
                        match st.items.pop_front() {
                            Some(item) => batch.push(item),
                            None => break,
                        }
                    }
                    break if batch.len() >= max {
                        FlushReason::Full
                    } else {
                        FlushReason::Deadline
                    };
                }
            }
        };
        // Space opened up: wake producers blocked on a bounded queue and
        // drain-waiters parked in `wait_empty` (which also rides the
        // space condvar — "space opened" and "possibly empty now" are
        // the same event from the consumer side).
        drop(st);
        self.cv_space.notify_all();
        Some(reason)
    }

    /// Block until the queue holds no queued items or `timeout` expires;
    /// returns whether the queue was observed empty. Items already handed
    /// to a consumer batch no longer count as queued — the serving DRAIN
    /// path relies on per-request replies for in-flight work and uses
    /// this only to wait out the backlog.
    pub fn wait_empty(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        while !st.items.is_empty() {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv_space.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, q) = batch_channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy::fixed(4, Duration::from_millis(50));
        assert_eq!(q.next_batch(policy).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(q.next_batch(policy).unwrap(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn returns_partial_batch_after_wait() {
        let (tx, q) = batch_channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy::fixed(100, Duration::from_millis(5));
        let start = Instant::now();
        assert_eq!(q.next_batch(policy).unwrap(), vec![1, 2]);
        assert!(start.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn none_on_closed_queue() {
        let (tx, q) = batch_channel::<u32>();
        drop(tx);
        assert!(q.next_batch(BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_pending_items_after_close() {
        let (tx, q) = batch_channel();
        tx.send(5).unwrap();
        drop(tx);
        assert_eq!(q.next_batch(BatchPolicy::default()).unwrap(), vec![5]);
        assert!(q.next_batch(BatchPolicy::default()).is_none());
    }

    #[test]
    fn blocks_for_first_then_batches_stragglers() {
        let (tx, q) = batch_channel();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(7).unwrap();
            tx.send(8).unwrap();
        });
        let policy = BatchPolicy::fixed(10, Duration::from_millis(20));
        let b = q.next_batch(policy).unwrap();
        assert!(!b.is_empty() && b[0] == 7);
        handle.join().unwrap();
    }

    #[test]
    fn full_batch_flushes_without_waiting_out_the_deadline() {
        // max_wait is far longer than the test budget: the only way this
        // returns quickly is the wake-on-fill path.
        let (tx, q) = batch_channel();
        let policy = BatchPolicy::fixed(4, Duration::from_secs(30));
        let handle = std::thread::spawn(move || {
            for i in 0..4 {
                std::thread::sleep(Duration::from_millis(2));
                tx.send(i).unwrap();
            }
            // Keep the sender alive well past the consumer's return so a
            // close-triggered flush can't mask a missing wakeup.
            std::thread::sleep(Duration::from_millis(200));
        });
        let start = Instant::now();
        let b = q.next_batch(policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "full batch waited on the deadline: {:?}",
            start.elapsed()
        );
        handle.join().unwrap();
    }

    #[test]
    fn send_after_close_returns_item() {
        let (tx, q) = batch_channel();
        q.close();
        assert_eq!(tx.send(9), Err(9));
        assert_eq!(tx.try_send(10), Err(TrySendError::Closed(10)));
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let (tx, q) = batch_channel_with_cap(2);
        assert_eq!(tx.try_send(1), Ok(()));
        assert_eq!(tx.try_send(2), Ok(()));
        assert_eq!(q.len(), 2);
        // At capacity: overload is shed, the item comes back.
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
        // Draining a batch opens space again.
        let policy = BatchPolicy::fixed(2, Duration::from_millis(1));
        assert_eq!(q.next_batch(policy).unwrap(), vec![1, 2]);
        assert!(q.is_empty());
        assert_eq!(tx.try_send(3), Ok(()));
    }

    #[test]
    fn blocking_send_waits_for_space_instead_of_overfilling() {
        let (tx, q) = batch_channel_with_cap(1);
        tx.send(1).unwrap();
        let handle = std::thread::spawn(move || {
            // Full queue: this send must park until the consumer drains.
            tx.send(2).unwrap();
        });
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.len(), 1, "bounded send overfilled the queue");
        let policy = BatchPolicy::fixed(1, Duration::from_millis(1));
        assert_eq!(q.next_batch(policy).unwrap(), vec![1]);
        handle.join().unwrap();
        assert_eq!(q.next_batch(policy).unwrap(), vec![2]);
    }

    #[test]
    fn close_wakes_consumer_blocked_on_empty_queue() {
        // A consumer parked in phase 1 (no deadline) must observe an
        // external close() and return None, not hang forever.
        let (tx, q) = batch_channel::<u32>();
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.next_batch(BatchPolicy::default()));
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
        drop(tx);
    }

    #[test]
    fn wait_empty_observes_a_consumer_draining_the_backlog() {
        let (tx, q) = batch_channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        // Not empty and nobody consuming: the bounded wait times out.
        assert!(!q.wait_empty(Duration::from_millis(5)));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            let policy = BatchPolicy::fixed(100, Duration::from_millis(1));
            q2.next_batch(policy)
        });
        // The drain waiter is woken by the consumer taking the batch —
        // even on an UNBOUNDED queue (the DRAIN path depends on this).
        assert!(q.wait_empty(Duration::from_secs(5)));
        assert!(q.is_empty());
        assert_eq!(consumer.join().unwrap().unwrap().len(), 5);
        // An already-empty queue reports success immediately.
        assert!(q.wait_empty(Duration::from_millis(1)));
        drop(tx);
    }

    #[test]
    fn adaptive_idle_shard_flushes_immediately() {
        // max_wait is far beyond the test budget: the only way a lone
        // item returns quickly is the adaptive idle fast path.
        let (tx, q) = batch_channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy::adaptive(100, Duration::from_secs(30));
        let mut batch = Vec::new();
        let start = Instant::now();
        let reason = q.next_batch_into(policy, &mut batch);
        assert_eq!(reason, Some(FlushReason::Idle));
        assert_eq!(batch, vec![42]);
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "idle flush waited on the deadline: {:?}",
            start.elapsed()
        );
        drop(tx);
    }

    #[test]
    fn adaptive_backlog_drains_without_waiting() {
        // A backlog that already fills the batch flushes as Full without
        // parking, even with a huge max_wait.
        let (tx, q) = batch_channel();
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy::adaptive(4, Duration::from_secs(30));
        let mut batch = Vec::new();
        let start = Instant::now();
        assert_eq!(q.next_batch_into(policy, &mut batch), Some(FlushReason::Full));
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(start.elapsed() < Duration::from_secs(5));
        drop(tx);
    }

    #[test]
    fn adaptive_scales_wait_with_depth() {
        // Depth 1 of max_batch 1000 scales a 10s max_wait down to 20ms:
        // returning at all inside the test budget proves the scaling.
        let (tx, q) = batch_channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let policy = BatchPolicy::adaptive(1000, Duration::from_secs(10));
        let mut batch = Vec::new();
        let start = Instant::now();
        assert_eq!(q.next_batch_into(policy, &mut batch), Some(FlushReason::Deadline));
        assert_eq!(batch, vec![1, 2]);
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "depth-scaled wait did not shrink: {:?}",
            start.elapsed()
        );
        drop(tx);
    }

    #[test]
    fn next_batch_into_recycles_the_buffer_and_reports_reasons() {
        let (tx, q) = batch_channel();
        for i in 0..6 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy::fixed(4, Duration::from_millis(5));
        let mut batch: Vec<i32> = Vec::new();
        assert_eq!(q.next_batch_into(policy, &mut batch), Some(FlushReason::Full));
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let cap = batch.capacity();
        assert_eq!(q.next_batch_into(policy, &mut batch), Some(FlushReason::Deadline));
        assert_eq!(batch, vec![4, 5]);
        assert_eq!(batch.capacity(), cap, "recycled buffer was reallocated");
        drop(tx);
        assert_eq!(q.next_batch_into(policy, &mut batch), None);
        assert!(batch.is_empty());
    }

    #[test]
    fn fixed_policy_label_and_adaptive_label() {
        assert_eq!(BatchPolicy::default().label(), "fixed");
        assert_eq!(BatchPolicy::adaptive(8, Duration::from_millis(1)).label(), "adaptive");
    }

    #[test]
    fn clone_keeps_queue_open() {
        let (tx, q) = batch_channel();
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(1).unwrap();
        assert_eq!(q.next_batch(BatchPolicy::default()).unwrap(), vec![1]);
        drop(tx2);
        assert!(q.next_batch(BatchPolicy::default()).is_none());
    }
}
