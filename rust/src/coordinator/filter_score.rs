//! Filter-and-Score pipeline (paper §3.1 "Filtering Candidates" and the
//! real-world experiments): reject the heavy-negative bulk quickly with
//! early-negative thresholds only; every example classified positive
//! receives its FULL ensemble score (later pipeline stages rank them), so
//! positives are always fully evaluated.

use crate::ensemble::Ensemble;
use crate::qwyc::FastClassifier;

/// Result of pushing one candidate through the pipeline.
#[derive(Clone, Copy, Debug)]
pub enum FilterOutcome {
    /// Rejected early after evaluating `models` base models.
    Rejected { models: u32 },
    /// Survived the filter: full score attached (all T models evaluated).
    Scored { score: f32 },
}

/// Aggregate pipeline statistics.
#[derive(Clone, Debug, Default)]
pub struct FilterStats {
    pub total: usize,
    pub rejected: usize,
    pub scored: usize,
    pub mean_models: f64,
}

/// Filter-and-score one batch of candidates. `fc` must be a neg-only
/// classifier (its ε⁺ are all +∞); this is validated on construction.
pub struct FilterPipeline {
    pub ensemble: Ensemble,
    pub fc: FastClassifier,
}

impl FilterPipeline {
    pub fn new(ensemble: Ensemble, fc: FastClassifier) -> Result<FilterPipeline, String> {
        fc.validate()?;
        if fc.eps_pos.iter().any(|&e| e != f32::INFINITY) {
            return Err("filter pipeline requires a neg-only classifier (eps_pos ≡ +inf)".into());
        }
        if ensemble.len() != fc.t() {
            return Err("ensemble/classifier size mismatch".into());
        }
        Ok(FilterPipeline { ensemble, fc })
    }

    pub fn run_one(&self, x: &[f32]) -> FilterOutcome {
        let r = self.fc.eval_single(&self.ensemble, x);
        if r.early {
            // Early exit in a neg-only classifier is always a rejection.
            debug_assert!(!r.positive);
            FilterOutcome::Rejected { models: r.models_evaluated as u32 }
        } else if r.positive {
            FilterOutcome::Scored { score: r.score }
        } else {
            // Fully evaluated and still negative: rejected, full cost.
            FilterOutcome::Rejected { models: r.models_evaluated as u32 }
        }
    }

    /// Run a dataset through the filter; returns (stats, scored
    /// candidates as (row index, full score), ready for ranking).
    pub fn run_batch(&self, x: &[f32], n: usize) -> (FilterStats, Vec<(usize, f32)>) {
        let d = self.ensemble.models.first().map(|_| x.len() / n.max(1)).unwrap_or(0);
        let mut stats = FilterStats { total: n, ..Default::default() };
        let mut scored = Vec::new();
        let mut models_sum = 0u64;
        for i in 0..n {
            match self.run_one(&x[i * d..(i + 1) * d]) {
                FilterOutcome::Rejected { models } => {
                    stats.rejected += 1;
                    models_sum += models as u64;
                }
                FilterOutcome::Scored { score } => {
                    stats.scored += 1;
                    models_sum += self.ensemble.len() as u64;
                    scored.push((i, score));
                }
            }
        }
        stats.mean_models = models_sum as f64 / n.max(1) as f64;
        // Rank survivors by score, best first (the downstream consumer).
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        (stats, scored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};
    use crate::lattice::{train_joint, LatticeParams};
    use crate::qwyc::{optimize_order, QwycConfig};

    fn setup() -> (crate::data::Dataset, FilterPipeline) {
        let (tr, te) = generate(Which::Rw1Like, 41, 0.005);
        let (ens, _) = train_joint(
            &tr,
            &LatticeParams { n_lattices: 5, dim: 6, steps: 150, ..Default::default() },
        );
        let sm = ens.score_matrix(&tr);
        let cfg = QwycConfig { alpha: 0.005, neg_only: true, ..Default::default() };
        let fc = optimize_order(&sm, &cfg);
        (te, FilterPipeline::new(ens, fc).unwrap())
    }

    #[test]
    fn rejects_bulk_and_scores_survivors_fully() {
        let (te, pipe) = setup();
        let (stats, scored) = pipe.run_batch(&te.x, te.n);
        assert_eq!(stats.total, te.n);
        assert_eq!(stats.rejected + stats.scored, te.n);
        // Heavy-negative prior ⇒ most candidates rejected.
        assert!(stats.rejected as f64 > 0.6 * te.n as f64, "rejected {}", stats.rejected);
        // Survivor scores must equal the full ensemble score.
        for &(i, score) in scored.iter().take(20) {
            let full = pipe.ensemble.eval_full(te.row(i));
            assert!((score - full).abs() < 1e-5);
            assert!(full >= pipe.ensemble.beta);
        }
        // Sorted descending.
        assert!(scored.windows(2).all(|w| w[0].1 >= w[1].1));
        // Early rejection means mean models < T.
        assert!(stats.mean_models < pipe.ensemble.len() as f64);
    }

    #[test]
    fn rejects_pos_threshold_classifiers() {
        let (_, pipe) = setup();
        let mut fc = pipe.fc.clone();
        fc.eps_pos[0] = 0.0;
        fc.eps_neg[0] = fc.eps_neg[0].min(0.0);
        assert!(FilterPipeline::new(pipe.ensemble.clone(), fc).is_err());
    }
}
