//! Filter-and-Score pipeline (paper §3.1 "Filtering Candidates" and the
//! real-world experiments): reject the heavy-negative bulk quickly with
//! early-negative thresholds only; every example classified positive
//! receives its FULL ensemble score (later pipeline stages rank them), so
//! positives are always fully evaluated.
//!
//! The pipeline owns a [`CompiledPlan`] and runs the crate-wide sweep
//! core (`qwyc::sweep`) — the same kernel the serving engine uses — so a
//! candidate filtered offline and a request served online take the same
//! code path and produce bitwise-identical outcomes.

use crate::error::QwycError;
use crate::plan::{CompiledPlan, QwycPlan};
use crate::util::pool::Pool;

/// Example-block width for the batched filter sweep (same cache logic as
/// the serving engine's block).
const FILTER_BLOCK: usize = 256;

/// Result of pushing one candidate through the pipeline.
#[derive(Clone, Copy, Debug)]
pub enum FilterOutcome {
    /// Rejected early after evaluating `models` base models.
    Rejected { models: u32 },
    /// Survived the filter: full score attached (all T models evaluated).
    Scored { score: f32 },
}

/// Aggregate pipeline statistics.
#[derive(Clone, Debug, Default)]
pub struct FilterStats {
    pub total: usize,
    pub rejected: usize,
    pub scored: usize,
    pub mean_models: f64,
    /// Mean evaluation cost per candidate (Σ c over the evaluated π
    /// prefix, from the plan's precomputed prefix-cost table; equals
    /// `mean_models` when all costs are 1).
    pub mean_cost: f64,
}

/// Filter-and-score a batch of candidates. The plan must be neg-only
/// (its ε⁺ are all +∞); this is validated on construction.
pub struct FilterPipeline {
    plan: CompiledPlan,
    pool: Pool,
}

impl FilterPipeline {
    /// Build from a plan artifact with the `QWYC_THREADS` pool.
    pub fn from_plan(plan: &QwycPlan) -> Result<FilterPipeline, QwycError> {
        FilterPipeline::from_plan_with_pool(plan, Pool::from_env())
    }

    pub fn from_plan_with_pool(plan: &QwycPlan, pool: Pool) -> Result<FilterPipeline, QwycError> {
        if plan.fc.eps_pos.iter().any(|&e| e != f32::INFINITY) {
            return Err(QwycError::Validate(
                "filter pipeline requires a neg-only classifier (eps_pos ≡ +inf)".into(),
            ));
        }
        Ok(FilterPipeline { plan: plan.compile()?, pool })
    }

    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    pub fn run_one(&self, x: &[f32]) -> FilterOutcome {
        let r = self.plan.eval_single(x);
        if r.early {
            // Early exit in a neg-only classifier is always a rejection.
            debug_assert!(!r.positive);
            FilterOutcome::Rejected { models: r.models_evaluated as u32 }
        } else if r.positive {
            FilterOutcome::Scored { score: r.score }
        } else {
            // Fully evaluated and still negative: rejected, full cost.
            FilterOutcome::Rejected { models: r.models_evaluated as u32 }
        }
    }

    /// Run a dataset through the filter; returns (stats, scored
    /// candidates as (row index, full score), ready for ranking). Rows
    /// may be wider than the plan's feature floor; the stride is taken
    /// from the buffer shape as before.
    pub fn run_batch(&self, x: &[f32], n: usize) -> (FilterStats, Vec<(usize, f32)>) {
        let d = if n == 0 { self.plan.n_features() } else { x.len() / n };
        let outcomes = self.plan.sweep_features(&x[..n * d], n, d, FILTER_BLOCK, &self.pool);
        let t = self.plan.t() as u64;
        let total_cost = self.plan.total_cost();
        let mut stats = FilterStats { total: n, ..Default::default() };
        let mut scored = Vec::new();
        let mut models_sum = 0u64;
        let mut cost_sum = 0f64;
        for (i, o) in outcomes.iter().enumerate() {
            if o.early {
                debug_assert!(!o.positive);
                stats.rejected += 1;
                models_sum += o.stop as u64;
                cost_sum += self.plan.prefix_cost(o.stop as usize);
            } else if o.positive {
                stats.scored += 1;
                models_sum += t;
                cost_sum += total_cost;
                scored.push((i, o.score));
            } else {
                stats.rejected += 1;
                models_sum += t;
                cost_sum += total_cost;
            }
        }
        stats.mean_models = models_sum as f64 / n.max(1) as f64;
        stats.mean_cost = cost_sum / n.max(1) as f64;
        // Rank survivors by score, best first (the downstream consumer).
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        (stats, scored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};
    use crate::ensemble::Ensemble;
    use crate::lattice::{train_joint, LatticeParams};
    use crate::qwyc::{optimize_order, FastClassifier, QwycConfig};

    fn setup() -> (crate::data::Dataset, Ensemble, FastClassifier, FilterPipeline) {
        let (tr, te) = generate(Which::Rw1Like, 41, 0.005);
        let (ens, _) = train_joint(
            &tr,
            &LatticeParams { n_lattices: 5, dim: 6, steps: 150, ..Default::default() },
        );
        let sm = ens.score_matrix(&tr);
        let cfg = QwycConfig { alpha: 0.005, neg_only: true, ..Default::default() };
        let fc = optimize_order(&sm, &cfg);
        let plan = QwycPlan::bundle(ens.clone(), fc.clone(), "filter-test", 0.005).unwrap();
        let pipe = FilterPipeline::from_plan(&plan).unwrap();
        (te, ens, fc, pipe)
    }

    #[test]
    fn rejects_bulk_and_scores_survivors_fully() {
        let (te, ens, _, pipe) = setup();
        let (stats, scored) = pipe.run_batch(&te.x, te.n);
        assert_eq!(stats.total, te.n);
        assert_eq!(stats.rejected + stats.scored, te.n);
        // Heavy-negative prior ⇒ most candidates rejected.
        assert!(stats.rejected as f64 > 0.6 * te.n as f64, "rejected {}", stats.rejected);
        // Survivor scores must equal the full ensemble score.
        for &(i, score) in scored.iter().take(20) {
            let full = ens.eval_full(te.row(i));
            assert!((score - full).abs() < 1e-5);
            assert!(full >= ens.beta);
        }
        // Sorted descending.
        assert!(scored.windows(2).all(|w| w[0].1 >= w[1].1));
        // Early rejection means mean models < T; unit costs make the
        // prefix-cost accounting collapse to the same number.
        assert!(stats.mean_models < ens.len() as f64);
        assert!((stats.mean_cost - stats.mean_models).abs() < 1e-9);
    }

    #[test]
    fn neg_only_invariant_matches_eval_single() {
        // The pre-refactor contract, now against the shared sweep:
        // rejected candidates stop exactly where eval_single stops, and
        // survivors carry the bit-exact full π-order score.
        let (te, ens, fc, pipe) = setup();
        let n = te.n.min(500);
        let (_, scored) = pipe.run_batch(&te.x[..n * te.d], n);
        let survivors: std::collections::BTreeMap<usize, u32> =
            scored.iter().map(|&(i, s)| (i, s.to_bits())).collect();
        for i in 0..n {
            let want = fc.eval_single(&ens, te.row(i));
            match pipe.run_one(te.row(i)) {
                FilterOutcome::Rejected { models } => {
                    assert!(!want.positive, "example {i}");
                    assert_eq!(models as usize, want.models_evaluated, "example {i}");
                    assert!(!survivors.contains_key(&i), "example {i}");
                }
                FilterOutcome::Scored { score } => {
                    assert!(want.positive && !want.early, "example {i}");
                    assert_eq!(want.models_evaluated, ens.len(), "example {i}");
                    assert_eq!(score.to_bits(), want.score.to_bits(), "example {i}");
                    assert_eq!(survivors.get(&i), Some(&want.score.to_bits()), "example {i}");
                }
            }
        }
    }

    #[test]
    fn batch_is_bit_identical_across_thread_counts() {
        let (te, ens, fc, _) = setup();
        let plan = QwycPlan::bundle(ens, fc, "filter-threads", 0.005).unwrap();
        let p1 = FilterPipeline::from_plan_with_pool(&plan, Pool::new(1)).unwrap();
        let p4 = FilterPipeline::from_plan_with_pool(&plan, Pool::new(4)).unwrap();
        let (s1, sc1) = p1.run_batch(&te.x, te.n);
        let (s4, sc4) = p4.run_batch(&te.x, te.n);
        assert_eq!(s1.rejected, s4.rejected);
        assert_eq!(s1.scored, s4.scored);
        assert_eq!(s1.mean_models.to_bits(), s4.mean_models.to_bits());
        assert_eq!(s1.mean_cost.to_bits(), s4.mean_cost.to_bits());
        let bits = |v: &[(usize, f32)]| {
            v.iter().map(|&(i, s)| (i, s.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(bits(&sc1), bits(&sc4));
    }

    #[test]
    fn rejects_pos_threshold_classifiers() {
        let (_, ens, fc, _) = setup();
        let mut fc = fc;
        fc.eps_pos[0] = 0.0;
        fc.eps_neg[0] = fc.eps_neg[0].min(0.0);
        let plan = QwycPlan::bundle(ens.clone(), fc.clone(), "bad", 0.0).unwrap();
        let err = FilterPipeline::from_plan(&plan).unwrap_err();
        assert_eq!(err.stage(), "validate", "{err}");
    }
}
