//! L3 serving coordinator: request routing, dynamic batching, early-exit
//! scheduling, metrics, and the TCP front-end. The QWYC fast classifier is
//! the scheduling policy: a batch walks the optimized order and examples
//! retire the moment their running score clears a threshold.

pub mod batcher;
pub mod filter_score;
pub mod metrics;
pub mod server;

pub use batcher::{batch_channel, BatchPolicy, BatchQueue, BatchSender};
pub use filter_score::{FilterOutcome, FilterPipeline, FilterStats};
pub use metrics::{Metrics, Snapshot};
pub use server::{Client, EvalResponse, Server};
