//! L3 serving coordinator: request routing across engine shards, dynamic
//! batching with bounded admission, early-exit scheduling, per-shard
//! metrics, and the TCP front-end. The QWYC fast classifier is the
//! scheduling policy: a batch walks the optimized order and examples
//! retire the moment their running score clears a threshold.

pub mod batcher;
pub mod cache;
pub mod filter_score;
pub mod metrics;
pub mod server;

pub use batcher::{
    batch_channel, batch_channel_with_cap, BatchPolicy, BatchQueue, BatchSender, FlushReason,
    TrySendError,
};
pub use cache::ResponseCache;
pub use filter_score::{FilterOutcome, FilterPipeline, FilterStats};
pub use metrics::{Metrics, OpsCounters, OpsSnapshot, ShardedMetrics, Snapshot};
pub use server::{
    format_ok_reply, parse_eval, Client, EvalParseError, EvalResponse, Reply, Server,
    ServerConfig, DEFAULT_QUEUE_CAP, MAX_LINE_BYTES,
};
