//! TCP serving front-end: line protocol, connection handling, and the
//! worker loop that owns the engine (for the native backend, the engine
//! is a [`CompiledPlan`](crate::plan::CompiledPlan) compiled once inside
//! the worker thread — see `NativeEngine::from_plan`). Requests flow
//!
//!   conn thread → BatchQueue (condvar) → batcher → engine.classify_batch
//!     → per-request response channel → conn thread → client
//!
//! Responses stream back as soon as their example is decided — an
//! early-exit example does not wait for the rest of its batch's full
//! evaluation path (no tokio offline; plain threads, a condvar batch
//! queue on the request path, and mpsc response channels — DESIGN.md §4).
//!
//! Protocol (one line per message):
//!   client → server:  EVAL <id> <f1>,<f2>,...      classify one example
//!                     STATS                         metrics snapshot
//!                     QUIT                          close connection
//!   server → client:  OK <id> <pos|neg> <score> <models> <latency_us>
//!                     STATS <report...>
//!                     ERR <message>

use super::batcher::{batch_channel, BatchPolicy, BatchSender};
use super::metrics::Metrics;
use crate::runtime::engine::Engine;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::Instant;

/// One in-flight request.
struct Request {
    id: u64,
    features: Vec<f32>,
    enqueued: Instant,
    respond: Sender<String>,
}

/// Server handle: address, shutdown flag, worker/acceptor joins.
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// Live connection streams; shut down on stop so connection threads
    /// (which hold request-channel senders) exit and the worker drains.
    conns: Arc<std::sync::Mutex<Vec<TcpStream>>>,
}

impl Server {
    /// Start serving on `bind_addr` (e.g. "127.0.0.1:0"). The engine is
    /// built by `engine_factory` *inside* the worker thread — PJRT
    /// handles are not `Send`, so the engine must be born where it lives.
    pub fn start<F>(
        bind_addr: &str,
        engine_factory: F,
        policy: BatchPolicy,
    ) -> std::io::Result<Server>
    where
        F: FnOnce() -> Box<dyn Engine> + Send + 'static,
    {
        let listener = TcpListener::bind(bind_addr)?;
        let addr = listener.local_addr()?;
        let metrics = Arc::new(Metrics::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, queue) = batch_channel::<Request>();

        // Worker: owns the engine, consumes batches.
        let worker_metrics = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut engine = engine_factory();
            let d = engine.n_features();
            let mut xbuf: Vec<f32> = Vec::new();
            while let Some(batch) = queue.next_batch(policy) {
                worker_metrics.record_batch(batch.len());
                xbuf.clear();
                let mut ok = true;
                for r in &batch {
                    if r.features.len() != d {
                        ok = false;
                    }
                    xbuf.extend_from_slice(&r.features);
                }
                if !ok {
                    for r in &batch {
                        let _ = r.respond.send(format!(
                            "ERR request {} has wrong feature count (want {d})",
                            r.id
                        ));
                    }
                    continue;
                }
                match engine.classify_batch(&xbuf, batch.len()) {
                    Ok(outcomes) => {
                        for (r, o) in batch.iter().zip(outcomes.iter()) {
                            let lat = r.enqueued.elapsed().as_nanos() as u64;
                            worker_metrics.record_request(lat, o.models_evaluated, o.early);
                            let _ = r.respond.send(format!(
                                "OK {} {} {:.6} {} {}",
                                r.id,
                                if o.positive { "pos" } else { "neg" },
                                o.score,
                                o.models_evaluated,
                                lat / 1_000
                            ));
                        }
                    }
                    Err(e) => {
                        for r in &batch {
                            let _ = r.respond.send(format!("ERR engine: {e}"));
                        }
                    }
                }
            }
        });

        // Acceptor: one thread per connection (serving fan-in is small;
        // the engine worker is the throughput bottleneck by design).
        let conns: Arc<std::sync::Mutex<Vec<TcpStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let acc_shutdown = shutdown.clone();
        let acc_metrics = metrics.clone();
        let acc_conns = conns.clone();
        let acceptor = std::thread::spawn(move || {
            listener.set_nonblocking(true).ok();
            loop {
                if acc_shutdown.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Ok(dup) = stream.try_clone() {
                            acc_conns.lock().unwrap().push(dup);
                        }
                        let tx = tx.clone();
                        let m = acc_metrics.clone();
                        std::thread::spawn(move || handle_conn(stream, tx, m));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            // tx drops here → once connection threads exit too, the worker
            // channel disconnects and the worker drains.
        });

        Ok(Server {
            addr,
            metrics,
            shutdown,
            acceptor: Some(acceptor),
            worker: Some(worker),
            conns,
        })
    }

    /// Signal shutdown, sever open connections, and join threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Force connection reader loops to end so their request senders
        // drop; otherwise the worker would wait on clients that outlive
        // the server handle.
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn handle_conn(stream: TcpStream, tx: BatchSender<Request>, metrics: Arc<Metrics>) {
    let peer_write = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = std::io::BufWriter::new(peer_write);
    let reader = BufReader::new(stream);
    // Response pump: a dedicated channel per connection keeps ordering
    // per-client while letting the worker answer out of batch order.
    let (resp_tx, resp_rx) = mpsc::channel::<String>();
    let pump = std::thread::spawn(move || {
        let mut w = writer;
        while let Ok(line) = resp_rx.recv() {
            if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                break;
            }
            let _ = w.flush();
        }
    });

    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.splitn(3, ' ');
        match parts.next() {
            Some("EVAL") => {
                let id = parts.next().and_then(|s| s.parse::<u64>().ok());
                let feats: Option<Vec<f32>> = parts
                    .next()
                    .map(|s| {
                        s.split(',')
                            .map(|t| t.trim().parse::<f32>())
                            .collect::<Result<_, _>>()
                    })
                    .transpose()
                    .ok()
                    .flatten();
                match (id, feats) {
                    (Some(id), Some(features)) => {
                        let req = Request {
                            id,
                            features,
                            enqueued: Instant::now(),
                            respond: resp_tx.clone(),
                        };
                        if tx.send(req).is_err() {
                            let _ = resp_tx.send("ERR server shutting down".into());
                        }
                    }
                    _ => {
                        let _ = resp_tx.send("ERR malformed EVAL".into());
                    }
                }
            }
            Some("STATS") => {
                let _ = resp_tx.send(format!("STATS {}", metrics.snapshot().report()));
            }
            Some("QUIT") => break,
            _ => {
                let _ = resp_tx.send("ERR unknown command".into());
            }
        }
    }
    drop(resp_tx);
    let _ = pump.join();
}

/// Minimal blocking client for tests/examples/load generators.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// Parsed server response to an EVAL.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    pub id: u64,
    pub positive: bool,
    pub score: f32,
    pub models: u32,
    pub latency_us: u64,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 0 })
    }

    /// Send one EVAL (does not wait for the response).
    pub fn send_eval(&mut self, features: &[f32]) -> std::io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let feats: Vec<String> = features.iter().map(|v| format!("{v}")).collect();
        writeln!(self.writer, "EVAL {id} {}", feats.join(","))?;
        Ok(id)
    }

    /// Read one response line (blocking).
    pub fn read_response(&mut self) -> std::io::Result<EvalResponse> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse_eval_response(line.trim())
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, line))
    }

    /// Convenience: send and wait.
    pub fn eval(&mut self, features: &[f32]) -> std::io::Result<EvalResponse> {
        self.send_eval(features)?;
        self.read_response()
    }

    pub fn stats(&mut self) -> std::io::Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

fn parse_eval_response(line: &str) -> Option<EvalResponse> {
    let mut p = line.split(' ');
    if p.next()? != "OK" {
        return None;
    }
    Some(EvalResponse {
        id: p.next()?.parse().ok()?,
        positive: p.next()? == "pos",
        score: p.next()?.parse().ok()?,
        models: p.next()?.parse().ok()?,
        latency_us: p.next()?.parse().ok()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_response_roundtrip() {
        let r = parse_eval_response("OK 42 pos 1.250000 7 133").unwrap();
        assert_eq!(r.id, 42);
        assert!(r.positive);
        assert_eq!(r.models, 7);
        assert_eq!(r.latency_us, 133);
        assert!(parse_eval_response("ERR nope").is_none());
    }
}
