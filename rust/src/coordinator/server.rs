//! TCP serving front-end: line protocol, connection handling, and the
//! supervised sharded engine runtime. The plan is compiled ONCE into a
//! shared `Arc<CompiledPlan>`; `--shards N` engine workers each own an
//! engine handle and drain their own bounded [`BatchQueue`]. Requests flow
//!
//!   conn thread → dispatcher (least-queued shard, try_send)
//!     → per-shard BatchQueue (condvar) → shard worker
//!     → engine.classify_batch → per-request response channel
//!     → conn thread → client
//!
//! Responses stream back as soon as their example is decided; each
//! example's early-exit sweep is independent, so responses are
//! bit-identical at any shard count (rust/tests/serving_e2e.rs).
//!
//! Failure semantics (rust/tests/chaos_serving.rs):
//! - **Supervision**: every batch is processed under `catch_unwind`. A
//!   panicking shard answers each not-yet-answered request in the
//!   poisoned batch with a terminal `ERR <id> shard_panic: <why>` (never
//!   a hang, never a duplicate reply — per-request progress flags
//!   survive the unwind), then the supervisor rebuilds the engine with
//!   capped exponential backoff and keeps draining the same queue.
//! - **Deadlines**: `ServerConfig::default_deadline` and the per-request
//!   `DEADLINE_MS=` token bound queueing latency; requests whose
//!   deadline has expired are shed with `TIMEOUT <id>` at the batch
//!   boundary, before any engine work.
//! - **Overload**: a full shard queue sheds with `BUSY <id>` instead of
//!   queueing unbounded latency.
//! - **Validated reload**: `RELOAD <path>` compiles the candidate and
//!   canary-scores it against a probe set captured from the live plan
//!   ([`ProbeSet`]); any mismatch keeps last-known-good and replies
//!   `RELOAD_REJECTED <stage>: <why>`. Accepted swaps land at batch
//!   boundaries via a [`PlanSlot`].
//! - **Drain**: `DRAIN` stops admission (subsequent EVALs get
//!   `ERR <id> draining`) and waits for the shard queues to empty.
//!
//! (No tokio offline; plain threads — DESIGN.md §4.)
//!
//! Data-plane performance (rust/tests/alloc_free.rs, BENCH_8.json):
//! - **Zero-allocation hot path**: per-connection buffer pools recycle
//!   feature vectors and reply strings through the worker/pump loop,
//!   the line reader fills a reusable byte buffer, and each shard
//!   worker classifies into persistent scratch — a steady-state `EVAL`
//!   round trip performs no heap allocation after warmup.
//! - **Adaptive batching**: [`BatchPolicy::adaptive`] scales each flush
//!   deadline with instantaneous queue depth (idle → flush at once,
//!   backlogged → fill toward `max_batch`); the decision mix surfaces
//!   as `flush(idle/full/deadline)` and `policy=` in `STATS`.
//! - **Response cache**: with `ServerConfig::cache_bytes > 0` each
//!   shard keeps a [`ResponseCache`] keyed on (plan generation, feature
//!   bit-pattern); a hit replays the bitwise-identical outcome without
//!   touching the engine, and a `RELOAD` invalidates implicitly because
//!   the generation is part of every key.
//!
//! Protocol (one line per message, lines capped at [`MAX_LINE_BYTES`]):
//!   client → server:  EVAL <id> [DEADLINE_MS=<d>] <f1>,<f2>,...
//!                     STATS                         metrics snapshot
//!                     RELOAD <path>                 validated hot-swap
//!                     DRAIN                         stop admission, drain
//!                     QUIT                          close connection
//!   server → client:  OK <id> <pos|neg> <score> <models> <latency_us>
//!                     BUSY <id>                     shard queues full
//!                     TIMEOUT <id>                  deadline expired queued
//!                     STATS <report...>
//!                     RELOADED <name> gen=<g> T=<t>
//!                     RELOAD_REJECTED <stage>: <why>
//!                     DRAINED queued=0
//!                     ERR <id> <message>            (`-` id when the
//!                                                   request id is unknown)

use super::batcher::{
    batch_channel_with_cap, BatchPolicy, BatchQueue, BatchSender, TrySendError,
};
use super::cache::ResponseCache;
use super::metrics::{Metrics, OpsCounters, ShardedMetrics};
use crate::error::QwycError;
use crate::plan::{CompiledPlan, PlanArtifact, PlanMeta, PlanSlot, ProbeSet, DEFAULT_PROBES};
use crate::runtime::engine::{Engine, NativeEngine, Outcome};
use crate::util::failpoints;
use crate::util::lineio::{read_line_capped, LineRead};
use crate::util::pool::{threads_from_env, Pool};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default bound on each shard's request queue (`--queue-cap`).
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// Hard cap on one protocol line; longer lines get a clean
/// `ERR - line too long` and the connection keeps working.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Supervisor restart backoff: base doubles per consecutive panic,
/// capped. Resets after any clean batch.
const BACKOFF_BASE_MS: u64 = 10;
const BACKOFF_CAP_MS: u64 = 1_000;

/// Seed for the reload canary's probe rows — fixed so a rejection
/// reproduces from the reply alone.
const CANARY_SEED: u64 = 0xca9a41;

/// Upper bound on how long a `DRAIN` command (either protocol) waits
/// for the shard backlogs to empty before reporting failure.
pub(crate) const DRAIN_TIMEOUT: Duration = Duration::from_secs(10);

/// Seed for each shard's response-cache hash; xor'd with the shard
/// index so shards don't share collision patterns.
const CACHE_SEED: u64 = 0x9e37_79b9_7f4a_7c15;

/// Bound on pooled buffers per connection; beyond this, returned
/// buffers are dropped (a burst shouldn't pin its high-water memory).
const BUF_POOL_CAP: usize = 256;

/// Per-connection buffer recycler closing the request path's allocation
/// loop: feature vectors travel conn thread → shard worker → back, and
/// reply strings travel shard worker → pump thread → back. After warmup
/// every buffer on a steady-state EVAL round trip comes from here
/// instead of the allocator (rust/tests/alloc_free.rs pins the
/// component functions). The HTTP front-end (`crate::http`) keeps one
/// per connection too, so its warmed data path recycles through the
/// same mechanism.
pub(crate) struct BufPool {
    strings: std::sync::Mutex<Vec<String>>,
    feats: std::sync::Mutex<Vec<Vec<f32>>>,
}

impl BufPool {
    pub(crate) fn new() -> BufPool {
        BufPool {
            strings: std::sync::Mutex::new(Vec::new()),
            feats: std::sync::Mutex::new(Vec::new()),
        }
    }

    pub(crate) fn get_string(&self) -> String {
        self.strings.lock().unwrap().pop().unwrap_or_default()
    }

    pub(crate) fn put_string(&self, mut s: String) {
        s.clear();
        let mut pool = self.strings.lock().unwrap();
        if pool.len() < BUF_POOL_CAP {
            pool.push(s);
        }
    }

    pub(crate) fn get_feats(&self) -> Vec<f32> {
        self.feats.lock().unwrap().pop().unwrap_or_default()
    }

    pub(crate) fn put_feats(&self, mut v: Vec<f32>) {
        v.clear();
        let mut pool = self.feats.lock().unwrap();
        if pool.len() < BUF_POOL_CAP {
            pool.push(v);
        }
    }
}

/// One in-flight request. Both front-ends (line protocol and HTTP)
/// build these; the shard workers never know which surface a request
/// came from.
pub(crate) struct Request {
    pub(crate) id: u64,
    pub(crate) features: Vec<f32>,
    pub(crate) enqueued: Instant,
    /// Shed with `TIMEOUT` if still queued past this instant.
    pub(crate) deadline: Option<Instant>,
    pub(crate) respond: Sender<String>,
    /// The owning connection's buffer pool; `features` and every reply
    /// `String` cycle back through it instead of being reallocated.
    pub(crate) pool: Arc<BufPool>,
}

/// Return a finished request's feature buffer to its connection's pool.
pub(crate) fn recycle(r: Request) {
    let Request { features, pool, .. } = r;
    pool.put_feats(features);
}

/// Build a reply in a pooled string and send it; the connection's pump
/// thread returns the string to the pool after writing it out.
fn send_pooled(r: &Request, build: impl FnOnce(&mut String)) {
    let mut s = r.pool.get_string();
    build(&mut s);
    let _ = r.respond.send(s);
}

/// Runtime shape of the serving coordinator.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Engine worker shards, each with its own queue (`--shards`).
    pub shards: usize,
    /// Per-shard queue bound; 0 = unbounded (`--queue-cap`).
    pub queue_cap: usize,
    /// Dynamic-batching policy applied by every shard.
    pub policy: BatchPolicy,
    /// Deadline applied to requests that don't carry their own
    /// `DEADLINE_MS=` token; `None` = no default deadline.
    pub default_deadline: Option<Duration>,
    /// Per-shard response-cache budget in bytes (`--cache-bytes`);
    /// 0 disables the cache.
    pub cache_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 1,
            queue_cap: DEFAULT_QUEUE_CAP,
            policy: BatchPolicy::default(),
            default_deadline: None,
            cache_bytes: 0,
        }
    }
}

/// Single-shard config with the given batching policy (the pre-sharding
/// call shape, kept so `Server::start(addr, factory, policy)` reads as
/// before).
impl From<BatchPolicy> for ServerConfig {
    fn from(policy: BatchPolicy) -> ServerConfig {
        ServerConfig { policy, ..ServerConfig::default() }
    }
}

/// Routes each request to the least-queued shard; a full shard queue
/// surfaces as BUSY instead of blocking the connection thread, and a
/// draining server refuses admission outright. Shared verbatim by the
/// line protocol and the HTTP front-end — one admission policy, two
/// wire formats.
pub(crate) struct Dispatcher {
    shards: Vec<(BatchSender<Request>, Arc<BatchQueue<Request>>)>,
    draining: AtomicBool,
}

pub(crate) enum RouteError {
    Busy(Request),
    Draining(Request),
    Closed(Request),
}

impl Dispatcher {
    pub(crate) fn route(&self, req: Request) -> Result<(), RouteError> {
        if self.draining.load(Ordering::Relaxed) {
            return Err(RouteError::Draining(req));
        }
        // Least-queued shard (ties → lowest index). Queue lengths move
        // under us, but any stale choice only costs balance, never
        // correctness — per-example sweeps are shard-independent.
        let mut best = 0usize;
        let mut best_len = usize::MAX;
        for (i, (_, q)) in self.shards.iter().enumerate() {
            let len = q.len();
            if len < best_len {
                best = i;
                best_len = len;
            }
        }
        match self.shards[best].0.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => Err(RouteError::Busy(r)),
            Err(TrySendError::Closed(r)) => Err(RouteError::Closed(r)),
        }
    }

    /// Stop admission, then wait (bounded) for every shard backlog to
    /// empty. Returns the number of requests still queued at timeout
    /// (0 = fully drained). In-flight batches answer through their own
    /// response channels as usual.
    pub(crate) fn drain(&self, timeout: Duration) -> usize {
        self.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + timeout;
        for (_, q) in &self.shards {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            q.wait_empty(deadline - now);
        }
        self.shards.iter().map(|(_, q)| q.len()).sum()
    }

    /// Whether admission has been stopped by a drain (either protocol's
    /// health surface reports this).
    pub(crate) fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    /// Number of engine shards behind this dispatcher.
    pub(crate) fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

/// Name + provenance of the plan currently in the slot, kept alongside
/// it so `GET /plan` can re-encode and describe the LIVE generation
/// (the slot itself only holds the compiled form). Updated atomically
/// with every accepted reload.
#[derive(Clone)]
pub(crate) struct PlanIdentity {
    pub(crate) meta: PlanMeta,
    pub(crate) ensemble_name: String,
}

/// Everything a connection thread needs, bundled so the acceptors (line
/// protocol and HTTP share one instance over one shard set) clone one
/// Arc per connection.
pub(crate) struct ConnShared {
    pub(crate) dispatch: Dispatcher,
    pub(crate) metrics: Arc<ShardedMetrics>,
    pub(crate) plan_slot: Option<Arc<PlanSlot>>,
    /// Present exactly when `plan_slot` is (native serving).
    pub(crate) identity: Option<std::sync::Mutex<PlanIdentity>>,
    pub(crate) default_deadline: Option<Duration>,
}

/// Server handle: address, shutdown flag, worker/acceptor joins.
pub struct Server {
    pub addr: std::net::SocketAddr,
    /// HTTP listener address once [`Server::attach_http`] has run.
    pub http_addr: Option<std::net::SocketAddr>,
    /// Per-shard metrics; `metrics.snapshot()` aggregates all shards.
    pub metrics: Arc<ShardedMetrics>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    http_acceptor: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Live connection streams; shut down on stop so connection threads
    /// (which hold request-channel senders) exit and the workers drain.
    conns: Arc<std::sync::Mutex<Vec<TcpStream>>>,
    /// Shared dispatch context, kept so a second front-end can be
    /// attached after start. `stop()` drops it before joining workers —
    /// its dispatcher senders would otherwise keep the shard queues
    /// open forever.
    ctx: Option<Arc<ConnShared>>,
}

impl Server {
    /// Start serving on `bind_addr` (e.g. "127.0.0.1:0") with engines
    /// built by `engine_factory(shard)` *inside* each shard's worker
    /// thread — PJRT handles are not `Send`, so an engine must be born
    /// where it lives. This generic entry point has no plan slot, so
    /// `RELOAD` is refused; native serving should prefer
    /// [`Server::start_with_plan`].
    pub fn start<F, C>(bind_addr: &str, engine_factory: F, config: C) -> std::io::Result<Server>
    where
        F: Fn(usize) -> Box<dyn Engine> + Send + Sync + 'static,
        C: Into<ServerConfig>,
    {
        Server::start_inner(bind_addr, Arc::new(engine_factory), config.into(), None, None)
    }

    /// Native sharded serving from one shared compiled plan: every shard
    /// gets an `Arc` handle to the SAME artifact (compile once — the
    /// plan is immutable and `Send + Sync` by construction) plus a
    /// private worker pool splitting `QWYC_THREADS` across shards.
    /// Enables `RELOAD <path>` validated hot-swap through a [`PlanSlot`].
    ///
    /// The plan identity reported by `GET /plan` is synthesized (the
    /// bare compiled form carries no provenance); serving from a loaded
    /// artifact should prefer [`Server::start_with_artifact`], which
    /// keeps the artifact's real name and metadata.
    pub fn start_with_plan<C>(
        bind_addr: &str,
        plan: Arc<CompiledPlan>,
        config: C,
    ) -> std::io::Result<Server>
    where
        C: Into<ServerConfig>,
    {
        let identity = PlanIdentity {
            meta: PlanMeta {
                name: "live-plan".to_string(),
                alpha: 0.0,
                neg_only: false,
                source: String::new(),
                created_by: "qwyc-serve".to_string(),
                n_features: plan.n_features(),
            },
            ensemble_name: "live".to_string(),
        };
        Server::start_native(bind_addr, plan, config.into(), identity)
    }

    /// Native sharded serving from a loaded [`PlanArtifact`], keeping
    /// its metadata as the live plan identity so the admin surface
    /// (`GET /plan`) describes what is actually deployed.
    pub fn start_with_artifact<C>(
        bind_addr: &str,
        artifact: &PlanArtifact,
        config: C,
    ) -> std::io::Result<Server>
    where
        C: Into<ServerConfig>,
    {
        let identity = PlanIdentity {
            meta: artifact.meta().clone(),
            ensemble_name: artifact.ensemble_name().to_string(),
        };
        Server::start_native(bind_addr, artifact.compiled(), config.into(), identity)
    }

    fn start_native(
        bind_addr: &str,
        plan: Arc<CompiledPlan>,
        config: ServerConfig,
        identity: PlanIdentity,
    ) -> std::io::Result<Server> {
        let slot = Arc::new(PlanSlot::new(plan));
        let per_shard_threads = (threads_from_env() / config.shards.max(1)).max(1);
        let factory_slot = slot.clone();
        let factory = move |_shard: usize| -> Box<dyn Engine> {
            Box::new(NativeEngine::from_shared(
                factory_slot.load(),
                Pool::new(per_shard_threads),
            ))
        };
        Server::start_inner(bind_addr, Arc::new(factory), config, Some(slot), Some(identity))
    }

    fn start_inner(
        bind_addr: &str,
        factory: Arc<dyn Fn(usize) -> Box<dyn Engine> + Send + Sync>,
        config: ServerConfig,
        plan_slot: Option<Arc<PlanSlot>>,
        identity: Option<PlanIdentity>,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(bind_addr)?;
        let addr = listener.local_addr()?;
        let n_shards = config.shards.max(1);
        let metrics = Arc::new(ShardedMetrics::new(n_shards));
        metrics.set_policy_label(config.policy.label());
        let shutdown = Arc::new(AtomicBool::new(false));

        // Shard workers: each owns an engine and drains its own queue
        // under supervision (see `supervise_shard`).
        let mut workers = Vec::with_capacity(n_shards);
        let mut shard_channels = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (tx, queue) = batch_channel_with_cap::<Request>(config.queue_cap);
            shard_channels.push((tx, queue.clone()));
            let rt = ShardRuntime {
                shard,
                queue,
                factory: factory.clone(),
                slot: plan_slot.clone(),
                m: metrics.shard(shard),
                ops: metrics.ops().clone(),
                policy: config.policy,
                cache_bytes: config.cache_bytes,
            };
            workers.push(std::thread::spawn(move || supervise_shard(rt)));
        }
        let ctx = Arc::new(ConnShared {
            dispatch: Dispatcher { shards: shard_channels, draining: AtomicBool::new(false) },
            metrics: metrics.clone(),
            plan_slot,
            identity: identity.map(std::sync::Mutex::new),
            default_deadline: config.default_deadline,
        });

        // Acceptor: one thread per connection (serving fan-in is small;
        // the shard workers are the throughput engine).
        let conns: Arc<std::sync::Mutex<Vec<TcpStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let acc_shutdown = shutdown.clone();
        let acc_conns = conns.clone();
        let acc_ctx = ctx.clone();
        let acceptor = std::thread::spawn(move || {
            listener.set_nonblocking(true).ok();
            loop {
                if acc_shutdown.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Ok(dup) = stream.try_clone() {
                            acc_conns.lock().unwrap().push(dup);
                        }
                        let ctx = acc_ctx.clone();
                        std::thread::spawn(move || handle_conn(stream, ctx));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
        });

        Ok(Server {
            addr,
            http_addr: None,
            metrics,
            shutdown,
            acceptor: Some(acceptor),
            http_acceptor: None,
            workers,
            conns,
            ctx: Some(ctx),
        })
    }

    /// Bind a second listener serving the HTTP/1.1 front-end
    /// (`crate::http`) over the SAME dispatcher, shard set, plan slot,
    /// and metrics as the line protocol — dual-protocol serving, one
    /// runtime. Returns the bound address (use port 0 to let the OS
    /// pick). Connections accepted here are severed by [`Server::stop`]
    /// exactly like line-protocol ones.
    pub fn attach_http(&mut self, bind_addr: &str) -> std::io::Result<std::net::SocketAddr> {
        let ctx = self.ctx.as_ref().expect("attach_http on a running server").clone();
        let listener = TcpListener::bind(bind_addr)?;
        let addr = listener.local_addr()?;
        let state = Arc::new(crate::http::HttpState::new(ctx));
        let acc_shutdown = self.shutdown.clone();
        let acc_conns = self.conns.clone();
        let acceptor = std::thread::spawn(move || {
            listener.set_nonblocking(true).ok();
            loop {
                if acc_shutdown.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Ok(dup) = stream.try_clone() {
                            acc_conns.lock().unwrap().push(dup);
                        }
                        let state = state.clone();
                        std::thread::spawn(move || crate::http::serve_conn(stream, state));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
        });
        self.http_acceptor = Some(acceptor);
        self.http_addr = Some(addr);
        Ok(addr)
    }

    /// Signal shutdown, sever open connections, and join threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if let Some(a) = self.http_acceptor.take() {
            let _ = a.join();
        }
        // Drop the handle's dispatcher senders, then force connection
        // reader loops to end so theirs drop too; otherwise the workers
        // would wait on clients that outlive the server handle.
        drop(self.ctx.take());
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Capped exponential restart backoff (10ms · 2ⁿ, max 1s).
fn restart_backoff(consecutive_panics: u32) -> Duration {
    let exp = consecutive_panics.min(7);
    Duration::from_millis((BACKOFF_BASE_MS << exp).min(BACKOFF_CAP_MS))
}

/// Best-effort human-readable panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic".to_string()
    }
}

/// Everything one shard worker owns, bundled so the spawn site stays
/// readable as serving knobs accumulate.
struct ShardRuntime {
    shard: usize,
    queue: Arc<BatchQueue<Request>>,
    factory: Arc<dyn Fn(usize) -> Box<dyn Engine> + Send + Sync>,
    slot: Option<Arc<PlanSlot>>,
    m: Arc<Metrics>,
    ops: Arc<OpsCounters>,
    policy: BatchPolicy,
    /// Response-cache budget in bytes; 0 disables the cache.
    cache_bytes: usize,
}

/// Per-worker reusable state: scratch buffers recycled across batches
/// (the zero-allocation path) plus the optional generation-keyed
/// response cache. `answered` lives here so it survives a batch unwind
/// and the supervisor can see exactly which requests were replied to.
struct BatchScratch {
    answered: Vec<bool>,
    xbuf: Vec<f32>,
    evals: Vec<usize>,
    outcomes: Vec<Outcome>,
    cache: Option<ResponseCache>,
    /// Plan generation the current batch evaluates under — part of
    /// every cache key, so an accepted reload invalidates implicitly.
    generation: u64,
}

/// The supervised shard worker loop. The worker thread itself never
/// dies to a panic: engine construction and batch processing both run
/// under `catch_unwind`, every request in a poisoned batch gets a
/// terminal reply, and the engine is rebuilt (after capped exponential
/// backoff) unless it declares itself [`Engine::reusable_after_panic`].
fn supervise_shard(rt: ShardRuntime) {
    let ShardRuntime { shard, queue, factory, slot, m, ops, policy, cache_bytes } = rt;
    let mut engine: Option<Box<dyn Engine>> = None;
    let mut gen = 0u64;
    let mut d = 0usize;
    let mut consecutive_panics = 0u32;
    // Recycled across iterations: the batch/live vectors and the
    // classify scratch reach a steady-state capacity and stop
    // allocating.
    let mut batch: Vec<Request> = Vec::new();
    let mut live: Vec<Request> = Vec::new();
    let mut scratch = BatchScratch {
        answered: Vec::new(),
        xbuf: Vec::new(),
        evals: Vec::new(),
        outcomes: Vec::new(),
        cache: (cache_bytes > 0)
            .then(|| ResponseCache::new(cache_bytes, CACHE_SEED ^ shard as u64)),
        generation: 0,
    };
    while let Some(reason) = queue.next_batch_into(policy, &mut batch) {
        m.record_flush(reason);
        if failpoints::enabled() {
            // Chaos hook: stall this shard's batch loop (`slow_batch`,
            // `ms=` payload) to force queue buildup and deadline expiry.
            failpoints::sleep_ms("slow_batch", shard as u64);
        }
        // Deadline shedding at the batch boundary: anything that expired
        // while queued is answered TIMEOUT before any engine work.
        let now = Instant::now();
        live.clear();
        for r in batch.drain(..) {
            match r.deadline {
                Some(deadline) if now >= deadline => {
                    ops.timeouts.fetch_add(1, Ordering::Relaxed);
                    send_pooled(&r, |s| {
                        let _ = write!(s, "TIMEOUT {}", r.id);
                    });
                    recycle(r);
                }
                _ => live.push(r),
            }
        }
        if live.is_empty() {
            continue;
        }
        // (Re)build the engine if the last panic consumed it. Factories
        // can panic too (artifact opening, device init), so this also
        // runs supervised; a failed rebuild errors the batch and backs
        // off before the next attempt.
        if engine.is_none() {
            // Read the generation BEFORE building the engine: a swap
            // racing the build is re-applied on the first batch (a
            // harmless duplicate) instead of being missed.
            gen = slot.as_ref().map(|s| s.generation()).unwrap_or(0);
            match catch_unwind(AssertUnwindSafe(|| factory(shard))) {
                Ok(e) => {
                    d = e.n_features();
                    engine = Some(e);
                    if consecutive_panics > 0 {
                        eprintln!("shard {shard}: engine rebuilt, resuming service");
                    }
                }
                Err(payload) => {
                    let why = panic_message(payload.as_ref());
                    eprintln!("shard {shard}: engine construction panicked: {why}");
                    for r in &live {
                        send_pooled(r, |s| {
                            let _ = write!(s, "ERR {} shard_panic: {why}", r.id);
                        });
                    }
                    ops.shard_restarts.fetch_add(1, Ordering::Relaxed);
                    let pause = restart_backoff(consecutive_panics);
                    consecutive_panics = consecutive_panics.saturating_add(1);
                    for r in live.drain(..) {
                        recycle(r);
                    }
                    std::thread::sleep(pause);
                    continue;
                }
            }
        }
        let eng = engine.as_mut().expect("engine present after rebuild");
        // Plan hot-swap happens only here, at a batch boundary: no batch
        // ever sees a half-swapped plan, and a batch being classified
        // when the swap lands completes against the plan it started
        // with. Requests still queued (including this just-drained
        // batch) evaluate under the NEW plan; if the new plan changes
        // the feature width, stale-width requests get clean per-request
        // ERRs below rather than being dropped.
        if let Some(slot) = &slot {
            let g = slot.generation();
            if g != gen {
                gen = g;
                // A new generation makes every cached key unreachable;
                // drop the bytes at once instead of waiting for FIFO
                // eviction to churn the dead entries out.
                if let Some(c) = &mut scratch.cache {
                    c.clear();
                }
                match eng.swap_plan(slot.load()) {
                    Ok(()) => d = eng.n_features(),
                    Err(e) => eprintln!("shard {shard}: plan reload failed: {e}"),
                }
            }
        }
        // Everything that touches the engine runs under catch_unwind.
        // The per-request `answered` flags are written the moment each
        // reply is sent and survive the unwind, so a panic mid-batch
        // yields exactly one terminal reply per request: already-sent
        // OKs are never duplicated, everything else gets shard_panic.
        scratch.generation = gen;
        scratch.answered.clear();
        scratch.answered.resize(live.len(), false);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            failpoints::maybe_panic("shard_panic", shard as u64);
            process_batch(eng.as_mut(), &live, d, &m, &ops, &mut scratch);
        }));
        match outcome {
            Ok(()) => consecutive_panics = 0,
            Err(payload) => {
                let why = panic_message(payload.as_ref());
                // Terminal replies first — no client may hang on the
                // poisoned batch — then recover the engine.
                for (r, &done) in live.iter().zip(scratch.answered.iter()) {
                    if !done {
                        send_pooled(r, |s| {
                            let _ = write!(s, "ERR {} shard_panic: {why}", r.id);
                        });
                    }
                }
                // The panic may have interrupted a cache insert; start
                // the cache cold alongside the engine.
                if let Some(c) = &mut scratch.cache {
                    c.clear();
                }
                ops.shard_restarts.fetch_add(1, Ordering::Relaxed);
                let reuse = engine.as_ref().is_some_and(|e| e.reusable_after_panic());
                if !reuse {
                    engine = None;
                }
                eprintln!(
                    "shard {shard}: batch panicked ({why}); {} (restart #{})",
                    if reuse { "engine reused" } else { "engine dropped for rebuild" },
                    consecutive_panics + 1
                );
                let pause = restart_backoff(consecutive_panics);
                consecutive_panics = consecutive_panics.saturating_add(1);
                std::thread::sleep(pause);
            }
        }
        // Every request has its terminal reply by now; hand the feature
        // buffers back to their connections' pools.
        for r in live.drain(..) {
            recycle(r);
        }
    }
}

/// One batch through the cache and engine: width checks, cache lookups,
/// classify into recycled buffers, pooled replies. Marks
/// `scratch.answered[j]` immediately after each send so the supervisor
/// knows exactly which requests still need a terminal reply if this
/// unwinds.
fn process_batch(
    engine: &mut dyn Engine,
    live: &[Request],
    d: usize,
    m: &Metrics,
    ops: &OpsCounters,
    scratch: &mut BatchScratch,
) {
    m.record_batch(live.len());
    let BatchScratch { answered, xbuf, evals, outcomes, cache, generation } = scratch;
    xbuf.clear();
    evals.clear();
    for (j, r) in live.iter().enumerate() {
        if r.features.len() != d {
            // Misfits fail alone; the rest of the batch still evaluates.
            send_pooled(r, |s| {
                let _ = write!(s, "ERR {} wrong feature count (want {d})", r.id);
            });
            answered[j] = true;
            continue;
        }
        if let Some(cache) = cache.as_ref() {
            if ResponseCache::cacheable(&r.features) {
                if let Some(o) = cache.lookup(*generation, &r.features) {
                    ops.cache_hits.fetch_add(1, Ordering::Relaxed);
                    let lat = r.enqueued.elapsed().as_nanos() as u64;
                    m.record_request(lat, o.models_evaluated, o.early);
                    send_pooled(r, |s| format_ok_reply(s, r.id, &o, lat / 1_000));
                    answered[j] = true;
                    continue;
                }
                ops.cache_misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        xbuf.extend_from_slice(&r.features);
        evals.push(j);
    }
    if evals.is_empty() {
        return;
    }
    match engine.classify_into(xbuf, evals.len(), outcomes) {
        Ok(()) => {
            for (&j, &o) in evals.iter().zip(outcomes.iter()) {
                let r = &live[j];
                if let Some(cache) = cache.as_mut() {
                    if ResponseCache::cacheable(&r.features) {
                        let evicted = cache.insert(*generation, &r.features, o);
                        if evicted > 0 {
                            ops.cache_evictions.fetch_add(evicted, Ordering::Relaxed);
                        }
                    }
                }
                let lat = r.enqueued.elapsed().as_nanos() as u64;
                m.record_request(lat, o.models_evaluated, o.early);
                send_pooled(r, |s| format_ok_reply(s, r.id, &o, lat / 1_000));
                answered[j] = true;
            }
        }
        Err(e) => {
            for &j in evals.iter() {
                let r = &live[j];
                send_pooled(r, |s| {
                    let _ = write!(s, "ERR {} engine: {e}", r.id);
                });
                answered[j] = true;
            }
        }
    }
}

/// Why one `EVAL` line failed to parse, mapped to the protocol's
/// per-request error replies by the connection loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalParseError {
    /// The id token is missing or not a `u64` (`ERR - malformed EVAL`).
    BadId,
    /// A `DEADLINE_MS=` token carried a non-numeric value.
    BadDeadline {
        /// The request id the error reply should carry.
        id: u64,
    },
    /// The feature list is empty or contains a non-float token.
    BadFeatures {
        /// The request id the error reply should carry.
        id: u64,
    },
}

/// Parse one `EVAL` body — `<id> [DEADLINE_MS=<d>] <f1>,<f2>,...` —
/// into a reusable feature buffer (cleared and refilled, never
/// reallocated after warmup). Returns the id and the optional
/// `DEADLINE_MS` value. Public so the allocation harness and benches
/// drive the exact production parser.
pub fn parse_eval(
    rest: &str,
    features: &mut Vec<f32>,
) -> Result<(u64, Option<u64>), EvalParseError> {
    features.clear();
    let (id_str, mut rest) =
        rest.split_once(' ').map(|(a, b)| (a, b.trim_start())).unwrap_or((rest, ""));
    let Ok(id) = id_str.parse::<u64>() else {
        return Err(EvalParseError::BadId);
    };
    let mut deadline_ms: Option<u64> = None;
    if let Some(after) = rest.strip_prefix("DEADLINE_MS=") {
        let (token, feats) =
            after.split_once(' ').map(|(a, b)| (a, b.trim_start())).unwrap_or((after, ""));
        match token.parse::<u64>() {
            Ok(ms) => {
                deadline_ms = Some(ms);
                rest = feats;
            }
            Err(_) => return Err(EvalParseError::BadDeadline { id }),
        }
    }
    if rest.is_empty() {
        return Err(EvalParseError::BadFeatures { id });
    }
    for token in rest.split(',') {
        match token.trim().parse::<f32>() {
            Ok(v) => features.push(v),
            Err(_) => return Err(EvalParseError::BadFeatures { id }),
        }
    }
    Ok((id, deadline_ms))
}

/// Format the protocol's `OK` reply into a reusable buffer:
/// `OK <id> <pos|neg> <score:.6> <models> <latency_us>`. The single
/// authority on the reply shape, shared by the cold, cached, and
/// panic-recovery paths — so their replies are bitwise-identical by
/// construction. Public so the allocation harness and benches drive the
/// exact production formatter.
pub fn format_ok_reply(buf: &mut String, id: u64, o: &Outcome, latency_us: u64) {
    buf.clear();
    let _ = write!(
        buf,
        "OK {id} {} {:.6} {} {latency_us}",
        if o.positive { "pos" } else { "neg" },
        o.score,
        o.models_evaluated
    );
}

/// Typed verdict of a reload attempt, shared by the line protocol
/// (`RELOAD <path>` → [`ReloadOutcome::into_line`]) and the HTTP admin
/// plane (`POST /reload` → 200/400/409/501 with a JSON body) so both
/// surfaces report the same staged decision from the same gate.
pub(crate) enum ReloadOutcome {
    /// Candidate accepted and published into the slot.
    Swapped {
        /// The artifact's plan name.
        name: String,
        /// New slot generation.
        generation: u64,
        /// Positions T of the accepted plan.
        t: usize,
    },
    /// Candidate refused at `stage` (`io`, `schema`, `canary`, ...);
    /// last-known-good keeps serving.
    Rejected { stage: String, why: String },
    /// This server has no plan slot (generic-factory backend).
    Unsupported,
    /// Empty path.
    Malformed,
}

impl ReloadOutcome {
    /// The line protocol's reply for this verdict (exact legacy shapes).
    pub(crate) fn into_line(self) -> String {
        match self {
            ReloadOutcome::Swapped { name, generation, t } => {
                format!("RELOADED {name} gen={generation} T={t}")
            }
            ReloadOutcome::Rejected { stage, why } => format!("RELOAD_REJECTED {stage}: {why}"),
            ReloadOutcome::Unsupported => "ERR - reload unsupported for this backend".into(),
            ReloadOutcome::Malformed => "ERR - malformed RELOAD (usage: RELOAD <path>)".into(),
        }
    }
}

/// Validated hot-reload: load + compile the candidate off the request
/// path (on the calling connection's thread), canary it against probes
/// captured from the LIVE plan, and only then publish into the slot
/// (updating the plan identity the admin surface reports). Any failure
/// — unreadable artifact, schema error, or a canary violation
/// (feature-width change, non-finite score, broken early-exit
/// invariant) — keeps last-known-good serving and yields the staged
/// rejection.
///
/// Shard workers adopt an accepted plan at their next batch boundary: a
/// batch mid-classification finishes on its old plan, and an accepted
/// swap (same feature space by construction — the canary enforces it)
/// never errors any request.
///
/// The path may name either artifact format — [`PlanArtifact::load`]
/// sniffs the magic bytes. Deploying the zero-copy `qwyc-plan-bin-v1`
/// form makes the reload near-free: one read + validated pointer casts
/// instead of a JSON parse + re-permute.
pub(crate) fn reload_plan(path: &str, ctx: &ConnShared) -> ReloadOutcome {
    let Some(slot) = &ctx.plan_slot else {
        return ReloadOutcome::Unsupported;
    };
    if path.is_empty() {
        return ReloadOutcome::Malformed;
    }
    let ops = ctx.metrics.ops();
    let reject = |e: QwycError| {
        ops.reload_rejected.fetch_add(1, Ordering::Relaxed);
        ReloadOutcome::Rejected { stage: e.stage().to_string(), why: e.message().to_string() }
    };
    let candidate = match PlanArtifact::load(Path::new(path)) {
        Ok(artifact) => artifact,
        Err(e) => return reject(e),
    };
    let compiled = candidate.compiled();
    let live = slot.load();
    let probes = ProbeSet::capture(&live, DEFAULT_PROBES, CANARY_SEED);
    let canary = if failpoints::fire("reload_corrupt") {
        // Chaos hook: force the canary verdict the harness expects from
        // a corrupt-but-loadable artifact.
        Err(QwycError::Validate("injected failpoint 'reload_corrupt'".into()))
    } else {
        probes.check(&compiled)
    };
    if let Err(e) = canary {
        // Canary verdicts get their own stage tag regardless of the
        // underlying error variant: the operator's question is "which
        // reload gate failed", not "which crate stage built the error".
        ops.reload_rejected.fetch_add(1, Ordering::Relaxed);
        return ReloadOutcome::Rejected {
            stage: "canary".to_string(),
            why: e.message().to_string(),
        };
    }
    let t = compiled.t();
    let generation = slot.swap(compiled);
    if let Some(identity) = &ctx.identity {
        *identity.lock().unwrap() = PlanIdentity {
            meta: candidate.meta().clone(),
            ensemble_name: candidate.ensemble_name().to_string(),
        };
    }
    ops.reload_ok.fetch_add(1, Ordering::Relaxed);
    ReloadOutcome::Swapped { name: candidate.name().to_string(), generation, t }
}

fn handle_conn(stream: TcpStream, ctx: Arc<ConnShared>) {
    let peer_write = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = std::io::BufWriter::new(peer_write);
    let mut reader = BufReader::new(stream);
    let pool = Arc::new(BufPool::new());
    // Response pump: a dedicated channel per connection keeps ordering
    // per-client while letting shard workers answer out of batch order.
    // Written reply strings go back to the connection's pool.
    let (resp_tx, resp_rx) = mpsc::channel::<String>();
    let pump_pool = pool.clone();
    let pump = std::thread::spawn(move || {
        let mut w = writer;
        while let Ok(line) = resp_rx.recv() {
            if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                break;
            }
            let _ = w.flush();
            pump_pool.put_string(line);
        }
    });

    let mut line_buf: Vec<u8> = Vec::new();
    loop {
        match read_line_capped(&mut reader, MAX_LINE_BYTES, &mut line_buf) {
            Err(_) | Ok(LineRead::Eof) => break,
            Ok(LineRead::TooLong) => {
                let _ = resp_tx.send(format!("ERR - line too long (cap {MAX_LINE_BYTES} bytes)"));
                continue;
            }
            Ok(LineRead::Line) => {}
        }
        // Borrowed for valid UTF-8 (the steady state, no allocation);
        // binary garbage is replaced lossily and rejected by the parse.
        let line = String::from_utf8_lossy(&line_buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (verb, rest) = match line.split_once(' ') {
            Some((v, r)) => (v, r.trim_start()),
            None => (line, ""),
        };
        match verb {
            "EVAL" => handle_eval(rest, &ctx, &resp_tx, &pool),
            "STATS" => {
                let _ = resp_tx.send(format!("STATS {}", ctx.metrics.report_cached()));
            }
            "RELOAD" => {
                // The path is everything after the verb (paths may
                // contain spaces).
                let reply = reload_plan(rest.trim(), &ctx).into_line();
                let _ = resp_tx.send(reply);
            }
            "DRAIN" => {
                let still_queued = ctx.dispatch.drain(DRAIN_TIMEOUT);
                let _ = resp_tx.send(if still_queued == 0 {
                    "DRAINED queued=0".to_string()
                } else {
                    format!("ERR - drain timed out ({still_queued} still queued)")
                });
            }
            "QUIT" => break,
            _ => {
                let _ = resp_tx.send("ERR - unknown command".into());
            }
        }
    }
    drop(resp_tx);
    let _ = pump.join();
}

/// Parse and route one `EVAL` request:
/// `<id> [DEADLINE_MS=<d>] <f1>,<f2>,...`. A `DEADLINE_MS` token
/// overrides the server default; `DEADLINE_MS=0` explicitly opts out.
/// The feature buffer comes from — and on any non-routed exit returns
/// to — the connection's pool.
fn handle_eval(rest: &str, ctx: &ConnShared, resp_tx: &Sender<String>, pool: &Arc<BufPool>) {
    let mut features = pool.get_feats();
    let (id, deadline_ms) = match parse_eval(rest, &mut features) {
        Ok(parsed) => parsed,
        Err(e) => {
            pool.put_feats(features);
            let _ = resp_tx.send(match e {
                EvalParseError::BadId => "ERR - malformed EVAL".to_string(),
                EvalParseError::BadDeadline { id } => format!("ERR {id} malformed DEADLINE_MS"),
                EvalParseError::BadFeatures { id } => format!("ERR {id} malformed EVAL"),
            });
            return;
        }
    };
    let deadline = match deadline_ms {
        Some(0) => None,
        Some(ms) => Some(Instant::now() + Duration::from_millis(ms)),
        None => ctx.default_deadline.map(|d| Instant::now() + d),
    };
    let req = Request {
        id,
        features,
        enqueued: Instant::now(),
        deadline,
        respond: resp_tx.clone(),
        pool: pool.clone(),
    };
    match ctx.dispatch.route(req) {
        Ok(()) => {}
        Err(RouteError::Busy(r)) => {
            ctx.metrics.ops().busy_shed.fetch_add(1, Ordering::Relaxed);
            let _ = resp_tx.send(format!("BUSY {}", r.id));
            recycle(r);
        }
        Err(RouteError::Draining(r)) => {
            let _ = resp_tx.send(format!("ERR {} draining", r.id));
            recycle(r);
        }
        Err(RouteError::Closed(r)) => {
            let _ = resp_tx.send(format!("ERR {} server shutting down", r.id));
            recycle(r);
        }
    }
}

/// Minimal blocking client for tests/examples/load generators.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// Parsed server response to an EVAL.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    pub id: u64,
    pub positive: bool,
    pub score: f32,
    pub models: u32,
    pub latency_us: u64,
}

/// Any server → client line, id-correlated where the protocol carries
/// one (every ERR line now does; `-` parses as `None`).
#[derive(Clone, Debug)]
pub enum Reply {
    Ok(EvalResponse),
    /// Request shed by a full shard queue; retry or back off.
    Busy { id: u64 },
    /// Request shed because its deadline expired while queued.
    Timeout { id: u64 },
    Err { id: Option<u64>, message: String },
    /// Accepted RELOAD (the full `RELOADED ...` line).
    Reloaded(String),
    /// Refused RELOAD: the failing stage (`io`, `schema`, `canary`, ...)
    /// and the human-readable reason.
    ReloadRejected { stage: String, why: String },
    /// STATS / DRAINED / anything else, verbatim.
    Other(String),
}

impl Reply {
    /// Classify one raw server → client line.
    pub fn parse(line: &str) -> Reply {
        if let Some(r) = parse_eval_response(line) {
            return Reply::Ok(r);
        }
        if let Some(rest) = line.strip_prefix("RELOAD_REJECTED ") {
            if let Some((stage, why)) = rest.split_once(": ") {
                return Reply::ReloadRejected { stage: stage.to_string(), why: why.to_string() };
            }
        }
        if line.starts_with("RELOADED ") {
            return Reply::Reloaded(line.to_string());
        }
        let mut p = line.splitn(3, ' ');
        match p.next() {
            Some("BUSY") => {
                if let Some(id) = p.next().and_then(|s| s.parse::<u64>().ok()) {
                    return Reply::Busy { id };
                }
            }
            Some("TIMEOUT") => {
                if let Some(id) = p.next().and_then(|s| s.parse::<u64>().ok()) {
                    return Reply::Timeout { id };
                }
            }
            Some("ERR") => {
                let id = p.next().and_then(|s| s.parse::<u64>().ok());
                let message = p.next().unwrap_or("").to_string();
                return Reply::Err { id, message };
            }
            _ => {}
        }
        Reply::Other(line.to_string())
    }
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 0 })
    }

    /// Send one EVAL (does not wait for the response).
    pub fn send_eval(&mut self, features: &[f32]) -> std::io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let feats: Vec<String> = features.iter().map(|v| format!("{v}")).collect();
        writeln!(self.writer, "EVAL {id} {}", feats.join(","))?;
        Ok(id)
    }

    /// Send one EVAL carrying a `DEADLINE_MS=` token (0 = explicitly no
    /// deadline, overriding the server default). Does not wait.
    pub fn send_eval_with_deadline(
        &mut self,
        features: &[f32],
        deadline_ms: u64,
    ) -> std::io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let feats: Vec<String> = features.iter().map(|v| format!("{v}")).collect();
        writeln!(self.writer, "EVAL {id} DEADLINE_MS={deadline_ms} {}", feats.join(","))?;
        Ok(id)
    }

    /// Read one response line and classify it (blocking).
    pub fn read_reply(&mut self) -> std::io::Result<Reply> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Ok(Reply::parse(line.trim()))
    }

    /// Read one OK response (blocking); any other reply is an error.
    pub fn read_response(&mut self) -> std::io::Result<EvalResponse> {
        match self.read_reply()? {
            Reply::Ok(r) => Ok(r),
            other => Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{other:?}"),
            )),
        }
    }

    /// Convenience: send and wait.
    pub fn eval(&mut self, features: &[f32]) -> std::io::Result<EvalResponse> {
        self.send_eval(features)?;
        self.read_response()
    }

    /// Fetch the server's STATS line. Replies are FIFO per connection,
    /// so call this only when no pipelined EVALs are outstanding (or use
    /// a dedicated control connection) — otherwise the next line read is
    /// an earlier EVAL's reply, not the STATS line.
    pub fn stats(&mut self) -> std::io::Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Ask the server to hot-swap its plan; returns the raw reply line
    /// (`RELOADED ...` on success, `RELOAD_REJECTED <stage>: <why>` on
    /// refusal — classify it with [`Reply::parse`]). Same FIFO caveat as
    /// [`Client::stats`]: issue RELOAD from a connection with no
    /// outstanding EVALs — a dedicated control connection, as
    /// `qwyc reload` and the e2e tests do.
    pub fn reload(&mut self, plan_path: &str) -> std::io::Result<String> {
        writeln!(self.writer, "RELOAD {plan_path}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Ask the server to stop admission and drain its queues; returns
    /// the raw reply line (`DRAINED queued=0` on success). Same FIFO
    /// caveat as [`Client::stats`].
    pub fn drain(&mut self) -> std::io::Result<String> {
        writeln!(self.writer, "DRAIN")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

fn parse_eval_response(line: &str) -> Option<EvalResponse> {
    let mut p = line.split(' ');
    if p.next()? != "OK" {
        return None;
    }
    Some(EvalResponse {
        id: p.next()?.parse().ok()?,
        positive: p.next()? == "pos",
        score: p.next()?.parse().ok()?,
        models: p.next()?.parse().ok()?,
        latency_us: p.next()?.parse().ok()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_response_roundtrip() {
        let r = parse_eval_response("OK 42 pos 1.250000 7 133").unwrap();
        assert_eq!(r.id, 42);
        assert!(r.positive);
        assert_eq!(r.models, 7);
        assert_eq!(r.latency_us, 133);
        assert!(parse_eval_response("ERR 1 nope").is_none());
    }

    #[test]
    fn parse_reply_classifies_protocol_lines() {
        match Reply::parse("OK 3 neg -0.500000 2 10") {
            Reply::Ok(r) => {
                assert_eq!(r.id, 3);
                assert!(!r.positive);
            }
            other => panic!("{other:?}"),
        }
        match Reply::parse("BUSY 17") {
            Reply::Busy { id } => assert_eq!(id, 17),
            other => panic!("{other:?}"),
        }
        match Reply::parse("TIMEOUT 23") {
            Reply::Timeout { id } => assert_eq!(id, 23),
            other => panic!("{other:?}"),
        }
        match Reply::parse("ERR 5 engine: boom") {
            Reply::Err { id, message } => {
                assert_eq!(id, Some(5));
                assert_eq!(message, "engine: boom");
            }
            other => panic!("{other:?}"),
        }
        // `-` id (request id unknown) parses as None.
        match Reply::parse("ERR - malformed EVAL") {
            Reply::Err { id, message } => {
                assert_eq!(id, None);
                assert_eq!(message, "malformed EVAL");
            }
            other => panic!("{other:?}"),
        }
        match Reply::parse("RELOADED demo gen=1 T=6") {
            Reply::Reloaded(s) => assert!(s.starts_with("RELOADED")),
            other => panic!("{other:?}"),
        }
        match Reply::parse("RELOAD_REJECTED canary: feature width changed") {
            Reply::ReloadRejected { stage, why } => {
                assert_eq!(stage, "canary");
                assert_eq!(why, "feature width changed");
            }
            other => panic!("{other:?}"),
        }
        match Reply::parse("DRAINED queued=0") {
            Reply::Other(s) => assert!(s.starts_with("DRAINED")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_eval_reuses_the_buffer_and_maps_errors() {
        let mut feats: Vec<f32> = Vec::new();
        assert_eq!(parse_eval("7 1.5,2.5,3", &mut feats), Ok((7, None)));
        assert_eq!(feats, vec![1.5, 2.5, 3.0]);
        // The buffer is cleared and refilled, not appended to.
        assert_eq!(parse_eval("8 DEADLINE_MS=250 1,2", &mut feats), Ok((8, Some(250))));
        assert_eq!(feats, vec![1.0, 2.0]);
        assert_eq!(parse_eval("8 DEADLINE_MS=0 4", &mut feats), Ok((8, Some(0))));
        assert_eq!(parse_eval("x 1,2", &mut feats), Err(EvalParseError::BadId));
        assert_eq!(
            parse_eval("9 DEADLINE_MS=abc 1", &mut feats),
            Err(EvalParseError::BadDeadline { id: 9 })
        );
        assert_eq!(parse_eval("9", &mut feats), Err(EvalParseError::BadFeatures { id: 9 }));
        assert_eq!(parse_eval("9 1,zap", &mut feats), Err(EvalParseError::BadFeatures { id: 9 }));
    }

    #[test]
    fn format_ok_reply_matches_the_wire_shape() {
        let o = Outcome { positive: true, score: 1.25, models_evaluated: 7, early: true };
        // A dirty recycled buffer is cleared, not appended to.
        let mut buf = String::from("junk");
        format_ok_reply(&mut buf, 42, &o, 133);
        assert_eq!(buf, "OK 42 pos 1.250000 7 133");
        let r = parse_eval_response(&buf).unwrap();
        assert_eq!((r.id, r.models, r.latency_us), (42, 7, 133));
        assert!(r.positive);
    }

    #[test]
    fn backoff_is_capped_exponential() {
        assert_eq!(restart_backoff(0), Duration::from_millis(10));
        assert_eq!(restart_backoff(1), Duration::from_millis(20));
        assert_eq!(restart_backoff(3), Duration::from_millis(80));
        assert_eq!(restart_backoff(7), Duration::from_millis(1_000));
        assert_eq!(restart_backoff(200), Duration::from_millis(1_000));
    }
}
