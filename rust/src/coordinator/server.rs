//! TCP serving front-end: line protocol, connection handling, and the
//! sharded engine runtime. The plan is compiled ONCE into a shared
//! `Arc<CompiledPlan>`; `--shards N` engine workers each own an engine
//! handle and drain their own bounded [`BatchQueue`]. Requests flow
//!
//!   conn thread → dispatcher (least-queued shard, try_send)
//!     → per-shard BatchQueue (condvar) → shard worker
//!     → engine.classify_batch → per-request response channel
//!     → conn thread → client
//!
//! Responses stream back as soon as their example is decided; each
//! example's early-exit sweep is independent, so responses are
//! bit-identical at any shard count (rust/tests/serving_e2e.rs).
//! A full shard queue sheds load with `BUSY <id>` instead of queueing
//! unbounded latency, and `RELOAD <path>` swaps the shared plan at
//! batch boundaries via a [`PlanSlot`] — width-compatible swaps never
//! error a request (no tokio offline; plain threads — DESIGN.md §4).
//!
//! Protocol (one line per message):
//!   client → server:  EVAL <id> <f1>,<f2>,...      classify one example
//!                     STATS                         metrics snapshot
//!                     RELOAD <path>                 hot-swap the plan
//!                     QUIT                          close connection
//!   server → client:  OK <id> <pos|neg> <score> <models> <latency_us>
//!                     BUSY <id>                     shard queues full
//!                     STATS <report...>
//!                     RELOADED <name> gen=<g> T=<t>
//!                     ERR <id> <message>            (`-` id when the
//!                                                   request id is unknown)

use super::batcher::{
    batch_channel_with_cap, BatchPolicy, BatchQueue, BatchSender, TrySendError,
};
use super::metrics::ShardedMetrics;
use crate::plan::{CompiledPlan, PlanArtifact, PlanSlot};
use crate::runtime::engine::{Engine, NativeEngine};
use crate::util::pool::{threads_from_env, Pool};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Default bound on each shard's request queue (`--queue-cap`).
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// One in-flight request.
struct Request {
    id: u64,
    features: Vec<f32>,
    enqueued: Instant,
    respond: Sender<String>,
}

/// Runtime shape of the serving coordinator.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Engine worker shards, each with its own queue (`--shards`).
    pub shards: usize,
    /// Per-shard queue bound; 0 = unbounded (`--queue-cap`).
    pub queue_cap: usize,
    /// Dynamic-batching policy applied by every shard.
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { shards: 1, queue_cap: DEFAULT_QUEUE_CAP, policy: BatchPolicy::default() }
    }
}

/// Single-shard config with the given batching policy (the pre-sharding
/// call shape, kept so `Server::start(addr, factory, policy)` reads as
/// before).
impl From<BatchPolicy> for ServerConfig {
    fn from(policy: BatchPolicy) -> ServerConfig {
        ServerConfig { policy, ..ServerConfig::default() }
    }
}

/// Routes each request to the least-queued shard; a full shard queue
/// surfaces as BUSY instead of blocking the connection thread.
struct Dispatcher {
    shards: Vec<(BatchSender<Request>, Arc<BatchQueue<Request>>)>,
}

enum RouteError {
    Busy(Request),
    Closed(Request),
}

impl Dispatcher {
    fn route(&self, req: Request) -> Result<(), RouteError> {
        // Least-queued shard (ties → lowest index). Queue lengths move
        // under us, but any stale choice only costs balance, never
        // correctness — per-example sweeps are shard-independent.
        let mut best = 0usize;
        let mut best_len = usize::MAX;
        for (i, (_, q)) in self.shards.iter().enumerate() {
            let len = q.len();
            if len < best_len {
                best = i;
                best_len = len;
            }
        }
        match self.shards[best].0.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => Err(RouteError::Busy(r)),
            Err(TrySendError::Closed(r)) => Err(RouteError::Closed(r)),
        }
    }
}

/// Server handle: address, shutdown flag, worker/acceptor joins.
pub struct Server {
    pub addr: std::net::SocketAddr,
    /// Per-shard metrics; `metrics.snapshot()` aggregates all shards.
    pub metrics: Arc<ShardedMetrics>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Live connection streams; shut down on stop so connection threads
    /// (which hold request-channel senders) exit and the workers drain.
    conns: Arc<std::sync::Mutex<Vec<TcpStream>>>,
}

impl Server {
    /// Start serving on `bind_addr` (e.g. "127.0.0.1:0") with engines
    /// built by `engine_factory(shard)` *inside* each shard's worker
    /// thread — PJRT handles are not `Send`, so an engine must be born
    /// where it lives. This generic entry point has no plan slot, so
    /// `RELOAD` is refused; native serving should prefer
    /// [`Server::start_with_plan`].
    pub fn start<F, C>(bind_addr: &str, engine_factory: F, config: C) -> std::io::Result<Server>
    where
        F: Fn(usize) -> Box<dyn Engine> + Send + Sync + 'static,
        C: Into<ServerConfig>,
    {
        Server::start_inner(bind_addr, Arc::new(engine_factory), config.into(), None)
    }

    /// Native sharded serving from one shared compiled plan: every shard
    /// gets an `Arc` handle to the SAME artifact (compile once — the
    /// plan is immutable and `Send + Sync` by construction) plus a
    /// private worker pool splitting `QWYC_THREADS` across shards.
    /// Enables `RELOAD <path>` hot-swap through a [`PlanSlot`].
    pub fn start_with_plan<C>(
        bind_addr: &str,
        plan: Arc<CompiledPlan>,
        config: C,
    ) -> std::io::Result<Server>
    where
        C: Into<ServerConfig>,
    {
        let config = config.into();
        let slot = Arc::new(PlanSlot::new(plan));
        let per_shard_threads = (threads_from_env() / config.shards.max(1)).max(1);
        let factory_slot = slot.clone();
        let factory = move |_shard: usize| -> Box<dyn Engine> {
            Box::new(NativeEngine::from_shared(
                factory_slot.load(),
                Pool::new(per_shard_threads),
            ))
        };
        Server::start_inner(bind_addr, Arc::new(factory), config, Some(slot))
    }

    fn start_inner(
        bind_addr: &str,
        factory: Arc<dyn Fn(usize) -> Box<dyn Engine> + Send + Sync>,
        config: ServerConfig,
        plan_slot: Option<Arc<PlanSlot>>,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(bind_addr)?;
        let addr = listener.local_addr()?;
        let n_shards = config.shards.max(1);
        let metrics = Arc::new(ShardedMetrics::new(n_shards));
        let shutdown = Arc::new(AtomicBool::new(false));

        // Shard workers: each owns an engine and drains its own queue.
        let mut workers = Vec::with_capacity(n_shards);
        let mut shard_channels = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (tx, queue) = batch_channel_with_cap::<Request>(config.queue_cap);
            shard_channels.push((tx, queue.clone()));
            let m = metrics.shard(shard);
            let slot = plan_slot.clone();
            let factory = factory.clone();
            let policy = config.policy;
            workers.push(std::thread::spawn(move || {
                // Read the generation BEFORE building the engine: a swap
                // racing the spawn is re-applied on the first batch (a
                // harmless duplicate) instead of being missed.
                let mut gen = slot.as_ref().map(|s| s.generation()).unwrap_or(0);
                let mut engine = factory(shard);
                let mut d = engine.n_features();
                let mut xbuf: Vec<f32> = Vec::new();
                while let Some(batch) = queue.next_batch(policy) {
                    // Plan hot-swap happens only here, at a batch
                    // boundary: no batch ever sees a half-swapped plan,
                    // and a batch being classified when the swap lands
                    // completes against the plan it started with.
                    // Requests still queued (including this just-drained
                    // batch) evaluate under the NEW plan; if the new
                    // plan changes the feature width, stale-width
                    // requests get clean per-request ERRs below rather
                    // than being dropped.
                    if let Some(slot) = &slot {
                        let g = slot.generation();
                        if g != gen {
                            gen = g;
                            match engine.swap_plan(slot.load()) {
                                Ok(()) => d = engine.n_features(),
                                Err(e) => {
                                    eprintln!("shard {shard}: plan reload failed: {e}")
                                }
                            }
                        }
                    }
                    m.record_batch(batch.len());
                    xbuf.clear();
                    let mut evals: Vec<&Request> = Vec::with_capacity(batch.len());
                    for r in &batch {
                        if r.features.len() == d {
                            xbuf.extend_from_slice(&r.features);
                            evals.push(r);
                        } else {
                            // Misfits fail alone; the rest of the batch
                            // still evaluates.
                            let _ = r.respond.send(format!(
                                "ERR {} wrong feature count (want {d})",
                                r.id
                            ));
                        }
                    }
                    if evals.is_empty() {
                        continue;
                    }
                    match engine.classify_batch(&xbuf, evals.len()) {
                        Ok(outcomes) => {
                            for (r, o) in evals.iter().zip(outcomes.iter()) {
                                let lat = r.enqueued.elapsed().as_nanos() as u64;
                                m.record_request(lat, o.models_evaluated, o.early);
                                let _ = r.respond.send(format!(
                                    "OK {} {} {:.6} {} {}",
                                    r.id,
                                    if o.positive { "pos" } else { "neg" },
                                    o.score,
                                    o.models_evaluated,
                                    lat / 1_000
                                ));
                            }
                        }
                        Err(e) => {
                            for r in &evals {
                                let _ = r.respond.send(format!("ERR {} engine: {e}", r.id));
                            }
                        }
                    }
                }
            }));
        }
        let dispatcher = Arc::new(Dispatcher { shards: shard_channels });

        // Acceptor: one thread per connection (serving fan-in is small;
        // the shard workers are the throughput engine).
        let conns: Arc<std::sync::Mutex<Vec<TcpStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let acc_shutdown = shutdown.clone();
        let acc_metrics = metrics.clone();
        let acc_conns = conns.clone();
        let acc_slot = plan_slot.clone();
        let acceptor = std::thread::spawn(move || {
            listener.set_nonblocking(true).ok();
            loop {
                if acc_shutdown.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Ok(dup) = stream.try_clone() {
                            acc_conns.lock().unwrap().push(dup);
                        }
                        let dispatch = dispatcher.clone();
                        let m = acc_metrics.clone();
                        let slot = acc_slot.clone();
                        std::thread::spawn(move || handle_conn(stream, dispatch, m, slot));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            // The dispatcher (and its senders) drops here → once
            // connection threads exit too, the shard queues close and
            // every worker drains.
        });

        Ok(Server {
            addr,
            metrics,
            shutdown,
            acceptor: Some(acceptor),
            workers,
            conns,
        })
    }

    /// Signal shutdown, sever open connections, and join threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Force connection reader loops to end so their request senders
        // drop; otherwise the workers would wait on clients that outlive
        // the server handle.
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle the `RELOAD <path>` control command: load + compile off the
/// request path (on this connection's thread), then atomically publish
/// into the slot. Shard workers adopt the new plan at their next batch
/// boundary: a batch mid-classification finishes on its old plan, and a
/// width-compatible swap (the deployment case: re-optimized π/ε for the
/// same feature space) never errors any request.
///
/// The path may name either artifact format — [`PlanArtifact::load`]
/// sniffs the magic bytes. Deploying the zero-copy `qwyc-plan-bin-v1`
/// form makes the reload near-free: one read + validated pointer casts
/// instead of a JSON parse + re-permute.
fn handle_reload(path: &str, slot: &Option<Arc<PlanSlot>>) -> String {
    let Some(slot) = slot else {
        return "ERR - reload unsupported for this backend".into();
    };
    if path.is_empty() {
        return "ERR - malformed RELOAD (usage: RELOAD <path>)".into();
    }
    match PlanArtifact::load(Path::new(path)) {
        Ok(artifact) => {
            let compiled = artifact.compiled();
            let t = compiled.t();
            let gen = slot.swap(compiled);
            format!("RELOADED {} gen={gen} T={t}", artifact.name())
        }
        Err(e) => format!("ERR - reload: {e}"),
    }
}

fn handle_conn(
    stream: TcpStream,
    dispatch: Arc<Dispatcher>,
    metrics: Arc<ShardedMetrics>,
    plan_slot: Option<Arc<PlanSlot>>,
) {
    let peer_write = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = std::io::BufWriter::new(peer_write);
    let reader = BufReader::new(stream);
    // Response pump: a dedicated channel per connection keeps ordering
    // per-client while letting shard workers answer out of batch order.
    let (resp_tx, resp_rx) = mpsc::channel::<String>();
    let pump = std::thread::spawn(move || {
        let mut w = writer;
        while let Ok(line) = resp_rx.recv() {
            if w.write_all(line.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                break;
            }
            let _ = w.flush();
        }
    });

    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.splitn(3, ' ');
        match parts.next() {
            Some("EVAL") => {
                let id = parts.next().and_then(|s| s.parse::<u64>().ok());
                let feats: Option<Vec<f32>> = parts
                    .next()
                    .map(|s| {
                        s.split(',')
                            .map(|t| t.trim().parse::<f32>())
                            .collect::<Result<_, _>>()
                    })
                    .transpose()
                    .ok()
                    .flatten();
                match (id, feats) {
                    (Some(id), Some(features)) => {
                        let req = Request {
                            id,
                            features,
                            enqueued: Instant::now(),
                            respond: resp_tx.clone(),
                        };
                        match dispatch.route(req) {
                            Ok(()) => {}
                            Err(RouteError::Busy(r)) => {
                                let _ = resp_tx.send(format!("BUSY {}", r.id));
                            }
                            Err(RouteError::Closed(r)) => {
                                let _ = resp_tx
                                    .send(format!("ERR {} server shutting down", r.id));
                            }
                        }
                    }
                    _ => {
                        let _ = resp_tx.send("ERR - malformed EVAL".into());
                    }
                }
            }
            Some("STATS") => {
                let _ = resp_tx.send(format!("STATS {}", metrics.snapshot().report()));
            }
            Some("RELOAD") => {
                // The path is everything after the verb (paths may
                // contain spaces).
                let path = line["RELOAD".len()..].trim();
                let _ = resp_tx.send(handle_reload(path, &plan_slot));
            }
            Some("QUIT") => break,
            _ => {
                let _ = resp_tx.send("ERR - unknown command".into());
            }
        }
    }
    drop(resp_tx);
    let _ = pump.join();
}

/// Minimal blocking client for tests/examples/load generators.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

/// Parsed server response to an EVAL.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    pub id: u64,
    pub positive: bool,
    pub score: f32,
    pub models: u32,
    pub latency_us: u64,
}

/// Any server → client line, id-correlated where the protocol carries
/// one (every ERR line now does; `-` parses as `None`).
#[derive(Clone, Debug)]
pub enum Reply {
    Ok(EvalResponse),
    /// Request shed by a full shard queue; retry or back off.
    Busy { id: u64 },
    Err { id: Option<u64>, message: String },
    /// STATS / RELOADED / anything else, verbatim.
    Other(String),
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 0 })
    }

    /// Send one EVAL (does not wait for the response).
    pub fn send_eval(&mut self, features: &[f32]) -> std::io::Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        let feats: Vec<String> = features.iter().map(|v| format!("{v}")).collect();
        writeln!(self.writer, "EVAL {id} {}", feats.join(","))?;
        Ok(id)
    }

    /// Read one response line and classify it (blocking).
    pub fn read_reply(&mut self) -> std::io::Result<Reply> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Ok(parse_reply(line.trim()))
    }

    /// Read one OK response (blocking); any other reply is an error.
    pub fn read_response(&mut self) -> std::io::Result<EvalResponse> {
        match self.read_reply()? {
            Reply::Ok(r) => Ok(r),
            other => Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{other:?}"),
            )),
        }
    }

    /// Convenience: send and wait.
    pub fn eval(&mut self, features: &[f32]) -> std::io::Result<EvalResponse> {
        self.send_eval(features)?;
        self.read_response()
    }

    /// Fetch the server's STATS line. Replies are FIFO per connection,
    /// so call this only when no pipelined EVALs are outstanding (or use
    /// a dedicated control connection) — otherwise the next line read is
    /// an earlier EVAL's reply, not the STATS line.
    pub fn stats(&mut self) -> std::io::Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }

    /// Ask the server to hot-swap its plan; returns the raw reply line
    /// (`RELOADED ...` on success, `ERR - reload: ...` on failure).
    /// Same FIFO caveat as [`Client::stats`]: issue RELOAD from a
    /// connection with no outstanding EVALs — a dedicated control
    /// connection, as `qwyc reload` and the e2e tests do.
    pub fn reload(&mut self, plan_path: &str) -> std::io::Result<String> {
        writeln!(self.writer, "RELOAD {plan_path}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

fn parse_reply(line: &str) -> Reply {
    if let Some(r) = parse_eval_response(line) {
        return Reply::Ok(r);
    }
    let mut p = line.splitn(3, ' ');
    match p.next() {
        Some("BUSY") => {
            if let Some(id) = p.next().and_then(|s| s.parse::<u64>().ok()) {
                return Reply::Busy { id };
            }
        }
        Some("ERR") => {
            let id = p.next().and_then(|s| s.parse::<u64>().ok());
            let message = p.next().unwrap_or("").to_string();
            return Reply::Err { id, message };
        }
        _ => {}
    }
    Reply::Other(line.to_string())
}

fn parse_eval_response(line: &str) -> Option<EvalResponse> {
    let mut p = line.split(' ');
    if p.next()? != "OK" {
        return None;
    }
    Some(EvalResponse {
        id: p.next()?.parse().ok()?,
        positive: p.next()? == "pos",
        score: p.next()?.parse().ok()?,
        models: p.next()?.parse().ok()?,
        latency_us: p.next()?.parse().ok()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_response_roundtrip() {
        let r = parse_eval_response("OK 42 pos 1.250000 7 133").unwrap();
        assert_eq!(r.id, 42);
        assert!(r.positive);
        assert_eq!(r.models, 7);
        assert_eq!(r.latency_us, 133);
        assert!(parse_eval_response("ERR 1 nope").is_none());
    }

    #[test]
    fn parse_reply_classifies_protocol_lines() {
        match parse_reply("OK 3 neg -0.500000 2 10") {
            Reply::Ok(r) => {
                assert_eq!(r.id, 3);
                assert!(!r.positive);
            }
            other => panic!("{other:?}"),
        }
        match parse_reply("BUSY 17") {
            Reply::Busy { id } => assert_eq!(id, 17),
            other => panic!("{other:?}"),
        }
        match parse_reply("ERR 5 engine: boom") {
            Reply::Err { id, message } => {
                assert_eq!(id, Some(5));
                assert_eq!(message, "engine: boom");
            }
            other => panic!("{other:?}"),
        }
        // `-` id (request id unknown) parses as None.
        match parse_reply("ERR - malformed EVAL") {
            Reply::Err { id, message } => {
                assert_eq!(id, None);
                assert_eq!(message, "malformed EVAL");
            }
            other => panic!("{other:?}"),
        }
        match parse_reply("RELOADED demo gen=1 T=6") {
            Reply::Other(s) => assert!(s.starts_with("RELOADED")),
            other => panic!("{other:?}"),
        }
    }
}
