//! `qwyc-plan-bin-v1`: the zero-copy binary plan artifact.
//!
//! A compiled plan flattened into one contiguous, alignment-padded
//! buffer: a fixed 64-byte header (magic / version / endianness tag /
//! section count), a fixed-width section table, and ten sections of
//! fixed-width `#[repr(C)]` records — scalars, the four meta strings,
//! the order π, the ε⁺/ε⁻ threshold vectors, per-position costs, a
//! model directory, the packed model payloads (16-byte tree node
//! records, u32 lattice feature subsets + f32 vertex tables), and the
//! two quantization sections added in version 2: `bin_edges` (per-
//! feature sorted distinct split thresholds) and `quant_nodes` (the
//! trees' u16 threshold-bin banks in position order; both empty when
//! the plan did not quantize — see `plan/quant.rs`). Loading is one
//! `read` into an 8-byte-aligned buffer followed by validated pointer
//! casts — no parsing, no re-permutation — so a serving `RELOAD` costs
//! little more than the file read plus the invariant checks every
//! compile path runs. The quantized layout itself is *rebuilt* by
//! `CompiledPlan::from_parts` (like the SoA banks); the stored
//! sections exist for `plan-info` inspection and are verified
//! byte-for-byte against the rebuild at decode, so a flipped bit in
//! either one fails loudly instead of shipping a silently divergent
//! kernel.
//!
//! Layout rules (documented in README "Plan artifacts"):
//! - all multi-byte fields are stored in the **writer's native byte
//!   order**; the header carries an endianness tag and readers reject a
//!   mismatch rather than byte-swap,
//! - the writer starts every section on a 64-byte boundary; readers
//!   require only the 8-byte alignment the record types need,
//! - section sizes are fully determined by `t` (from the scalars
//!   section) and the model directory, and every length is checked
//!   before a cast — a flipped byte fails loudly as
//!   [`QwycError::Schema`] naming the bad section,
//! - the version field is bumped on any layout change; readers accept
//!   exactly the versions they know.
//!
//! The section payloads store the *compiled* (position-major) form plus
//! the original-index order π, which is enough to reconstruct the
//! uncompiled [`QwycPlan`](super::QwycPlan) exactly (inverse-permute
//! models and costs), so `plan-info`, JSON re-export, and `simulate`
//! work from either format.

use super::compiled::CompiledPlan;
use super::PlanMeta;
use crate::ensemble::BaseModel;
use crate::error::QwycError;
use crate::gbt::tree::{Node, Tree};
use crate::lattice::model::MAX_DIM;
use crate::lattice::Lattice;
use std::io::Read;
use std::mem::{align_of, size_of};
use std::path::Path;

/// First eight bytes of every binary plan. Distinct from `{` so format
/// auto-detection is a one-byte sniff.
pub const MAGIC: [u8; 8] = *b"QWYCBIN1";
/// Current layout version; bumped on any change to the byte layout.
/// Version 2 appended the `bin_edges` and `quant_nodes` sections after
/// `model_data`; sections 0–7 are laid out exactly as in version 1.
pub const VERSION: u32 = 2;
/// Stored natively by the writer; a reader that sees these bytes in a
/// different order is running on hardware with the opposite endianness.
const ENDIAN_TAG: u32 = 0x0102_0304;
const N_SECTIONS: usize = 10;
const SECTION_NAMES: [&str; N_SECTIONS] = [
    "scalars",
    "strings",
    "order",
    "eps_pos",
    "eps_neg",
    "costs",
    "model_dir",
    "model_data",
    "bin_edges",
    "quant_nodes",
];
const FMT: &str = "qwyc-plan-bin-v1";

// ---- on-disk records ---------------------------------------------------
// Sizes and alignments are pinned by const assertions in
// `plan/compiled.rs`; a field reorder is a compile error, not a corrupt
// artifact. None of these records have padding bytes, so writing them
// as raw bytes never leaks uninitialized memory.

/// Fixed 64-byte file header.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct FileHeader {
    /// [`MAGIC`].
    pub magic: [u8; 8],
    /// [`VERSION`].
    pub version: u32,
    /// Endianness tag (must read back as `0x01020304`).
    pub endian: u32,
    /// Total header size in bytes (64 for every version so far).
    pub header_len: u32,
    /// Number of section-table entries that follow the header.
    pub n_sections: u32,
    /// Total file length in bytes — rejects truncated files up front.
    pub file_len: u64,
    /// Reserved, zero-filled.
    pub reserved: [u8; 32],
}

/// One section-table entry.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct SectionEntry {
    /// Section kind; v2 requires the ten known kinds in order 0..=9.
    pub kind: u32,
    /// Reserved, zero.
    pub reserved: u32,
    /// Byte offset of the section payload from the start of the file.
    pub offset: u64,
    /// Payload length in bytes (excluding alignment padding).
    pub len: u64,
}

/// Fixed-width scalar fields of the plan (section 0).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PlanScalars {
    /// Trade-off weight the plan was optimized for (provenance).
    pub alpha: f64,
    /// Declared serving feature width (0 ⇒ infer from the models).
    pub n_features: u64,
    /// Number of positions T; sizes every other section.
    pub t: u64,
    /// Ensemble bias folded into the running score at position 0.
    pub bias: f32,
    /// Full-classifier decision threshold β.
    pub beta: f32,
    /// 1 if the plan is negative-exit-only (derived metadata).
    pub neg_only: u32,
    /// Reserved, zero.
    pub reserved: u32,
}

/// Model directory entry (section 6), one per position.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct ModelRec {
    /// 0 = tree (payload: `count` × [`Node`]), 1 = lattice (payload:
    /// `count` × u32 features, padded to 8, then 2^count × f32 theta).
    pub kind: u32,
    /// Node count (tree) or dimension (lattice).
    pub count: u32,
    /// Payload byte offset *within* the model-data section.
    pub offset: u64,
    /// Payload byte length.
    pub len: u64,
}

/// Marker for types that may be reinterpreted to/from raw bytes.
///
/// # Safety
/// Implement only for `#[repr(C)]` types in which every bit pattern is
/// a valid value and whose layout has no padding bytes (both pinned by
/// the const assertions in `plan/compiled.rs`).
unsafe trait Pod: Copy {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for Node {}
unsafe impl Pod for FileHeader {}
unsafe impl Pod for SectionEntry {}
unsafe impl Pod for PlanScalars {}
unsafe impl Pod for ModelRec {}

fn bytes_of<T: Pod>(v: &T) -> &[u8] {
    // SAFETY: Pod guarantees no padding, so all size_of::<T>() bytes
    // are initialized; lifetime is tied to the borrow of `v`.
    unsafe { std::slice::from_raw_parts((v as *const T).cast::<u8>(), size_of::<T>()) }
}

fn bytes_of_slice<T: Pod>(v: &[T]) -> &[u8] {
    // SAFETY: as above, element count times.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

fn view<T: Pod>(b: &[u8], what: &str) -> Result<&T, QwycError> {
    if b.len() != size_of::<T>() {
        return Err(QwycError::Schema(format!(
            "{FMT}: {what}: expected {} bytes, got {}",
            size_of::<T>(),
            b.len()
        )));
    }
    if b.as_ptr() as usize % align_of::<T>() != 0 {
        return Err(QwycError::Schema(format!("{FMT}: {what}: payload is misaligned")));
    }
    // SAFETY: length and alignment checked; Pod makes any bytes valid.
    Ok(unsafe { &*b.as_ptr().cast::<T>() })
}

fn view_slice<'a, T: Pod>(b: &'a [u8], what: &str) -> Result<&'a [T], QwycError> {
    if b.len() % size_of::<T>() != 0 {
        return Err(QwycError::Schema(format!(
            "{FMT}: {what}: {} bytes is not a whole number of {}-byte records",
            b.len(),
            size_of::<T>()
        )));
    }
    if b.as_ptr() as usize % align_of::<T>() != 0 {
        return Err(QwycError::Schema(format!("{FMT}: {what}: payload is misaligned")));
    }
    // SAFETY: length and alignment checked; Pod makes any bytes valid.
    Ok(unsafe { std::slice::from_raw_parts(b.as_ptr().cast::<T>(), b.len() / size_of::<T>()) })
}

/// An owned byte buffer whose storage is 8-byte aligned, so section
/// payloads (whose offsets are multiples of 8) can be viewed in place
/// as `&[u32]`/`&[f32]`/record slices without copying.
pub(super) struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    /// Read a whole file with a single `read_exact` into aligned storage.
    pub fn read_file(path: &Path) -> Result<AlignedBuf, QwycError> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| QwycError::Io(format!("{}: {e}", path.display())))?;
        let len = f
            .metadata()
            .map_err(|e| QwycError::Io(format!("{}: {e}", path.display())))?
            .len() as usize;
        let mut buf = AlignedBuf { words: vec![0u64; len.div_ceil(8)], len };
        f.read_exact(buf.bytes_mut())
            .map_err(|e| QwycError::Io(format!("{}: {e}", path.display())))?;
        Ok(buf)
    }

    /// Copy an existing byte slice into aligned storage (tests, sniffed
    /// in-memory buffers).
    pub fn from_bytes(b: &[u8]) -> AlignedBuf {
        let mut buf = AlignedBuf { words: vec![0u64; b.len().div_ceil(8)], len: b.len() };
        buf.bytes_mut().copy_from_slice(b);
        buf
    }

    pub fn bytes(&self) -> &[u8] {
        // SAFETY: words owns at least `len` initialized bytes.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>(), self.len) }
    }

    fn bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: as above, and the borrow is exclusive.
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr().cast::<u8>(), self.len) }
    }
}

// ---- encode ------------------------------------------------------------

fn pad_to(buf: &mut Vec<u8>, align: usize) {
    while buf.len() % align != 0 {
        buf.push(0);
    }
}

fn push_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_ne_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Section 8 payload: `u32` feature-slot count, then one `u32` edge
/// count per feature, then every feature's sorted distinct thresholds
/// as concatenated `f32`s. Empty when the plan did not quantize.
fn encode_bin_edges(cp: &CompiledPlan) -> Vec<u8> {
    let Some(q) = cp.quant() else { return Vec::new() };
    let counts = q.edge_counts();
    let mut buf = Vec::with_capacity(4 * (1 + counts.len() + q.total_edges()));
    buf.extend_from_slice(&(counts.len() as u32).to_ne_bytes());
    buf.extend_from_slice(bytes_of_slice(&counts));
    for f in 0..q.n_features() {
        buf.extend_from_slice(bytes_of_slice(q.edges(f)));
    }
    buf
}

/// Section 9 payload: every tree's `u16` threshold-bin bank,
/// concatenated in position (π) order. Empty when the plan did not
/// quantize.
fn encode_quant_nodes(cp: &CompiledPlan) -> Vec<u8> {
    bytes_of_slice(&cp.quantized_node_bins()).to_vec()
}

/// Serialize a compiled plan (plus its meta and the ensemble name, which
/// the compiled form does not carry) into a `qwyc-plan-bin-v1` buffer.
pub(super) fn encode(meta: &PlanMeta, ensemble_name: &str, cp: &CompiledPlan) -> Vec<u8> {
    let t = cp.t();
    assert!(t < u32::MAX as usize, "plan too large for qwyc-plan-bin-v1");
    let scalars = PlanScalars {
        alpha: meta.alpha,
        n_features: meta.n_features as u64,
        t: t as u64,
        bias: cp.bias(),
        beta: cp.beta(),
        neg_only: meta.neg_only as u32,
        reserved: 0,
    };
    let mut strings = Vec::new();
    for s in [meta.name.as_str(), ensemble_name, meta.source.as_str(), meta.created_by.as_str()] {
        push_str(&mut strings, s);
    }
    let order: Vec<u32> = cp.order().iter().map(|&m| m as u32).collect();
    let mut dir: Vec<ModelRec> = Vec::with_capacity(t);
    let mut data: Vec<u8> = Vec::new();
    for m in cp.models() {
        pad_to(&mut data, 8);
        let off = data.len() as u64;
        match m {
            BaseModel::Tree(tr) => {
                data.extend_from_slice(bytes_of_slice(&tr.nodes));
                dir.push(ModelRec {
                    kind: 0,
                    count: tr.nodes.len() as u32,
                    offset: off,
                    len: data.len() as u64 - off,
                });
            }
            BaseModel::Lattice(l) => {
                let feats: Vec<u32> = l.features.iter().map(|&f| f as u32).collect();
                data.extend_from_slice(bytes_of_slice(&feats));
                pad_to(&mut data, 8);
                data.extend_from_slice(bytes_of_slice(&l.theta));
                dir.push(ModelRec {
                    kind: 1,
                    count: l.dim() as u32,
                    offset: off,
                    len: data.len() as u64 - off,
                });
            }
        }
    }

    let bin_edges = encode_bin_edges(cp);
    let quant_nodes = encode_quant_nodes(cp);
    let payloads: [&[u8]; N_SECTIONS] = [
        bytes_of(&scalars),
        &strings,
        bytes_of_slice(&order),
        bytes_of_slice(cp.eps_pos()),
        bytes_of_slice(cp.eps_neg()),
        bytes_of_slice(cp.position_costs()),
        bytes_of_slice(&dir),
        &data,
        &bin_edges,
        &quant_nodes,
    ];
    let table_len = N_SECTIONS * size_of::<SectionEntry>();
    let mut file = vec![0u8; size_of::<FileHeader>() + table_len];
    let mut entries = [SectionEntry { kind: 0, reserved: 0, offset: 0, len: 0 }; N_SECTIONS];
    for (k, payload) in payloads.iter().enumerate() {
        // The writer starts every section on a 64-byte boundary; readers
        // only require the record alignment (8).
        pad_to(&mut file, 64);
        entries[k] = SectionEntry {
            kind: k as u32,
            reserved: 0,
            offset: file.len() as u64,
            len: payload.len() as u64,
        };
        file.extend_from_slice(payload);
    }
    let header = FileHeader {
        magic: MAGIC,
        version: VERSION,
        endian: ENDIAN_TAG,
        header_len: size_of::<FileHeader>() as u32,
        n_sections: N_SECTIONS as u32,
        file_len: file.len() as u64,
        reserved: [0; 32],
    };
    file[..size_of::<FileHeader>()].copy_from_slice(bytes_of(&header));
    file[size_of::<FileHeader>()..size_of::<FileHeader>() + table_len]
        .copy_from_slice(bytes_of_slice(&entries));
    file
}

// ---- decode ------------------------------------------------------------

/// Everything a binary artifact yields: the serving-ready compiled plan
/// plus the metadata needed to reconstruct the uncompiled `QwycPlan`.
pub(super) struct DecodedPlan {
    pub compiled: CompiledPlan,
    pub meta: PlanMeta,
    pub ensemble_name: String,
}

/// True if `bytes` starts with the `qwyc-plan-bin-v1` magic.
pub(super) fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] == MAGIC
}

fn parse_header(bytes: &[u8]) -> Result<&FileHeader, QwycError> {
    if bytes.len() < size_of::<FileHeader>() {
        return Err(QwycError::Schema(format!(
            "{FMT}: file too short for the header ({} bytes)",
            bytes.len()
        )));
    }
    if bytes[..MAGIC.len()] != MAGIC {
        return Err(QwycError::Schema(format!("{FMT}: bad magic (not a binary plan)")));
    }
    let hdr: &FileHeader = view(&bytes[..size_of::<FileHeader>()], "header")?;
    if hdr.version != VERSION {
        return Err(QwycError::Schema(format!(
            "{FMT}: unsupported version {} (this reader knows version {VERSION})",
            hdr.version
        )));
    }
    if hdr.endian != ENDIAN_TAG {
        return Err(QwycError::Schema(format!(
            "{FMT}: endianness mismatch (written on opposite-endian hardware)"
        )));
    }
    if hdr.header_len as usize != size_of::<FileHeader>()
        || hdr.n_sections as usize != N_SECTIONS
    {
        return Err(QwycError::Schema(format!(
            "{FMT}: header geometry mismatch (header_len {}, n_sections {})",
            hdr.header_len, hdr.n_sections
        )));
    }
    if hdr.file_len != bytes.len() as u64 {
        return Err(QwycError::Schema(format!(
            "{FMT}: truncated or padded file (header says {} bytes, file has {})",
            hdr.file_len,
            bytes.len()
        )));
    }
    Ok(hdr)
}

fn parse_sections(bytes: &[u8]) -> Result<&[SectionEntry], QwycError> {
    let lo = size_of::<FileHeader>();
    let hi = lo + N_SECTIONS * size_of::<SectionEntry>();
    if bytes.len() < hi {
        return Err(QwycError::Schema(format!("{FMT}: file too short for the section table")));
    }
    let entries: &[SectionEntry] = view_slice(&bytes[lo..hi], "section table")?;
    for (k, e) in entries.iter().enumerate() {
        let name = SECTION_NAMES[k];
        if e.kind != k as u32 {
            return Err(QwycError::Schema(format!(
                "{FMT}: section {k} ({name}): unexpected kind {}",
                e.kind
            )));
        }
        if e.offset % 8 != 0 {
            return Err(QwycError::Schema(format!(
                "{FMT}: section {name}: offset {} is not 8-byte aligned",
                e.offset
            )));
        }
        let end = e.offset.checked_add(e.len).ok_or_else(|| {
            QwycError::Schema(format!("{FMT}: section {name}: offset+len overflows"))
        })?;
        if end > bytes.len() as u64 {
            return Err(QwycError::Schema(format!(
                "{FMT}: section {name}: [{}, {end}) runs past end of file ({} bytes)",
                e.offset,
                bytes.len()
            )));
        }
    }
    Ok(entries)
}

fn section<'a>(bytes: &'a [u8], entries: &[SectionEntry], k: usize) -> &'a [u8] {
    let e = &entries[k];
    &bytes[e.offset as usize..(e.offset + e.len) as usize]
}

fn read_str(buf: &[u8], cursor: &mut usize, what: &str) -> Result<String, QwycError> {
    let err = |m: String| QwycError::Schema(format!("{FMT}: section strings: {what}: {m}"));
    let lo = *cursor;
    if lo + 4 > buf.len() {
        return Err(err("length prefix runs past section end".into()));
    }
    let n = u32::from_ne_bytes(buf[lo..lo + 4].try_into().unwrap()) as usize;
    let (s0, s1) = (lo + 4, lo + 4 + n);
    if s1 > buf.len() {
        return Err(err(format!("{n}-byte string runs past section end")));
    }
    *cursor = s1;
    String::from_utf8(buf[s0..s1].to_vec()).map_err(|_| err("not valid UTF-8".into()))
}

fn expect_len(name: &str, got: usize, want: usize) -> Result<(), QwycError> {
    if got != want {
        return Err(QwycError::Schema(format!(
            "{FMT}: section {name}: expected {want} entries, got {got}"
        )));
    }
    Ok(())
}

/// Decode a `qwyc-plan-bin-v1` buffer (must come from an [`AlignedBuf`]
/// or otherwise be 8-byte aligned). Every section is bounds- and
/// shape-checked before its pointer cast, then the parts run through the
/// same [`CompiledPlan::from_parts`] validation as the JSON path.
pub(super) fn decode(bytes: &[u8]) -> Result<DecodedPlan, QwycError> {
    parse_header(bytes)?;
    let entries = parse_sections(bytes)?;

    let scalars: &PlanScalars = view(section(bytes, entries, 0), "section scalars")?;
    let t = scalars.t as usize;

    let strings = section(bytes, entries, 1);
    let mut cursor = 0usize;
    let plan_name = read_str(strings, &mut cursor, "plan name")?;
    let ensemble_name = read_str(strings, &mut cursor, "ensemble name")?;
    let source = read_str(strings, &mut cursor, "source")?;
    let created_by = read_str(strings, &mut cursor, "created_by")?;

    let order_raw: &[u32] = view_slice(section(bytes, entries, 2), "section order")?;
    expect_len("order", order_raw.len(), t)?;
    let eps_pos: &[f32] = view_slice(section(bytes, entries, 3), "section eps_pos")?;
    expect_len("eps_pos", eps_pos.len(), t)?;
    let eps_neg: &[f32] = view_slice(section(bytes, entries, 4), "section eps_neg")?;
    expect_len("eps_neg", eps_neg.len(), t)?;
    let costs: &[f32] = view_slice(section(bytes, entries, 5), "section costs")?;
    expect_len("costs", costs.len(), t)?;
    let dir: &[ModelRec] = view_slice(section(bytes, entries, 6), "section model_dir")?;
    expect_len("model_dir", dir.len(), t)?;

    let data = section(bytes, entries, 7);
    let mut models: Vec<BaseModel> = Vec::with_capacity(t);
    for (r, rec) in dir.iter().enumerate() {
        let err = |m: String| {
            QwycError::Schema(format!("{FMT}: section model_data: model at position {r}: {m}"))
        };
        let end = rec
            .offset
            .checked_add(rec.len)
            .ok_or_else(|| err("offset+len overflows".into()))?;
        if end > data.len() as u64 || rec.offset % 8 != 0 {
            return Err(err(format!(
                "payload [{}, {end}) is misaligned or out of bounds ({} bytes)",
                rec.offset,
                data.len()
            )));
        }
        let payload = &data[rec.offset as usize..end as usize];
        match rec.kind {
            0 => {
                let nodes: &[Node] = view_slice(payload, "tree payload")?;
                if nodes.len() != rec.count as usize {
                    return Err(err(format!(
                        "directory says {} nodes, payload holds {}",
                        rec.count,
                        nodes.len()
                    )));
                }
                models.push(BaseModel::Tree(Tree { nodes: nodes.to_vec() }));
            }
            1 => {
                let dim = rec.count as usize;
                if dim > MAX_DIM {
                    return Err(err(format!("lattice dim {dim} > MAX_DIM {MAX_DIM}")));
                }
                let feats_len = dim * 4;
                let theta_off = feats_len.div_ceil(8) * 8;
                let want = theta_off + (1usize << dim) * 4;
                if payload.len() != want {
                    return Err(err(format!(
                        "lattice payload is {} bytes, dim {dim} requires {want}",
                        payload.len()
                    )));
                }
                let feats: &[u32] = view_slice(&payload[..feats_len], "lattice features")?;
                let theta: &[f32] = view_slice(&payload[theta_off..], "lattice theta")?;
                models.push(BaseModel::Lattice(Lattice::from_params(
                    feats.iter().map(|&f| f as usize).collect(),
                    theta.to_vec(),
                )));
            }
            k => return Err(err(format!("unknown model kind {k}"))),
        }
    }

    let compiled = CompiledPlan::from_parts(
        &plan_name,
        models,
        order_raw.iter().map(|&m| m as usize).collect(),
        eps_pos.to_vec(),
        eps_neg.to_vec(),
        scalars.bias,
        scalars.beta,
        costs.to_vec(),
        scalars.n_features as usize,
    )?;
    // The quantized layout the kernel actually runs is rebuilt by
    // `from_parts` from the model payloads; the stored sections are the
    // writer's view of the same data. A byte-level mismatch means the
    // artifact was corrupted or hand-edited, so fail loudly rather than
    // serve a plan whose inspection output lies about its kernel.
    for (k, mismatch) in [
        (8usize, section(bytes, entries, 8) != encode_bin_edges(&compiled)),
        (9usize, section(bytes, entries, 9) != encode_quant_nodes(&compiled)),
    ] {
        if mismatch {
            return Err(QwycError::Schema(format!(
                "{FMT}: section {}: stored quantization does not match the \
                 layout rebuilt from the model payloads",
                SECTION_NAMES[k]
            )));
        }
    }
    let meta = PlanMeta {
        name: plan_name,
        alpha: scalars.alpha,
        neg_only: scalars.neg_only != 0,
        source,
        created_by,
        n_features: scalars.n_features as usize,
    };
    Ok(DecodedPlan { compiled, meta, ensemble_name })
}

// ---- inspection --------------------------------------------------------

/// One section-table row, for `plan-info`.
#[derive(Clone, Debug)]
pub struct SectionInfo {
    /// Section name (fixed per kind in v1).
    pub name: &'static str,
    /// Byte offset from the start of the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
}

/// Header-level summary of a binary plan artifact.
#[derive(Clone, Debug)]
pub struct BinaryInfo {
    /// Layout version from the header.
    pub version: u32,
    /// Total file length in bytes.
    pub file_len: u64,
    /// Plan name (strings section).
    pub plan_name: String,
    /// Number of positions T.
    pub t: u64,
    /// Declared feature width (0 ⇒ inferred at compile).
    pub n_features: u64,
    /// Per-feature bin-edge counts from the `bin_edges` section; empty
    /// when the plan is not quantized.
    pub edge_counts: Vec<u32>,
    /// The section table.
    pub sections: Vec<SectionInfo>,
}

/// Parse the per-feature edge counts out of a `bin_edges` section
/// payload (layout documented on [`encode_bin_edges`]). An empty
/// section means the plan is not quantized and yields an empty vector.
fn parse_edge_counts(payload: &[u8]) -> Result<Vec<u32>, QwycError> {
    let err = |m: &str| QwycError::Schema(format!("{FMT}: section bin_edges: {m}"));
    if payload.is_empty() {
        return Ok(Vec::new());
    }
    if payload.len() < 4 {
        return Err(err("too short for the feature-count prefix"));
    }
    let f = u32::from_ne_bytes(payload[..4].try_into().unwrap()) as usize;
    let counts_end = 4 + 4 * f;
    if counts_end > payload.len() {
        return Err(err("count table runs past section end"));
    }
    let counts: &[u32] = view_slice(&payload[4..counts_end], "bin_edges counts")?;
    let total: u64 = counts.iter().map(|&c| u64::from(c)).sum();
    if payload.len() as u64 != counts_end as u64 + 4 * total {
        return Err(err("edge payload length does not match the count table"));
    }
    Ok(counts.to_vec())
}

/// Read only the header, section table, scalars, plan name, and the
/// quantization edge counts — the cheap ops-debugging view behind
/// `plan-info`.
pub(super) fn inspect(bytes: &[u8]) -> Result<BinaryInfo, QwycError> {
    let hdr = parse_header(bytes)?;
    let entries = parse_sections(bytes)?;
    let scalars: &PlanScalars = view(section(bytes, entries, 0), "section scalars")?;
    let mut cursor = 0usize;
    let plan_name = read_str(section(bytes, entries, 1), &mut cursor, "plan name")?;
    Ok(BinaryInfo {
        version: hdr.version,
        file_len: hdr.file_len,
        plan_name,
        t: scalars.t,
        n_features: scalars.n_features,
        edge_counts: parse_edge_counts(section(bytes, entries, 8))?,
        sections: entries
            .iter()
            .enumerate()
            .map(|(k, e)| SectionInfo { name: SECTION_NAMES[k], offset: e.offset, len: e.len })
            .collect(),
    })
}
