//! The serving-ready form of a [`QwycPlan`](super::QwycPlan).
//!
//! `compile()` pays every per-load cost exactly once: base models are
//! cloned into π order (position r holds the model the sweep evaluates
//! r-th — no indirection through `order[r]` on the hot path), trees get
//! their [`TreeSoa`] banks built per position, the prefix-cost table
//! cum[r] = Σ_{q<r} c_{π(q)} is tabulated, and the structural invariants
//! (classifier, trees, feature-count agreement) are verified. Everything
//! downstream — `NativeEngine`, `FilterPipeline`, the CLI — holds a
//! `CompiledPlan` and calls the shared sweep core without re-checking.

use super::QwycPlan;
use crate::ensemble::BaseModel;
use crate::error::QwycError;
use crate::gbt::tree::TreeSoa;
use crate::qwyc::sweep::{sweep_batched, SweepOutcome, SweepParams};
use crate::qwyc::SingleResult;
use crate::util::pool::Pool;

/// A validated, position-major, ready-to-sweep plan.
///
/// The compiled form is **immutable and self-contained** — every field
/// is owned data, and all per-evaluation scratch (active lists, running
/// scores, lattice walk buffers) lives with the caller, never in the
/// plan. That makes `CompiledPlan: Send + Sync` by construction, so one
/// compile can be shared across N serving shards behind an `Arc`
/// (asserted below; the sharded server relies on it).
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// Base models in evaluation order: `models[r]` runs at position r.
    models: Vec<BaseModel>,
    /// Per-position SoA banks (None for lattices), aligned with `models`.
    soa: Vec<Option<TreeSoa>>,
    eps_pos: Vec<f32>,
    eps_neg: Vec<f32>,
    bias: f32,
    beta: f32,
    /// π — position r evaluates original model `order[r]` (provenance).
    order: Vec<usize>,
    /// `prefix_cost[r]` = Σ_{q<r} c_{π(q)}; `prefix_cost[T]` is the full
    /// evaluation cost.
    prefix_cost: Vec<f64>,
    /// Serving feature width (declared by the plan, or inferred).
    n_features: usize,
    /// Largest feature index any base model reads, plus one — the floor
    /// every input row stride must meet.
    min_features: usize,
}

// Compile once, hand out `Arc<CompiledPlan>` to every shard: the plan
// must stay shareable across worker threads.
const _: fn() = || {
    fn shared_across_shards<T: Send + Sync>() {}
    shared_across_shards::<CompiledPlan>();
};

impl CompiledPlan {
    pub(super) fn from_plan(plan: &QwycPlan) -> Result<CompiledPlan, QwycError> {
        plan.validate()?;
        let t = plan.fc.t();
        let mut models = Vec::with_capacity(t);
        let mut prefix_cost = vec![0f64; t + 1];
        for (r, &m) in plan.fc.order.iter().enumerate() {
            let model = &plan.ensemble.models[m];
            if let BaseModel::Tree(tr) = model {
                tr.validate().map_err(|e| {
                    QwycError::Compile(format!("position {r} (model {m}): {}", e.message()))
                })?;
            }
            models.push(model.clone());
            prefix_cost[r + 1] = prefix_cost[r] + plan.ensemble.costs[m] as f64;
        }
        let soa: Vec<Option<TreeSoa>> = models
            .iter()
            .map(|m| match m {
                BaseModel::Tree(tr) => Some(tr.to_soa()),
                BaseModel::Lattice(_) => None,
            })
            .collect();
        let min_features = plan.ensemble.feature_count();
        if min_features == 0 && t > 0 {
            return Err(QwycError::Compile(format!(
                "plan '{}': cannot infer a feature count from the ensemble",
                plan.meta.name
            )));
        }
        let n_features = if plan.meta.n_features > 0 {
            if plan.meta.n_features < min_features {
                return Err(QwycError::Compile(format!(
                    "plan '{}': declared n_features {} < {} required by the base models",
                    plan.meta.name, plan.meta.n_features, min_features
                )));
            }
            plan.meta.n_features
        } else {
            min_features
        };
        Ok(CompiledPlan {
            models,
            soa,
            eps_pos: plan.fc.eps_pos.clone(),
            eps_neg: plan.fc.eps_neg.clone(),
            bias: plan.fc.bias,
            beta: plan.fc.beta,
            order: plan.fc.order.clone(),
            prefix_cost,
            n_features,
            min_features,
        })
    }

    // ---- geometry ------------------------------------------------------

    pub fn t(&self) -> usize {
        self.models.len()
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Minimum row stride any input must provide.
    pub fn min_features(&self) -> usize {
        self.min_features
    }

    pub fn order(&self) -> &[usize] {
        &self.order
    }

    pub fn bias(&self) -> f32 {
        self.bias
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    pub fn eps_pos(&self) -> &[f32] {
        &self.eps_pos
    }

    pub fn eps_neg(&self) -> &[f32] {
        &self.eps_neg
    }

    /// Cost of evaluating the first `r` positions of π.
    pub fn prefix_cost(&self, r: usize) -> f64 {
        self.prefix_cost[r]
    }

    /// Cost of full evaluation, Σ c over all positions.
    pub fn total_cost(&self) -> f64 {
        self.prefix_cost[self.t()]
    }

    /// Threshold view for the shared sweep core.
    pub fn sweep_params(&self) -> SweepParams<'_> {
        SweepParams {
            eps_pos: &self.eps_pos,
            eps_neg: &self.eps_neg,
            bias: self.bias,
            beta: self.beta,
        }
    }

    // ---- evaluation ----------------------------------------------------

    /// Fill `out[j]` with position r's score for the gathered rows
    /// `rows[j]` of the row-major block `x` (stride `d`). Trees go
    /// through their per-position SoA bank; lattices walk with the
    /// caller's scratch so a block sweep allocates it once.
    pub fn score_position(
        &self,
        r: usize,
        x: &[f32],
        d: usize,
        rows: &[u32],
        out: &mut [f32],
        lat_scratch: &mut Vec<f32>,
    ) {
        match (&self.soa[r], &self.models[r]) {
            (Some(s), _) => s.eval_indexed(x, d, rows, out),
            (None, BaseModel::Lattice(l)) => {
                if lat_scratch.len() < l.n_vertices() {
                    lat_scratch.resize(l.n_vertices(), 0.0);
                }
                for (slot, &i) in out.iter_mut().zip(rows.iter()) {
                    let row = &x[i as usize * d..(i as usize + 1) * d];
                    *slot = l.eval_with_scratch(row, lat_scratch);
                }
            }
            (None, BaseModel::Tree(_)) => unreachable!("trees always have a SoA mirror"),
        }
    }

    /// Run the shared early-exit sweep over `n` row-major examples of
    /// stride `d` (must cover every feature the models read), in blocks
    /// of `block` fanned across `pool`. Outcomes are in example order and
    /// bit-identical at every thread count.
    pub fn sweep_features(
        &self,
        x: &[f32],
        n: usize,
        d: usize,
        block: usize,
        pool: &Pool,
    ) -> Vec<SweepOutcome> {
        assert!(
            d >= self.min_features,
            "row stride {d} < {} required by the base models",
            self.min_features
        );
        assert_eq!(x.len(), n * d, "feature buffer is not n × d");
        let params = self.sweep_params();
        sweep_batched(&params, n, block, pool, |lo, hi| {
            let xblk = &x[lo * d..hi * d];
            let mut lat_scratch: Vec<f32> = Vec::new();
            move |r: usize, rows: &[u32], out: &mut [f32]| {
                self.score_position(r, xblk, d, rows, out, &mut lat_scratch)
            }
        })
    }

    /// Early-exit evaluation of one example — the compiled twin of
    /// [`FastClassifier::eval_single`](crate::qwyc::FastClassifier::eval_single),
    /// walking the pre-permuted models without order indirection.
    pub fn eval_single(&self, x: &[f32]) -> SingleResult {
        let mut g = self.bias;
        for (r, m) in self.models.iter().enumerate() {
            g += m.eval(x);
            if g > self.eps_pos[r] {
                return SingleResult {
                    positive: true,
                    score: g,
                    models_evaluated: r + 1,
                    early: true,
                };
            }
            if g < self.eps_neg[r] {
                return SingleResult {
                    positive: false,
                    score: g,
                    models_evaluated: r + 1,
                    early: true,
                };
            }
        }
        let t = self.t();
        SingleResult { positive: g >= self.beta, score: g, models_evaluated: t, early: false }
    }

    /// Full-ensemble score in π order (for survivor cross-checks).
    pub fn eval_full(&self, x: &[f32]) -> f32 {
        self.bias + self.models.iter().map(|m| m.eval(x)).sum::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};
    use crate::gbt::{train, GbtParams};
    use crate::plan::QwycPlan;
    use crate::qwyc::{optimize_order_with_pool, QwycConfig};

    #[test]
    fn sweep_features_matches_eval_single_on_trees() {
        let (tr, te) = generate(Which::AdultLike, 71, 0.02);
        let (ens, _) = train(&tr, &GbtParams { n_trees: 18, max_depth: 3, ..Default::default() });
        let sm = ens.score_matrix_par(&tr, &Pool::new(1));
        let fc = optimize_order_with_pool(
            &sm,
            &QwycConfig { alpha: 0.01, ..Default::default() },
            &Pool::new(1),
        );
        let mut plan = QwycPlan::bundle(ens, fc, "cp-test", 0.01).unwrap();
        plan.meta.n_features = te.d;
        let cp = plan.compile().unwrap();
        let n = te.n.min(400);
        for threads in [1, 4] {
            let outs = cp.sweep_features(&te.x[..n * te.d], n, te.d, 64, &Pool::new(threads));
            assert_eq!(outs.len(), n);
            for (i, o) in outs.iter().enumerate() {
                let want = cp.eval_single(te.row(i));
                assert_eq!(o.positive, want.positive, "example {i}");
                assert_eq!(o.stop as usize, want.models_evaluated, "example {i}");
                assert_eq!(o.early, want.early, "example {i}");
                assert_eq!(o.score.to_bits(), want.score.to_bits(), "example {i}");
            }
        }
    }
}
