//! The serving-ready form of a [`QwycPlan`](super::QwycPlan).
//!
//! `compile()` pays every per-load cost exactly once: base models are
//! cloned into π order (position r holds the model the sweep evaluates
//! r-th — no indirection through `order[r]` on the hot path), trees get
//! their [`TreeSoa`] banks built per position, the prefix-cost table
//! cum[r] = Σ_{q<r} c_{π(q)} is tabulated, and the structural invariants
//! (classifier, trees, feature-count agreement) are verified. Everything
//! downstream — `NativeEngine`, `FilterPipeline`, the CLI — holds a
//! `CompiledPlan` and calls the shared sweep core without re-checking.

use super::quant::FeatureQuant;
use super::QwycPlan;
use crate::ensemble::BaseModel;
use crate::error::QwycError;
use crate::gbt::tree::TreeSoa;
use crate::qwyc::sweep::{sweep_batched, sweep_block_with, SweepOutcome, SweepParams, SweepScratch};
use crate::qwyc::{FastClassifier, SingleResult};
use crate::util::pool::Pool;

// ---- binary-layout record pinning --------------------------------------
//
// Every record type that lands in the `qwyc-plan-bin-v1` artifact is
// `#[repr(C)]` and its size/alignment is asserted here, so a silent
// struct reorder or field-width change becomes a compile error instead
// of a corrupt artifact. The layouts themselves live next to the
// encoder/decoder in `plan/binary.rs` (and `gbt/tree.rs` for `Node`).
const _: () = {
    use super::binary::{FileHeader, ModelRec, PlanScalars, SectionEntry};
    use crate::gbt::tree::Node;
    use std::mem::{align_of, size_of};
    assert!(size_of::<FileHeader>() == 64 && align_of::<FileHeader>() == 8);
    assert!(size_of::<SectionEntry>() == 24 && align_of::<SectionEntry>() == 8);
    assert!(size_of::<PlanScalars>() == 40 && align_of::<PlanScalars>() == 8);
    assert!(size_of::<ModelRec>() == 24 && align_of::<ModelRec>() == 8);
    assert!(size_of::<Node>() == 16 && align_of::<Node>() == 4);
};

/// A validated, position-major, ready-to-sweep plan.
///
/// The compiled form is **immutable and self-contained** — every field
/// is owned data, and all per-evaluation scratch (active lists, running
/// scores, lattice walk buffers) lives with the caller, never in the
/// plan. That makes `CompiledPlan: Send + Sync` by construction, so one
/// compile can be shared across N serving shards behind an `Arc`
/// (asserted below; the sharded server relies on it).
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// Base models in evaluation order: `models[r]` runs at position r.
    models: Vec<BaseModel>,
    /// Per-position SoA banks (None for lattices), aligned with `models`.
    soa: Vec<Option<TreeSoa>>,
    eps_pos: Vec<f32>,
    eps_neg: Vec<f32>,
    bias: f32,
    beta: f32,
    /// π — position r evaluates original model `order[r]` (provenance).
    order: Vec<usize>,
    /// Per-position costs `costs[r] = c_{π(r)}` as declared by the plan
    /// (kept exact so the binary artifact and plan reconstruction never
    /// have to recover f32 costs by differencing the f64 prefix table).
    costs: Vec<f32>,
    /// `prefix_cost[r]` = Σ_{q<r} c_{π(q)}; `prefix_cost[T]` is the full
    /// evaluation cost.
    prefix_cost: Vec<f64>,
    /// Serving feature width (declared by the plan, or inferred).
    n_features: usize,
    /// Largest feature index any base model reads, plus one — the floor
    /// every input row stride must meet.
    min_features: usize,
    /// Per-feature split-threshold edge tables, present when every tree
    /// threshold quantized (see `plan/quant.rs`). When present, the
    /// sweep entry points quantize each request row once and walk the
    /// trees' u16 banks — bitwise-identical outcomes, integer compares.
    /// `None` ⇒ the raw f32 path serves (lattice-only plans, NaN
    /// thresholds, edge-table overflow).
    quant: Option<FeatureQuant>,
}

// Compile once, hand out `Arc<CompiledPlan>` to every shard: the plan
// must stay shareable across worker threads.
const _: fn() = || {
    fn shared_across_shards<T: Send + Sync>() {}
    shared_across_shards::<CompiledPlan>();
};

impl CompiledPlan {
    pub(super) fn from_plan(plan: &QwycPlan) -> Result<CompiledPlan, QwycError> {
        plan.validate()?;
        let t = plan.fc.t();
        let mut models = Vec::with_capacity(t);
        let mut costs = Vec::with_capacity(t);
        for &m in &plan.fc.order {
            models.push(plan.ensemble.models[m].clone());
            costs.push(plan.ensemble.costs[m]);
        }
        CompiledPlan::from_parts(
            &plan.meta.name,
            models,
            plan.fc.order.clone(),
            plan.fc.eps_pos.clone(),
            plan.fc.eps_neg.clone(),
            plan.fc.bias,
            plan.fc.beta,
            costs,
            plan.meta.n_features,
        )
    }

    /// Assemble a compiled plan from position-major parts, running every
    /// invariant check `compile()` has always run: classifier geometry
    /// (lengths, permutation, NaN thresholds, finite bias/β), per-tree
    /// structural soundness, and feature-count agreement. This is the
    /// binary decoder's entry point, and [`CompiledPlan::from_plan`]
    /// funnels through it too, so JSON- and binary-loaded plans are
    /// validated and assembled identically. The prefix-cost table is
    /// recomputed here with the same f64 accumulation both paths share —
    /// bitwise identical regardless of the source format.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn from_parts(
        name: &str,
        models: Vec<BaseModel>,
        order: Vec<usize>,
        eps_pos: Vec<f32>,
        eps_neg: Vec<f32>,
        bias: f32,
        beta: f32,
        costs: Vec<f32>,
        declared_features: usize,
    ) -> Result<CompiledPlan, QwycError> {
        let t = models.len();
        if order.len() != t || costs.len() != t {
            return Err(QwycError::Validate(format!(
                "plan '{name}': {t} models but {} order entries and {} costs",
                order.len(),
                costs.len()
            )));
        }
        let fc = FastClassifier { order, eps_pos, eps_neg, bias, beta };
        fc.validate()?;
        let FastClassifier { order, eps_pos, eps_neg, bias, beta } = fc;
        for (r, model) in models.iter().enumerate() {
            if let BaseModel::Tree(tr) = model {
                tr.validate().map_err(|e| {
                    QwycError::Compile(format!(
                        "position {r} (model {}): {}",
                        order[r],
                        e.message()
                    ))
                })?;
            }
        }
        let mut prefix_cost = vec![0f64; t + 1];
        for (r, &c) in costs.iter().enumerate() {
            prefix_cost[r + 1] = prefix_cost[r] + c as f64;
        }
        let mut soa: Vec<Option<TreeSoa>> = models
            .iter()
            .map(|m| match m {
                BaseModel::Tree(tr) => Some(tr.to_soa()),
                BaseModel::Lattice(_) => None,
            })
            .collect();
        let mut min_features = 0usize;
        for m in &models {
            match m {
                BaseModel::Lattice(l) => {
                    for &f in &l.features {
                        min_features = min_features.max(f + 1);
                    }
                }
                BaseModel::Tree(tr) => {
                    for n in &tr.nodes {
                        if !n.is_leaf() {
                            min_features = min_features.max(n.feature as usize + 1);
                        }
                    }
                }
            }
        }
        if min_features == 0 && t > 0 {
            return Err(QwycError::Compile(format!(
                "plan '{name}': cannot infer a feature count from the ensemble"
            )));
        }
        let n_features = if declared_features > 0 {
            if declared_features < min_features {
                return Err(QwycError::Compile(format!(
                    "plan '{name}': declared n_features {declared_features} < {min_features} \
                     required by the base models"
                )));
            }
            declared_features
        } else {
            min_features
        };
        // Quantization is *rebuilt* at every load, exactly like the SoA
        // banks — both artifact formats funnel through here, so the
        // quantized layout can never drift from the stored f32 plan.
        let mut quant = FeatureQuant::from_models(&models, n_features);
        if let Some(q) = &quant {
            let all_quantized = soa
                .iter_mut()
                .flatten()
                .all(|s| s.quantize_with(|f, t| q.threshold_bin(f, t)));
            if !all_quantized {
                // Defensive: from_models collected these same thresholds,
                // so every lookup should hit. Fall back to the raw path
                // rather than serve a half-quantized plan.
                quant = None;
            }
        }
        Ok(CompiledPlan {
            models,
            soa,
            eps_pos,
            eps_neg,
            bias,
            beta,
            order,
            costs,
            prefix_cost,
            n_features,
            min_features,
            quant,
        })
    }

    // ---- geometry ------------------------------------------------------

    pub fn t(&self) -> usize {
        self.models.len()
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Minimum row stride any input must provide.
    pub fn min_features(&self) -> usize {
        self.min_features
    }

    pub fn order(&self) -> &[usize] {
        &self.order
    }

    /// Base models in evaluation order (`models()[r]` runs at position r).
    pub fn models(&self) -> &[BaseModel] {
        &self.models
    }

    /// Per-position evaluation costs `c_{π(r)}`, exactly as the plan
    /// declared them (the f64 [`CompiledPlan::prefix_cost`] table is
    /// derived from these).
    pub fn position_costs(&self) -> &[f32] {
        &self.costs
    }

    pub fn bias(&self) -> f32 {
        self.bias
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }

    pub fn eps_pos(&self) -> &[f32] {
        &self.eps_pos
    }

    pub fn eps_neg(&self) -> &[f32] {
        &self.eps_neg
    }

    /// Cost of evaluating the first `r` positions of π.
    pub fn prefix_cost(&self, r: usize) -> f64 {
        self.prefix_cost[r]
    }

    /// Cost of full evaluation, Σ c over all positions.
    pub fn total_cost(&self) -> f64 {
        self.prefix_cost[self.t()]
    }

    /// The feature-quantization tables, when the plan quantized (every
    /// tree threshold rewritten as a u16 bin index). `None` means the
    /// raw f32 path serves.
    pub fn quant(&self) -> Option<&FeatureQuant> {
        self.quant.as_ref()
    }

    /// Concatenated per-position quantized threshold banks in position
    /// order (lattice positions contribute nothing) — the
    /// `quant_nodes` payload of the binary artifact. Empty when the
    /// plan is unquantized.
    pub(super) fn quantized_node_bins(&self) -> Vec<u16> {
        if self.quant.is_none() {
            return Vec::new();
        }
        let mut bins = Vec::new();
        for s in self.soa.iter().flatten() {
            bins.extend_from_slice(s.qthresholds());
        }
        bins
    }

    /// Threshold view for the shared sweep core.
    pub fn sweep_params(&self) -> SweepParams<'_> {
        SweepParams {
            eps_pos: &self.eps_pos,
            eps_neg: &self.eps_neg,
            bias: self.bias,
            beta: self.beta,
        }
    }

    // ---- evaluation ----------------------------------------------------

    /// Fill `out[j]` with position r's score for the gathered rows
    /// `rows[j]` of the row-major block `x` (stride `d`). Trees go
    /// through their per-position SoA bank; lattices walk with the
    /// caller's scratch so a block sweep allocates it once.
    pub fn score_position(
        &self,
        r: usize,
        x: &[f32],
        d: usize,
        rows: &[u32],
        out: &mut [f32],
        lat_scratch: &mut Vec<f32>,
    ) {
        match (&self.soa[r], &self.models[r]) {
            (Some(s), _) => s.eval_indexed(x, d, rows, out),
            (None, BaseModel::Lattice(l)) => {
                if lat_scratch.len() < l.n_vertices() {
                    lat_scratch.resize(l.n_vertices(), 0.0);
                }
                for (slot, &i) in out.iter_mut().zip(rows.iter()) {
                    let row = &x[i as usize * d..(i as usize + 1) * d];
                    *slot = l.eval_with_scratch(row, lat_scratch);
                }
            }
            (None, BaseModel::Tree(_)) => unreachable!("trees always have a SoA mirror"),
        }
    }

    /// [`CompiledPlan::score_position`] over a quantized block: tree
    /// positions with a quantized bank walk the u16 bins in `qx`
    /// (`bin(x) <= bin(t) ⟺ x <= t`, so scores are bitwise-identical);
    /// lattices and unquantized banks read the raw rows in `x`.
    #[allow(clippy::too_many_arguments)]
    fn score_position_quant(
        &self,
        r: usize,
        x: &[f32],
        qx: &[u16],
        d: usize,
        rows: &[u32],
        out: &mut [f32],
        lat_scratch: &mut Vec<f32>,
    ) {
        match &self.soa[r] {
            Some(s) if s.is_quantized() => s.eval_indexed_quant(qx, d, rows, out),
            _ => self.score_position(r, x, d, rows, out, lat_scratch),
        }
    }

    /// Run the shared early-exit sweep over `n` row-major examples of
    /// stride `d` (must cover every feature the models read), in blocks
    /// of `block` fanned across `pool`. Outcomes are in example order and
    /// bit-identical at every thread count.
    ///
    /// When the plan quantized (see [`CompiledPlan::quant`]), each
    /// block's rows are binned once and the tree walks run the integer
    /// kernel — outcomes stay bitwise-identical to the raw path
    /// ([`CompiledPlan::sweep_features_raw`], pinned by
    /// rust/tests/quantized_equiv.rs).
    pub fn sweep_features(
        &self,
        x: &[f32],
        n: usize,
        d: usize,
        block: usize,
        pool: &Pool,
    ) -> Vec<SweepOutcome> {
        let Some(q) = &self.quant else {
            return self.sweep_features_raw(x, n, d, block, pool);
        };
        assert!(
            d >= self.min_features,
            "row stride {d} < {} required by the base models",
            self.min_features
        );
        assert_eq!(x.len(), n * d, "feature buffer is not n × d");
        let params = self.sweep_params();
        sweep_batched(&params, n, block, pool, |lo, hi| {
            let xblk = &x[lo * d..hi * d];
            let mut lat_scratch: Vec<f32> = Vec::new();
            // Quantize the block once, in the worker that sweeps it.
            let mut qx: Vec<u16> = Vec::new();
            q.quantize_block(xblk, d, &mut qx);
            move |r: usize, rows: &[u32], out: &mut [f32]| {
                self.score_position_quant(r, xblk, &qx, d, rows, out, &mut lat_scratch)
            }
        })
    }

    /// The unquantized sweep: always walks the f32 `TreeSoa` banks.
    /// Public as the reference path the quantized kernel is pinned
    /// against (and the fallback [`CompiledPlan::sweep_features`] takes
    /// when the plan did not quantize).
    pub fn sweep_features_raw(
        &self,
        x: &[f32],
        n: usize,
        d: usize,
        block: usize,
        pool: &Pool,
    ) -> Vec<SweepOutcome> {
        assert!(
            d >= self.min_features,
            "row stride {d} < {} required by the base models",
            self.min_features
        );
        assert_eq!(x.len(), n * d, "feature buffer is not n × d");
        let params = self.sweep_params();
        sweep_batched(&params, n, block, pool, |lo, hi| {
            let xblk = &x[lo * d..hi * d];
            let mut lat_scratch: Vec<f32> = Vec::new();
            move |r: usize, rows: &[u32], out: &mut [f32]| {
                self.score_position(r, xblk, d, rows, out, &mut lat_scratch)
            }
        })
    }

    /// Single-block raw-path sweep with caller-owned scratch:
    /// allocation-free once warmed. Bitwise-identical to
    /// [`sweep_features_raw`](Self::sweep_features_raw) whenever
    /// `n ≤ block` there (the batched driver then runs exactly one
    /// block over the same scorer) — and, by the quantization
    /// equivalence, to the quantized entry points too. The caller is
    /// responsible for splitting larger inputs. `lat_scratch` replaces
    /// the per-block lattice scratch the batched path allocates.
    pub fn sweep_features_into<'s>(
        &self,
        x: &[f32],
        n: usize,
        d: usize,
        scratch: &'s mut SweepScratch,
        lat_scratch: &mut Vec<f32>,
    ) -> &'s [SweepOutcome] {
        assert!(
            d >= self.min_features,
            "row stride {d} < {} required by the base models",
            self.min_features
        );
        assert_eq!(x.len(), n * d, "feature buffer is not n × d");
        let params = self.sweep_params();
        sweep_block_with(
            &params,
            n,
            |r: usize, rows: &[u32], out: &mut [f32]| {
                self.score_position(r, x, d, rows, out, lat_scratch)
            },
            scratch,
        )
    }

    /// Quantized twin of [`CompiledPlan::sweep_features_into`] — the
    /// serving hot path. The rows are binned once into the caller's
    /// persistent `qx` buffer (allocation-free once warmed, like
    /// `scratch`), then the single-block sweep walks the u16 banks.
    /// Outcomes are bitwise-identical to the raw path; plans without
    /// quantization fall through to it directly.
    pub fn sweep_features_quant_into<'s>(
        &self,
        x: &[f32],
        n: usize,
        d: usize,
        scratch: &'s mut SweepScratch,
        lat_scratch: &mut Vec<f32>,
        qx: &mut Vec<u16>,
    ) -> &'s [SweepOutcome] {
        let Some(q) = &self.quant else {
            return self.sweep_features_into(x, n, d, scratch, lat_scratch);
        };
        assert!(
            d >= self.min_features,
            "row stride {d} < {} required by the base models",
            self.min_features
        );
        assert_eq!(x.len(), n * d, "feature buffer is not n × d");
        q.quantize_block(x, d, qx);
        let params = self.sweep_params();
        sweep_block_with(
            &params,
            n,
            |r: usize, rows: &[u32], out: &mut [f32]| {
                self.score_position_quant(r, x, qx, d, rows, out, lat_scratch)
            },
            scratch,
        )
    }

    /// Early-exit evaluation of one example — the compiled twin of
    /// [`FastClassifier::eval_single`](crate::qwyc::FastClassifier::eval_single),
    /// walking the pre-permuted models without order indirection.
    pub fn eval_single(&self, x: &[f32]) -> SingleResult {
        let mut g = self.bias;
        for (r, m) in self.models.iter().enumerate() {
            g += m.eval(x);
            if g > self.eps_pos[r] {
                return SingleResult {
                    positive: true,
                    score: g,
                    models_evaluated: r + 1,
                    early: true,
                };
            }
            if g < self.eps_neg[r] {
                return SingleResult {
                    positive: false,
                    score: g,
                    models_evaluated: r + 1,
                    early: true,
                };
            }
        }
        let t = self.t();
        SingleResult { positive: g >= self.beta, score: g, models_evaluated: t, early: false }
    }

    /// Full-ensemble score in π order (for survivor cross-checks).
    pub fn eval_full(&self, x: &[f32]) -> f32 {
        self.bias + self.models.iter().map(|m| m.eval(x)).sum::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};
    use crate::gbt::{train, GbtParams};
    use crate::plan::QwycPlan;
    use crate::qwyc::{optimize_order_with_pool, QwycConfig};

    #[test]
    fn sweep_features_matches_eval_single_on_trees() {
        let (tr, te) = generate(Which::AdultLike, 71, 0.02);
        let (ens, _) = train(&tr, &GbtParams { n_trees: 18, max_depth: 3, ..Default::default() });
        let sm = ens.score_matrix_par(&tr, &Pool::new(1));
        let fc = optimize_order_with_pool(
            &sm,
            &QwycConfig { alpha: 0.01, ..Default::default() },
            &Pool::new(1),
        );
        let mut plan = QwycPlan::bundle(ens, fc, "cp-test", 0.01).unwrap();
        plan.meta.n_features = te.d;
        let cp = plan.compile().unwrap();
        // Tree plans quantize, so this equivalence now pins the
        // quantized kernel against the raw eval_single walk.
        assert!(cp.quant().is_some(), "tree plan should quantize");
        let n = te.n.min(400);
        for threads in [1, 4] {
            let outs = cp.sweep_features(&te.x[..n * te.d], n, te.d, 64, &Pool::new(threads));
            assert_eq!(outs.len(), n);
            for (i, o) in outs.iter().enumerate() {
                let want = cp.eval_single(te.row(i));
                assert_eq!(o.positive, want.positive, "example {i}");
                assert_eq!(o.stop as usize, want.models_evaluated, "example {i}");
                assert_eq!(o.early, want.early, "example {i}");
                assert_eq!(o.score.to_bits(), want.score.to_bits(), "example {i}");
            }
        }
    }
}
