//! The deployable QWYC artifact.
//!
//! The paper's deliverable is a *deployed* fast classifier: a fixed
//! evaluation order π plus per-position thresholds ε± that a serving
//! system runs position-major with early exit. [`QwycPlan`] bundles
//! everything that deployment needs — the ensemble, π, ε±, bias/β, the
//! per-model costs (carried by the ensemble), the α the thresholds were
//! optimized for, and provenance — into one versioned JSON artifact
//! (schema [`PLAN_SCHEMA`] = `qwyc-plan-v1`), replacing the old loose
//! `model.json` + `fast.json` pair that every consumer re-validated and
//! re-packed on load.
//!
//! [`QwycPlan::compile`] turns the artifact into a [`CompiledPlan`]: base
//! models pre-permuted into evaluation order, trees pre-packed into
//! per-position `TreeSoa` banks, the prefix-cost table precomputed, and
//! every invariant (classifier structure, tree structure, feature-count
//! agreement) checked once — so the sweep core and the serving worker
//! never validate per call. `simulate`, `NativeEngine`, and
//! `FilterPipeline` all consume the same artifact through the same
//! sweep (`qwyc::sweep`).
//!
//! Plans ship in two interchangeable formats behind one load/save
//! surface, [`PlanArtifact`]: the self-describing JSON document above,
//! and the zero-copy binary form `qwyc-plan-bin-v1` (module `binary`)
//! that stores the *compiled* layout and loads by one read + validated
//! pointer casts. [`PlanArtifact::load`] auto-detects the format from
//! the leading magic bytes; both paths funnel through the same
//! `CompiledPlan::from_parts` validation, so a plan behaves bit-for-bit
//! identically however it was stored.

mod binary;
mod compiled;
pub mod quant;

pub use binary::{BinaryInfo, SectionInfo};
pub use compiled::CompiledPlan;
pub use quant::FeatureQuant;
// Re-exported so plan consumers get the crate error type where the
// artifact lives.
pub use crate::error::QwycError;

use crate::ensemble::Ensemble;
use crate::qwyc::FastClassifier;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Schema tag written into (and required from) every plan JSON document.
pub const PLAN_SCHEMA: &str = "qwyc-plan-v1";

/// Provenance and deployment metadata carried by a plan.
#[derive(Clone, Debug)]
pub struct PlanMeta {
    /// Human-readable plan name (defaults to the ensemble name).
    pub name: String,
    /// The α the thresholds were optimized for (provenance; 0 = unrecorded).
    pub alpha: f64,
    /// Filter-and-score artifact (all ε⁺ ≡ +∞)? Derived from the
    /// classifier at construction — recorded so operators can tell a
    /// filter plan from a full early-exit plan without reading ε.
    pub neg_only: bool,
    /// Free-form provenance (dataset, pipeline id, commit, ...).
    pub source: String,
    /// Tool that produced the artifact.
    pub created_by: String,
    /// Declared serving feature width; 0 = infer from the ensemble at
    /// compile time. When set it must cover every feature index any base
    /// model reads (checked by [`QwycPlan::compile`]).
    pub n_features: usize,
}

impl PlanMeta {
    fn named(name: &str, alpha: f64) -> PlanMeta {
        PlanMeta {
            name: name.to_string(),
            alpha,
            neg_only: false,
            source: String::new(),
            created_by: concat!("qwyc ", env!("CARGO_PKG_VERSION")).to_string(),
            n_features: 0,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("alpha", Json::Num(self.alpha)),
            ("neg_only", Json::Bool(self.neg_only)),
            ("source", Json::str(&self.source)),
            ("created_by", Json::str(&self.created_by)),
            ("n_features", Json::Num(self.n_features as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<PlanMeta, QwycError> {
        let schema = |e: QwycError| e.context("meta");
        Ok(PlanMeta {
            name: v.req("name").and_then(|v| v.as_str().map(str::to_string)).map_err(schema)?,
            alpha: v.req("alpha").and_then(|v| v.as_f64()).map_err(schema)?,
            neg_only: v.req("neg_only").and_then(|v| v.as_bool()).map_err(schema)?,
            source: v.req("source").and_then(|v| v.as_str().map(str::to_string)).map_err(schema)?,
            created_by: v
                .req("created_by")
                .and_then(|v| v.as_str().map(str::to_string))
                .map_err(schema)?,
            n_features: v.req("n_features").and_then(|v| v.as_usize()).map_err(schema)?,
        })
    }
}

/// Ensemble + optimized fast classifier + metadata: the unit that ships.
#[derive(Clone, Debug)]
pub struct QwycPlan {
    pub ensemble: Ensemble,
    pub fc: FastClassifier,
    pub meta: PlanMeta,
}

impl QwycPlan {
    /// Bundle an ensemble and its optimized classifier into a plan,
    /// validating the pair once. `alpha` is recorded as provenance.
    pub fn new(
        ensemble: Ensemble,
        fc: FastClassifier,
        mut meta: PlanMeta,
    ) -> Result<QwycPlan, QwycError> {
        meta.neg_only = fc.eps_pos.iter().all(|&e| e == f32::INFINITY);
        let plan = QwycPlan { ensemble, fc, meta };
        plan.validate()?;
        Ok(plan)
    }

    /// Convenience constructor with default provenance.
    pub fn bundle(
        ensemble: Ensemble,
        fc: FastClassifier,
        name: &str,
        alpha: f64,
    ) -> Result<QwycPlan, QwycError> {
        QwycPlan::new(ensemble, fc, PlanMeta::named(name, alpha))
    }

    /// [`QwycPlan::bundle`] with a declared serving feature width,
    /// checked here against the base models (0 = infer at compile time)
    /// so a too-narrow declaration fails at build time, not at deploy.
    pub fn bundle_with_width(
        ensemble: Ensemble,
        fc: FastClassifier,
        name: &str,
        alpha: f64,
        n_features: usize,
    ) -> Result<QwycPlan, QwycError> {
        let mut plan = QwycPlan::bundle(ensemble, fc, name, alpha)?;
        if n_features > 0 {
            let need = plan.ensemble.feature_count();
            if n_features < need {
                return Err(QwycError::Compile(format!(
                    "plan '{}': declared n_features {n_features} < {need} required by the \
                     base models",
                    plan.meta.name
                )));
            }
        }
        plan.meta.n_features = n_features;
        Ok(plan)
    }

    /// Structural validation shared by construction and deserialization:
    /// classifier invariants, size agreement, and bias/β consistency
    /// between the ensemble and the classifier (they are two views of
    /// the same deployed model — a mismatch is a packaging error).
    pub fn validate(&self) -> Result<(), QwycError> {
        self.fc.validate()?;
        if self.ensemble.len() != self.fc.t() {
            return Err(QwycError::Validate(format!(
                "plan '{}': ensemble has {} models but classifier covers {}",
                self.meta.name,
                self.ensemble.len(),
                self.fc.t()
            )));
        }
        if self.fc.bias != self.ensemble.bias || self.fc.beta != self.ensemble.beta {
            return Err(QwycError::Validate(format!(
                "plan '{}': classifier bias/beta ({}, {}) disagree with ensemble ({}, {})",
                self.meta.name, self.fc.bias, self.fc.beta, self.ensemble.bias, self.ensemble.beta
            )));
        }
        // meta.neg_only is derived metadata; a document asserting the
        // wrong value (hand-edited artifact) must not load.
        let neg_only = self.fc.eps_pos.iter().all(|&e| e == f32::INFINITY);
        if self.meta.neg_only != neg_only {
            return Err(QwycError::Validate(format!(
                "plan '{}': meta.neg_only={} but the classifier's thresholds say {}",
                self.meta.name, self.meta.neg_only, neg_only
            )));
        }
        Ok(())
    }

    /// Compile into the serving-ready form: models pre-permuted into π
    /// order, SoA banks built, prefix costs tabulated, feature counts
    /// agreed — all checks run here, once, instead of per call.
    pub fn compile(&self) -> Result<CompiledPlan, QwycError> {
        CompiledPlan::from_plan(self)
    }

    /// Compile straight into the shared serving form: an
    /// `Arc<CompiledPlan>` ready to hand to N engine shards (and to a
    /// [`PlanSlot`] for hot-reload).
    pub fn compile_shared(&self) -> Result<Arc<CompiledPlan>, QwycError> {
        self.compile().map(Arc::new)
    }

    // ---- serialization (qwyc-plan-v1) ---------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(PLAN_SCHEMA)),
            ("meta", self.meta.to_json()),
            ("ensemble", self.ensemble.to_json()),
            ("fast", self.fc.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<QwycPlan, QwycError> {
        let schema = v.req("schema").and_then(|v| v.as_str())?;
        if schema != PLAN_SCHEMA {
            return Err(QwycError::Schema(format!(
                "expected schema '{PLAN_SCHEMA}', got '{schema}'"
            )));
        }
        let plan = QwycPlan {
            ensemble: Ensemble::from_json(v.req("ensemble")?)
                .map_err(|e| e.context("ensemble"))?,
            fc: FastClassifier::from_json(v.req("fast")?).map_err(|e| e.context("fast"))?,
            meta: PlanMeta::from_json(v.req("meta")?)?,
        };
        plan.validate()?;
        Ok(plan)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> Result<QwycPlan, QwycError> {
        // read_file reports missing/unreadable files as Io and corrupt
        // bytes as Schema; both propagate as-is.
        QwycPlan::from_json(&crate::util::json::read_file(path)?)
    }
}

// ------------------------------------------------------------ artifact

/// On-disk encoding of a plan artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanFormat {
    /// Self-describing `qwyc-plan-v1` JSON: diff-able, hand-inspectable.
    Json,
    /// Zero-copy `qwyc-plan-bin-v1`: the compiled layout, loaded by one
    /// read + validated pointer casts (the serving/`RELOAD` format).
    Binary,
}

impl PlanFormat {
    /// Parse a CLI-style format name (`json` | `bin` | `binary`).
    pub fn parse(s: &str) -> Result<PlanFormat, QwycError> {
        match s {
            "json" => Ok(PlanFormat::Json),
            "bin" | "binary" => Ok(PlanFormat::Binary),
            other => {
                Err(QwycError::Config(format!("unknown plan format '{other}' (json|bin)")))
            }
        }
    }
}

/// Header-level summary of a plan artifact, for `plan-info`.
#[derive(Clone, Debug)]
pub enum ArtifactInfo {
    /// A `qwyc-plan-v1` JSON document.
    Json {
        /// Plan name from the meta block.
        name: String,
        /// Number of positions T.
        t: usize,
        /// Declared feature width (0 ⇒ inferred at compile).
        n_features: usize,
    },
    /// A `qwyc-plan-bin-v1` binary artifact.
    Binary(BinaryInfo),
}

impl ArtifactInfo {
    /// Render the `plan-info` report for this artifact. Lives on the
    /// info type (not in main.rs) so the CLI output shape — which CI
    /// smoke tests grep — is pinned by library tests.
    ///
    /// Binary artifacts get the full view: the section table (with the
    /// writer's alignment padding per section), and a quantization
    /// summary built from the `bin_edges`/`quant_nodes` sections.
    pub fn render(&self, path_label: &str) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        match self {
            ArtifactInfo::Json { name, t, n_features } => {
                let _ = writeln!(out, "{path_label}: qwyc-plan-v1 (JSON)");
                let _ = writeln!(out, "  plan '{name}'  T={t}  n_features={n_features}");
            }
            ArtifactInfo::Binary(info) => {
                let _ =
                    writeln!(out, "{path_label}: qwyc-plan-bin-v1 version {}", info.version);
                let _ = writeln!(
                    out,
                    "  plan '{}'  T={}  n_features={}  file_len={} bytes",
                    info.plan_name, info.t, info.n_features, info.file_len
                );
                let _ = writeln!(
                    out,
                    "  {:<12} {:>10} {:>10} {:>6}",
                    "section", "offset", "bytes", "pad"
                );
                for (k, s) in info.sections.iter().enumerate() {
                    // Alignment padding the writer inserted between this
                    // payload's end and the next section's 64-byte start
                    // (end of file for the last section).
                    let next = info
                        .sections
                        .get(k + 1)
                        .map_or(info.file_len, |n| n.offset);
                    let pad = next.saturating_sub(s.offset + s.len);
                    let _ = writeln!(
                        out,
                        "  {:<12} {:>10} {:>10} {:>6}",
                        s.name, s.offset, s.len, pad
                    );
                }
                if info.edge_counts.is_empty() {
                    let _ = writeln!(out, "  quantization: none (raw f32 thresholds)");
                } else {
                    let total: u64 = info.edge_counts.iter().map(|&c| u64::from(c)).sum();
                    let bank = info
                        .sections
                        .iter()
                        .find(|s| s.name == "quant_nodes")
                        .map_or(0, |s| s.len);
                    let _ = writeln!(
                        out,
                        "  quantization: {} features, {} bin edges, quantized bank {} bytes",
                        info.edge_counts.len(),
                        total,
                        bank
                    );
                    let per: Vec<String> =
                        info.edge_counts.iter().map(|c| c.to_string()).collect();
                    let _ = writeln!(out, "    edges/feature: {}", per.join(" "));
                }
            }
        }
        out
    }

    /// Structured render of the same report — what the HTTP admin plane
    /// serves from `GET /plan`. Binary artifacts carry the full section
    /// table (with the writer's per-section alignment padding, computed
    /// exactly as in [`ArtifactInfo::render`]) and the quantization
    /// summary (`null` for raw-threshold plans).
    pub fn to_json(&self) -> Json {
        match self {
            ArtifactInfo::Json { name, t, n_features } => Json::obj(vec![
                ("format", Json::str("qwyc-plan-v1")),
                ("name", Json::str(name)),
                ("t", Json::Num(*t as f64)),
                ("n_features", Json::Num(*n_features as f64)),
            ]),
            ArtifactInfo::Binary(info) => {
                let sections: Vec<Json> = info
                    .sections
                    .iter()
                    .enumerate()
                    .map(|(k, s)| {
                        let next =
                            info.sections.get(k + 1).map_or(info.file_len, |n| n.offset);
                        let pad = next.saturating_sub(s.offset + s.len);
                        Json::obj(vec![
                            ("name", Json::str(&s.name)),
                            ("offset", Json::Num(s.offset as f64)),
                            ("bytes", Json::Num(s.len as f64)),
                            ("pad", Json::Num(pad as f64)),
                        ])
                    })
                    .collect();
                let quantization = if info.edge_counts.is_empty() {
                    Json::Null
                } else {
                    let total: u64 = info.edge_counts.iter().map(|&c| u64::from(c)).sum();
                    let bank = info
                        .sections
                        .iter()
                        .find(|s| s.name == "quant_nodes")
                        .map_or(0, |s| s.len);
                    Json::obj(vec![
                        ("features", Json::Num(info.edge_counts.len() as f64)),
                        ("bin_edges", Json::Num(total as f64)),
                        ("bank_bytes", Json::Num(bank as f64)),
                        (
                            "edges_per_feature",
                            Json::Arr(
                                info.edge_counts
                                    .iter()
                                    .map(|&c| Json::Num(f64::from(c)))
                                    .collect(),
                            ),
                        ),
                    ])
                };
                Json::obj(vec![
                    ("format", Json::str("qwyc-plan-bin-v1")),
                    ("version", Json::Num(f64::from(info.version))),
                    ("name", Json::str(&info.plan_name)),
                    ("t", Json::Num(info.t as f64)),
                    ("n_features", Json::Num(info.n_features as f64)),
                    ("file_len", Json::Num(info.file_len as f64)),
                    ("sections", Json::Arr(sections)),
                    ("quantization", quantization),
                ])
            }
        }
    }
}

/// The single load/save surface for plan artifacts, format-agnostic.
///
/// Construction always compiles (and therefore fully validates) the
/// plan, so holding a `PlanArtifact` means holding a serving-ready
/// [`Arc<CompiledPlan>`] plus the metadata needed to re-export either
/// format. [`PlanArtifact::load`] sniffs the leading magic bytes to pick
/// the decoder; [`PlanArtifact::save`] writes whichever [`PlanFormat`]
/// the caller asks for — a binary-loaded artifact can be re-exported as
/// JSON (the binary form carries π, so the original model order is
/// recoverable exactly) and vice versa.
pub struct PlanArtifact {
    compiled: Arc<CompiledPlan>,
    meta: PlanMeta,
    ensemble_name: String,
    format: PlanFormat,
    /// Present when the artifact came from JSON or an in-memory plan;
    /// binary loads reconstruct it on demand in [`PlanArtifact::to_plan`].
    plan: Option<QwycPlan>,
}

impl PlanArtifact {
    /// Wrap (and compile) an in-memory plan.
    pub fn from_plan(plan: QwycPlan) -> Result<PlanArtifact, QwycError> {
        let compiled = plan.compile_shared()?;
        Ok(PlanArtifact {
            compiled,
            meta: plan.meta.clone(),
            ensemble_name: plan.ensemble.name.clone(),
            format: PlanFormat::Json,
            plan: Some(plan),
        })
    }

    /// Load a plan artifact in either format, auto-detected from the
    /// file's leading bytes. Either way the result is validated by the
    /// same `CompiledPlan` checks, so downstream code cannot observe
    /// which format a plan came from (except via [`PlanArtifact::format`]).
    pub fn load(path: &std::path::Path) -> Result<PlanArtifact, QwycError> {
        let buf = binary::AlignedBuf::read_file(path)?;
        if binary::is_binary(buf.bytes()) {
            let d = binary::decode(buf.bytes())?;
            return Ok(PlanArtifact {
                compiled: Arc::new(d.compiled),
                meta: d.meta,
                ensemble_name: d.ensemble_name,
                format: PlanFormat::Binary,
                plan: None,
            });
        }
        let text = std::str::from_utf8(buf.bytes())
            .map_err(|_| QwycError::Schema(format!("parse {path:?}: not UTF-8 JSON")))?;
        let json = Json::parse(text).map_err(|e| e.context(&format!("parse {path:?}")))?;
        let plan = QwycPlan::from_json(&json)?;
        let mut art = PlanArtifact::from_plan(plan)?;
        art.format = PlanFormat::Json;
        Ok(art)
    }

    /// [`PlanArtifact::load`], returning just the serving handle.
    pub fn load_compiled(path: &std::path::Path) -> Result<Arc<CompiledPlan>, QwycError> {
        PlanArtifact::load(path).map(|a| a.compiled())
    }

    /// Save in the requested format (creating parent directories).
    pub fn save(&self, path: &std::path::Path, format: PlanFormat) -> Result<(), QwycError> {
        let io = |e: std::io::Error| QwycError::Io(format!("write {path:?}: {e}"));
        match format {
            PlanFormat::Json => self.to_plan()?.save(path).map_err(io),
            PlanFormat::Binary => {
                let bytes = binary::encode(&self.meta, &self.ensemble_name, &self.compiled);
                if let Some(parent) = path.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent).map_err(io)?;
                    }
                }
                std::fs::write(path, bytes).map_err(io)
            }
        }
    }

    /// The shared serving handle (cheap Arc clone).
    pub fn compiled(&self) -> Arc<CompiledPlan> {
        self.compiled.clone()
    }

    /// Provenance/deployment metadata.
    pub fn meta(&self) -> &PlanMeta {
        &self.meta
    }

    /// Plan name (meta).
    pub fn name(&self) -> &str {
        &self.meta.name
    }

    /// Name of the underlying ensemble.
    pub fn ensemble_name(&self) -> &str {
        &self.ensemble_name
    }

    /// The format this artifact was loaded from ([`PlanFormat::Json`]
    /// for in-memory constructions).
    pub fn format(&self) -> PlanFormat {
        self.format
    }

    /// The uncompiled [`QwycPlan`]. JSON-backed artifacts return a clone
    /// of the loaded plan; binary-backed artifacts reconstruct it
    /// exactly by inverse-permuting the compiled (position-major) models
    /// and costs back to original model indices through π.
    pub fn to_plan(&self) -> Result<QwycPlan, QwycError> {
        if let Some(p) = &self.plan {
            return Ok(p.clone());
        }
        let cp = &self.compiled;
        let t = cp.t();
        let mut models: Vec<Option<crate::ensemble::BaseModel>> = vec![None; t];
        let mut costs = vec![0f32; t];
        for (r, &m) in cp.order().iter().enumerate() {
            models[m] = Some(cp.models()[r].clone());
            costs[m] = cp.position_costs()[r];
        }
        let models = models
            .into_iter()
            .map(|m| m.expect("compiled order is a validated permutation"))
            .collect();
        let ensemble = Ensemble {
            name: self.ensemble_name.clone(),
            models,
            bias: cp.bias(),
            beta: cp.beta(),
            costs,
        };
        let fc = FastClassifier {
            order: cp.order().to_vec(),
            eps_pos: cp.eps_pos().to_vec(),
            eps_neg: cp.eps_neg().to_vec(),
            bias: cp.bias(),
            beta: cp.beta(),
        };
        QwycPlan::new(ensemble, fc, self.meta.clone())
    }

    /// Cheap header-level summary of an artifact file, without
    /// compiling it (ops debugging; the `plan-info` subcommand).
    pub fn info(path: &std::path::Path) -> Result<ArtifactInfo, QwycError> {
        let buf = binary::AlignedBuf::read_file(path)?;
        if binary::is_binary(buf.bytes()) {
            return Ok(ArtifactInfo::Binary(binary::inspect(buf.bytes())?));
        }
        let text = std::str::from_utf8(buf.bytes())
            .map_err(|_| QwycError::Schema(format!("parse {path:?}: not UTF-8 JSON")))?;
        let json = Json::parse(text).map_err(|e| e.context(&format!("parse {path:?}")))?;
        let plan = QwycPlan::from_json(&json)?;
        Ok(ArtifactInfo::Json {
            name: plan.meta.name.clone(),
            t: plan.fc.t(),
            n_features: plan.meta.n_features,
        })
    }

    /// Header-level view of a LIVE compiled plan, no file involved: the
    /// plan is encoded to the binary layout in memory and inspected —
    /// exactly what `GET /plan` reports for the currently-deployed
    /// generation (section table, padding, quantization summary).
    pub fn live_info(
        meta: &PlanMeta,
        ensemble_name: &str,
        compiled: &CompiledPlan,
    ) -> Result<ArtifactInfo, QwycError> {
        let bytes = binary::encode(meta, ensemble_name, compiled);
        // `inspect` validates section alignment against the buffer base,
        // so route through the same aligned storage loads use.
        let buf = binary::AlignedBuf::from_bytes(&bytes);
        Ok(ArtifactInfo::Binary(binary::inspect(buf.bytes())?))
    }
}

// ---------------------------------------------------------------- slot

/// Shared, atomically swappable handle to the *current* serving plan —
/// the control-plane side of `RELOAD`.
///
/// Engine shards keep their own `Arc<CompiledPlan>` and compare
/// [`PlanSlot::generation`] (one atomic load) at every batch boundary;
/// only on a mismatch do they take the mutex and clone the new handle.
/// A batch mid-classification finishes against the plan it started
/// with, and shards adopt the new plan at their next batch boundary —
/// the `ArcSwap` pattern with std-only parts (Mutex<Arc<_>> + an
/// AtomicU64 generation as the fast path).
pub struct PlanSlot {
    current: Mutex<Arc<CompiledPlan>>,
    generation: AtomicU64,
}

impl PlanSlot {
    pub fn new(plan: Arc<CompiledPlan>) -> PlanSlot {
        PlanSlot { current: Mutex::new(plan), generation: AtomicU64::new(0) }
    }

    /// Generation counter; bumped by every [`PlanSlot::swap`].
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Clone the current plan handle.
    pub fn load(&self) -> Arc<CompiledPlan> {
        self.current.lock().unwrap().clone()
    }

    /// Install a new plan and return the new generation. The plan is
    /// published before the generation bump, so a reader that observes
    /// the new generation always loads the new (or an even newer) plan.
    pub fn swap(&self, plan: Arc<CompiledPlan>) -> u64 {
        let mut cur = self.current.lock().unwrap();
        *cur = plan;
        let gen = self.generation.fetch_add(1, Ordering::AcqRel) + 1;
        drop(cur);
        gen
    }
}

// ----------------------------------------------------------- probe set

/// Default probe count used by the serving canary.
pub const DEFAULT_PROBES: usize = 32;

/// Canary probe set for validated plan swaps.
///
/// Captured from the *live* plan's geometry (feature width) before a
/// `RELOAD`, then replayed against the candidate: any candidate that
/// disagrees on feature width, produces a non-finite score, or violates
/// the early-exit invariant (an exit that didn't cross its threshold, or
/// a "full" evaluation that stopped short of T) is refused before a
/// single request can reach it. Probe rows are deterministic — two fixed
/// lattice-corner rows (all-zeros, all-ones) plus seeded uniform [0, 1)
/// rows — so a rejection is reproducible from the reply alone.
pub struct ProbeSet {
    d: usize,
    n: usize,
    rows: Vec<f32>,
}

impl ProbeSet {
    /// Capture `n_probes` rows (min 2) against `live`'s feature width.
    pub fn capture(live: &CompiledPlan, n_probes: usize, seed: u64) -> ProbeSet {
        let d = live.n_features();
        let n = n_probes.max(2);
        let mut rows = vec![0f32; n * d];
        for v in rows[d..2 * d].iter_mut() {
            *v = 1.0;
        }
        let mut rng = crate::util::rng::Rng::new(seed);
        for v in rows[2 * d..].iter_mut() {
            *v = rng.f32();
        }
        ProbeSet { d, n, rows }
    }

    /// Feature width the probes were captured against.
    pub fn width(&self) -> usize {
        self.d
    }

    /// Number of probe rows.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Score every probe against `candidate` and check the serving
    /// invariants; `Err` explains the first violation (its message is
    /// what `RELOAD_REJECTED canary:` carries).
    pub fn check(&self, candidate: &CompiledPlan) -> Result<(), QwycError> {
        if candidate.n_features() != self.d {
            return Err(QwycError::Validate(format!(
                "feature width changed: live plan serves d={}, candidate wants d={}",
                self.d,
                candidate.n_features()
            )));
        }
        let t = candidate.t();
        for i in 0..self.n {
            let x = &self.rows[i * self.d..(i + 1) * self.d];
            let r = candidate.eval_single(x);
            if !r.score.is_finite() {
                return Err(QwycError::Validate(format!(
                    "probe {i}: non-finite score {}",
                    r.score
                )));
            }
            if r.early {
                let p = r.models_evaluated;
                if p == 0 || p > t {
                    return Err(QwycError::Validate(format!(
                        "probe {i}: early exit after {p} models, outside 1..={t}"
                    )));
                }
                let crossed = r.score > candidate.eps_pos()[p - 1]
                    || r.score < candidate.eps_neg()[p - 1];
                if !crossed {
                    return Err(QwycError::Validate(format!(
                        "probe {i}: early exit at position {p} without crossing a threshold"
                    )));
                }
            } else if r.models_evaluated != t {
                return Err(QwycError::Validate(format!(
                    "probe {i}: full evaluation stopped after {} of {t} models",
                    r.models_evaluated
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ensemble::BaseModel;
    use crate::lattice::model::Lattice;

    fn toy_plan() -> QwycPlan {
        // Two 1-feature lattices (f0 = x0, f1 = 1 - x1), neg-only ε.
        let l0 = Lattice::from_params(vec![0], vec![0.0, 1.0]);
        let l1 = Lattice::from_params(vec![1], vec![1.0, 0.0]);
        let ens = Ensemble::new(
            "toy",
            vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1)],
            0.25,
            1.0,
        );
        let fc = FastClassifier {
            order: vec![1, 0],
            eps_pos: vec![f32::INFINITY, f32::INFINITY],
            eps_neg: vec![-0.5, f32::NEG_INFINITY],
            bias: 0.25,
            beta: 1.0,
        };
        QwycPlan::bundle(ens, fc, "toy-plan", 0.01).unwrap()
    }

    #[test]
    fn roundtrips_through_schema_v1() {
        let plan = toy_plan();
        let j = plan.to_json();
        assert_eq!(j.req("schema").unwrap().as_str().unwrap(), PLAN_SCHEMA);
        let back = QwycPlan::from_json(&j).unwrap();
        assert_eq!(back.fc.order, plan.fc.order);
        assert_eq!(back.meta.name, "toy-plan");
        assert_eq!(back.meta.alpha, 0.01);
        assert!(back.meta.neg_only, "all eps_pos are +inf");
        assert_eq!(back.ensemble.len(), 2);
        // Threshold bits survive the trip (±inf encode as strings).
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.fc.eps_neg), bits(&plan.fc.eps_neg));
    }

    #[test]
    fn rejects_wrong_schema_and_mismatched_parts() {
        let plan = toy_plan();
        let mut j = plan.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::str("qwyc-plan-v0"));
        }
        assert!(QwycPlan::from_json(&j).is_err());

        // Classifier covering a different T than the ensemble.
        let mut fc = plan.fc.clone();
        fc.order = vec![0];
        fc.eps_pos = vec![f32::INFINITY];
        fc.eps_neg = vec![f32::NEG_INFINITY];
        assert!(QwycPlan::bundle(plan.ensemble.clone(), fc, "bad", 0.0).is_err());

        // bias drift between the two views.
        let mut fc2 = plan.fc.clone();
        fc2.bias = 0.5;
        assert!(QwycPlan::bundle(plan.ensemble.clone(), fc2, "bad", 0.0).is_err());

        // A hand-edited artifact lying about neg_only must not load.
        let mut j2 = toy_plan().to_json();
        if let Json::Obj(m) = &mut j2 {
            if let Some(Json::Obj(meta)) = m.get_mut("meta") {
                meta.insert("neg_only".into(), Json::Bool(false));
            }
        }
        assert!(QwycPlan::from_json(&j2).is_err());
    }

    #[test]
    fn errors_are_staged() {
        // Missing file → Io; wrong schema tag → Schema; mismatched
        // parts → Validate (the typed replacements for the old strings).
        let e = QwycPlan::load(std::path::Path::new("/nonexistent/plan.json")).unwrap_err();
        assert_eq!(e.stage(), "io", "{e}");

        let mut j = toy_plan().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::str("qwyc-plan-v0"));
        }
        assert_eq!(QwycPlan::from_json(&j).unwrap_err().stage(), "schema");

        let plan = toy_plan();
        let mut fc = plan.fc.clone();
        fc.bias = 0.5;
        let e = QwycPlan::bundle(plan.ensemble.clone(), fc, "bad", 0.0).unwrap_err();
        assert_eq!(e.stage(), "validate", "{e}");

        let mut narrow = plan;
        narrow.meta.n_features = 1;
        assert_eq!(narrow.compile().unwrap_err().stage(), "compile");
    }

    #[test]
    fn plan_slot_swaps_atomically_and_bumps_generation() {
        let plan = toy_plan();
        let slot = PlanSlot::new(plan.compile_shared().unwrap());
        assert_eq!(slot.generation(), 0);
        let before = slot.load();
        assert_eq!(before.t(), 2);

        let mut wide = toy_plan();
        wide.meta.n_features = 7;
        let gen = slot.swap(wide.compile_shared().unwrap());
        assert_eq!(gen, 1);
        assert_eq!(slot.generation(), 1);
        // New readers see the new plan; the old handle stays valid for
        // any batch still in flight.
        assert_eq!(slot.load().n_features(), 7);
        assert_eq!(before.n_features(), 2);
    }

    #[test]
    fn plan_slot_is_safe_under_concurrent_swap_and_load() {
        let slot = std::sync::Arc::new(PlanSlot::new(toy_plan().compile_shared().unwrap()));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let slot = slot.clone();
                s.spawn(move || {
                    for _ in 0..200 {
                        let gen = slot.generation();
                        let plan = slot.load();
                        // A loaded plan is always fully formed.
                        assert_eq!(plan.t(), 2);
                        assert!(slot.generation() >= gen);
                    }
                });
            }
            let swapper = slot.clone();
            s.spawn(move || {
                for _ in 0..50 {
                    swapper.swap(toy_plan().compile_shared().unwrap());
                }
            });
        });
        assert_eq!(slot.generation(), 50);
    }

    #[test]
    fn compile_checks_feature_agreement() {
        let plan = toy_plan();
        let cp = plan.compile().unwrap();
        assert_eq!(cp.t(), 2);
        assert_eq!(cp.n_features(), 2, "lattices read features 0 and 1");
        assert_eq!(cp.order(), &[1, 0]);
        // Declared width below what the models read must fail compile.
        let mut narrow = plan.clone();
        narrow.meta.n_features = 1;
        assert!(narrow.compile().is_err());
        // Declared width above is allowed (extra features are ignored).
        let mut wide = plan;
        wide.meta.n_features = 7;
        assert_eq!(wide.compile().unwrap().n_features(), 7);
    }

    #[test]
    fn compiled_prefix_costs_follow_pi() {
        let mut plan = toy_plan();
        plan.ensemble.costs = vec![3.0, 5.0];
        let cp = plan.compile().unwrap();
        // π = [1, 0] ⇒ prefix costs 0, c1, c1+c0.
        assert_eq!(cp.prefix_cost(0), 0.0);
        assert_eq!(cp.prefix_cost(1), 5.0);
        assert_eq!(cp.prefix_cost(2), 8.0);
        assert_eq!(cp.total_cost(), 8.0);
    }

    #[test]
    fn artifact_roundtrips_binary_and_json_with_lattices() {
        let dir = std::env::temp_dir().join(format!("qwyc-artifact-rt-{}", std::process::id()));
        let bin = dir.join("plan.bin");
        let json = dir.join("plan.json");
        let art = PlanArtifact::from_plan(toy_plan()).unwrap();
        art.save(&bin, PlanFormat::Binary).unwrap();
        art.save(&json, PlanFormat::Json).unwrap();
        let from_bin = PlanArtifact::load(&bin).unwrap();
        let from_json = PlanArtifact::load(&json).unwrap();
        assert_eq!(from_bin.format(), PlanFormat::Binary);
        assert_eq!(from_json.format(), PlanFormat::Json);
        let (a, b) = (from_bin.compiled(), from_json.compiled());
        assert_eq!(a.t(), b.t());
        assert_eq!(a.order(), b.order());
        assert_eq!(a.n_features(), b.n_features());
        assert_eq!(a.bias().to_bits(), b.bias().to_bits());
        assert_eq!(a.beta().to_bits(), b.beta().to_bits());
        let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(a.eps_pos()), bits(b.eps_pos()));
        assert_eq!(bits(a.eps_neg()), bits(b.eps_neg()));
        assert_eq!(a.total_cost().to_bits(), b.total_cost().to_bits());
        for x in [[0.1f32, 0.9], [0.9, 0.1], [0.5, 0.5]] {
            let (ra, rb) = (a.eval_single(&x), b.eval_single(&x));
            assert_eq!(ra.score.to_bits(), rb.score.to_bits());
            assert_eq!(ra.models_evaluated, rb.models_evaluated);
        }
        // Binary-backed artifacts reconstruct the uncompiled plan
        // exactly (inverse permutation through π).
        let back = from_bin.to_plan().unwrap();
        let orig = toy_plan();
        assert_eq!(back.ensemble.name, orig.ensemble.name);
        assert_eq!(back.fc.order, orig.fc.order);
        assert_eq!(bits(&back.ensemble.costs), bits(&orig.ensemble.costs));
        assert_eq!(back.meta.name, orig.meta.name);
        assert_eq!(back.meta.alpha, orig.meta.alpha);
        // ... and can re-export as JSON that loads identically.
        let json2 = dir.join("plan2.json");
        from_bin.save(&json2, PlanFormat::Json).unwrap();
        let again = PlanArtifact::load(&json2).unwrap();
        assert_eq!(bits(again.compiled().eps_neg()), bits(a.eps_neg()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_info_render_pins_output_shape() {
        // JSON view: exactly two lines; CI smoke greps the format tag.
        let info = ArtifactInfo::Json { name: "toy-plan".into(), t: 2, n_features: 0 };
        assert_eq!(
            info.render("p.json"),
            "p.json: qwyc-plan-v1 (JSON)\n  plan 'toy-plan'  T=2  n_features=0\n"
        );

        // Binary view from a real (lattice ⇒ unquantized) artifact: the
        // version line, all ten section rows with a pad column, and the
        // explicit not-quantized marker.
        let dir = std::env::temp_dir().join(format!("qwyc-plan-info-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bin = dir.join("plan.bin");
        PlanArtifact::from_plan(toy_plan()).unwrap().save(&bin, PlanFormat::Binary).unwrap();
        let rendered = PlanArtifact::info(&bin).unwrap().render("plan.bin");
        assert!(rendered.starts_with("plan.bin: qwyc-plan-bin-v1 version 2\n"), "{rendered}");
        assert!(rendered.contains(" pad\n"), "{rendered}");
        for name in ["scalars", "model_data", "bin_edges", "quant_nodes"] {
            assert!(rendered.contains(name), "missing section {name} in:\n{rendered}");
        }
        assert!(rendered.contains("quantization: none (raw f32 thresholds)"), "{rendered}");
        let _ = std::fs::remove_dir_all(&dir);

        // Quantized summary lines, pinned byte-for-byte on a synthetic
        // info (the fields are public exactly so this stays testable).
        let info = ArtifactInfo::Binary(BinaryInfo {
            version: 2,
            file_len: 1024,
            plan_name: "q".into(),
            t: 3,
            n_features: 2,
            edge_counts: vec![2, 1],
            sections: vec![
                SectionInfo { name: "bin_edges", offset: 832, len: 24 },
                SectionInfo { name: "quant_nodes", offset: 896, len: 14 },
            ],
        });
        let r = info.render("q.bin");
        assert!(r.contains("  bin_edges           832         24     40\n"), "{r}");
        assert!(r.contains("  quant_nodes         896         14    114\n"), "{r}");
        assert!(
            r.contains("  quantization: 2 features, 3 bin edges, quantized bank 14 bytes\n"),
            "{r}"
        );
        assert!(r.contains("    edges/feature: 2 1\n"), "{r}");
    }

    #[test]
    fn plan_format_parses_cli_names() {
        assert_eq!(PlanFormat::parse("json").unwrap(), PlanFormat::Json);
        assert_eq!(PlanFormat::parse("bin").unwrap(), PlanFormat::Binary);
        assert_eq!(PlanFormat::parse("binary").unwrap(), PlanFormat::Binary);
        assert_eq!(PlanFormat::parse("yaml").unwrap_err().stage(), "config");
    }

    #[test]
    fn compiled_eval_single_matches_classifier_path() {
        let plan = toy_plan();
        let cp = plan.compile().unwrap();
        for x in [[0.1f32, 0.9], [0.9, 0.1], [0.5, 0.5], [1.0, 0.0]] {
            let want = plan.fc.eval_single(&plan.ensemble, &x);
            let got = cp.eval_single(&x);
            assert_eq!(got.positive, want.positive);
            assert_eq!(got.models_evaluated, want.models_evaluated);
            assert_eq!(got.early, want.early);
            assert_eq!(got.score.to_bits(), want.score.to_bits());
        }
    }

    /// A 2-feature plan whose base models output `f32::MAX` each: every
    /// probe row sums to +inf (validly structured, scores garbage) — the
    /// shape of corruption that compiles fine but must fail the canary.
    fn overflowing_plan() -> QwycPlan {
        let l0 = Lattice::from_params(vec![0], vec![f32::MAX, f32::MAX]);
        let l1 = Lattice::from_params(vec![1], vec![f32::MAX, f32::MAX]);
        let ens =
            Ensemble::new("hot", vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1)], 0.25, 1.0);
        let fc = FastClassifier {
            order: vec![0, 1],
            eps_pos: vec![f32::INFINITY, f32::INFINITY],
            eps_neg: vec![f32::NEG_INFINITY, f32::NEG_INFINITY],
            bias: 0.25,
            beta: 1.0,
        };
        QwycPlan::bundle(ens, fc, "hot-plan", 0.01).unwrap()
    }

    #[test]
    fn probe_set_accepts_a_well_behaved_candidate() {
        let live = toy_plan().compile().unwrap();
        let probes = ProbeSet::capture(&live, DEFAULT_PROBES, 42);
        assert_eq!(probes.width(), 2);
        assert_eq!(probes.len(), DEFAULT_PROBES);
        assert!(!probes.is_empty());
        // The live plan trivially passes its own probes, and so does an
        // identically-shaped recompile (the RELOAD happy path).
        probes.check(&live).unwrap();
        probes.check(&toy_plan().compile().unwrap()).unwrap();
    }

    #[test]
    fn probe_set_capture_is_deterministic_for_a_seed() {
        let live = toy_plan().compile().unwrap();
        let a = ProbeSet::capture(&live, 8, 7);
        let b = ProbeSet::capture(&live, 8, 7);
        assert_eq!(a.rows, b.rows);
        let c = ProbeSet::capture(&live, 8, 8);
        assert_ne!(a.rows, c.rows);
    }

    #[test]
    fn probe_set_rejects_width_mismatch() {
        let live = toy_plan().compile().unwrap();
        let probes = ProbeSet::capture(&live, 4, 1);
        // A 3-feature candidate: same toy shape plus one extra input.
        let l0 = Lattice::from_params(vec![0], vec![0.0, 1.0]);
        let l1 = Lattice::from_params(vec![1], vec![1.0, 0.0]);
        let l2 = Lattice::from_params(vec![2], vec![0.0, 1.0]);
        let ens = Ensemble::new(
            "wide",
            vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1), BaseModel::Lattice(l2)],
            0.25,
            1.0,
        );
        let fc = FastClassifier {
            order: vec![0, 1, 2],
            eps_pos: vec![f32::INFINITY; 3],
            eps_neg: vec![f32::NEG_INFINITY; 3],
            bias: 0.25,
            beta: 1.0,
        };
        let wide = QwycPlan::bundle(ens, fc, "wide-plan", 0.01).unwrap().compile().unwrap();
        let err = probes.check(&wide).unwrap_err();
        assert_eq!(err.stage(), "validate");
        assert!(err.message().contains("feature width"), "{}", err.message());
    }

    #[test]
    fn probe_set_rejects_non_finite_scores() {
        let live = toy_plan().compile().unwrap();
        let probes = ProbeSet::capture(&live, 4, 1);
        let hot = overflowing_plan().compile().unwrap();
        let err = probes.check(&hot).unwrap_err();
        assert_eq!(err.stage(), "validate");
        assert!(err.message().contains("non-finite"), "{}", err.message());
    }
}
