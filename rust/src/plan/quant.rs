//! Feature quantization for the compiled sweep kernel.
//!
//! At compile time, [`FeatureQuant::from_models`] collects every
//! distinct split threshold each feature sees across the plan's trees
//! into a sorted per-feature edge table. Bin indices are defined as
//!
//! ```text
//! bin(x) = #{ e ∈ edges[f] : e < x }        (NaN ⇒ NAN_BIN)
//! ```
//!
//! so a node splitting feature f at threshold t = edges\[f\]\[k\]
//! satisfies `x <= t  ⟺  bin(x) <= k` for every non-NaN x (any edge
//! below x is below t, so bin(x) ≤ k; conversely x > t makes all of
//! edges\[0..=k\] < x, so bin(x) ≥ k+1). ±∞ and subnormals need no
//! special cases — the proof only uses IEEE `<` on finite-or-infinite
//! values. NaN *would* land in bin 0 (every compare false) and wrongly
//! route LEFT where the raw walk's `v <= t` routes RIGHT; instead NaN
//! quantizes to the [`NAN_BIN`] sentinel, which exceeds every
//! threshold bin (edge counts are capped at [`MAX_EDGES_PER_FEATURE`]),
//! so the quantized compare also routes RIGHT. The result: rewriting
//! node thresholds as u16 bin indices and feature values as u16 bins
//! is **bitwise-identical** to the raw f32 walk — leaf values are
//! untouched and accumulate in π order exactly as before.
//!
//! Quantization is rebuilt deterministically at every plan load (both
//! JSON and binary funnel through `CompiledPlan::from_parts`), like the
//! SoA banks; the binary artifact additionally stores the edge tables
//! and quantized node banks so `plan-info` can inspect them and the
//! decoder can verify them against the rebuild.

use crate::ensemble::BaseModel;

/// Quantized value of a NaN feature: compares greater than every
/// threshold bin, so NaN routes right exactly like the raw `v <= t`.
pub const NAN_BIN: u16 = u16::MAX;

/// Cap on distinct thresholds per feature: keeps every threshold bin
/// ≤ 65533 and every finite value bin ≤ 65534, both strictly below the
/// [`NAN_BIN`] sentinel. A feature with more distinct thresholds
/// disables quantization for the whole plan (the raw path still
/// serves it).
pub const MAX_EDGES_PER_FEATURE: usize = 65534;

/// Per-feature sorted distinct split-threshold tables, plus the bin
/// mapping built on them. Immutable once constructed; shared by the
/// compiled plan.
#[derive(Clone, Debug)]
pub struct FeatureQuant {
    /// `edges[f]` is sorted ascending with no duplicates (IEEE `==`
    /// dedup, so -0.0/+0.0 merge — they are the same split).
    edges: Vec<Vec<f32>>,
}

impl FeatureQuant {
    /// Collect each feature's distinct tree-split thresholds. Returns
    /// `None` — quantization disabled, raw path serves — when the
    /// models contain no tree splits at all, any split threshold is
    /// NaN, or a feature exceeds [`MAX_EDGES_PER_FEATURE`] distinct
    /// thresholds. Lattice models are untouched by quantization (the
    /// sweep evaluates them on the raw rows) and don't affect the
    /// decision.
    pub fn from_models(models: &[BaseModel], n_features: usize) -> Option<FeatureQuant> {
        let mut edges: Vec<Vec<f32>> = vec![Vec::new(); n_features];
        let mut any_split = false;
        for m in models {
            if let BaseModel::Tree(tr) = m {
                for node in &tr.nodes {
                    if node.is_leaf() {
                        continue;
                    }
                    if node.threshold.is_nan() {
                        return None;
                    }
                    // from_parts validated feature < n_features via
                    // min_features; stay defensive anyway.
                    let f = node.feature as usize;
                    if f >= n_features {
                        return None;
                    }
                    edges[f].push(node.threshold);
                    any_split = true;
                }
            }
        }
        if !any_split {
            return None;
        }
        for per_feature in edges.iter_mut() {
            per_feature.sort_unstable_by(f32::total_cmp);
            per_feature.dedup_by(|a, b| a == b);
            if per_feature.len() > MAX_EDGES_PER_FEATURE {
                return None;
            }
        }
        Some(FeatureQuant { edges })
    }

    /// Number of feature slots (the plan's `n_features`).
    pub fn n_features(&self) -> usize {
        self.edges.len()
    }

    /// Sorted distinct thresholds of feature `f` (empty when no tree
    /// splits on it).
    pub fn edges(&self, f: usize) -> &[f32] {
        &self.edges[f]
    }

    /// Per-feature edge counts (the `bin_edges` section header in the
    /// binary artifact).
    pub fn edge_counts(&self) -> Vec<u32> {
        self.edges.iter().map(|e| e.len() as u32).collect()
    }

    /// Total edges across all features.
    pub fn total_edges(&self) -> usize {
        self.edges.iter().map(Vec::len).sum()
    }

    /// Bin index of threshold `t` on feature `f`: the k with
    /// `edges[f][k] == t`, the right-hand side of the equivalence
    /// `x <= t ⟺ bin(x) <= k`. `None` if `t` is not in the table
    /// (never happens for thresholds collected by
    /// [`FeatureQuant::from_models`] from the same models).
    pub fn threshold_bin(&self, f: usize, t: f32) -> Option<u16> {
        if t.is_nan() {
            return None;
        }
        let edges = self.edges.get(f)?;
        // First index with edges[k] >= t; `e < t` is monotone over the
        // sorted table for non-NaN t.
        let k = edges.partition_point(|&e| e < t);
        // IEEE == matches -0.0 against a stored +0.0 (they were
        // deduped as one edge).
        if k < edges.len() && edges[k] == t {
            Some(k as u16)
        } else {
            None
        }
    }

    /// Quantize one feature value against a sorted edge table:
    /// branchless lower-bound binary search counting edges strictly
    /// below `x`; NaN maps to [`NAN_BIN`].
    #[inline]
    pub fn bin_of(edges: &[f32], x: f32) -> u16 {
        if x.is_nan() {
            return NAN_BIN;
        }
        if edges.is_empty() {
            return 0;
        }
        let mut lo = 0usize;
        let mut n = edges.len();
        while n > 1 {
            let half = n / 2;
            // Branchless: cmov-friendly select, no data-dependent jump.
            lo = if edges[lo + half] < x { lo + half } else { lo };
            n -= half;
        }
        (lo + usize::from(edges[lo] < x)) as u16
    }

    /// Quantize one row-major block of feature rows (stride `d`,
    /// `x.len() == n·d`) into `out`, resized to match. Each value costs
    /// one branchless binary search over its feature's edge table;
    /// features beyond the plan's width (rows wider than `n_features`)
    /// or without splits take the empty-table fast path (bin 0).
    pub fn quantize_block(&self, x: &[f32], d: usize, out: &mut Vec<u16>) {
        debug_assert!(d == 0 || x.len() % d == 0);
        out.clear();
        out.resize(x.len(), 0);
        if d == 0 {
            return;
        }
        for (row, qrow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            for (f, (&v, q)) in row.iter().zip(qrow.iter_mut()).enumerate() {
                let edges: &[f32] = if f < self.edges.len() { &self.edges[f] } else { &[] };
                *q = FeatureQuant::bin_of(edges, v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbt::tree::{Node, Tree};

    fn tree(splits: &[(u32, f32)]) -> Tree {
        // A right-deep chain: each split's left child is a leaf.
        let mut nodes = Vec::new();
        for (i, &(f, t)) in splits.iter().enumerate() {
            nodes.push(Node {
                feature: f,
                threshold: t,
                left: (2 * i + 1) as u32,
                value: 0.0,
            });
            nodes.push(Node::leaf(i as f32));
        }
        nodes.push(Node::leaf(-1.0));
        let tr = Tree { nodes };
        tr.validate().unwrap();
        tr
    }

    #[test]
    fn edges_are_sorted_distinct_per_feature() {
        let models = vec![
            BaseModel::Tree(tree(&[(0, 3.0), (1, -1.0)])),
            BaseModel::Tree(tree(&[(0, 1.0), (0, 3.0)])),
        ];
        let q = FeatureQuant::from_models(&models, 4).unwrap();
        assert_eq!(q.edges(0), &[1.0, 3.0]);
        assert_eq!(q.edges(1), &[-1.0]);
        assert!(q.edges(2).is_empty() && q.edges(3).is_empty());
        assert_eq!(q.edge_counts(), vec![2, 1, 0, 0]);
        assert_eq!(q.total_edges(), 3);
        assert_eq!(q.threshold_bin(0, 1.0), Some(0));
        assert_eq!(q.threshold_bin(0, 3.0), Some(1));
        assert_eq!(q.threshold_bin(1, -1.0), Some(0));
        assert_eq!(q.threshold_bin(0, 2.0), None);
    }

    #[test]
    fn nan_threshold_or_no_splits_disables_quantization() {
        assert!(FeatureQuant::from_models(&[], 3).is_none());
        let leaf_only = vec![BaseModel::Tree(Tree::single_leaf(1.0))];
        assert!(FeatureQuant::from_models(&leaf_only, 3).is_none());
        let nan = vec![BaseModel::Tree(tree(&[(0, f32::NAN)]))];
        assert!(FeatureQuant::from_models(&nan, 3).is_none());
    }

    #[test]
    fn negative_zero_merges_with_positive_zero() {
        let models =
            vec![BaseModel::Tree(tree(&[(0, -0.0), (0, 0.0)]))];
        let q = FeatureQuant::from_models(&models, 1).unwrap();
        assert_eq!(q.edges(0).len(), 1);
        // Both spellings of zero resolve to the same bin.
        assert_eq!(q.threshold_bin(0, 0.0), Some(0));
        assert_eq!(q.threshold_bin(0, -0.0), Some(0));
        // And -0.0/+0.0 feature values quantize identically (IEEE ==).
        let e = q.edges(0);
        assert_eq!(FeatureQuant::bin_of(e, -0.0), FeatureQuant::bin_of(e, 0.0));
    }

    /// The theorem the whole kernel rests on: for every edge table and
    /// every probe value (threshold-equal, between, ±∞, subnormal),
    /// `x <= t ⟺ bin(x) <= bin(t)`.
    #[test]
    fn bin_mapping_preserves_threshold_compares() {
        let tables: [&[f32]; 4] = [
            &[1.0, 3.0, 5.0],
            &[-2.5],
            &[f32::MIN_POSITIVE / 2.0, 0.0, 1.0e-30, 7.0],
            &[f32::NEG_INFINITY, -1.0, 1.0, f32::INFINITY],
        ];
        for edges in tables {
            let mut probes: Vec<f32> = edges.to_vec();
            probes.extend_from_slice(&[
                f32::NEG_INFINITY,
                -10.0,
                -0.0,
                0.0,
                f32::MIN_POSITIVE / 4.0,
                2.0,
                4.0,
                6.0,
                1.0e30,
                f32::INFINITY,
            ]);
            for &x in &probes {
                let bx = FeatureQuant::bin_of(edges, x);
                for (k, &t) in edges.iter().enumerate() {
                    assert_eq!(
                        x <= t,
                        bx <= k as u16,
                        "x={x} t={t} (bin {k}) bx={bx} edges={edges:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn nan_value_gets_the_sentinel_bin() {
        let edges = [1.0f32, 2.0];
        assert_eq!(FeatureQuant::bin_of(&edges, f32::NAN), NAN_BIN);
        // Sentinel exceeds every representable threshold bin.
        assert!(u64::from(NAN_BIN) > MAX_EDGES_PER_FEATURE as u64 - 1);
    }

    #[test]
    fn quantize_block_handles_stride_and_empty() {
        let models = vec![BaseModel::Tree(tree(&[(0, 1.0), (1, 5.0)]))];
        let q = FeatureQuant::from_models(&models, 2).unwrap();
        let x = [0.5f32, 6.0, 1.0, 5.0, f32::NAN, 4.0];
        let mut out = Vec::new();
        q.quantize_block(&x, 2, &mut out);
        assert_eq!(out, vec![0, 1, 0, 0, NAN_BIN, 0]);
        q.quantize_block(&[], 2, &mut out);
        assert!(out.is_empty());
        // Rows wider than n_features: extra columns bin to 0.
        q.quantize_block(&[2.0, 6.0, 9.9], 3, &mut out);
        assert_eq!(out, vec![1, 1, 0]);
    }
}
