//! The typed end-to-end pipeline facade: **train → optimize → compile →
//! evaluate** as one library API.
//!
//! The paper's deliverable is a pipeline — train an additive ensemble,
//! jointly optimize its evaluation order π and early-stopping thresholds
//! ε± (Algorithm 1), compile the result into a deployable artifact, and
//! serve it with early exit. [`PlanBuilder`] makes that pipeline a
//! *typed-state* value: each stage transition returns the next stage's
//! type, so "optimize before training" or "compile before optimizing"
//! are **compile errors**, not runtime panics.
//!
//! ```text
//! PlanBuilder<Untrained>
//!   ├─ .train(TrainSpec)            ──> PlanBuilder<Trained>
//!   └─ .with_ensemble(ens, &data)   ──> PlanBuilder<Trained>
//! PlanBuilder<Trained>
//!   └─ .optimize(&QwycConfig, &Pool)──> PlanBuilder<Optimized>
//! PlanBuilder<Optimized>
//!   ├─ .compile()                   ──> Arc<CompiledPlan>
//!   ├─ .into_plan()                 ──> QwycPlan (the artifact that ships)
//!   └─ .session()                   ──> EvalSession (streaming decisions)
//! ```
//!
//! The builder is a veneer, not a fork: `.optimize` runs exactly
//! [`optimize_order_with_pool`] on exactly
//! [`Ensemble::score_matrix_par`]'s output, so its plans are
//! **bit-identical** to the loose-function path at every thread count
//! (pinned in `rust/tests/pipeline_api.rs`). Evaluation goes through
//! [`EvalSession`], whose [`Decision`]s come from the same shared sweep
//! core every other consumer uses.

#![warn(missing_docs)]

mod session;

pub use session::{Decision, DecisionIter, EvalSession};

use crate::data::Dataset;
use crate::ensemble::{Ensemble, ScoreMatrix};
use crate::error::QwycError;
use crate::gbt::GbtParams;
use crate::lattice::model::MAX_DIM;
use crate::lattice::LatticeParams;
use crate::plan::{CompiledPlan, PlanArtifact, PlanFormat, QwycPlan};
use crate::qwyc::{optimize_order_with_pool, FastClassifier, QwycConfig};
use crate::util::pool::Pool;
use std::borrow::Cow;
use std::sync::Arc;

// ------------------------------------------------------------ training

/// Which ensemble family to train, with its hyperparameters.
#[derive(Clone, Debug)]
pub enum ModelSpec {
    /// Gradient-boosted trees (the paper's benchmark experiments).
    Gbt(GbtParams),
    /// Jointly trained lattice ensemble (the paper's production models).
    LatticeJoint(LatticeParams),
    /// Independently trained lattices (the re-trained comparison).
    LatticeIndependent(LatticeParams),
}

/// A training request: the dataset plus the model family to fit. The
/// dataset doubles as the optimization set for the following
/// [`PlanBuilder::optimize`](PlanBuilder::optimize) stage.
#[derive(Clone, Debug)]
pub struct TrainSpec<'a> {
    /// Training (and threshold-optimization) examples.
    pub data: &'a Dataset,
    /// Ensemble family and hyperparameters.
    pub model: ModelSpec,
}

impl<'a> TrainSpec<'a> {
    /// Boosted-tree spec.
    pub fn gbt(data: &'a Dataset, params: GbtParams) -> TrainSpec<'a> {
        TrainSpec { data, model: ModelSpec::Gbt(params) }
    }

    /// Jointly trained lattice spec.
    pub fn lattice_joint(data: &'a Dataset, params: LatticeParams) -> TrainSpec<'a> {
        TrainSpec { data, model: ModelSpec::LatticeJoint(params) }
    }

    /// Independently trained lattice spec.
    pub fn lattice_independent(data: &'a Dataset, params: LatticeParams) -> TrainSpec<'a> {
        TrainSpec { data, model: ModelSpec::LatticeIndependent(params) }
    }

    /// Reject impossible requests before the trainers' internal asserts
    /// can panic: degenerate datasets and zero-sized or over-wide models
    /// are `Train` errors.
    fn validate(&self) -> Result<(), QwycError> {
        let train_err = |m: String| Err(QwycError::Train(m));
        if self.data.n < 2 {
            return train_err(format!("need at least 2 training examples, got {}", self.data.n));
        }
        match &self.model {
            ModelSpec::Gbt(p) => {
                if p.n_trees == 0 {
                    return train_err("gbt: n_trees must be >= 1".into());
                }
            }
            ModelSpec::LatticeJoint(p) | ModelSpec::LatticeIndependent(p) => {
                if p.n_lattices == 0 {
                    return train_err("lattice: n_lattices must be >= 1".into());
                }
                if p.dim > MAX_DIM {
                    return train_err(format!("lattice: dim {} > MAX_DIM {MAX_DIM}", p.dim));
                }
                if p.dim > self.data.d {
                    return train_err(format!(
                        "lattice: dim {} > dataset width {}",
                        p.dim, self.data.d
                    ));
                }
            }
        }
        Ok(())
    }

    /// Run the trainer; returns the ensemble and its per-round train
    /// losses. [`PlanBuilder::train`] calls this — it is public so
    /// embedders can also fit an ensemble without entering the builder.
    pub fn fit(&self) -> Result<(Ensemble, Vec<f64>), QwycError> {
        self.validate()?;
        Ok(match &self.model {
            ModelSpec::Gbt(p) => crate::gbt::train(self.data, p),
            ModelSpec::LatticeJoint(p) => crate::lattice::train_joint(self.data, p),
            ModelSpec::LatticeIndependent(p) => crate::lattice::train_independent(self.data, p),
        })
    }
}

// -------------------------------------------------------------- stages

/// Where the optimize stage reads its score matrix from.
enum OptSet<'a> {
    /// Score the dataset at optimize time (through the builder's pool).
    Data(&'a Dataset),
    /// A caller-precomputed matrix (must agree with the ensemble).
    Scores(&'a ScoreMatrix),
}

/// Typed stage: no ensemble yet.
pub struct Untrained(());

/// Typed stage: an ensemble exists; order/thresholds do not. The
/// ensemble is borrowed when the caller brought their own
/// (`with_ensemble`/`with_scores`) and owned when [`PlanBuilder::train`]
/// fitted it — no deep copies until an artifact is actually bundled.
pub struct Trained<'a> {
    ensemble: Cow<'a, Ensemble>,
    losses: Vec<f64>,
    opt_set: OptSet<'a>,
}

/// Typed stage: order π and thresholds ε± are optimized.
pub struct Optimized<'a> {
    ensemble: Cow<'a, Ensemble>,
    fc: FastClassifier,
    alpha: f64,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for super::Untrained {}
    impl Sealed for super::Trained<'_> {}
    impl Sealed for super::Optimized<'_> {}
}

/// Marker for the builder's typed states (sealed: the state machine is
/// closed — embedders cannot add stages that skip its checks).
pub trait Stage: sealed::Sealed {}

impl Stage for Untrained {}
impl Stage for Trained<'_> {}
impl Stage for Optimized<'_> {}

/// The capability gating the terminal methods: `classifier`, `alpha`,
/// `plan`, `into_plan`, `compile`, and `session` are implemented for
/// `PlanBuilder<S>` **only when `S: CompileReady`**, and the only stage
/// implementing it is [`Optimized`] — so skipping the optimize stage is
/// an unsatisfied-trait-bound error at compile time:
///
/// ```compile_fail
/// use qwyc::data::Dataset;
/// use qwyc::ensemble::Ensemble;
/// use qwyc::pipeline::PlanBuilder;
///
/// let ds = Dataset::new("d", 1);
/// let ens = Ensemble::new("e", vec![], 0.0, 0.0);
/// let trained = PlanBuilder::new("p").with_ensemble(&ens, &ds);
/// let _ = trained.compile(); // ERROR: `Trained<'_>: CompileReady` is not satisfied
/// ```
pub trait CompileReady: Stage {
    /// Borrow the optimized parts: (ensemble, classifier, alpha).
    #[doc(hidden)]
    fn parts(&self) -> (&Ensemble, &FastClassifier, f64);
    /// Take the optimized parts, cloning the ensemble only if it was
    /// brought in by reference.
    #[doc(hidden)]
    fn into_parts(self) -> (Ensemble, FastClassifier, f64)
    where
        Self: Sized;
}

impl CompileReady for Optimized<'_> {
    fn parts(&self) -> (&Ensemble, &FastClassifier, f64) {
        (self.ensemble.as_ref(), &self.fc, self.alpha)
    }

    fn into_parts(self) -> (Ensemble, FastClassifier, f64) {
        (self.ensemble.into_owned(), self.fc, self.alpha)
    }
}

// ------------------------------------------------------------- builder

/// Typed-state builder for the train → optimize → compile pipeline.
/// See the [module docs](self) for the state machine.
pub struct PlanBuilder<S: Stage> {
    name: String,
    n_features: usize,
    source: String,
    stage: S,
}

impl<S: Stage> PlanBuilder<S> {
    /// Rename the plan (defaults to the name given at [`PlanBuilder::new`]).
    pub fn named(mut self, name: &str) -> Self {
        name.clone_into(&mut self.name);
        self
    }

    /// Declare the serving feature width recorded in the plan (0 = infer:
    /// the optimization dataset's width when one is given, else the
    /// widest feature any base model reads).
    pub fn with_n_features(mut self, d: usize) -> Self {
        self.n_features = d;
        self
    }

    /// Free-form provenance recorded in the plan (dataset, pipeline id,
    /// commit, ...).
    pub fn with_source(mut self, source: &str) -> Self {
        source.clone_into(&mut self.source);
        self
    }

    /// The plan name this builder will record.
    pub fn name(&self) -> &str {
        &self.name
    }

    fn carry<T: Stage>(self, stage: T) -> PlanBuilder<T> {
        PlanBuilder {
            name: self.name,
            n_features: self.n_features,
            source: self.source,
            stage,
        }
    }
}

impl PlanBuilder<Untrained> {
    /// Start a pipeline; `name` becomes the plan name (provenance).
    pub fn new(name: &str) -> PlanBuilder<Untrained> {
        PlanBuilder {
            name: name.to_string(),
            n_features: 0,
            source: String::new(),
            stage: Untrained(()),
        }
    }

    /// Train an ensemble per `spec`; its dataset becomes the
    /// optimization set for [`PlanBuilder::optimize`].
    pub fn train(self, spec: TrainSpec<'_>) -> Result<PlanBuilder<Trained<'_>>, QwycError> {
        let (ensemble, losses) = spec.fit()?;
        let ensemble = Cow::Owned(ensemble);
        Ok(self.carry(Trained { ensemble, losses, opt_set: OptSet::Data(spec.data) }))
    }

    /// Bring an already-trained ensemble (borrowed — nothing is cloned
    /// until an artifact is bundled); `opt_set` is the data the
    /// order/threshold optimization will run against.
    pub fn with_ensemble<'a>(
        self,
        ensemble: &'a Ensemble,
        opt_set: &'a Dataset,
    ) -> PlanBuilder<Trained<'a>> {
        let ensemble = Cow::Borrowed(ensemble);
        self.carry(Trained { ensemble, losses: Vec::new(), opt_set: OptSet::Data(opt_set) })
    }

    /// Bring an ensemble plus its precomputed score matrix (skips the
    /// scoring pass inside [`PlanBuilder::optimize`]). The matrix must be
    /// the ensemble's own: matching T, bias, and β.
    pub fn with_scores<'a>(
        self,
        ensemble: &'a Ensemble,
        scores: &'a ScoreMatrix,
    ) -> Result<PlanBuilder<Trained<'a>>, QwycError> {
        if scores.t != ensemble.len() {
            return Err(QwycError::Validate(format!(
                "score matrix covers {} models but the ensemble has {}",
                scores.t,
                ensemble.len()
            )));
        }
        if scores.bias != ensemble.bias || scores.beta != ensemble.beta {
            return Err(QwycError::Validate(format!(
                "score matrix bias/beta ({}, {}) disagree with ensemble ({}, {})",
                scores.bias, scores.beta, ensemble.bias, ensemble.beta
            )));
        }
        let ensemble = Cow::Borrowed(ensemble);
        Ok(self.carry(Trained { ensemble, losses: Vec::new(), opt_set: OptSet::Scores(scores) }))
    }
}

impl<'a> PlanBuilder<Trained<'a>> {
    /// The trained (or provided) ensemble.
    pub fn ensemble(&self) -> &Ensemble {
        &self.stage.ensemble
    }

    /// Per-round train losses when [`PlanBuilder::train`] fitted the
    /// ensemble (empty for `with_ensemble`/`with_scores`).
    pub fn losses(&self) -> &[f64] {
        &self.stage.losses
    }

    /// Give up on the pipeline and take the ensemble (e.g. to save a
    /// `model.json` without optimizing yet — the CLI `train` arm).
    /// Clones only if the ensemble was brought in by reference.
    pub fn into_ensemble(self) -> Ensemble {
        self.stage.ensemble.into_owned()
    }

    /// Jointly optimize evaluation order π and thresholds ε± (QWYC*,
    /// Algorithm 1) across `pool`. Exactly the loose-function path —
    /// [`Ensemble::score_matrix_par`] then [`optimize_order_with_pool`] —
    /// so the result is bit-identical to it at every thread count.
    pub fn optimize(
        self,
        cfg: &QwycConfig,
        pool: &Pool,
    ) -> Result<PlanBuilder<Optimized<'a>>, QwycError> {
        if !(0.0..=1.0).contains(&cfg.alpha) {
            return Err(QwycError::Config(format!(
                "alpha must be within [0, 1], got {}",
                cfg.alpha
            )));
        }
        if self.stage.ensemble.is_empty() {
            return Err(QwycError::Train("cannot optimize an empty ensemble".into()));
        }
        let mut n_features = self.n_features;
        let owned;
        let sm: &ScoreMatrix = match &self.stage.opt_set {
            OptSet::Data(ds) => {
                let need = self.stage.ensemble.feature_count();
                if ds.d < need {
                    return Err(QwycError::Config(format!(
                        "optimization set is {} features wide but the ensemble reads {need}",
                        ds.d
                    )));
                }
                if n_features == 0 {
                    n_features = ds.d;
                }
                owned = self.stage.ensemble.score_matrix_par(ds, pool);
                &owned
            }
            OptSet::Scores(sm) => *sm,
        };
        let fc = optimize_order_with_pool(sm, cfg, pool);
        let stage = Optimized { ensemble: self.stage.ensemble, fc, alpha: cfg.alpha };
        let mut next = PlanBuilder {
            name: self.name,
            n_features,
            source: self.source,
            stage,
        };
        if next.source.is_empty() {
            next.source = String::from("qwyc::pipeline");
        }
        Ok(next)
    }
}

impl<S: CompileReady> PlanBuilder<S> {
    /// The optimized fast classifier (π + ε± + bias/β).
    pub fn classifier(&self) -> &FastClassifier {
        self.stage.parts().1
    }

    /// The α the thresholds were optimized for.
    pub fn alpha(&self) -> f64 {
        self.stage.parts().2
    }

    /// Bundle into the versioned `qwyc-plan-v1` artifact — fully
    /// validated, including the declared feature width, so the result
    /// is safe to save and deploy as-is.
    pub fn plan(&self) -> Result<QwycPlan, QwycError> {
        let (ensemble, fc, alpha) = self.stage.parts();
        let mut plan = QwycPlan::bundle_with_width(
            ensemble.clone(),
            fc.clone(),
            &self.name,
            alpha,
            self.n_features,
        )?;
        plan.meta.source.clone_from(&self.source);
        Ok(plan)
    }

    /// [`PlanBuilder::plan`], consuming the builder — the zero-extra-copy
    /// path when the builder trained (and therefore owns) the ensemble.
    pub fn into_plan(self) -> Result<QwycPlan, QwycError> {
        let (ensemble, fc, alpha) = self.stage.into_parts();
        let mut plan =
            QwycPlan::bundle_with_width(ensemble, fc, &self.name, alpha, self.n_features)?;
        plan.meta.source = self.source;
        Ok(plan)
    }

    /// Compile into the shared serving form: invariants checked once,
    /// models pre-permuted, ready to hand to engine shards or an
    /// [`EvalSession`].
    pub fn compile(&self) -> Result<Arc<CompiledPlan>, QwycError> {
        self.plan()?.compile_shared()
    }

    /// Compile and write the deployable plan artifact in one call —
    /// zero-copy `qwyc-plan-bin-v1` ([`PlanFormat::Binary`]) or the
    /// diff-able `qwyc-plan-v1` JSON document ([`PlanFormat::Json`]).
    /// Returns the artifact so callers can keep serving from the same
    /// compiled plan they just wrote.
    pub fn save(
        &self,
        path: &std::path::Path,
        format: PlanFormat,
    ) -> Result<PlanArtifact, QwycError> {
        let artifact = PlanArtifact::from_plan(self.plan()?)?;
        artifact.save(path, format)?;
        Ok(artifact)
    }

    /// Compile and open an evaluation session with the `QWYC_THREADS`
    /// pool — the one-call path from an optimized builder to streaming
    /// [`Decision`]s.
    pub fn session(&self) -> Result<EvalSession, QwycError> {
        Ok(EvalSession::new(self.compile()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};

    fn tiny() -> (Dataset, Ensemble) {
        let (tr, _) = generate(Which::AdultLike, 5, 0.01);
        let (ens, _) = crate::gbt::train(
            &tr,
            &GbtParams { n_trees: 8, max_depth: 3, ..Default::default() },
        );
        (tr, ens)
    }

    #[test]
    fn train_stage_rejects_degenerate_specs() {
        let ds = Dataset::new("empty", 3);
        let spec = TrainSpec::gbt(&ds, GbtParams::default());
        let err = PlanBuilder::new("p").train(spec).unwrap_err();
        assert_eq!(err.stage(), "train", "{err}");

        let (tr, _) = generate(Which::AdultLike, 5, 0.01);
        let spec = TrainSpec::gbt(&tr, GbtParams { n_trees: 0, ..Default::default() });
        assert_eq!(PlanBuilder::new("p").train(spec).unwrap_err().stage(), "train");

        let wide =
            LatticeParams { n_lattices: 2, dim: tr.d + 1, steps: 5, ..Default::default() };
        let spec = TrainSpec::lattice_joint(&tr, wide);
        assert_eq!(PlanBuilder::new("p").train(spec).unwrap_err().stage(), "train");
    }

    #[test]
    fn with_scores_rejects_mismatched_matrices() {
        let (tr, ens) = tiny();
        let mut sm = ens.score_matrix_par(&tr, &Pool::new(1));
        sm.bias += 1.0;
        let err = PlanBuilder::new("p").with_scores(&ens, &sm).unwrap_err();
        assert_eq!(err.stage(), "validate", "{err}");

        let sm = ens.score_matrix_par(&tr, &Pool::new(1));
        let short = ens.prefix(ens.len() - 1);
        let err = PlanBuilder::new("p").with_scores(&short, &sm).unwrap_err();
        assert_eq!(err.stage(), "validate", "{err}");
    }

    #[test]
    fn optimize_rejects_bad_config_and_narrow_data() {
        let (tr, ens) = tiny();
        let pool = Pool::new(1);
        let bad = QwycConfig { alpha: 1.5, ..Default::default() };
        let err = PlanBuilder::new("p")
            .with_ensemble(&ens, &tr)
            .optimize(&bad, &pool)
            .unwrap_err();
        assert_eq!(err.stage(), "config", "{err}");

        let mut narrow = Dataset::new("narrow", 1);
        narrow.push(&[0.1], 0.0);
        narrow.push(&[0.9], 1.0);
        let err = PlanBuilder::new("p")
            .with_ensemble(&ens, &narrow)
            .optimize(&QwycConfig::default(), &pool)
            .unwrap_err();
        assert_eq!(err.stage(), "config", "{err}");
    }

    #[test]
    fn narrow_declared_width_fails_at_bundle_not_deploy() {
        let (tr, ens) = tiny();
        let pool = Pool::new(1);
        let opt = PlanBuilder::new("narrow")
            .with_ensemble(&ens, &tr)
            .optimize(&QwycConfig::default(), &pool)
            .unwrap()
            .with_n_features(1);
        let err = opt.plan().unwrap_err();
        assert_eq!(err.stage(), "compile", "{err}");
        assert_eq!(opt.into_plan().unwrap_err().stage(), "compile");
    }

    #[test]
    fn full_flow_produces_a_compilable_plan() {
        let (tr, _) = generate(Which::AdultLike, 5, 0.01);
        let spec = TrainSpec::gbt(
            &tr,
            GbtParams { n_trees: 8, max_depth: 3, ..Default::default() },
        );
        let trained = PlanBuilder::new("flow").train(spec).unwrap();
        assert_eq!(trained.losses().len(), 8);
        let opt = trained
            .optimize(&QwycConfig { alpha: 0.01, ..Default::default() }, &Pool::new(1))
            .unwrap();
        assert_eq!(opt.alpha(), 0.01);
        let plan = opt.plan().unwrap();
        // The optimization set's width is recorded automatically.
        assert_eq!(plan.meta.n_features, tr.d);
        assert_eq!(plan.meta.name, "flow");
        let compiled = opt.compile().unwrap();
        assert_eq!(compiled.n_features(), tr.d);
        assert_eq!(compiled.t(), 8);
    }

}
