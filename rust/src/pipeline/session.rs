//! Evaluation sessions over a compiled plan: per-example [`Decision`]s
//! via one-shot [`EvalSession::decide`], batched
//! [`EvalSession::decide_batch`], or the pull-based streaming
//! [`EvalSession::decide_iter`].
//!
//! All three surfaces run the crate-wide sweep arithmetic (per-example
//! f32 accumulation in π order — [`CompiledPlan::eval_single`]'s
//! contract), so their decisions are **bitwise identical** to each other
//! and to the serving engine, at every thread count and block boundary
//! (pinned in `rust/tests/pipeline_api.rs`).

use crate::error::QwycError;
use crate::plan::CompiledPlan;
use crate::qwyc::sweep::{sweep_block, SweepOutcome};
use crate::qwyc::SingleResult;
use crate::util::pool::Pool;
use std::sync::Arc;

/// Example-block width for batched/streaming decisions (same cache logic
/// as the serving engine's block).
const SESSION_BLOCK: usize = 256;

/// One early-exit classification outcome, with its cost accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// Running score at the stop position (the full score for examples
    /// that never exited early).
    pub score: f32,
    /// The classification: `true` = positive.
    pub label: bool,
    /// 1-based count of base models evaluated (T when nothing exited).
    pub exit_position: u32,
    /// Evaluation cost Σ c over the evaluated π prefix (equals
    /// `exit_position` when every base model costs 1).
    pub cost: f64,
    /// Did a threshold retire this example before position T?
    pub exited_early: bool,
}

impl Decision {
    fn from_sweep(plan: &CompiledPlan, o: &SweepOutcome) -> Decision {
        Decision {
            score: o.score,
            label: o.positive,
            exit_position: o.stop,
            cost: plan.prefix_cost(o.stop as usize),
            exited_early: o.early,
        }
    }

    fn from_single(plan: &CompiledPlan, r: SingleResult) -> Decision {
        Decision {
            score: r.score,
            label: r.positive,
            exit_position: r.models_evaluated as u32,
            cost: plan.prefix_cost(r.models_evaluated),
            exited_early: r.early,
        }
    }
}

/// An evaluation handle over a shared [`CompiledPlan`]: the embedder's
/// equivalent of one serving shard. Cheap to construct (the plan is
/// behind an `Arc`), safe to use from many threads (one session per
/// thread; the per-call scratch lives inside each call).
pub struct EvalSession {
    plan: Arc<CompiledPlan>,
    pool: Pool,
}

impl EvalSession {
    /// Open a session with the pool implied by `QWYC_THREADS` (or all
    /// available cores).
    pub fn new(plan: Arc<CompiledPlan>) -> EvalSession {
        EvalSession::with_pool(plan, Pool::from_env())
    }

    /// Open a session over an explicit worker pool (e.g. `Pool::new(1)`
    /// to keep batch decisions off other cores).
    pub fn with_pool(plan: Arc<CompiledPlan>, pool: Pool) -> EvalSession {
        EvalSession { plan, pool }
    }

    /// The compiled plan this session evaluates.
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    /// Feature width expected per example by [`EvalSession::decide_batch`]
    /// and [`EvalSession::decide_iter`].
    pub fn n_features(&self) -> usize {
        self.plan.n_features()
    }

    /// Capture a canary probe set from this session's plan — the
    /// embedder-side half of a validated plan swap. Before replacing a
    /// live session with a candidate plan, run
    /// `session.probe_set(n, seed).check(&candidate)` and keep the old
    /// session on any `Err` (the serving runtime's `RELOAD` does exactly
    /// this; see `coordinator::server`).
    pub fn probe_set(&self, n_probes: usize, seed: u64) -> crate::plan::ProbeSet {
        crate::plan::ProbeSet::capture(&self.plan, n_probes, seed)
    }

    fn check_stride(&self, x: &[f32], n: usize) -> Result<usize, QwycError> {
        let d = self.plan.n_features();
        if x.len() != n * d {
            return Err(QwycError::Config(format!(
                "feature buffer holds {} floats but {n} examples x {d} features need {}",
                x.len(),
                n * d
            )));
        }
        Ok(d)
    }

    /// Classify one example (early-exit walk over the pre-permuted
    /// models). The row may be wider than the plan's feature floor.
    pub fn decide(&self, x: &[f32]) -> Result<Decision, QwycError> {
        if x.len() < self.plan.min_features() {
            return Err(QwycError::Config(format!(
                "example has {} features but the plan's base models read {}",
                x.len(),
                self.plan.min_features()
            )));
        }
        Ok(Decision::from_single(&self.plan, self.plan.eval_single(x)))
    }

    /// Classify `n` row-major examples of stride
    /// [`n_features`](EvalSession::n_features), fanned across the
    /// session's pool. Decisions come back in example order.
    pub fn decide_batch(&self, x: &[f32], n: usize) -> Result<Vec<Decision>, QwycError> {
        let d = self.check_stride(x, n)?;
        let outcomes = self.plan.sweep_features(x, n, d, SESSION_BLOCK, &self.pool);
        Ok(outcomes.iter().map(|o| Decision::from_sweep(&self.plan, o)).collect())
    }

    /// Pull-based streaming evaluation: an iterator yielding one
    /// [`Decision`] per example, in order, sweeping lazily in
    /// cache-sized blocks — consumers that stop early (e.g. "collect the
    /// first K positives") never pay for the rest of the buffer, and
    /// nothing materializes a whole batch of decisions.
    pub fn decide_iter<'a>(
        &'a self,
        x: &'a [f32],
        n: usize,
    ) -> Result<DecisionIter<'a>, QwycError> {
        let d = self.check_stride(x, n)?;
        Ok(DecisionIter {
            plan: &self.plan,
            x,
            d,
            n,
            swept: 0,
            buf: Vec::new(),
            buf_pos: 0,
            lat_scratch: Vec::new(),
        })
    }
}

/// Streaming iterator over per-example [`Decision`]s; see
/// [`EvalSession::decide_iter`].
pub struct DecisionIter<'a> {
    plan: &'a CompiledPlan,
    x: &'a [f32],
    d: usize,
    n: usize,
    /// Examples swept so far (block granularity).
    swept: usize,
    buf: Vec<Decision>,
    buf_pos: usize,
    /// Lattice walk scratch, reused across blocks (8K floats at dim 13 —
    /// re-allocating per block would waste hot-path work).
    lat_scratch: Vec<f32>,
}

impl Iterator for DecisionIter<'_> {
    type Item = Decision;

    fn next(&mut self) -> Option<Decision> {
        if self.buf_pos == self.buf.len() {
            if self.swept == self.n {
                return None;
            }
            let (lo, hi) = (self.swept, (self.swept + SESSION_BLOCK).min(self.n));
            let (plan, d) = (self.plan, self.d);
            let xblk = &self.x[lo * d..hi * d];
            let params = plan.sweep_params();
            let lat_scratch = &mut self.lat_scratch;
            let outcomes = sweep_block(&params, hi - lo, |r, rows, out| {
                plan.score_position(r, xblk, d, rows, out, lat_scratch)
            });
            self.buf.clear();
            self.buf.extend(outcomes.iter().map(|o| Decision::from_sweep(plan, o)));
            self.buf_pos = 0;
            self.swept = hi;
        }
        let d = self.buf[self.buf_pos];
        self.buf_pos += 1;
        Some(d)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rem = (self.n - self.swept) + (self.buf.len() - self.buf_pos);
        (rem, Some(rem))
    }
}

impl ExactSizeIterator for DecisionIter<'_> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};
    use crate::gbt::GbtParams;
    use crate::pipeline::{PlanBuilder, TrainSpec};
    use crate::qwyc::QwycConfig;

    fn session() -> (crate::data::Dataset, EvalSession) {
        let (tr, te) = generate(Which::AdultLike, 9, 0.01);
        let spec = TrainSpec::gbt(
            &tr,
            GbtParams { n_trees: 10, max_depth: 3, ..Default::default() },
        );
        let s = PlanBuilder::new("session-test")
            .train(spec)
            .unwrap()
            .optimize(&QwycConfig { alpha: 0.01, ..Default::default() }, &Pool::new(1))
            .unwrap()
            .session()
            .unwrap();
        (te, s)
    }

    #[test]
    fn iter_streams_the_same_decisions_as_batch() {
        let (te, s) = session();
        let n = te.n.min(300); // spans two SESSION_BLOCKs
        let x = &te.x[..n * te.d];
        let batch = s.decide_batch(x, n).unwrap();
        let iter = s.decide_iter(x, n).unwrap();
        assert_eq!(iter.len(), n);
        let streamed: Vec<Decision> = iter.collect();
        assert_eq!(streamed.len(), n);
        for (i, (a, b)) in batch.iter().zip(streamed.iter()).enumerate() {
            assert_eq!(a.label, b.label, "example {i}");
            assert_eq!(a.exit_position, b.exit_position, "example {i}");
            assert_eq!(a.exited_early, b.exited_early, "example {i}");
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "example {i}");
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "example {i}");
        }
    }

    #[test]
    fn early_consumers_stop_without_sweeping_everything() {
        let (te, s) = session();
        let n = te.n.min(600);
        let x = &te.x[..n * te.d];
        let mut iter = s.decide_iter(x, n).unwrap();
        let first = iter.next().unwrap();
        let alone = s.decide(te.row(0)).unwrap();
        assert_eq!(first.score.to_bits(), alone.score.to_bits());
        // Only the first block has been swept so far.
        assert!(iter.swept <= 256, "swept {} examples for one pull", iter.swept);
        assert_eq!(iter.size_hint(), (n - 1, Some(n - 1)));
    }

    #[test]
    fn stride_mismatches_are_config_errors() {
        let (te, s) = session();
        let err = s.decide_batch(&te.x[..te.d + 1], 1).unwrap_err();
        assert_eq!(err.stage(), "config", "{err}");
        let err = s.decide_iter(&te.x[..te.d - 1], 1).unwrap_err();
        assert_eq!(err.stage(), "config", "{err}");
        let err = s.decide(&te.x[..0]).unwrap_err();
        assert_eq!(err.stage(), "config", "{err}");
    }

    #[test]
    fn empty_input_yields_no_decisions() {
        let (_, s) = session();
        assert!(s.decide_batch(&[], 0).unwrap().is_empty());
        assert_eq!(s.decide_iter(&[], 0).unwrap().count(), 0);
    }

    #[test]
    fn probe_set_validates_the_sessions_own_plan() {
        let (_, s) = session();
        let probes = s.probe_set(8, 11);
        assert_eq!(probes.width(), s.n_features());
        assert_eq!(probes.len(), 8);
        // A session's live plan always passes its own canary.
        probes.check(s.plan()).unwrap();
    }
}
