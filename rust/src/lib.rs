//! # QWYC — Quit When You Can
//!
//! Production-oriented reproduction of *"Quit When You Can: Efficient
//! Evaluation of Ensembles with Ordering Optimization"* (Wang, Gupta, You,
//! 2018): jointly optimize a fixed evaluation order of an additive
//! ensemble's base models together with per-position early-stopping
//! thresholds, so that easy examples are classified after a few base
//! models while the fast classifier's decisions differ from the full
//! ensemble on at most a fraction α of examples.
//!
//! The crate is organized as a three-layer serving system:
//!
//! - **L3 (this crate)** — ensemble training substrates ([`gbt`],
//!   [`lattice`]), the QWYC optimizer ([`qwyc`]) and baselines ([`fan`],
//!   [`orderings`]), the deployable [`plan`] artifact
//!   ([`plan::PlanArtifact`]: `qwyc-plan-v1` JSON or zero-copy
//!   `qwyc-plan-bin-v1`, compiled into one
//!   [`plan::CompiledPlan`]) every evaluator consumes through one shared
//!   sweep core ([`qwyc::sweep`]), and a serving [`coordinator`] with
//!   dynamic batching and early-exit scheduling — exposed over two wire
//!   surfaces sharing one shard set: the line protocol and a std-only
//!   HTTP/1.1 front-end ([`http`]) — backed by [`runtime`]
//!   (PJRT) for the AOT-compiled dense path. Embedders program the whole
//!   train → optimize → compile → evaluate flow through the typed
//!   [`pipeline`] facade (`use qwyc::prelude::*`); every fallible API
//!   reports a staged [`error::QwycError`].
//! - **L2/L1 (build-time Python)** — JAX graph + Pallas lattice kernel,
//!   AOT-lowered to HLO text (`python/compile/`), never on the request
//!   path.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod coordinator;
pub mod data;
pub mod ensemble;
pub mod error;
pub mod experiments;
pub mod fan;
pub mod gbt;
pub mod http;
pub mod lattice;
pub mod orderings;
pub mod pipeline;
pub mod plan;
// The crate and its core-algorithm module intentionally share the name.
#[allow(clippy::module_inception)]
pub mod qwyc;
pub mod runtime;
pub mod util;

/// The blessed embedder surface in one import:
/// `use qwyc::prelude::*;` brings in the typed pipeline
/// ([`pipeline::PlanBuilder`] → [`pipeline::EvalSession`]), the artifact
/// types, the crate error, and the substrate types their signatures
/// mention. See the README's "Library API" section for a quickstart.
pub mod prelude {
    pub use crate::data::synth::{generate, Which};
    pub use crate::data::Dataset;
    pub use crate::ensemble::{Ensemble, ScoreMatrix};
    pub use crate::error::QwycError;
    pub use crate::gbt::GbtParams;
    pub use crate::lattice::LatticeParams;
    pub use crate::pipeline::{
        Decision, DecisionIter, EvalSession, ModelSpec, PlanBuilder, TrainSpec,
    };
    pub use crate::plan::{CompiledPlan, PlanArtifact, PlanFormat, ProbeSet, QwycPlan};
    pub use crate::qwyc::{FastClassifier, QwycConfig};
    pub use crate::util::pool::Pool;
}
