//! `qwyc` — the command-line launcher for the QWYC serving system.
//!
//! Subcommands:
//!   gen-data     generate a synthetic dataset as CSV
//!   train        train an ensemble (GBT or lattice) and save it
//!   optimize     run QWYC (Algorithm 1 or 2) and save the fast classifier
//!   compile-plan bundle model + fast classifier into a plan artifact
//!                (--format bin → zero-copy qwyc-plan-bin-v1, the default;
//!                 --format json → diff-able qwyc-plan-v1)
//!   plan-info    print an artifact's header/version/section sizes
//!   simulate     evaluate a plan on a dataset
//!   serve        start the supervised sharded TCP coordinator from a plan
//!                (--http-port N additionally binds the std-only HTTP/1.1
//!                 front-end over the same shard set)
//!   reload       validated hot-swap of a running server's plan (RELOAD)
//!   drain        stop admission on a running server and drain its queues
//!   bench-client load-test a running server — closed-loop (N pipelined
//!                connections, BUSY retried with jittered exponential
//!                backoff) or open-loop (`--target-rps`: fixed-rate
//!                lateness-corrected arrival schedule, no retries); --http
//!                drives POST /v1/score instead of the line protocol
//!   experiment   regenerate paper figures/tables (fig1..fig6, tables, all)
//!
//! Every subcommand that takes `--plan` accepts either artifact format
//! transparently — `PlanArtifact::load` sniffs the magic bytes.
//!
//! The CLI is a thin veneer over the same typed pipeline embedders get
//! (`qwyc::pipeline::PlanBuilder` → plan artifact →
//! serving). Every failure prints `error[stage]: message` to stderr —
//! the stage tag comes from `QwycError::stage()` — and exits non-zero
//! (2 for config-stage errors, i.e. unusable arguments; 1 for
//! everything else).
//!
//! Flags are listed in USAGE below per arm; unknown flags error out.

use qwyc::coordinator::{BatchPolicy, Client, Reply, Server, ServerConfig, DEFAULT_QUEUE_CAP};
use qwyc::data::synth::{generate, Which};
use qwyc::data::{csv, Dataset};
use qwyc::ensemble::Ensemble;
use qwyc::error::QwycError;
use qwyc::experiments::{figures, tables, FigConfig};
use qwyc::gbt::GbtParams;
use qwyc::http::HttpClient;
use qwyc::lattice::LatticeParams;
use qwyc::pipeline::{ModelSpec, PlanBuilder, TrainSpec};
use qwyc::plan::{PlanArtifact, PlanFormat, QwycPlan};
use qwyc::qwyc::{optimize_thresholds_for_order, simulate, FastClassifier, QwycConfig};
#[cfg(feature = "pjrt")]
use qwyc::runtime::engine::PjrtEngine;
use qwyc::util::cli::Args;
use qwyc::util::json::Json;
use qwyc::util::pool::Pool;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => fail(&e),
    };
    if let Err(e) = run(&args) {
        fail(&e);
    }
}

/// Every CLI failure lands here: one `error[stage]: message` line on
/// stderr and a non-zero exit — 2 for config-stage errors (unusable
/// flags/arguments), 1 for every runtime failure.
fn fail(e: &QwycError) -> ! {
    eprintln!("error[{}]: {}", e.stage(), e.message());
    std::process::exit(if matches!(e, QwycError::Config(_)) { 2 } else { 1 });
}

fn run(args: &Args) -> Result<(), QwycError> {
    match args.subcommand() {
        Some("gen-data") => gen_data(args),
        Some("train") => train(args),
        Some("optimize") => optimize(args),
        Some("compile-plan") => compile_plan(args),
        Some("plan-info") => plan_info(args),
        Some("simulate") => simulate_cmd(args),
        Some("serve") => serve(args),
        Some("reload") => reload_cmd(args),
        Some("drain") => drain_cmd(args),
        Some("bench-client") => bench_client(args),
        Some("experiment") => experiment(args),
        _ => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "qwyc — Quit When You Can: efficient ensemble evaluation (Wang/Gupta/You 2018)

USAGE: qwyc <subcommand> [flags]

  gen-data     --dataset adult|nomao|rw1|rw2 --scale 1.0 --seed N --out dir/
  train        --dataset ... --kind gbt|lattice-joint|lattice-indep
               [--trees 500 --depth 5 | --lattices 5 --dim 13 --steps 400]
               --scale 1.0 --out model.json
  optimize     --model model.json --dataset ... --alpha 0.005
               [--neg-only] [--fixed-order natural|random|ind-mse|greedy-mse]
               [--max-opt 0] --out fast.json
  compile-plan --model model.json --fast fast.json --out plan.bin
               [--format bin|json  (default bin: zero-copy qwyc-plan-bin-v1)]
               [--name my-plan --alpha 0.005 --n-features D | --dataset adult]
  plan-info    <plan.bin|plan.json>   print header/version/section sizes
  simulate     --plan plan.bin|plan.json --dataset ... [--split test]
  serve        --plan plan.bin|plan.json --addr 127.0.0.1:7077
               [--backend native|pjrt --artifact rw1_stage --artifacts-dir artifacts]
               [--shards 1 --queue-cap 1024 --max-batch 256 --max-wait-ms 2]
               [--adaptive  (depth-scaled flush deadlines; shows as policy= in STATS)]
               [--cache-bytes 0  (per-shard response-cache budget; 0 = off)]
               [--deadline-ms 0  (default request deadline; 0 = none)]
               [--http-port 0  (also serve HTTP/1.1 on the same host over the
                same shards: POST /v1/score[-batch], GET /healthz /stats
                /metrics /plan, POST /reload /drain; 0 = line protocol only)]
  reload       --addr 127.0.0.1:7077 --plan plan.bin     (validated hot-swap;
               either artifact format; exits non-zero on RELOAD_REJECTED)
  drain        --addr 127.0.0.1:7077     (stop admission, drain the queues)
  bench-client --addr 127.0.0.1:7077 --dataset ... --requests 5000
               [--pipeline 64 --concurrency 1 --deadline-ms 0]
               [--target-rps 0  (open-loop: fixed-rate arrivals; 0 = closed loop)]
               [--http  (--addr is an HTTP listener: drive POST /v1/score with
                the same closed/open-loop shapes, 503 retried like BUSY)]
  experiment   fig1|fig2|fig3|fig4|fig5|fig6|table1|tables|all
               [--scale 0.1 --trees 500 --max-opt 3000 --runs 5 --out results/]
";

fn which_of(args: &Args) -> Result<Which, QwycError> {
    Which::parse(&args.get_str("dataset", "adult"))
}

fn gen_data(args: &Args) -> Result<(), QwycError> {
    let which = which_of(args)?;
    let scale = args.get_f64("scale", 1.0)?;
    let seed = args.get_u64("seed", 1)?;
    let out = PathBuf::from(args.get_str("out", "data"));
    args.check_unknown()?;
    let (tr, te) = generate(which, seed, scale);
    csv::save(&tr, &out.join(format!("{}_train.csv", which.name())))?;
    csv::save(&te, &out.join(format!("{}_test.csv", which.name())))?;
    println!(
        "wrote {}_{{train,test}}.csv  (train n={} test n={} d={} pos-rate={:.3})",
        which.name(),
        tr.n,
        te.n,
        tr.d,
        tr.positive_rate()
    );
    Ok(())
}

fn load_data(args: &Args) -> Result<(Dataset, Dataset), QwycError> {
    if let Some(path) = args.get_opt("data") {
        let ds = csv::load(Path::new(&path))?;
        Ok(ds.split(0.2, args.get_u64("seed", 1)?))
    } else {
        let which = which_of(args)?;
        Ok(generate(which, args.get_u64("seed", 1)?, args.get_f64("scale", 1.0)?))
    }
}

fn train(args: &Args) -> Result<(), QwycError> {
    let (tr, te) = load_data(args)?;
    let kind = args.get_str("kind", "gbt");
    let out = PathBuf::from(args.get_str("out", "model.json"));
    let sw = qwyc::util::timer::Stopwatch::new();
    let mut lattice_dim = 0usize;
    let model = match kind.as_str() {
        "gbt" => {
            let params = GbtParams {
                n_trees: args.get_usize("trees", 500)?,
                max_depth: args.get_usize("depth", 5)?,
                learning_rate: args.get_f64("lr", 0.1)? as f32,
                ..Default::default()
            };
            args.check_unknown()?;
            ModelSpec::Gbt(params)
        }
        "lattice-joint" | "lattice-indep" => {
            let params = LatticeParams {
                n_lattices: args.get_usize("lattices", 5)?,
                dim: args.get_usize("dim", 13)?,
                steps: args.get_usize("steps", 400)?,
                batch: args.get_usize("batch", 128)?,
                lr: args.get_f64("lr", 0.05)?,
                l2: 1e-5,
                seed: args.get_u64("seed", 1)?,
            };
            args.check_unknown()?;
            lattice_dim = params.dim;
            if kind == "lattice-joint" {
                ModelSpec::LatticeJoint(params)
            } else {
                ModelSpec::LatticeIndependent(params)
            }
        }
        other => return Err(QwycError::Config(format!("unknown --kind {other}"))),
    };
    // The same typed first stage embedders use; the CLI just saves the
    // ensemble instead of carrying on to optimize.
    let trained = PlanBuilder::new("cli-train").train(TrainSpec { data: &tr, model })?;
    let final_loss = trained.losses().last().copied().unwrap_or(f64::NAN);
    if kind == "gbt" {
        println!("gbt: {} trees, final train logloss {final_loss:.4}", trained.ensemble().len());
    } else {
        println!(
            "{kind}: {} lattices (dim {lattice_dim}), final train loss {final_loss:.4}",
            trained.ensemble().len()
        );
    }
    let ens = trained.into_ensemble();
    println!(
        "trained in {:.1}s; train acc {:.4}, test acc {:.4}",
        sw.elapsed_s(),
        ens.accuracy(&tr),
        ens.accuracy(&te)
    );
    ens.save(&out)?;
    println!("saved {}", out.display());
    Ok(())
}

fn optimize(args: &Args) -> Result<(), QwycError> {
    let model = PathBuf::from(args.get_str("model", "model.json"));
    let ens = Ensemble::load(&model)?;
    let (tr, _) = load_data(args)?;
    let alpha = args.get_f64("alpha", 0.005)?;
    let neg_only = args.get_bool("neg-only", false)?;
    let max_opt = args.get_usize("max-opt", 0)?;
    let out = PathBuf::from(args.get_str("out", "fast.json"));
    let fixed = args.get_opt("fixed-order");
    args.check_unknown()?;

    println!("computing score matrix ({} x {})...", tr.n, ens.len());
    let sm = ens.score_matrix(&tr);
    let sw = qwyc::util::timer::Stopwatch::new();
    let fc = match fixed.as_deref() {
        None => {
            // QWYC* through the typed pipeline — identical (bitwise) to
            // the loose optimize_order_with_pool path.
            let cfg = QwycConfig { alpha, neg_only, max_opt_examples: max_opt, seed: 17 };
            PlanBuilder::new("cli-optimize")
                .with_scores(&ens, &sm)?
                .optimize(&cfg, &Pool::from_env())?
                .classifier()
                .clone()
        }
        Some(name) => {
            let order = match name {
                "natural" => qwyc::orderings::natural(sm.t),
                "random" => qwyc::orderings::random(sm.t, 17),
                "ind-mse" => qwyc::orderings::individual_mse(&sm, &tr.y),
                "greedy-mse" => qwyc::orderings::greedy_mse(&sm, &tr.y),
                other => {
                    return Err(QwycError::Config(format!("unknown --fixed-order {other}")))
                }
            };
            optimize_thresholds_for_order(&sm, &order, alpha, neg_only)
        }
    };
    let sim = simulate(&fc, &sm);
    println!(
        "optimized in {:.1}s: train mean models {:.2}/{} ({:.1}x), diff {:.3}% (alpha {:.3}%)",
        sw.elapsed_s(),
        sim.mean_models,
        sm.t,
        sm.t as f64 / sim.mean_models,
        sim.pct_diff * 100.0,
        alpha * 100.0
    );
    fc.save(&out)?;
    println!("saved {}", out.display());
    Ok(())
}

/// Bundle an ensemble + fast classifier into a plan artifact that
/// `simulate --plan` / `serve --plan` consume — zero-copy
/// `qwyc-plan-bin-v1` by default, `--format json` for the diff-able
/// `qwyc-plan-v1` document. Compiles the plan once here so every
/// invariant is checked at build time, not at load time on every server
/// start.
fn compile_plan(args: &Args) -> Result<(), QwycError> {
    let model = PathBuf::from(args.get_str("model", "model.json"));
    let fast = PathBuf::from(args.get_str("fast", "fast.json"));
    let format = PlanFormat::parse(&args.get_str("format", "bin"))?;
    let default_out = if format == PlanFormat::Json { "plan.json" } else { "plan.bin" };
    let out = PathBuf::from(args.get_str("out", default_out));
    let alpha = args.get_f64("alpha", 0.0)?;
    let mut n_features = args.get_usize("n-features", 0)?;
    let dataset = args.get_opt("dataset");
    let name = args.get_opt("name");
    args.check_unknown()?;

    // --dataset records the dataset's feature width (and provenance)
    // without generating any data.
    if let Some(ds) = &dataset {
        n_features = n_features.max(Which::parse(ds)?.sizes().2);
    }
    let ens = Ensemble::load(&model)?;
    let fc = FastClassifier::load(&fast)?;
    let name = name.unwrap_or_else(|| ens.name.clone());
    let mut plan = QwycPlan::bundle(ens, fc, &name, alpha)?;
    plan.meta.n_features = n_features;
    if let Some(ds) = &dataset {
        plan.meta.source = format!("dataset={ds}");
    }
    let artifact = PlanArtifact::from_plan(plan)?;
    artifact.save(&out, format)?;
    let compiled = artifact.compiled();
    println!(
        "compiled plan '{}' (T={}, d={}, neg_only={}, total_cost={}, format={}) -> {}",
        artifact.name(),
        compiled.t(),
        compiled.n_features(),
        artifact.meta().neg_only,
        compiled.total_cost(),
        if format == PlanFormat::Json { "json" } else { "bin" },
        out.display()
    );
    Ok(())
}

/// Print the header-level summary of a plan artifact (either format):
/// `qwyc plan-info <path>` or `qwyc plan-info --plan <path>`.
fn plan_info(args: &Args) -> Result<(), QwycError> {
    let path = match args.get_opt("plan").or_else(|| args.positional.get(1).cloned()) {
        Some(p) => PathBuf::from(p),
        None => return Err(QwycError::Config("usage: qwyc plan-info <plan.bin|plan.json>".into())),
    };
    args.check_unknown()?;
    // The report body lives on ArtifactInfo::render so library tests pin
    // the exact output shape the CI smoke greps.
    print!("{}", PlanArtifact::info(&path)?.render(&path.display().to_string()));
    Ok(())
}

/// Load the plan artifact named by `--plan` — the only deployed unit.
/// Either format (JSON or binary) is accepted; the magic bytes decide.
fn load_artifact(args: &Args) -> Result<PlanArtifact, QwycError> {
    match args.get_opt("plan") {
        Some(p) => PlanArtifact::load(Path::new(&p)),
        None => Err(QwycError::Config(
            "--plan <plan.bin|plan.json> is required (the --model/--fast pair was removed: \
             run `qwyc compile-plan` once and pass --plan)"
                .into(),
        )),
    }
}

fn simulate_cmd(args: &Args) -> Result<(), QwycError> {
    let plan = load_artifact(args)?.to_plan()?;
    let (tr, te) = load_data(args)?;
    let split = args.get_str("split", "test");
    args.check_unknown()?;
    let ds = if split == "train" { &tr } else { &te };
    let sm = plan.ensemble.score_matrix(ds);
    let sim = simulate(&plan.fc, &sm);
    println!(
        "{} ({} examples): mean models {:.2}/{} ({:.2}x), diff {:.3}%, early {:.1}%, acc {:.4}",
        split,
        ds.n,
        sim.mean_models,
        sm.t,
        sm.t as f64 / sim.mean_models,
        sim.pct_diff * 100.0,
        sim.n_early as f64 / ds.n as f64 * 100.0,
        sim.accuracy(&ds.y)
    );
    Ok(())
}

fn serve(args: &Args) -> Result<(), QwycError> {
    let addr = args.get_str("addr", "127.0.0.1:7077");
    let backend = args.get_str("backend", "native");
    let artifact = args.get_str("artifact", "rw1_stage");
    let artifacts_dir = args.get_str("artifacts-dir", "artifacts");
    let max_batch = args.get_usize("max-batch", 256)?;
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 2)?);
    let config = ServerConfig {
        shards: args.get_usize("shards", 1)?.max(1),
        queue_cap: args.get_usize("queue-cap", DEFAULT_QUEUE_CAP)?,
        policy: if args.get_bool("adaptive", false)? {
            BatchPolicy::adaptive(max_batch, max_wait)
        } else {
            BatchPolicy::fixed(max_batch, max_wait)
        },
        default_deadline: match args.get_u64("deadline-ms", 0)? {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        cache_bytes: args.get_usize("cache-bytes", 0)?,
    };
    let http_port = args.get_u64("http-port", 0)?;
    let loaded = load_artifact(args)?;
    args.check_unknown()?;

    if backend == "pjrt" && !cfg!(feature = "pjrt") {
        return Err(QwycError::Config(
            "this binary was built without the 'pjrt' feature; rebuild with \
             `cargo build --release --features pjrt`"
                .into(),
        ));
    }
    println!(
        "serving plan '{}' ({}, T={}, backend={backend}, shards={}, queue_cap={}) on {addr}; \
         batch<={} wait<={:?} policy={} cache_bytes={}",
        loaded.name(),
        loaded.ensemble_name(),
        loaded.compiled().t(),
        config.shards,
        config.queue_cap,
        config.policy.max_batch,
        config.policy.max_wait,
        config.policy.label(),
        config.cache_bytes
    );
    #[cfg(feature = "pjrt")]
    if backend == "pjrt" {
        // PJRT stays a per-shard factory: device handles are not `Send`,
        // so each shard builds its own engine inside its worker thread.
        // No PlanSlot → the server answers RELOAD with an ERR.
        let plan = loaded.to_plan()?;
        let (ens, fc) = (plan.ensemble.clone(), plan.fc.clone());
        let mut server = Server::start(
            &addr,
            move |_shard| -> Box<dyn qwyc::runtime::engine::Engine> {
                let rt = qwyc::runtime::Runtime::open(Path::new(&artifacts_dir))
                    .expect("open artifacts (run `make artifacts`)");
                Box::new(PjrtEngine::new(rt, &artifact, &ens, &fc).expect("pjrt engine"))
            },
            config,
        )?;
        attach_http_if(&mut server, &addr, http_port)?;
        return stats_loop(server);
    }
    let _ = (&backend, &artifact, &artifacts_dir);
    // The artifact is already compiled (for binary plans, load itself was
    // near-free); all shards share the same immutable Arc'd plan, and
    // RELOAD swaps it at batch boundaries. start_with_artifact (vs
    // start_with_plan) keeps the artifact's real name/metadata so
    // `GET /plan` reports the deployed identity, not a placeholder.
    let mut server = Server::start_with_artifact(&addr, &loaded, config)?;
    attach_http_if(&mut server, &addr, http_port)?;
    stats_loop(server)
}

/// Bind the HTTP/1.1 front-end next to the line-protocol listener —
/// same host as `--addr`, port `--http-port` — over the SAME shard set.
/// Port 0 leaves the line protocol as the only surface.
fn attach_http_if(server: &mut Server, addr: &str, http_port: u64) -> Result<(), QwycError> {
    if http_port == 0 {
        return Ok(());
    }
    let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
    let bound = server.attach_http(&format!("{host}:{http_port}"))?;
    println!(
        "http listening on {bound} (POST /v1/score[-batch], GET /healthz /stats /metrics /plan, \
         POST /reload /drain)"
    );
    Ok(())
}

/// Print the aggregated per-shard metrics every 10s, forever. Uses the
/// cached report so an idle server's stats tick costs one version check
/// instead of a full rebuild.
fn stats_loop(server: Server) -> Result<(), QwycError> {
    println!("listening on {} — Ctrl-C to stop", server.addr);
    loop {
        std::thread::sleep(Duration::from_secs(10));
        println!("{}", server.metrics.report_cached());
    }
}

/// Ask a running server for a validated plan hot-swap (`RELOAD <path>`);
/// the server accepts either artifact format. The reply is parsed, not
/// pattern-sniffed: a `RELOAD_REJECTED` (or any ERR) exits non-zero with
/// the server's staged message so deploy scripts can gate on it.
fn reload_cmd(args: &Args) -> Result<(), QwycError> {
    let addr = parse_addr(args)?;
    let plan_path = args.get_str("plan", "plan.bin");
    args.check_unknown()?;
    let mut client = Client::connect(&addr)?;
    let line = client.reload(&plan_path)?;
    match Reply::parse(&line) {
        Reply::Reloaded(msg) => {
            println!("{msg}");
            Ok(())
        }
        // A remote refusal is a runtime failure, not a usage error.
        Reply::ReloadRejected { stage, why } => {
            Err(QwycError::Io(format!("reload rejected at stage '{stage}': {why}")))
        }
        Reply::Err { message, .. } => {
            Err(QwycError::Io(format!("server refused the reload: {message}")))
        }
        _ => Err(QwycError::Io(format!("unexpected reload reply: {line}"))),
    }
}

/// Ask a running server to stop admission and drain its queues (`DRAIN`).
fn drain_cmd(args: &Args) -> Result<(), QwycError> {
    let addr = parse_addr(args)?;
    args.check_unknown()?;
    let mut client = Client::connect(&addr)?;
    let line = client.drain()?;
    if line.starts_with("DRAINED") {
        println!("{line}");
        Ok(())
    } else {
        Err(QwycError::Io(format!("drain failed: {line}")))
    }
}

fn parse_addr(args: &Args) -> Result<std::net::SocketAddr, QwycError> {
    args.get_str("addr", "127.0.0.1:7077")
        .parse()
        .map_err(|e| QwycError::Config(format!("--addr: {e}")))
}

/// BUSY retry policy: a shed request is retried up to this many times
/// with jittered exponential backoff before the client gives up on it.
const RETRY_MAX_ATTEMPTS: u32 = 5;
const RETRY_BASE_US: u64 = 500;
const RETRY_CAP_US: u64 = 20_000;

fn bench_client(args: &Args) -> Result<(), QwycError> {
    let addr = parse_addr(args)?;
    let requests = args.get_usize("requests", 5000)?;
    let pipeline = args.get_usize("pipeline", 64)?.max(1);
    let concurrency = args.get_usize("concurrency", 1)?.max(1);
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let target_rps = args.get_f64("target-rps", 0.0)?;
    let http = args.get_bool("http", false)?;
    let (_, te) = load_data(args)?;
    args.check_unknown()?;
    if target_rps > 0.0 {
        return bench_open_loop(&addr, &te, requests, concurrency, deadline_ms, target_rps, http);
    }

    // `--concurrency N` opens N pipelined connections so an N-shard
    // server actually sees parallel load; requests are split evenly.
    let counts: Vec<usize> = (0..concurrency)
        .map(|c| requests / concurrency + usize::from(c < requests % concurrency))
        .collect();
    let sw = qwyc::util::timer::Stopwatch::new();
    let results: Vec<Result<ConnLoad, QwycError>> = std::thread::scope(|s| {
        let handles: Vec<_> = counts
            .iter()
            .enumerate()
            .map(|(c, &n)| {
                let te = &te;
                s.spawn(move || {
                    if http {
                        run_conn_load_http(&addr, te, n, pipeline, c * 7919, deadline_ms)
                    } else {
                        run_conn_load(&addr, te, n, pipeline, c * 7919, deadline_ms)
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let el = sw.elapsed_s();

    let mut lat_us: Vec<f64> = Vec::with_capacity(requests);
    let mut tot = ConnLoad::default();
    for r in results {
        let load = r?;
        lat_us.extend(load.lat_us);
        tot.models_sum += load.models_sum;
        tot.busy += load.busy;
        tot.retries += load.retries;
        tot.shed += load.shed;
        tot.timeouts += load.timeouts;
        tot.errors += load.errors;
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Attempts (wire sends) and completions are different units: every
    // BUSY retry is an extra attempt for the SAME request, so attempts =
    // requests + retries, while the completion breakdown below accounts
    // for each of the `requests` exactly once and its percents sum to
    // 100 by construction.
    let ok = lat_us.len() as u64;
    let answered = lat_us.len().max(1);
    let pct = |n: u64| n as f64 / requests.max(1) as f64 * 100.0;
    println!(
        "closed-loop: {} requests = {} attempts ({} conns, {} BUSY replies, {} retries) \
         in {:.2}s = {:.0} rps",
        requests,
        requests as u64 + tot.retries,
        concurrency,
        tot.busy,
        tot.retries,
        el,
        requests as f64 / el
    );
    println!(
        "completions: ok {} ({:.2}%) + shed {} ({:.2}%) + timeouts {} ({:.2}%) + \
         errors {} ({:.2}%) = {} (100%)",
        ok,
        pct(ok),
        tot.shed,
        pct(tot.shed),
        tot.timeouts,
        pct(tot.timeouts),
        tot.errors,
        pct(tot.errors),
        requests
    );
    println!(
        "latency p50/p95/p99 = {:.0}/{:.0}/{:.0} us; mean models {:.2}",
        qwyc::util::stats::percentile_sorted(&lat_us, 50.0),
        qwyc::util::stats::percentile_sorted(&lat_us, 95.0),
        qwyc::util::stats::percentile_sorted(&lat_us, 99.0),
        tot.models_sum as f64 / answered as f64
    );
    print_server_stats(&addr, http)
}

/// Post-run server-side view: `STATS` over the line protocol, or
/// `GET /stats` when the benchmark drove the HTTP front-end.
fn print_server_stats(addr: &std::net::SocketAddr, http: bool) -> Result<(), QwycError> {
    if http {
        let mut client = HttpClient::connect(addr)?;
        let resp = client.request("GET", "/stats", &[], b"")?;
        println!("server stats:\n{}", resp.body.trim_end());
    } else {
        let mut client = Client::connect(addr)?;
        println!("server: {}", client.stats()?);
    }
    Ok(())
}

/// Per-connection open-loop schedule: request `k` on connection `c` is
/// sent at `start + phase_ns + k·interval_ns` — an ABSOLUTE schedule.
/// A late send is corrected by sending immediately (catching up in a
/// burst) and the anchor is never re-based, so a slow server faces the
/// arrival rate it was asked to face instead of quietly pacing the
/// generator down to its own speed.
struct OpenLoopConn {
    requests: usize,
    interval_ns: u64,
    phase_ns: u64,
    row_offset: usize,
    deadline_ms: u64,
    start: std::time::Instant,
}

/// Aggregated open-loop results for one connection. Latencies are
/// client-measured (send instant → reply read), so they include queue
/// buildup the server-reported latency would miss for shed replies.
#[derive(Default)]
struct OpenLoad {
    lat_us: Vec<f64>,
    models_sum: u64,
    ok: u64,
    busy: u64,
    timeouts: u64,
    errors: u64,
}

/// Open-loop load generation (`--target-rps`): arrivals follow a fixed
/// deterministic schedule split across `--concurrency` phase-staggered
/// connections, never paced by responses. There are no BUSY retries —
/// a shed arrival is a shed arrival — so the completion mix (ok / busy
/// / timeout / error, fractions summing to 1.0) is the server's honest
/// behavior at the offered rate.
fn bench_open_loop(
    addr: &std::net::SocketAddr,
    te: &Dataset,
    requests: usize,
    concurrency: usize,
    deadline_ms: u64,
    target_rps: f64,
    http: bool,
) -> Result<(), QwycError> {
    let counts: Vec<usize> = (0..concurrency)
        .map(|c| requests / concurrency + usize::from(c < requests % concurrency))
        .collect();
    let interval_ns = (1e9 * concurrency as f64 / target_rps) as u64;
    let start = std::time::Instant::now();
    let sw = qwyc::util::timer::Stopwatch::new();
    let results: Vec<Result<OpenLoad, QwycError>> = std::thread::scope(|s| {
        let handles: Vec<_> = counts
            .iter()
            .enumerate()
            .map(|(c, &n)| {
                let cfg = OpenLoopConn {
                    requests: n,
                    interval_ns,
                    phase_ns: c as u64 * interval_ns / concurrency as u64,
                    row_offset: c * 7919,
                    deadline_ms,
                    start,
                };
                s.spawn(move || {
                    if http {
                        run_conn_open_http(addr, te, cfg)
                    } else {
                        run_conn_open(addr, te, cfg)
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let el = sw.elapsed_s();

    let mut lat_us: Vec<f64> = Vec::with_capacity(requests);
    let mut tot = OpenLoad::default();
    for r in results {
        let load = r?;
        lat_us.extend(load.lat_us);
        tot.models_sum += load.models_sum;
        tot.ok += load.ok;
        tot.busy += load.busy;
        tot.timeouts += load.timeouts;
        tot.errors += load.errors;
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = (tot.ok + tot.busy + tot.timeouts + tot.errors).max(1);
    let frac = |n: u64| n as f64 / total as f64;
    println!(
        "open-loop: target {target_rps:.0} rps, achieved {:.0} rps \
         ({requests} requests, {concurrency} conns, {el:.2}s)",
        requests as f64 / el
    );
    println!(
        "completions: ok {:.3} | busy {:.3} | timeout {:.3} | error {:.3} (fractions sum to 1.0)",
        frac(tot.ok),
        frac(tot.busy),
        frac(tot.timeouts),
        frac(tot.errors)
    );
    println!(
        "client latency p50/p95/p99 = {:.0}/{:.0}/{:.0} us; mean models {:.2}",
        qwyc::util::stats::percentile_sorted(&lat_us, 50.0),
        qwyc::util::stats::percentile_sorted(&lat_us, 95.0),
        qwyc::util::stats::percentile_sorted(&lat_us, 99.0),
        tot.models_sum as f64 / tot.ok.max(1) as f64
    );
    print_server_stats(addr, http)
}

/// One open-loop connection: the writer (this thread) follows the
/// absolute schedule while a reader thread drains replies and matches
/// each OK against the send-instant table to get client-side latency.
fn run_conn_open(
    addr: &std::net::SocketAddr,
    te: &Dataset,
    cfg: OpenLoopConn,
) -> Result<OpenLoad, QwycError> {
    use std::fmt::Write as _;
    use std::io::{BufRead, Write};
    use std::sync::atomic::{AtomicU64, Ordering};

    let io_err = |e: std::io::Error| QwycError::Io(format!("open-loop connection: {e}"));
    let stream = std::net::TcpStream::connect(addr).map_err(io_err)?;
    stream.set_nodelay(true).ok();
    let mut wr = stream.try_clone().map_err(io_err)?;
    let mut reader = std::io::BufReader::new(stream);
    // Send instants in nanos since `cfg.start`, indexed by request id
    // (ids are per-connection and sequential from 0).
    let sends: Vec<AtomicU64> = (0..cfg.requests).map(|_| AtomicU64::new(0)).collect();

    std::thread::scope(|s| -> Result<OpenLoad, QwycError> {
        let sends_ref = &sends;
        let reader_cfg = &cfg;
        let read_side = s.spawn(move || -> Result<OpenLoad, QwycError> {
            let mut load = OpenLoad::default();
            let mut line = String::new();
            let mut seen = 0usize;
            while seen < reader_cfg.requests {
                line.clear();
                if reader.read_line(&mut line).map_err(io_err)? == 0 {
                    return Err(QwycError::Io("server closed the connection".into()));
                }
                let now_ns = reader_cfg.start.elapsed().as_nanos() as u64;
                match Reply::parse(line.trim()) {
                    Reply::Ok(r) => {
                        if let Some(cell) = sends_ref.get(r.id as usize) {
                            let sent_ns = cell.load(Ordering::Acquire);
                            load.lat_us.push(now_ns.saturating_sub(sent_ns) as f64 / 1_000.0);
                        }
                        load.models_sum += r.models as u64;
                        load.ok += 1;
                        seen += 1;
                    }
                    Reply::Busy { .. } => {
                        load.busy += 1;
                        seen += 1;
                    }
                    Reply::Timeout { .. } => {
                        load.timeouts += 1;
                        seen += 1;
                    }
                    Reply::Err { .. } => {
                        load.errors += 1;
                        seen += 1;
                    }
                    other => {
                        return Err(QwycError::Io(format!("unexpected reply: {other:?}")));
                    }
                }
            }
            Ok(load)
        });

        let mut buf = String::new();
        for k in 0..cfg.requests {
            let sched_ns = cfg.phase_ns + k as u64 * cfg.interval_ns;
            let sched = cfg.start + Duration::from_nanos(sched_ns);
            let now = std::time::Instant::now();
            if sched > now {
                std::thread::sleep(sched - now);
            }
            // Late? Send immediately — the schedule is never re-based.
            let row = te.row((cfg.row_offset + k) % te.n);
            buf.clear();
            let _ = write!(buf, "EVAL {k}");
            if cfg.deadline_ms > 0 {
                let _ = write!(buf, " DEADLINE_MS={}", cfg.deadline_ms);
            }
            for (i, v) in row.iter().enumerate() {
                buf.push(if i == 0 { ' ' } else { ',' });
                let _ = write!(buf, "{v}");
            }
            buf.push('\n');
            sends[k].store(cfg.start.elapsed().as_nanos() as u64, Ordering::Release);
            wr.write_all(buf.as_bytes()).map_err(io_err)?;
        }
        read_side.join().expect("open-loop reader thread")
    })
}

/// [`run_conn_open`] over the HTTP front-end: the writer half follows
/// the same absolute arrival schedule issuing raw `POST /v1/score`
/// requests while a reader thread drains responses. HTTP/1.1 answers
/// FIFO per connection, so the k-th response pairs with the k-th send —
/// no id lookup — but the send-instant slots stay atomic because the
/// reader races the writer for fresh entries.
fn run_conn_open_http(
    addr: &std::net::SocketAddr,
    te: &Dataset,
    cfg: OpenLoopConn,
) -> Result<OpenLoad, QwycError> {
    use std::fmt::Write as _;
    use std::io::Write;
    use std::sync::atomic::{AtomicU64, Ordering};

    let io_err = |e: std::io::Error| QwycError::Io(format!("open-loop http connection: {e}"));
    let stream = std::net::TcpStream::connect(addr).map_err(io_err)?;
    stream.set_nodelay(true).ok();
    let mut wr = stream.try_clone().map_err(io_err)?;
    let mut reader = std::io::BufReader::new(stream);
    // Send instants in nanos since `cfg.start`, indexed by send order.
    let sends: Vec<AtomicU64> = (0..cfg.requests).map(|_| AtomicU64::new(0)).collect();

    std::thread::scope(|s| -> Result<OpenLoad, QwycError> {
        let sends_ref = &sends;
        let reader_cfg = &cfg;
        let read_side = s.spawn(move || -> Result<OpenLoad, QwycError> {
            let mut load = OpenLoad::default();
            for k in 0..reader_cfg.requests {
                let resp = qwyc::http::read_response_from(&mut reader).map_err(io_err)?;
                let now_ns = reader_cfg.start.elapsed().as_nanos() as u64;
                match resp.status {
                    200 => {
                        let sent_ns = sends_ref[k].load(Ordering::Acquire);
                        load.lat_us.push(now_ns.saturating_sub(sent_ns) as f64 / 1_000.0);
                        if let Ok(j) = Json::parse(&resp.body) {
                            if let Some(m) = j.get("models") {
                                load.models_sum += m.as_f64().unwrap_or(0.0) as u64;
                            }
                        }
                        load.ok += 1;
                    }
                    503 => load.busy += 1,
                    504 => load.timeouts += 1,
                    _ => load.errors += 1,
                }
            }
            Ok(load)
        });

        let mut body = String::new();
        let mut req = String::new();
        for k in 0..cfg.requests {
            let sched = cfg.start + Duration::from_nanos(cfg.phase_ns + k as u64 * cfg.interval_ns);
            let now = std::time::Instant::now();
            if sched > now {
                std::thread::sleep(sched - now);
            }
            // Late? Send immediately — the schedule is never re-based.
            write_row_body(&mut body, te.row((cfg.row_offset + k) % te.n));
            req.clear();
            let _ = write!(req, "POST /v1/score HTTP/1.1\r\nHost: qwyc\r\n");
            if cfg.deadline_ms > 0 {
                let _ = write!(req, "X-Deadline-Ms: {}\r\n", cfg.deadline_ms);
            }
            let _ = write!(req, "Content-Length: {}\r\n\r\n{body}", body.len());
            sends[k].store(cfg.start.elapsed().as_nanos() as u64, Ordering::Release);
            wr.write_all(req.as_bytes()).map_err(io_err)?;
        }
        read_side.join().expect("open-loop http reader thread")
    })
}

/// Per-connection load results (latencies of OK replies only).
#[derive(Default)]
struct ConnLoad {
    lat_us: Vec<f64>,
    models_sum: u64,
    /// BUSY replies received (each may trigger a retry).
    busy: u64,
    /// Re-sends issued after a BUSY.
    retries: u64,
    /// Requests abandoned after `RETRY_MAX_ATTEMPTS` BUSY replies.
    shed: u64,
    /// TIMEOUT replies (request expired in queue past its deadline).
    timeouts: u64,
    /// Per-request ERR replies (e.g. `shard_panic` during a fault).
    errors: u64,
}

/// Jittered exponential backoff for BUSY retries: base·2^(attempt-1)
/// capped, scaled by a uniform factor in [0.5, 1.5) so retrying
/// connections don't re-collide in lockstep.
fn retry_backoff(attempt: u32, rng: &mut qwyc::util::rng::Rng) -> Duration {
    let exp = (RETRY_BASE_US << (attempt.saturating_sub(1)).min(10)).min(RETRY_CAP_US);
    Duration::from_micros((exp as f64 * (0.5 + rng.f64())) as u64)
}

/// One closed-loop pipelined connection. BUSY replies are retried with
/// jittered exponential backoff (the same row, a fresh id) up to
/// `RETRY_MAX_ATTEMPTS`; only then does the request count as shed.
/// TIMEOUT and per-request ERR replies are terminal for their request —
/// counted, not fatal — so the load keeps flowing through faults.
fn run_conn_load(
    addr: &std::net::SocketAddr,
    te: &Dataset,
    requests: usize,
    pipeline: usize,
    row_offset: usize,
    deadline_ms: u64,
) -> Result<ConnLoad, QwycError> {
    let mut client = Client::connect(addr)?;
    let mut rng = qwyc::util::rng::Rng::new(0x9e3779b9 ^ row_offset as u64);
    let (mut sent, mut done) = (0usize, 0usize);
    let mut load = ConnLoad { lat_us: Vec::with_capacity(requests), ..Default::default() };
    // In-flight requests by id → (dataset row, attempt number), so a
    // BUSY can re-send the same row and attribute the retry correctly.
    let mut outstanding: std::collections::BTreeMap<u64, (usize, u32)> =
        std::collections::BTreeMap::new();
    let mut send = |client: &mut Client, row: usize| -> Result<u64, QwycError> {
        let id = if deadline_ms == 0 {
            client.send_eval(te.row(row % te.n))?
        } else {
            client.send_eval_with_deadline(te.row(row % te.n), deadline_ms)?
        };
        Ok(id)
    };
    let mut err_shown = 0usize;
    while done < requests {
        while sent < requests && outstanding.len() < pipeline {
            let row = row_offset + sent;
            let id = send(&mut client, row)?;
            outstanding.insert(id, (row, 1));
            sent += 1;
        }
        match client.read_reply()? {
            Reply::Ok(r) => {
                if outstanding.remove(&r.id).is_some() {
                    load.models_sum += r.models as u64;
                    load.lat_us.push(r.latency_us as f64);
                    done += 1;
                }
            }
            Reply::Busy { id } => {
                load.busy += 1;
                if let Some((row, attempt)) = outstanding.remove(&id) {
                    if attempt >= RETRY_MAX_ATTEMPTS {
                        load.shed += 1;
                        done += 1;
                    } else {
                        std::thread::sleep(retry_backoff(attempt, &mut rng));
                        let new_id = send(&mut client, row)?;
                        outstanding.insert(new_id, (row, attempt + 1));
                        load.retries += 1;
                    }
                }
            }
            Reply::Timeout { id } => {
                if outstanding.remove(&id).is_some() {
                    load.timeouts += 1;
                    done += 1;
                }
            }
            Reply::Err { id: Some(id), message } => {
                if outstanding.remove(&id).is_some() {
                    load.errors += 1;
                    done += 1;
                    if err_shown < 3 {
                        eprintln!("request {id} failed: {message}");
                        err_shown += 1;
                    }
                }
            }
            Reply::Err { id: None, message } => {
                return Err(QwycError::Io(format!("server error: {message}")));
            }
            Reply::Reloaded(line) | Reply::Other(line) => {
                return Err(QwycError::Io(format!("unexpected reply: {line}")))
            }
            Reply::ReloadRejected { stage, why } => {
                return Err(QwycError::Io(format!(
                    "unexpected reply: RELOAD_REJECTED {stage}: {why}"
                )))
            }
        }
    }
    Ok(load)
}

/// Format a feature row as the JSON array body `POST /v1/score` takes,
/// with the same `{v}` float formatting the line protocol's `EVAL`
/// encoder uses — both surfaces put byte-identical feature text on the
/// wire, which is what makes the bitwise-equivalence test meaningful.
fn write_row_body(body: &mut String, row: &[f32]) {
    use std::fmt::Write as _;
    body.clear();
    body.push('[');
    for (i, v) in row.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        let _ = write!(body, "{v}");
    }
    body.push(']');
}

/// One pipelined `POST /v1/score` send (no response read).
fn send_score(
    client: &mut HttpClient,
    body: &mut String,
    row: &[f32],
    deadline_hdr: &str,
    deadline_ms: u64,
) -> Result<(), QwycError> {
    write_row_body(body, row);
    let with_deadline = [("X-Deadline-Ms", deadline_hdr)];
    let headers: &[(&str, &str)] = if deadline_ms > 0 { &with_deadline } else { &[] };
    client
        .send("POST", "/v1/score", headers, body.as_bytes())
        .map_err(|e| QwycError::Io(format!("http send: {e}")))
}

/// [`run_conn_load`] over the HTTP front-end: the same closed-loop
/// pipelined shape (keep up to `pipeline` `POST /v1/score` sends in
/// flight, then drain) and the same retry policy, with 503 standing in
/// for `BUSY` and 504 for `TIMEOUT`. HTTP/1.1 answers FIFO per
/// connection, so in-flight requests live in a queue matched by arrival
/// order instead of a by-id map.
fn run_conn_load_http(
    addr: &std::net::SocketAddr,
    te: &Dataset,
    requests: usize,
    pipeline: usize,
    row_offset: usize,
    deadline_ms: u64,
) -> Result<ConnLoad, QwycError> {
    let mut client = HttpClient::connect(addr)?;
    let mut rng = qwyc::util::rng::Rng::new(0x9e3779b9 ^ row_offset as u64);
    let (mut sent, mut done) = (0usize, 0usize);
    let mut load = ConnLoad { lat_us: Vec::with_capacity(requests), ..Default::default() };
    let mut outstanding: std::collections::VecDeque<(usize, u32)> =
        std::collections::VecDeque::new();
    let deadline_hdr = deadline_ms.to_string();
    let mut body = String::new();
    let mut err_shown = 0usize;
    while done < requests {
        while sent < requests && outstanding.len() < pipeline {
            let row = row_offset + sent;
            send_score(&mut client, &mut body, te.row(row % te.n), &deadline_hdr, deadline_ms)?;
            outstanding.push_back((row, 1));
            sent += 1;
        }
        let resp = client.read_response().map_err(|e| QwycError::Io(format!("http: {e}")))?;
        let (row, attempt) = outstanding
            .pop_front()
            .ok_or_else(|| QwycError::Io("response without an in-flight request".into()))?;
        match resp.status {
            200 => {
                let j = Json::parse(&resp.body)?;
                load.models_sum += j.req("models")?.as_f64()? as u64;
                load.lat_us.push(j.req("latency_us")?.as_f64()?);
                done += 1;
            }
            503 => {
                load.busy += 1;
                if attempt >= RETRY_MAX_ATTEMPTS {
                    load.shed += 1;
                    done += 1;
                } else {
                    std::thread::sleep(retry_backoff(attempt, &mut rng));
                    let r = te.row(row % te.n);
                    send_score(&mut client, &mut body, r, &deadline_hdr, deadline_ms)?;
                    // A retry goes to the back of the FIFO — it is also
                    // the newest send on the wire, so order holds.
                    outstanding.push_back((row, attempt + 1));
                    load.retries += 1;
                }
            }
            504 => {
                load.timeouts += 1;
                done += 1;
            }
            422 => {
                load.errors += 1;
                done += 1;
                if err_shown < 3 {
                    eprintln!("request for row {row} failed: {}", resp.body);
                    err_shown += 1;
                }
            }
            other => {
                return Err(QwycError::Io(format!("unexpected HTTP {other}: {}", resp.body)));
            }
        }
    }
    Ok(load)
}

fn experiment(args: &Args) -> Result<(), QwycError> {
    let what = args.positional.get(1).cloned().unwrap_or_else(|| "all".into());
    let cfg = FigConfig {
        scale: args.get_f64("scale", 0.1)?,
        trees: args.get_usize("trees", 500)?,
        max_opt: args.get_usize("max-opt", 3000)?,
        out_dir: PathBuf::from(args.get_str("out", "results")),
        ..Default::default()
    };
    let runs = args.get_usize("runs", 5)?;
    let timing_examples = args.get_usize("timing-examples", 2000)?;
    args.check_unknown()?;
    std::fs::create_dir_all(&cfg.out_dir).ok();

    match what.as_str() {
        "fig1" | "fig3" => figures::fig1_fig3(&cfg),
        "fig2" => figures::fig2_or_fig4(&cfg, true),
        "fig4" => figures::fig2_or_fig4(&cfg, false),
        "fig5" | "fig6" => figures::fig5_fig6(&cfg),
        "table1" => tables::table1(cfg.scale),
        "tables" => tables::tables_2_to_5(&cfg, runs, timing_examples),
        "all" => {
            tables::table1(cfg.scale);
            figures::fig1_fig3(&cfg);
            figures::fig2_or_fig4(&cfg, true);
            figures::fig2_or_fig4(&cfg, false);
            figures::fig5_fig6(&cfg);
            tables::tables_2_to_5(&cfg, runs, timing_examples);
        }
        other => return Err(QwycError::Config(format!("unknown experiment '{other}'"))),
    }
    println!("\nresults written under {}", cfg.out_dir.display());
    Ok(())
}
