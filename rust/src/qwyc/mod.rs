//! The paper's core contribution: joint optimization of the base-model
//! evaluation order π and per-position early-stopping thresholds
//! (ε⁺, ε⁻) — "Quit When You Can" (Algorithms 1 and 2), plus the fast
//! classifier they produce and simulators/evaluators over score matrices.
//!
//! Evaluation rule for an example x after the r-th model in order π
//! (paper §3.1): with running score g_r = bias + Σ_{t≤r} f_{π(t)}(x),
//!
//! - g_r > ε_r⁺  ⇒ classify positive, stop;
//! - g_r < ε_r⁻  ⇒ classify negative, stop;
//! - otherwise continue; after all T models, classify by f(x) ≥ β.
//!
//! The optimizers guarantee the empirical fraction of examples whose fast
//! decision differs from the full ensemble's is ≤ α on the optimization
//! set (the paper's constraint in problem (2)).
//!
//! **Serial-equivalence guarantee.** The optimizer and simulator hot
//! paths fan out across the `QWYC_THREADS` worker pool
//! ([`crate::util::pool::Pool`]), but every parallel section either
//! computes pure per-candidate/per-example functions merged in
//! deterministic order or feeds a sequential commit step with the serial
//! tie-breaking — so [`optimize_order`] and [`simulate`] return
//! **bit-identical** results at every thread count (asserted in
//! rust/tests/parallel_equiv.rs).

pub mod evaluator;
pub mod multiclass;
pub mod order;
pub mod sweep;
pub mod thresholds;

pub use evaluator::{simulate, simulate_with_pool, SimResult};
pub use order::{optimize_order, optimize_order_with_pool};
pub use sweep::{
    sweep_batched, sweep_block, sweep_block_with, SweepOutcome, SweepParams, SweepScratch,
};
pub use thresholds::optimize_thresholds_for_order;

use crate::error::QwycError;
use crate::util::json::Json;

/// Configuration for the QWYC optimizers.
#[derive(Clone, Debug)]
pub struct QwycConfig {
    /// Maximum fraction of examples whose fast decision may differ from
    /// the full ensemble (the constraint level α in problem (2)).
    pub alpha: f64,
    /// Filter-and-score mode: only early-*negative* thresholds are
    /// optimized (ε⁺ ≡ +∞); positives always receive the full score
    /// (paper §3.1 "Filtering Candidates", used in Experiments 3-6).
    pub neg_only: bool,
    /// Subsample the optimization set to at most this many examples
    /// (0 = use all). Keeps Algorithm 1's O(T²N) tractable at T=500 on
    /// this single-core testbed; documented wherever used.
    pub max_opt_examples: usize,
    pub seed: u64,
}

impl Default for QwycConfig {
    fn default() -> Self {
        QwycConfig { alpha: 0.005, neg_only: false, max_opt_examples: 0, seed: 17 }
    }
}

/// The optimized fast classifier: an evaluation order plus 2T thresholds.
#[derive(Clone, Debug)]
pub struct FastClassifier {
    /// π: `order[r]` is the index (into the original ensemble) of the
    /// base model evaluated at position r.
    pub order: Vec<usize>,
    /// Early-positive thresholds ε_r⁺ (`+∞` ⇒ no early positive at r).
    pub eps_pos: Vec<f32>,
    /// Early-negative thresholds ε_r⁻ (`-∞` ⇒ no early negative at r).
    pub eps_neg: Vec<f32>,
    /// Ensemble bias folded into the running score at r = 0.
    pub bias: f32,
    /// Full-classifier decision threshold β.
    pub beta: f32,
}

impl FastClassifier {
    /// A "never stop early" classifier over the given order — the
    /// full-evaluation baseline expressed in the same machinery.
    pub fn no_early_stop(order: Vec<usize>, bias: f32, beta: f32) -> FastClassifier {
        let t = order.len();
        FastClassifier {
            order,
            eps_pos: vec![f32::INFINITY; t],
            eps_neg: vec![f32::NEG_INFINITY; t],
            bias,
            beta,
        }
    }

    pub fn t(&self) -> usize {
        self.order.len()
    }

    /// Check structural invariants (order is a permutation; no NaN
    /// thresholds; ε⁻ ≤ ε⁺; finite bias and β). Run once per load — the
    /// sweep and serving hot paths assume these hold.
    // `!(a <= b)` is deliberate: NaN thresholds must fail validation too.
    #[allow(clippy::neg_cmp_op_on_partial_ord)]
    pub fn validate(&self) -> Result<(), QwycError> {
        let t = self.order.len();
        if self.eps_pos.len() != t || self.eps_neg.len() != t {
            return Err(QwycError::Validate("threshold vectors must have length T".into()));
        }
        if !self.bias.is_finite() {
            return Err(QwycError::Validate(format!("bias must be finite, got {}", self.bias)));
        }
        if !self.beta.is_finite() {
            return Err(QwycError::Validate(format!("beta must be finite, got {}", self.beta)));
        }
        let mut seen = vec![false; t];
        for &m in &self.order {
            if m >= t || seen[m] {
                return Err(QwycError::Validate(format!("order is not a permutation (model {m})")));
            }
            seen[m] = true;
        }
        for r in 0..t {
            if self.eps_pos[r].is_nan() || self.eps_neg[r].is_nan() {
                return Err(QwycError::Validate(format!("NaN threshold at position {r}")));
            }
            if !(self.eps_neg[r] <= self.eps_pos[r]) {
                return Err(QwycError::Validate(format!(
                    "eps_neg[{r}]={} > eps_pos[{r}]={}",
                    self.eps_neg[r], self.eps_pos[r]
                )));
            }
        }
        Ok(())
    }

    /// True early-exit evaluation of one example against a live ensemble:
    /// evaluates base models lazily in order — this is the serving hot
    /// path measured in the paper's Tables 2-5.
    pub fn eval_single(&self, ens: &crate::ensemble::Ensemble, x: &[f32]) -> SingleResult {
        let mut g = self.bias;
        for (r, &m) in self.order.iter().enumerate() {
            g += ens.models[m].eval(x);
            if g > self.eps_pos[r] {
                return SingleResult {
                    positive: true,
                    score: g,
                    models_evaluated: r + 1,
                    early: true,
                };
            }
            if g < self.eps_neg[r] {
                return SingleResult {
                    positive: false,
                    score: g,
                    models_evaluated: r + 1,
                    early: true,
                };
            }
        }
        SingleResult {
            positive: g >= self.beta,
            score: g,
            models_evaluated: self.order.len(),
            early: false,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("order", Json::arr_usize(&self.order)),
            ("eps_pos", Json::arr_f32_inf(&self.eps_pos)),
            ("eps_neg", Json::arr_f32_inf(&self.eps_neg)),
            ("bias", Json::Num(self.bias as f64)),
            ("beta", Json::Num(self.beta as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FastClassifier, QwycError> {
        let fc = FastClassifier {
            order: v.req("order")?.as_vec_usize()?,
            eps_pos: v.req("eps_pos")?.as_vec_f32_inf()?,
            eps_neg: v.req("eps_neg")?.as_vec_f32_inf()?,
            bias: v.req("bias")?.as_f64()? as f32,
            beta: v.req("beta")?.as_f64()? as f32,
        };
        fc.validate()?;
        Ok(fc)
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> Result<FastClassifier, QwycError> {
        FastClassifier::from_json(&crate::util::json::read_file(path)?)
    }
}

/// Outcome of a single-example early-exit evaluation.
#[derive(Clone, Copy, Debug)]
pub struct SingleResult {
    pub positive: bool,
    pub score: f32,
    pub models_evaluated: usize,
    pub early: bool,
}

// JSON helpers for ±∞ thresholds (JSON has no Infinity literal).
impl Json {
    pub fn arr_f32_inf(xs: &[f32]) -> Json {
        Json::Arr(
            xs.iter()
                .map(|&v| {
                    if v == f32::INFINITY {
                        Json::str("+inf")
                    } else if v == f32::NEG_INFINITY {
                        Json::str("-inf")
                    } else {
                        Json::Num(v as f64)
                    }
                })
                .collect(),
        )
    }
}

trait JsonInfExt {
    fn as_vec_f32_inf(&self) -> Result<Vec<f32>, QwycError>;
}

impl JsonInfExt for Json {
    fn as_vec_f32_inf(&self) -> Result<Vec<f32>, QwycError> {
        self.as_arr()?
            .iter()
            .map(|v| match v {
                Json::Str(s) if s == "+inf" => Ok(f32::INFINITY),
                Json::Str(s) if s == "-inf" => Ok(f32::NEG_INFINITY),
                other => other.as_f64().map(|x| x as f32),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_permutation() {
        let fc = FastClassifier {
            order: vec![0, 0, 1],
            eps_pos: vec![f32::INFINITY; 3],
            eps_neg: vec![f32::NEG_INFINITY; 3],
            bias: 0.0,
            beta: 0.0,
        };
        assert!(fc.validate().is_err());
    }

    #[test]
    fn validate_catches_crossed_thresholds() {
        let fc = FastClassifier {
            order: vec![0, 1],
            eps_pos: vec![0.0, 1.0],
            eps_neg: vec![0.5, -1.0],
            bias: 0.0,
            beta: 0.0,
        };
        assert!(fc.validate().is_err());
    }

    #[test]
    fn validate_catches_nan_and_non_finite_scalars() {
        let good = FastClassifier {
            order: vec![0, 1],
            eps_pos: vec![1.0, f32::INFINITY],
            eps_neg: vec![-1.0, f32::NEG_INFINITY],
            bias: 0.0,
            beta: 0.0,
        };
        assert!(good.validate().is_ok());
        let mut nan_thr = good.clone();
        nan_thr.eps_pos[0] = f32::NAN;
        assert!(nan_thr.validate().is_err());
        let mut nan_neg = good.clone();
        nan_neg.eps_neg[1] = f32::NAN;
        assert!(nan_neg.validate().is_err());
        let mut bad_bias = good.clone();
        bad_bias.bias = f32::NAN;
        assert!(bad_bias.validate().is_err());
        let mut inf_beta = good.clone();
        inf_beta.beta = f32::INFINITY;
        assert!(inf_beta.validate().is_err());
    }

    #[test]
    fn from_json_rejects_malformed_classifier() {
        // A structurally well-formed document whose payload violates the
        // invariants must fail at load, not at serving time (mirrors the
        // Tree::from_json hardening).
        let nan_bias = Json::obj(vec![
            ("order", Json::arr_usize(&[0, 1])),
            ("eps_pos", Json::arr_f32_inf(&[1.0, f32::INFINITY])),
            ("eps_neg", Json::arr_f32_inf(&[-1.0, f32::NEG_INFINITY])),
            ("bias", Json::Num(f64::NAN)),
            ("beta", Json::Num(0.0)),
        ]);
        assert!(FastClassifier::from_json(&nan_bias).is_err());
        let crossed = Json::obj(vec![
            ("order", Json::arr_usize(&[0, 1])),
            ("eps_pos", Json::arr_f32_inf(&[-2.0, f32::INFINITY])),
            ("eps_neg", Json::arr_f32_inf(&[2.0, f32::NEG_INFINITY])),
            ("bias", Json::Num(0.0)),
            ("beta", Json::Num(0.0)),
        ]);
        assert!(FastClassifier::from_json(&crossed).is_err());
    }

    #[test]
    fn json_roundtrip_with_infinities() {
        let fc = FastClassifier {
            order: vec![2, 0, 1],
            eps_pos: vec![1.5, f32::INFINITY, 0.25],
            eps_neg: vec![f32::NEG_INFINITY, -3.0, -0.25],
            bias: 0.5,
            beta: 0.1,
        };
        let back = FastClassifier::from_json(&fc.to_json()).unwrap();
        assert_eq!(back.order, fc.order);
        assert_eq!(back.eps_pos, fc.eps_pos);
        assert_eq!(back.eps_neg, fc.eps_neg);
    }
}
