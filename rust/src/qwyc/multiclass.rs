//! Multi-class QWYC — the extension the paper's Conclusions call
//! "straightforward": per-class additive scores g_c,r accumulate along a
//! shared base-model order, and an example exits early at position r when
//! the leading class's margin over the runner-up clears a per-position
//! threshold ε_r:
//!
//! ```text
//! exit with class c*  iff  g_{c*,r} − max_{c≠c*} g_{c,r} > ε_r.
//! ```
//!
//! The 1-D threshold structure is the same monotone tradeoff as the
//! binary case (raising ε_r ⇒ fewer exits and fewer disagreements with
//! the full classifier), so Algorithm 2's search and Algorithm 1's
//! greedy cost-ratio ordering carry over verbatim; the error budget α
//! again bounds the fraction of examples whose fast label differs from
//! the full ensemble's argmax.

use crate::util::kth_largest;

/// Per-class score tensors: `scores[c][t*n + i]` = f_{c,t}(x_i) — one
/// additive ensemble per class over a shared base-model index space
/// (one-vs-rest training produces exactly this).
#[derive(Clone, Debug)]
pub struct MultiScoreMatrix {
    pub n: usize,
    pub t: usize,
    pub c: usize,
    scores: Vec<Vec<f32>>,
    pub biases: Vec<f32>,
    pub costs: Vec<f32>,
    /// Cached full-classifier argmax labels.
    full_label: Vec<u16>,
}

impl MultiScoreMatrix {
    pub fn new(
        n: usize,
        t: usize,
        scores: Vec<Vec<f32>>,
        biases: Vec<f32>,
        costs: Vec<f32>,
    ) -> Self {
        let c = scores.len();
        assert!(c >= 2, "need >= 2 classes");
        assert_eq!(biases.len(), c);
        assert_eq!(costs.len(), t);
        for s in &scores {
            assert_eq!(s.len(), n * t);
        }
        // Full scores per class → argmax label.
        let mut full_label = vec![0u16; n];
        let mut best = vec![f32::NEG_INFINITY; n];
        for (ci, s) in scores.iter().enumerate() {
            for i in 0..n {
                let mut v = biases[ci];
                for t_i in 0..t {
                    v += s[t_i * n + i];
                }
                if v > best[i] {
                    best[i] = v;
                    full_label[i] = ci as u16;
                }
            }
        }
        MultiScoreMatrix { n, t, c, scores, biases, costs, full_label }
    }

    #[inline]
    pub fn col(&self, class: usize, t: usize) -> &[f32] {
        &self.scores[class][t * self.n..(t + 1) * self.n]
    }

    #[inline]
    pub fn full_label(&self, i: usize) -> usize {
        self.full_label[i] as usize
    }
}

/// Multi-class fast classifier: shared order + per-position margin
/// thresholds (+∞ ⇒ never exit at that position).
#[derive(Clone, Debug)]
pub struct MultiFastClassifier {
    pub order: Vec<usize>,
    pub eps: Vec<f32>,
    pub biases: Vec<f32>,
}

/// Simulation result (mirrors the binary `SimResult`).
#[derive(Clone, Debug)]
pub struct MultiSimResult {
    pub mean_models: f64,
    pub pct_diff: f64,
    pub labels: Vec<u16>,
    pub stops: Vec<u32>,
}

impl MultiSimResult {
    pub fn accuracy(&self, y: &[u16]) -> f64 {
        let ok = self.labels.iter().zip(y.iter()).filter(|(a, b)| a == b).count();
        ok as f64 / y.len().max(1) as f64
    }
}

/// State shared by the optimizer passes: per-class running scores.
struct Running {
    g: Vec<Vec<f32>>, // [c][n]
}

impl Running {
    fn new(sm: &MultiScoreMatrix) -> Running {
        Running { g: sm.biases.iter().map(|&b| vec![b; sm.n]).collect() }
    }

    fn advance(&mut self, sm: &MultiScoreMatrix, model: usize, active: &[u32]) {
        for (ci, gc) in self.g.iter_mut().enumerate() {
            let col = sm.col(ci, model);
            for &i in active {
                gc[i as usize] += col[i as usize];
            }
        }
    }

    /// Margin of the current leader over the runner-up, plus the leader.
    #[inline]
    fn margin(&self, i: usize) -> (f32, u16) {
        let (mut best, mut second, mut arg) = (f32::NEG_INFINITY, f32::NEG_INFINITY, 0u16);
        for (ci, gc) in self.g.iter().enumerate() {
            let v = gc[i];
            if v > best {
                second = best;
                best = v;
                arg = ci as u16;
            } else if v > second {
                second = v;
            }
        }
        (best - second, arg)
    }
}

/// Optimize per-position margin thresholds along a fixed order
/// (multi-class Algorithm 2): at each position, the smallest feasible
/// ε_r admits the most exits; feasibility = would-be-wrong exits within
/// the remaining budget. Exits use strict `margin > ε_r`.
pub fn optimize_thresholds_multiclass(
    sm: &MultiScoreMatrix,
    order: &[usize],
    alpha: f64,
) -> MultiFastClassifier {
    assert_eq!(order.len(), sm.t);
    let n = sm.n;
    let budget_total = (alpha * n as f64).floor() as usize;
    let mut spent = 0usize;
    let mut run = Running::new(sm);
    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut eps = vec![f32::INFINITY; sm.t];
    let mut wrong_margins: Vec<f32> = Vec::with_capacity(n);

    for (r, &m) in order.iter().enumerate() {
        run.advance(sm, m, &active);
        if r + 1 == sm.t {
            break;
        }
        // Margins of actives whose current leader DISAGREES with the full
        // label — exits on those spend budget. ε_r must keep
        // #{wrong margins > ε} ≤ remaining budget ⇒ ε at the (B+1)-th
        // largest wrong margin (strict >).
        wrong_margins.clear();
        for &i in &active {
            let (mg, lead) = run.margin(i as usize);
            if lead as usize != sm.full_label(i as usize) {
                wrong_margins.push(mg);
            }
        }
        let budget = budget_total - spent;
        let e = if wrong_margins.is_empty() {
            // Any exit is safe; exit everything with margin > 0.
            0.0
        } else if budget >= wrong_margins.len() {
            0.0f32.min(neg_inf_guard())
        } else {
            kth_largest(&mut wrong_margins, budget).max(0.0)
        };
        eps[r] = e;
        // Commit: retire exits, charge errors.
        let mut w = 0usize;
        for idx in 0..active.len() {
            let i = active[idx];
            let (mg, lead) = run.margin(i as usize);
            if mg > e {
                if lead as usize != sm.full_label(i as usize) {
                    spent += 1;
                }
            } else {
                active[w] = i;
                w += 1;
            }
        }
        active.truncate(w);
        if active.is_empty() {
            break;
        }
    }
    MultiFastClassifier { order: order.to_vec(), eps, biases: sm.biases.clone() }
}

#[inline]
fn neg_inf_guard() -> f32 {
    // ε may not go below 0: a non-positive margin means the leader is
    // tied/ambiguous, and exits there would be arbitrary.
    0.0
}

/// Greedy joint order + thresholds (multi-class Algorithm 1): at each
/// position pick the remaining base model minimizing c_k·|C| / #exits
/// under the budget-feasible threshold.
pub fn optimize_order_multiclass(sm: &MultiScoreMatrix, alpha: f64) -> MultiFastClassifier {
    let t = sm.t;
    let n = sm.n;
    let budget_total = (alpha * n as f64).floor() as usize;
    let mut spent = 0usize;
    let mut run = Running::new(sm);
    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut pi: Vec<usize> = (0..t).collect();
    let mut eps = vec![f32::INFINITY; t];
    let mut wrong_margins: Vec<f32> = Vec::with_capacity(n);

    for r in 0..t {
        if active.is_empty() || r + 1 == t {
            pi[r..].sort_by(|&a, &b| sm.costs[a].partial_cmp(&sm.costs[b]).unwrap());
            break;
        }
        let c_before = active.len();
        let mut best: Option<(f64, usize, f32)> = None; // (J, k, eps)
        for k in r..t {
            let m = pi[k];
            // Tentative advance: compute margins with model m added.
            let mut exits = 0usize;
            wrong_margins.clear();
            let budget = budget_total - spent;
            // Two passes: collect wrong margins, then count exits under ε.
            let mut margins: Vec<(f32, bool)> = Vec::with_capacity(active.len());
            for &i in &active {
                let iu = i as usize;
                let (mg, lead) = margin_with(sm, &run, m, iu);
                let wrong = lead as usize != sm.full_label(iu);
                margins.push((mg, wrong));
                if wrong {
                    wrong_margins.push(mg);
                }
            }
            let e = if wrong_margins.is_empty() || budget >= wrong_margins.len() {
                0.0
            } else {
                kth_largest(&mut wrong_margins, budget).max(0.0)
            };
            for &(mg, _) in &margins {
                if mg > e {
                    exits += 1;
                }
            }
            if exits == 0 {
                continue;
            }
            let j = sm.costs[m] as f64 * c_before as f64 / exits as f64;
            if best.map(|(bj, ..)| j < bj).unwrap_or(true) {
                best = Some((j, k, e));
            }
        }
        let (k_star, e) = best.map(|(_, k, e)| (k, e)).unwrap_or((r, f32::INFINITY));
        pi.swap(r, k_star);
        run.advance(sm, pi[r], &active);
        eps[r] = e;
        let mut w = 0usize;
        for idx in 0..active.len() {
            let i = active[idx];
            let (mg, lead) = run.margin(i as usize);
            if mg > e {
                if lead as usize != sm.full_label(i as usize) {
                    spent += 1;
                }
            } else {
                active[w] = i;
                w += 1;
            }
        }
        active.truncate(w);
    }
    MultiFastClassifier { order: pi, eps, biases: sm.biases.clone() }
}

#[inline]
fn margin_with(sm: &MultiScoreMatrix, run: &Running, model: usize, i: usize) -> (f32, u16) {
    let (mut best, mut second, mut arg) = (f32::NEG_INFINITY, f32::NEG_INFINITY, 0u16);
    for ci in 0..sm.c {
        let v = run.g[ci][i] + sm.col(ci, model)[i];
        if v > best {
            second = best;
            best = v;
            arg = ci as u16;
        } else if v > second {
            second = v;
        }
    }
    (best - second, arg)
}

/// Simulate a multi-class fast classifier over a score matrix.
pub fn simulate_multiclass(fc: &MultiFastClassifier, sm: &MultiScoreMatrix) -> MultiSimResult {
    let n = sm.n;
    let t = sm.t;
    assert_eq!(fc.order.len(), t);
    let mut run = Running::new(sm);
    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut labels = vec![0u16; n];
    let mut stops = vec![t as u32; n];
    let mut models_sum = 0f64;
    for r in 0..t {
        run.advance(sm, fc.order[r], &active);
        let e = fc.eps[r];
        let mut w = 0usize;
        for idx in 0..active.len() {
            let i = active[idx];
            let iu = i as usize;
            let (mg, lead) = run.margin(iu);
            if r + 1 < t && mg > e {
                labels[iu] = lead;
                stops[iu] = (r + 1) as u32;
                models_sum += (r + 1) as f64;
            } else {
                active[w] = i;
                w += 1;
            }
        }
        active.truncate(w);
        if active.is_empty() {
            break;
        }
    }
    for &i in &active {
        let iu = i as usize;
        let (_, lead) = run.margin(iu);
        labels[iu] = lead;
        stops[iu] = t as u32;
        models_sum += t as f64;
    }
    let diffs = (0..n).filter(|&i| labels[i] as usize != sm.full_label(i)).count();
    MultiSimResult {
        mean_models: models_sum / n.max(1) as f64,
        pct_diff: diffs as f64 / n.max(1) as f64,
        labels,
        stops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthetic 3-class problem: latent class center per example, each
    /// base model votes noisily for the true class.
    fn synthetic(
        n: usize,
        t: usize,
        c: usize,
        noise: f32,
        seed: u64,
    ) -> (MultiScoreMatrix, Vec<u16>) {
        let mut rng = Rng::new(seed);
        let y: Vec<u16> = (0..n).map(|_| rng.below(c) as u16).collect();
        let mut scores: Vec<Vec<f32>> = vec![vec![0f32; n * t]; c];
        for t_i in 0..t {
            for i in 0..n {
                for (ci, s) in scores.iter_mut().enumerate() {
                    let signal = if ci == y[i] as usize { 1.0 } else { 0.0 };
                    s[t_i * n + i] = signal + noise * rng.normal() as f32;
                }
            }
        }
        let sm = MultiScoreMatrix::new(n, t, scores, vec![0.0; c], vec![1.0; t]);
        (sm, y)
    }

    #[test]
    fn full_label_matches_bruteforce() {
        let (sm, _) = synthetic(50, 4, 3, 0.5, 1);
        for i in 0..sm.n {
            let mut best = (f32::NEG_INFINITY, 0usize);
            for ci in 0..sm.c {
                let v: f32 = sm.biases[ci] + (0..sm.t).map(|t| sm.col(ci, t)[i]).sum::<f32>();
                if v > best.0 {
                    best = (v, ci);
                }
            }
            assert_eq!(sm.full_label(i), best.1);
        }
    }

    #[test]
    fn alpha_zero_is_faithful() {
        let (sm, _) = synthetic(400, 8, 4, 0.8, 2);
        let order: Vec<usize> = (0..sm.t).collect();
        let fc = optimize_thresholds_multiclass(&sm, &order, 0.0);
        let sim = simulate_multiclass(&fc, &sm);
        assert_eq!(sim.pct_diff, 0.0);
        assert!(sim.mean_models <= sm.t as f64);
    }

    #[test]
    fn budget_buys_earlier_exits_and_respects_alpha() {
        let (sm, _) = synthetic(600, 10, 3, 1.0, 3);
        let order: Vec<usize> = (0..sm.t).collect();
        let mut prev = f64::INFINITY;
        for &alpha in &[0.0, 0.01, 0.05] {
            let fc = optimize_thresholds_multiclass(&sm, &order, alpha);
            let sim = simulate_multiclass(&fc, &sm);
            assert!(sim.pct_diff <= alpha + 1e-9, "alpha={alpha} diff={}", sim.pct_diff);
            assert!(sim.mean_models <= prev + 1e-9);
            prev = sim.mean_models;
        }
    }

    #[test]
    fn joint_order_beats_or_matches_natural() {
        let (sm, _) = synthetic(500, 12, 3, 0.9, 4);
        let alpha = 0.01;
        let star = simulate_multiclass(&optimize_order_multiclass(&sm, alpha), &sm);
        let natural: Vec<usize> = (0..sm.t).collect();
        let fixed = simulate_multiclass(&optimize_thresholds_multiclass(&sm, &natural, alpha), &sm);
        assert!(star.pct_diff <= alpha + 1e-9);
        assert!(
            star.mean_models <= fixed.mean_models + 1e-9,
            "joint {} vs natural {}",
            star.mean_models,
            fixed.mean_models
        );
    }

    #[test]
    fn easy_examples_exit_early() {
        // Low noise ⇒ most examples decided after very few models.
        let (sm, y) = synthetic(400, 20, 4, 0.2, 5);
        let fc = optimize_order_multiclass(&sm, 0.005);
        let sim = simulate_multiclass(&fc, &sm);
        assert!(sim.mean_models < 5.0, "mean models {}", sim.mean_models);
        // And the fast labels remain accurate against ground truth.
        assert!(sim.accuracy(&y) > 0.95, "acc {}", sim.accuracy(&y));
    }
}
