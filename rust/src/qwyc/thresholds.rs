//! Algorithm 2: optimal early-stopping thresholds for one position.
//!
//! For position r with active set C_{r-1} and running scores g_r, the
//! objective of problem (2) is monotone decreasing in ε_r⁻ (raising it
//! lets more examples exit negative early) while the constraint violation
//! is monotone increasing — so the optimum is the *largest feasible* ε_r⁻
//! (and symmetrically the smallest feasible ε_r⁺). The paper finds these
//! by binary search over the real line; we compute them exactly as order
//! statistics: the largest ε⁻ admitting at most B new disagreements is
//! the (B+1)-th smallest running score among active examples the full
//! ensemble classifies positive (strict `g < ε⁻` exits). Quickselect makes
//! each search O(|C|), which is what keeps Algorithm 1's candidate loop
//! tractable (this is the innermost hot path of the whole optimizer).
//! A bisection variant (`search = Bisect`) is kept for parity with the
//! paper's description and cross-checked in tests.
//!
//! [`optimize_position`] is a pure function of its inputs plus a
//! caller-provided scratch buffer — no globals, no interior mutability —
//! which is what lets Algorithm 1's candidate loop call it concurrently
//! from pool workers (each worker owns one scratch buffer; see
//! qwyc/order.rs) while keeping results bit-identical to the serial
//! sweep.

use crate::ensemble::ScoreMatrix;
use crate::util::{kth_largest, kth_smallest};

/// Result of optimizing (ε⁻, ε⁺) at one position.
#[derive(Clone, Copy, Debug)]
pub struct ThresholdOpt {
    pub eps_neg: f32,
    pub eps_pos: f32,
    /// Active examples that exit (negative / positive) under these
    /// thresholds.
    pub exits_neg: usize,
    pub exits_pos: usize,
    /// Exits that disagree with the full classifier (spend α-budget).
    pub errs_neg: usize,
    pub errs_pos: usize,
}

impl ThresholdOpt {
    pub fn exits(&self) -> usize {
        self.exits_neg + self.exits_pos
    }

    pub fn errs(&self) -> usize {
        self.errs_neg + self.errs_pos
    }
}

/// Which 1-D search to use (Exact = order-statistic via quickselect;
/// Bisect = the paper's binary search over threshold values).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Search {
    Exact,
    Bisect,
}

/// Optimize thresholds for one position given the active examples'
/// running scores `g`, their full-classifier decisions `full_pos`, and the
/// remaining disagreement budgets (counts of examples). The negative
/// threshold is searched first with the whole remaining budget, then the
/// positive threshold with what is left — matching Algorithm 2's
/// sequential lines 4-5. `neg_only` forces ε⁺ = +∞ (Filter-and-Score).
pub fn optimize_position(
    g: &[f32],
    full_pos: &[bool],
    budget: usize,
    neg_only: bool,
    search: Search,
    scratch: &mut Vec<f32>,
) -> ThresholdOpt {
    debug_assert_eq!(g.len(), full_pos.len());

    // ---- ε⁻: largest value with ≤ budget wrong early-negatives --------
    // Wrong exits are full-POSITIVE examples with g < ε⁻.
    scratch.clear();
    scratch.extend(
        g.iter()
            .zip(full_pos.iter())
            .filter(|(_, &fp)| fp)
            .map(|(&gi, _)| gi),
    );
    let eps_neg = match search {
        _ if scratch.is_empty() => f32::INFINITY, // nothing can go wrong
        Search::Exact => {
            if budget >= scratch.len() {
                f32::INFINITY
            } else {
                // Strict `g < ε` exits ⇒ ε at the (budget+1)-th smallest
                // wrong-inducing score admits at most `budget` errors.
                kth_smallest(scratch, budget)
            }
        }
        Search::Bisect => bisect_max_feasible(scratch, budget),
    };
    let (exits_neg, errs_neg) = count_neg(g, full_pos, eps_neg);

    // ---- ε⁺: smallest value with ≤ remaining budget wrong positives ---
    // Wrong exits are full-NEGATIVE examples with g > ε⁺. Examples that
    // already exited negative are no longer candidates.
    let budget_pos = budget.saturating_sub(errs_neg);
    let eps_pos = if neg_only {
        f32::INFINITY
    } else {
        scratch.clear();
        scratch.extend(
            g.iter()
                .zip(full_pos.iter())
                .filter(|(&gi, &fp)| !fp && gi >= eps_neg)
                .map(|(&gi, _)| gi),
        );
        if scratch.is_empty() {
            f32::NEG_INFINITY // no full-negative actives: any ε⁺ is safe
        } else {
            match search {
                Search::Exact => {
                    if budget_pos >= scratch.len() {
                        f32::NEG_INFINITY
                    } else {
                        // (budget_pos+1)-th LARGEST score.
                        kth_largest(scratch, budget_pos)
                    }
                }
                Search::Bisect => bisect_min_feasible(scratch, budget_pos),
            }
        }
    };
    // Enforce ε⁻ ≤ ε⁺ (raising ε⁺ only removes early-positive exits, so
    // feasibility is preserved).
    let eps_pos = eps_pos.max(eps_neg);
    let (exits_pos, errs_pos) = count_pos(g, full_pos, eps_pos, eps_neg);

    ThresholdOpt { eps_neg, eps_pos, exits_neg, exits_pos, errs_neg, errs_pos }
}

/// Count exits/errors for ε⁻: strict `g < ε⁻`.
fn count_neg(g: &[f32], full_pos: &[bool], eps_neg: f32) -> (usize, usize) {
    let mut exits = 0;
    let mut errs = 0;
    for (&gi, &fp) in g.iter().zip(full_pos.iter()) {
        if gi < eps_neg {
            exits += 1;
            errs += fp as usize;
        }
    }
    (exits, errs)
}

/// Count exits/errors for ε⁺: strict `g > ε⁺`, excluding examples that
/// already exited negative (g < ε⁻ — disjoint since ε⁻ ≤ ε⁺).
fn count_pos(g: &[f32], full_pos: &[bool], eps_pos: f32, eps_neg: f32) -> (usize, usize) {
    let mut exits = 0;
    let mut errs = 0;
    for (&gi, &fp) in g.iter().zip(full_pos.iter()) {
        if gi > eps_pos && gi >= eps_neg {
            exits += 1;
            errs += !fp as usize;
        }
    }
    (exits, errs)
}

/// The paper's binary search: largest ε with #{v ∈ vals : v < ε} ≤ budget.
/// Bisection on the value axis with a fixed iteration cap.
fn bisect_max_feasible(vals: &[f32], budget: usize) -> f32 {
    if budget >= vals.len() {
        return f32::INFINITY;
    }
    let (mut lo, mut hi) = bounds(vals);
    // Feasible at lo (nothing below the minimum), infeasible above hi.
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        let wrong = vals.iter().filter(|&&v| v < mid).count();
        if wrong <= budget {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= f32::EPSILON * lo.abs().max(1.0) {
            break;
        }
    }
    lo
}

/// Smallest ε with #{v ∈ vals : v > ε} ≤ budget.
fn bisect_min_feasible(vals: &[f32], budget: usize) -> f32 {
    if budget >= vals.len() {
        return f32::NEG_INFINITY;
    }
    let (mut lo, mut hi) = bounds(vals);
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        let wrong = vals.iter().filter(|&&v| v > mid).count();
        if wrong <= budget {
            hi = mid;
        } else {
            lo = mid;
        }
        if hi - lo <= f32::EPSILON * hi.abs().max(1.0) {
            break;
        }
    }
    hi
}

fn bounds(vals: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    (lo - 1.0, hi + 1.0)
}

/// Algorithm 2 applied along a **fixed** ordering: optimize thresholds
/// position by position, spending the α budget greedily (this is the
/// "QWYC (X order)" baseline used throughout the paper's experiments).
pub fn optimize_thresholds_for_order(
    sm: &ScoreMatrix,
    order: &[usize],
    alpha: f64,
    neg_only: bool,
) -> super::FastClassifier {
    let t = order.len();
    assert_eq!(t, sm.t);
    let n = sm.n;
    let budget_total = (alpha * n as f64).floor() as usize;
    let mut spent = 0usize;

    // Active example state.
    let mut active: Vec<u32> = (0..n as u32).collect();
    let mut g: Vec<f32> = vec![sm.bias; n];
    let full_pos_all: Vec<bool> = (0..n).map(|i| sm.full_positive(i)).collect();

    let mut eps_pos = vec![f32::INFINITY; t];
    let mut eps_neg = vec![f32::NEG_INFINITY; t];
    let mut gbuf: Vec<f32> = Vec::with_capacity(n);
    let mut fbuf: Vec<bool> = Vec::with_capacity(n);
    let mut scratch: Vec<f32> = Vec::with_capacity(n);

    for (r, &m) in order.iter().enumerate() {
        let col = sm.col(m);
        // Advance running scores for actives.
        for &i in &active {
            g[i as usize] += col[i as usize];
        }
        if r + 1 == t {
            // Last position: the full score is known; no thresholds needed
            // (decision falls through to β). Leave ±∞.
            break;
        }
        gbuf.clear();
        fbuf.clear();
        for &i in &active {
            gbuf.push(g[i as usize]);
            fbuf.push(full_pos_all[i as usize]);
        }
        let opt = optimize_position(
            &gbuf,
            &fbuf,
            budget_total - spent,
            neg_only,
            Search::Exact,
            &mut scratch,
        );
        eps_neg[r] = opt.eps_neg;
        eps_pos[r] = opt.eps_pos;
        spent += opt.errs();
        // Retire exited examples.
        active.retain(|&i| {
            let gi = g[i as usize];
            !(gi < opt.eps_neg || gi > opt.eps_pos)
        });
        if active.is_empty() {
            break;
        }
    }

    super::FastClassifier { order: order.to_vec(), eps_pos, eps_neg, bias: sm.bias, beta: sm.beta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn opt(g: &[f32], fp: &[bool], budget: usize, neg_only: bool, s: Search) -> ThresholdOpt {
        let mut scratch = Vec::new();
        optimize_position(g, fp, budget, neg_only, s, &mut scratch)
    }

    #[test]
    fn zero_budget_stops_below_min_positive() {
        // Active: negatives at -2,-1; positives at 0.5, 1.0.
        let g = [-2.0f32, -1.0, 0.5, 1.0];
        let fp = [false, false, true, true];
        let o = opt(&g, &fp, 0, false, Search::Exact);
        // Largest safe ε⁻ is the smallest positive's g: 0.5 (strict <).
        assert_eq!(o.eps_neg, 0.5);
        assert_eq!(o.exits_neg, 2);
        assert_eq!(o.errs_neg, 0);
        // Both negatives already exited below ε⁻, so no full-negative
        // candidates remain: ε⁺ collapses to ε⁻ = 0.5 and only the g=1.0
        // positive exits early-positive (strict >).
        assert_eq!(o.eps_pos, 0.5);
        assert_eq!(o.exits_pos, 1);
        assert_eq!(o.errs_pos, 0);
    }

    #[test]
    fn budget_buys_more_exits() {
        let g = [-2.0f32, -1.0, -0.5, 0.5, 1.0];
        let fp = [false, false, true, true, true]; // positive at -0.5!
        let o0 = opt(&g, &fp, 0, true, Search::Exact);
        assert_eq!(o0.eps_neg, -0.5); // can't cross the misranked positive
        assert_eq!(o0.exits_neg, 2);
        let o1 = opt(&g, &fp, 1, true, Search::Exact);
        assert_eq!(o1.eps_neg, 0.5); // spend 1 error on the -0.5 positive
        assert_eq!(o1.exits_neg, 3);
        assert_eq!(o1.errs_neg, 1);
    }

    #[test]
    fn neg_only_never_sets_pos_threshold() {
        let g = [-1.0f32, 2.0];
        let fp = [false, true];
        let o = opt(&g, &fp, 5, true, Search::Exact);
        assert_eq!(o.eps_pos, f32::INFINITY);
        assert_eq!(o.exits_pos, 0);
    }

    #[test]
    fn all_same_class_allows_infinite_threshold() {
        let g = [-1.0f32, -0.3, -2.0];
        let fp = [false, false, false];
        let o = opt(&g, &fp, 0, false, Search::Exact);
        // No full-positives: every early-negative is safe.
        assert_eq!(o.eps_neg, f32::INFINITY);
        assert_eq!(o.exits_neg, 3);
        assert_eq!(o.errs(), 0);
    }

    #[test]
    fn exact_matches_bisect_on_random_cases() {
        check("exact==bisect", 300, |gen: &mut Gen| {
            let n = gen.usize_in(1, 120);
            let g: Vec<f32> =
                (0..n).map(|_| (gen.rng.normal() as f32 * 2.0).round() / 2.0).collect();
            let fp: Vec<bool> = (0..n).map(|_| gen.rng.bool(0.4)).collect();
            let budget = gen.usize_in(0, n / 4);
            let neg_only = gen.rng.bool(0.5);
            let a = opt(&g, &fp, budget, neg_only, Search::Exact);
            let b = opt(&g, &fp, budget, neg_only, Search::Bisect);
            // Threshold VALUES may differ (bisect converges to an interval
            // edge) but exits/errors — the objective — must agree.
            if a.exits_neg != b.exits_neg || a.errs_neg != b.errs_neg {
                let m = format!("neg mismatch: {a:?} vs {b:?} g={g:?} fp={fp:?} b={budget}");
                return Err(m.into());
            }
            if a.exits_pos != b.exits_pos || a.errs_pos != b.errs_pos {
                let m = format!("pos mismatch: {a:?} vs {b:?} g={g:?} fp={fp:?} b={budget}");
                return Err(m.into());
            }
            Ok(())
        });
    }

    #[test]
    fn errors_never_exceed_budget_property() {
        check("errs<=budget", 500, |gen: &mut Gen| {
            let n = gen.usize_in(1, 200);
            let g: Vec<f32> = (0..n).map(|_| gen.score()).collect();
            let fp: Vec<bool> = (0..n).map(|_| gen.rng.bool(0.5)).collect();
            let budget = gen.usize_in(0, n);
            let o = opt(&g, &fp, budget, gen.rng.bool(0.3), Search::Exact);
            if o.errs() > budget {
                return Err(format!("errs {} > budget {budget}", o.errs()).into());
            }
            if o.eps_neg > o.eps_pos {
                return Err("eps_neg > eps_pos".into());
            }
            Ok(())
        });
    }

    #[test]
    fn exits_maximal_property() {
        // Raising ε⁻ by any amount above the optimum must violate budget.
        check("eps_neg maximal", 300, |gen: &mut Gen| {
            let n = gen.usize_in(2, 150);
            let g: Vec<f32> = (0..n).map(|_| gen.score()).collect();
            let fp: Vec<bool> = (0..n).map(|_| gen.rng.bool(0.5)).collect();
            let budget = gen.usize_in(0, 3);
            let o = opt(&g, &fp, budget, true, Search::Exact);
            if o.eps_neg == f32::INFINITY {
                return Ok(());
            }
            // Next candidate threshold: smallest positive g strictly above.
            let next = g
                .iter()
                .zip(fp.iter())
                .filter(|(&gi, &f)| f && gi >= o.eps_neg)
                .map(|(&gi, _)| gi)
                .fold(f32::INFINITY, f32::min);
            if next == f32::INFINITY {
                return Ok(());
            }
            let eps_up = next + 1e-3;
            let wrong = g
                .iter()
                .zip(fp.iter())
                .filter(|(&gi, &f)| f && gi < eps_up)
                .count();
            if wrong <= budget {
                return Err(format!(
                    "could have pushed eps_neg from {} to {eps_up} (wrong={wrong} <= {budget})",
                    o.eps_neg
                )
                .into());
            }
            Ok(())
        });
    }

    #[test]
    fn fixed_order_respects_alpha_on_train() {
        use crate::data::synth::{generate, Which};
        use crate::gbt::{train, GbtParams};
        let (tr, _) = generate(Which::AdultLike, 11, 0.03);
        let (ens, _) = train(&tr, &GbtParams { n_trees: 40, max_depth: 3, ..Default::default() });
        let sm = ens.score_matrix(&tr);
        for &alpha in &[0.0, 0.005, 0.02] {
            let order: Vec<usize> = (0..sm.t).collect();
            let fc = optimize_thresholds_for_order(&sm, &order, alpha, false);
            fc.validate().unwrap();
            let sim = crate::qwyc::simulate(&fc, &sm);
            assert!(
                sim.pct_diff <= alpha + 1e-9,
                "alpha={alpha}: train diff {} exceeds budget",
                sim.pct_diff
            );
            assert!(sim.mean_models <= sm.t as f64 + 1e-9);
        }
    }
}
