//! Batched simulation of a fast classifier over a precomputed score
//! matrix: reproduces the paper's evaluation metrics — mean number of base
//! models evaluated, mean evaluation cost, % classification differences
//! from the full ensemble, accuracy against labels, and the per-example
//! stop-position histogram (Figures 5-6).
//!
//! The sweep itself is the crate-wide position-major active-list core in
//! [`crate::qwyc::sweep`] — simulation's only contribution is the scorer
//! (a contiguous window of each score-matrix column) and the aggregate
//! reduction. Examples are independent, so the sweep runs over
//! cache-sized example blocks fanned across the [`Pool`]; per-example
//! outcomes come back in example order and the scalar aggregates are
//! reduced in a deterministic serial pass afterwards — `simulate` is
//! bit-identical at every thread count.

use super::sweep::{sweep_batched, SweepParams};
use super::FastClassifier;
use crate::ensemble::ScoreMatrix;
use crate::util::pool::Pool;

/// Example-block width for the parallel sweep: 4K examples × 4-byte
/// scores keeps a block's window of one column (16 KiB) plus its running
/// scores comfortably in L1/L2 while giving the pool enough blocks to
/// balance.
const SIM_BLOCK: usize = 4096;

/// Aggregate simulation result.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Mean number of base models evaluated per example.
    pub mean_models: f64,
    /// Mean evaluation cost (Σ c over evaluated prefix; equals
    /// `mean_models` when all costs are 1).
    pub mean_cost: f64,
    /// Fraction of examples whose fast decision differs from the full
    /// classifier's decision.
    pub pct_diff: f64,
    /// Fast decision per example.
    pub decisions: Vec<bool>,
    /// Stop position (1-based count of models evaluated) per example.
    pub stops: Vec<u32>,
    /// Examples that exited early (vs. falling through to full eval).
    pub n_early: usize,
}

impl SimResult {
    /// Accuracy of the fast decisions against labels.
    pub fn accuracy(&self, labels: &[f32]) -> f64 {
        assert_eq!(labels.len(), self.decisions.len());
        let correct = self
            .decisions
            .iter()
            .zip(labels.iter())
            .filter(|(&d, &y)| d == (y > 0.5))
            .count();
        correct as f64 / labels.len().max(1) as f64
    }

    /// Histogram of stop positions with `bins` buckets over [1, T].
    /// Degenerate inputs clamp instead of panicking: `bins` is limited to
    /// [1, T] so tiny ensembles (t = 1, or even t = 0) never ask
    /// `Histogram` for zero or zero-width buckets.
    pub fn stop_histogram(&self, t: usize, bins: usize) -> crate::util::stats::Histogram {
        let t = t.max(1);
        let mut h = crate::util::stats::Histogram::new(0.5, t as f64 + 0.5, bins.clamp(1, t));
        for &s in &self.stops {
            h.add(s as f64);
        }
        h
    }
}

/// Simulate the fast classifier on every example of the score matrix with
/// the pool implied by `QWYC_THREADS` (or all available cores).
pub fn simulate(fc: &FastClassifier, sm: &ScoreMatrix) -> SimResult {
    simulate_with_pool(fc, sm, &Pool::from_env())
}

/// Simulate the fast classifier across an explicit pool. The scorer hands
/// the shared sweep a contiguous window of each score-matrix column, so
/// the arithmetic is identical to the serving path (per-example scores
/// accumulate in π order as f32).
pub fn simulate_with_pool(fc: &FastClassifier, sm: &ScoreMatrix, pool: &Pool) -> SimResult {
    let n = sm.n;
    let t = fc.order.len();
    assert_eq!(t, sm.t, "classifier/matrix T mismatch");
    // The sweep takes bias/β from the classifier; the pre-refactor loop
    // took β from the matrix. They are two views of the same ensemble —
    // pin that so a drifted pair cannot silently change survivor
    // decisions relative to `pct_diff`'s sm-side reference.
    assert_eq!(fc.bias, sm.bias, "classifier/matrix bias mismatch");
    assert_eq!(fc.beta, sm.beta, "classifier/matrix beta mismatch");

    let params = SweepParams::of_classifier(fc);
    let outcomes = sweep_batched(&params, n, SIM_BLOCK, pool, |lo, hi| {
        move |r: usize, active: &[u32], scores: &mut [f32]| {
            let col = &sm.col(fc.order[r])[lo..hi];
            for (slot, &i) in scores.iter_mut().zip(active.iter()) {
                *slot = col[i as usize];
            }
        }
    });

    // Aggregates reduce serially over the in-order outcomes so every
    // float is added in the same order at every thread count.
    // cum[r] = Σ_{q<r} c_{π(q)} is the cost of an exit after position r
    // (the same table `CompiledPlan` precomputes for the serving path).
    let mut cum = vec![0f64; t + 1];
    for r in 0..t {
        cum[r + 1] = cum[r] + sm.costs[fc.order[r]] as f64;
    }
    let total_cost = sm.total_cost();
    let mut decisions = Vec::with_capacity(n);
    let mut stops = Vec::with_capacity(n);
    let mut models_sum = 0f64;
    let mut cost_sum = 0f64;
    let mut n_early = 0usize;
    let mut diffs = 0usize;
    for (i, o) in outcomes.iter().enumerate() {
        decisions.push(o.positive);
        stops.push(o.stop);
        models_sum += o.stop as f64;
        if o.early {
            cost_sum += cum[o.stop as usize];
            n_early += 1;
        } else {
            cost_sum += total_cost;
        }
        if o.positive != sm.full_positive(i) {
            diffs += 1;
        }
    }

    SimResult {
        mean_models: models_sum / n.max(1) as f64,
        mean_cost: cost_sum / n.max(1) as f64,
        pct_diff: diffs as f64 / n.max(1) as f64,
        decisions,
        stops,
        n_early,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ensemble::ScoreMatrix;

    /// 4 examples, 2 models; bias 0, β 0.
    /// cols: m0 = [2, -2, 0.1, -0.1], m1 = [1, -1, 1, -1].
    /// full  = [3, -3, 1.1, -1.1] → decisions [P, N, P, N].
    fn toy() -> ScoreMatrix {
        ScoreMatrix::new(
            4,
            2,
            vec![2.0, -2.0, 0.1, -0.1, 1.0, -1.0, 1.0, -1.0],
            0.0,
            0.0,
            vec![1.0, 1.0],
        )
    }

    #[test]
    fn no_early_stop_matches_full() {
        let sm = toy();
        let fc = FastClassifier::no_early_stop(vec![0, 1], 0.0, 0.0);
        let sim = simulate(&fc, &sm);
        assert_eq!(sim.pct_diff, 0.0);
        assert_eq!(sim.mean_models, 2.0);
        assert_eq!(sim.n_early, 0);
        assert_eq!(sim.decisions, vec![true, false, true, false]);
    }

    #[test]
    fn thresholds_trigger_early_exits() {
        let sm = toy();
        // After model 0: exit positive above 1.5, negative below -1.5.
        let fc = FastClassifier {
            order: vec![0, 1],
            eps_pos: vec![1.5, f32::INFINITY],
            eps_neg: vec![-1.5, f32::NEG_INFINITY],
            bias: 0.0,
            beta: 0.0,
        };
        let sim = simulate(&fc, &sm);
        assert_eq!(sim.stops, vec![1, 1, 2, 2]);
        assert_eq!(sim.n_early, 2);
        assert_eq!(sim.mean_models, 1.5);
        assert_eq!(sim.pct_diff, 0.0);
        assert_eq!(sim.decisions, vec![true, false, true, false]);
    }

    #[test]
    fn wrong_early_exit_counts_as_diff() {
        let sm = toy();
        // Aggressive ε⁻ = +0.5 after model 0 forces example 2 (g=0.1,
        // full-positive) to exit negative — one disagreement.
        let fc = FastClassifier {
            order: vec![0, 1],
            eps_pos: vec![1.5, f32::INFINITY],
            eps_neg: vec![0.5, f32::NEG_INFINITY],
            bias: 0.0,
            beta: 0.0,
        };
        let sim = simulate(&fc, &sm);
        assert_eq!(sim.pct_diff, 0.25);
        assert!(!sim.decisions[2]);
    }

    #[test]
    fn order_is_respected() {
        let sm = toy();
        // Evaluate m1 first with a tight positive threshold: examples 0 and
        // 2 (m1 = +1) exit at position 1.
        let fc = FastClassifier {
            order: vec![1, 0],
            eps_pos: vec![0.5, f32::INFINITY],
            eps_neg: vec![-0.5, f32::NEG_INFINITY],
            bias: 0.0,
            beta: 0.0,
        };
        let sim = simulate(&fc, &sm);
        assert_eq!(sim.stops, vec![1, 1, 1, 1]);
        assert_eq!(sim.decisions, vec![true, false, true, false]);
    }

    #[test]
    fn accuracy_against_labels() {
        let sm = toy();
        let fc = FastClassifier::no_early_stop(vec![0, 1], 0.0, 0.0);
        let sim = simulate(&fc, &sm);
        assert_eq!(sim.accuracy(&[1.0, 0.0, 1.0, 0.0]), 1.0);
        assert_eq!(sim.accuracy(&[0.0, 0.0, 1.0, 0.0]), 0.75);
    }

    #[test]
    fn stop_histogram_degenerate_bins() {
        // t=1 ensemble: every stop is at position 1. bins > t used to
        // produce zero-width buckets; now it clamps to one bucket.
        let sm = ScoreMatrix::new(3, 1, vec![1.0, -1.0, 2.0], 0.0, 0.0, vec![1.0]);
        let fc = FastClassifier::no_early_stop(vec![0], 0.0, 0.0);
        let sim = simulate(&fc, &sm);
        let h = sim.stop_histogram(1, 10);
        assert_eq!(h.counts.len(), 1);
        assert_eq!(h.counts[0], 3);
        assert_eq!(h.total, 3);
        // t=0 (no models at all) must clamp rather than panic.
        let h0 = sim.stop_histogram(0, 4);
        assert_eq!(h0.counts.len(), 1);
        // Regular case keeps the requested binning.
        assert_eq!(sim.stop_histogram(8, 4).counts.len(), 4);
    }

    #[test]
    fn simulate_agrees_with_eval_single() {
        use crate::data::synth::{generate, Which};
        use crate::lattice::{train_joint, LatticeParams};
        let (tr, _) = generate(Which::Rw2Like, 9, 0.01);
        let (ens, _) = train_joint(
            &tr,
            &LatticeParams { n_lattices: 6, dim: 4, steps: 60, ..Default::default() },
        );
        let sm = ens.score_matrix(&tr);
        let order: Vec<usize> = (0..sm.t).collect();
        let fc = crate::qwyc::optimize_thresholds_for_order(&sm, &order, 0.01, false);
        let sim = simulate(&fc, &sm);
        for i in (0..tr.n).step_by(17) {
            let single = fc.eval_single(&ens, tr.row(i));
            assert_eq!(single.positive, sim.decisions[i], "example {i}");
            assert_eq!(single.models_evaluated as u32, sim.stops[i], "example {i}");
        }
    }
}
