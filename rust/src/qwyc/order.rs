//! Algorithm 1: greedy joint optimization of the evaluation order π and
//! the early-stopping thresholds (QWYC*).
//!
//! At each position r the optimizer tries every remaining base model k as
//! π(r): it advances the active examples' running scores by k's column,
//! runs the Algorithm-2 threshold search under the remaining α budget, and
//! scores the candidate by the paper's evaluation-time ratio
//!
//! ```text
//! J_r(k) = c_k · |C_{r-1}|  /  #newly-decided(k)
//! ```
//!
//! (∞ when k decides nothing). The argmin-J candidate is committed — its
//! thresholds become (ε_r⁻, ε_r⁺), the examples it decides are retired,
//! and its disagreements are charged against the α budget. This is the
//! greedy cost-ratio rule of Munagala et al.'s Pipelined Set Cover, which
//! gives QWYC its 4-approximation guarantee (paper Theorem 1, reproduced
//! as a test in `rust/tests/pipeline_example.rs`).
//!
//! Complexity: O(T²·N̄) where N̄ is the (shrinking) active-set size; the
//! per-candidate threshold search is O(|C|) via quickselect (see
//! thresholds.rs). `QwycConfig::max_opt_examples` bounds N for T=500 runs.
//!
//! Parallelism: the candidate loop `for k in r..t` is embarrassingly
//! parallel — each candidate reads the shared (g, active, full_pos)
//! state and writes nothing — so it fans out across
//! [`Pool`](crate::util::pool::Pool) workers with thread-local scratch.
//! The *commit* step (argmin-J selection, π swap, score advance, α-budget
//! spend) stays sequential and scans candidate results in ascending k
//! with the same strict-improvement tie-break as the serial loop, so the
//! returned `FastClassifier` is bit-identical at every thread count
//! (asserted in rust/tests/parallel_equiv.rs).

use super::thresholds::{optimize_position, Search, ThresholdOpt};
use super::{FastClassifier, QwycConfig};
use crate::ensemble::ScoreMatrix;
use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// Run QWYC* (Algorithm 1) on a score matrix with the pool implied by
/// `QWYC_THREADS` (or all available cores).
pub fn optimize_order(sm_full: &ScoreMatrix, cfg: &QwycConfig) -> FastClassifier {
    optimize_order_with_pool(sm_full, cfg, &Pool::from_env())
}

/// Run QWYC* (Algorithm 1) on a score matrix across an explicit pool.
pub fn optimize_order_with_pool(
    sm_full: &ScoreMatrix,
    cfg: &QwycConfig,
    pool: &Pool,
) -> FastClassifier {
    // Optional optimization-set subsample (keeps O(T²N) tractable for
    // T=500 on this testbed; the paper itself optimizes on the full train
    // set). Only the greedy ORDER search runs on the subsample — the
    // final thresholds are refit on the full set below, which avoids the
    // winner's-curse overfit of picking, at every position, the candidate
    // whose subsample order statistics happened to look most permissive.
    let subsampled = cfg.max_opt_examples > 0 && sm_full.n > cfg.max_opt_examples;
    let sub;
    let sm = if subsampled {
        let mut rng = Rng::new(cfg.seed ^ 0x0b7);
        let idx = rng.choose_k(sm_full.n, cfg.max_opt_examples);
        sub = sm_full.select_examples(&idx);
        &sub
    } else {
        sm_full
    };

    let t = sm.t;
    let n = sm.n;
    let budget_total = (cfg.alpha * n as f64).floor() as usize;
    let mut spent = 0usize;

    let full_pos_all: Vec<bool> = (0..n).map(|i| sm.full_positive(i)).collect();
    let mut g: Vec<f32> = vec![sm.bias; n];
    let mut active: Vec<u32> = (0..n as u32).collect();

    // π as a mutable array over model indices; position r picks from
    // remaining[r..] by swapping (exactly Algorithm 1's swap structure).
    let mut pi: Vec<usize> = (0..t).collect();
    let mut eps_pos = vec![f32::INFINITY; t];
    let mut eps_neg = vec![f32::NEG_INFINITY; t];

    // Shared per-position gather of the actives' full decisions; the
    // per-candidate g/scratch buffers are thread-local inside the pool
    // workers (each candidate's threshold search is independent).
    let mut fbuf: Vec<bool> = Vec::with_capacity(n);

    for r in 0..t {
        if active.is_empty() || r + 1 == t {
            // Nothing left to decide (or last position, where thresholds
            // are moot): keep remaining models in cheapest-first order so
            // stragglers pay as little as possible per step.
            pi[r..].sort_by(|&a, &b| sm.costs[a].partial_cmp(&sm.costs[b]).unwrap());
            break;
        }
        // Gather active full_pos once per position.
        fbuf.clear();
        for &i in &active {
            fbuf.push(full_pos_all[i as usize]);
        }

        let c_before = active.len();
        let mut best_k = r; // default: leave π unchanged at this position
        let mut best_j = f64::INFINITY;
        let mut best_opt = None;

        // Fan the independent candidate evaluations out across the pool.
        // Chunks are scheduled dynamically (later chunks can be cheaper as
        // quickselect inputs shrink); each worker reuses one g/scratch
        // buffer pair across its chunk's candidates.
        let cand: Vec<usize> = (r..t).collect();
        let chunk = candidate_chunk(cand.len(), c_before, pool.n_threads());
        let evaluated: Vec<Vec<(usize, ThresholdOpt)>> = pool.par_chunks(&cand, chunk, |_, ks| {
            let mut gbuf: Vec<f32> = Vec::with_capacity(c_before);
            let mut scratch: Vec<f32> = Vec::with_capacity(c_before);
            let mut out = Vec::new();
            for &k in ks {
                let col = sm.col(pi[k]);
                gbuf.clear();
                for &i in &active {
                    gbuf.push(g[i as usize] + col[i as usize]);
                }
                let opt = optimize_position(
                    &gbuf,
                    &fbuf,
                    budget_total - spent,
                    cfg.neg_only,
                    Search::Exact,
                    &mut scratch,
                );
                if opt.exits() > 0 {
                    out.push((k, opt));
                }
            }
            out
        });
        // Commit selection stays sequential, in ascending k with strict
        // `<` improvement — exactly the serial loop's argmin/tie-break.
        for (k, opt) in evaluated.into_iter().flatten() {
            let j = sm.costs[pi[k]] as f64 * c_before as f64 / opt.exits() as f64;
            if j < best_j {
                best_j = j;
                best_k = k;
                best_opt = Some(opt);
            }
        }

        pi.swap(r, best_k);
        let m = pi[r];
        let col = sm.col(m);
        // Commit: advance running scores for actives.
        for &i in &active {
            g[i as usize] += col[i as usize];
        }
        if let Some(opt) = best_opt {
            eps_neg[r] = opt.eps_neg;
            eps_pos[r] = opt.eps_pos;
            spent += opt.errs();
            active.retain(|&i| {
                let gi = g[i as usize];
                !(gi < opt.eps_neg || gi > opt.eps_pos)
            });
        }
        // If no candidate decided anything (best_opt None), thresholds stay
        // ±∞ at r and the greedy continues — later positions may succeed
        // once more score mass has accumulated.
    }

    if subsampled {
        // Refit thresholds on the FULL optimization set along the chosen
        // order (cost O(T·N), negligible next to the O(T²·N̄) search).
        return super::thresholds::optimize_thresholds_for_order(
            sm_full,
            &pi,
            cfg.alpha,
            cfg.neg_only,
        );
    }
    FastClassifier { order: pi, eps_pos, eps_neg, bias: sm.bias, beta: sm.beta }
}

/// Chunk size for the candidate fan-out: ~4 chunks per worker so dynamic
/// scheduling can balance the shrinking active set, but collapse to one
/// serial chunk when the total work (candidates × actives) is too small
/// to amortize a thread scope.
fn candidate_chunk(candidates: usize, actives: usize, threads: usize) -> usize {
    const MIN_PAR_WORK: usize = 1 << 14;
    if candidates * actives < MIN_PAR_WORK {
        return candidates.max(1);
    }
    candidates.div_ceil(4 * threads.max(1)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qwyc::simulate;

    /// The paper's Appendix A.1 PIPELINE example: 8 examples, 3 base
    /// models, α = 0, c_t = 1, decision threshold 0.
    ///   f1: e1 → +1, e2 → −1, else 0
    ///   f2: e3 → +1, e4 → +1, e5 → −1, else 0
    ///   f3: e5 → −1, e6 → +1, e7 → −1, e8 → −1, else 0
    /// Optimal order is [3, 2, 1] with cost (8 + 4 + 2)/8 = 7/4.
    pub(crate) fn appendix_a1() -> ScoreMatrix {
        let n = 8;
        let mut cols = vec![0f32; n * 3];
        // f1 (model 0)
        cols[0] = 1.0;
        cols[1] = -1.0;
        // f2 (model 1)
        cols[n + 2] = 1.0;
        cols[n + 3] = 1.0;
        cols[n + 4] = -1.0;
        // f3 (model 2)
        cols[2 * n + 4] = -1.0;
        cols[2 * n + 5] = 1.0;
        cols[2 * n + 6] = -1.0;
        cols[2 * n + 7] = -1.0;
        ScoreMatrix::new(n, 3, cols, 0.0, 0.0, vec![1.0; 3])
    }

    #[test]
    fn recovers_appendix_a1_optimal_order() {
        let sm = appendix_a1();
        let cfg = QwycConfig { alpha: 0.0, neg_only: false, max_opt_examples: 0, seed: 1 };
        let fc = optimize_order(&sm, &cfg);
        fc.validate().unwrap();
        // Greedy picks f3 first (4 exits), then f2 (3 of remaining 4...),
        // The paper's optimum: π = [3, 2, 1] (1-based) = [2, 1, 0].
        assert_eq!(fc.order, vec![2, 1, 0], "order {:?}", fc.order);
        let sim = simulate(&fc, &sm);
        assert_eq!(sim.pct_diff, 0.0, "alpha=0 must classify identically");
        // OPT cost = (8·1 + 4·1 + 2·1)/8 = 7/4 mean models.
        assert!(
            (sim.mean_models - 1.75).abs() < 1e-9,
            "mean models {} != 7/4",
            sim.mean_models
        );
    }

    #[test]
    fn alpha_zero_is_faithful_on_gbt() {
        use crate::data::synth::{generate, Which};
        use crate::gbt::{train, GbtParams};
        let (tr, te) = generate(Which::NomaoLike, 21, 0.02);
        let (ens, _) = train(&tr, &GbtParams { n_trees: 30, max_depth: 3, ..Default::default() });
        let sm_tr = ens.score_matrix(&tr);
        let cfg = QwycConfig { alpha: 0.0, ..Default::default() };
        let fc = optimize_order(&sm_tr, &cfg);
        fc.validate().unwrap();
        let sim = simulate(&fc, &sm_tr);
        assert_eq!(sim.pct_diff, 0.0, "train diffs at alpha=0");
        assert!(sim.mean_models < sm_tr.t as f64, "no speedup at all");
        // Held-out: differences possible but should be small.
        let sm_te = ens.score_matrix(&te);
        let sim_te = simulate(&fc, &sm_te);
        assert!(sim_te.pct_diff < 0.05, "test diff {}", sim_te.pct_diff);
    }

    #[test]
    fn larger_alpha_never_evaluates_more_models() {
        use crate::data::synth::{generate, Which};
        use crate::lattice::{train_joint, LatticeParams};
        let (tr, _) = generate(Which::Rw1Like, 22, 0.005);
        let (ens, _) = train_joint(
            &tr,
            &LatticeParams { n_lattices: 5, dim: 6, steps: 120, ..Default::default() },
        );
        let sm = ens.score_matrix(&tr);
        let mut prev = f64::INFINITY;
        for &alpha in &[0.0, 0.002, 0.01, 0.05] {
            let cfg = QwycConfig { alpha, neg_only: true, ..Default::default() };
            let fc = optimize_order(&sm, &cfg);
            let sim = simulate(&fc, &sm);
            assert!(sim.pct_diff <= alpha + 1e-9, "alpha={alpha} diff={}", sim.pct_diff);
            assert!(
                sim.mean_models <= prev + 1e-6,
                "alpha={alpha}: {} models > previous {prev}",
                sim.mean_models
            );
            prev = sim.mean_models;
        }
    }

    #[test]
    fn subsampled_optimization_still_valid() {
        use crate::data::synth::{generate, Which};
        use crate::gbt::{train, GbtParams};
        let (tr, _) = generate(Which::AdultLike, 23, 0.02);
        let (ens, _) = train(&tr, &GbtParams { n_trees: 25, max_depth: 3, ..Default::default() });
        let sm = ens.score_matrix(&tr);
        let cfg = QwycConfig { alpha: 0.01, max_opt_examples: 400, ..Default::default() };
        let fc = optimize_order(&sm, &cfg);
        fc.validate().unwrap();
        let sim = simulate(&fc, &sm);
        // Budget was enforced on a 400-example subsample only, so the
        // full-set diff can exceed alpha — but must stay the same order of
        // magnitude (generalization of thresholds, paper §3.1).
        assert!(sim.pct_diff < 0.08, "diff {}", sim.pct_diff);
    }
}
