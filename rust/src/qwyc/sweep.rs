//! The single position-major early-exit sweep core.
//!
//! Every batched early-exit consumer in this crate — offline
//! [`simulate`](crate::qwyc::simulate) over a score matrix,
//! `NativeEngine::classify_batch` over live feature rows, and the
//! `FilterPipeline` candidate filter — is the same loop: walk the
//! optimized order π position by position, keep an active list of
//! still-undecided examples, add each position's scores to the running
//! totals g, retire examples that cross a threshold (ε⁺ checked first),
//! and decide survivors of all T positions by `g ≥ β`. The only thing
//! that differs between consumers is *where the per-position scores come
//! from* — a score-matrix column, a `TreeSoa` bank, a lattice walk. This
//! module owns the loop once; consumers supply a scorer callback.
//!
//! Arithmetic contract: per example, scores accumulate as f32 in π order
//! starting from `bias` — exactly `FastClassifier::eval_single` — so any
//! scorer whose position scores are bitwise equal to the single-example
//! path yields bitwise-identical outcomes (asserted in
//! rust/tests/plan_equiv.rs). Blocks are merged in index order, so the
//! batched driver is also bit-identical at every thread count.

use super::FastClassifier;
use crate::util::pool::Pool;
use crate::util::simd;

/// Thresholds + bias/β view the sweep needs, position-major. Borrowed
/// from either a [`FastClassifier`] or a
/// [`CompiledPlan`](crate::plan::CompiledPlan).
#[derive(Clone, Copy, Debug)]
pub struct SweepParams<'a> {
    /// Early-positive thresholds ε_r⁺ (`+∞` ⇒ no early positive at r).
    pub eps_pos: &'a [f32],
    /// Early-negative thresholds ε_r⁻ (`-∞` ⇒ no early negative at r).
    pub eps_neg: &'a [f32],
    /// Ensemble bias folded into the running score at r = 0.
    pub bias: f32,
    /// Full-classifier decision threshold β.
    pub beta: f32,
}

impl<'a> SweepParams<'a> {
    pub fn of_classifier(fc: &'a FastClassifier) -> SweepParams<'a> {
        SweepParams { eps_pos: &fc.eps_pos, eps_neg: &fc.eps_neg, bias: fc.bias, beta: fc.beta }
    }

    /// Number of positions T.
    pub fn t(&self) -> usize {
        self.eps_pos.len()
    }
}

/// Per-example outcome of an early-exit sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepOutcome {
    /// Final decision (early threshold crossing, or `g ≥ β` after T).
    pub positive: bool,
    /// Running score at the stop position.
    pub score: f32,
    /// 1-based count of positions evaluated (T for survivors).
    pub stop: u32,
    /// Exited before position T?
    pub early: bool,
}

/// Run the sweep over one block of `nb` examples.
///
/// `score_position(r, active, out)` must fill `out[j]` with position r's
/// score for the example whose block-local index is `active[j]`
/// (`out.len() == active.len()`). It is called once per position in π
/// order, with `active` shrinking as examples retire, and never called
/// again once the active list empties.
///
/// The per-position body is branchless. Running scores live in `g`,
/// *compacted in parallel with* the active list (`g[j]` belongs to
/// example `active[j]`), so the accumulate loop is a linear
/// `g[j] += scores[j]` with a keep-mask side output instead of a
/// gather/scatter with a per-example `if exited`. A second linear pass
/// unconditionally records an as-if-exited outcome for every active
/// example — exiters keep theirs, survivors overwrite at a later
/// position or in the final β pass — and stream-compacts `active`/`g` by
/// the mask in one go. No branch in either loop depends on the scores,
/// so mixed exit patterns cost the same as uniform ones; pass 1 runs
/// the explicitly vectorized, runtime-dispatched `util::simd` kernel
/// (AVX2/SSE2/scalar) and pass 2 auto-vectorizes.
///
/// The accumulation itself is untouched: per example, f32 adds in π
/// order from `bias`, identical to the scalar path and to the previous
/// branchy sweep (pinned by the `reference_sweep` tests below; the keep
/// mask is `!((g > ε⁺) | (g < ε⁻))` — both compares are false for a NaN
/// running score, so NaN keeps an example active exactly as before).
pub fn sweep_block<S>(
    params: &SweepParams<'_>,
    nb: usize,
    score_position: S,
) -> Vec<SweepOutcome>
where
    S: FnMut(usize, &[u32], &mut [f32]),
{
    let mut scratch = SweepScratch::default();
    sweep_block_with(params, nb, score_position, &mut scratch);
    scratch.out
}

/// Reusable working set for [`sweep_block_with`]: the five per-block
/// vectors (`out`, running scores, position scores, keep mask, active
/// list) that [`sweep_block`] would otherwise allocate on every call.
/// Every field is cleared and fully rewritten at the start of each
/// sweep, so a scratch can be reused across calls — including after a
/// panic unwound through an earlier call — without carrying state over.
#[derive(Default)]
pub struct SweepScratch {
    out: Vec<SweepOutcome>,
    g: Vec<f32>,
    scores: Vec<f32>,
    keep: Vec<u8>,
    active: Vec<u32>,
}

impl SweepScratch {
    /// Outcomes of the most recent [`sweep_block_with`] call (`len` is
    /// that call's `nb`; empty before the first call).
    pub fn outcomes(&self) -> &[SweepOutcome] {
        &self.out
    }
}

/// [`sweep_block`] with caller-owned scratch: identical arithmetic and
/// outcome order, zero heap allocation once `scratch` has warmed up to
/// the largest `nb` seen. Returns the filled `scratch.outcomes()` slice.
pub fn sweep_block_with<'s, S>(
    params: &SweepParams<'_>,
    nb: usize,
    mut score_position: S,
    scratch: &'s mut SweepScratch,
) -> &'s [SweepOutcome]
where
    S: FnMut(usize, &[u32], &mut [f32]),
{
    let t = params.t();
    debug_assert_eq!(params.eps_neg.len(), t);
    scratch.out.clear();
    scratch.out.resize(
        nb,
        SweepOutcome { positive: false, score: 0.0, stop: t as u32, early: false },
    );
    scratch.g.clear();
    scratch.g.resize(nb, params.bias);
    scratch.scores.clear();
    scratch.scores.resize(nb, 0f32);
    scratch.keep.clear();
    scratch.keep.resize(nb, 0u8);
    scratch.active.clear();
    scratch.active.extend(0..nb as u32);
    let out = &mut scratch.out;
    let g = &mut scratch.g;
    let scores = &mut scratch.scores;
    let keep = &mut scratch.keep;
    let active = &mut scratch.active;

    for r in 0..t {
        let m = active.len();
        if m == 0 {
            break;
        }
        score_position(r, &active[..m], &mut scores[..m]);
        let (ep, en) = (params.eps_pos[r], params.eps_neg[r]);
        // Pass 1: accumulate and build the keep mask — the runtime-
        // dispatched SIMD kernel (AVX2/SSE2/scalar, util::simd). Same
        // per-element f32 add and strict compares on every tier, so
        // outcomes stay bitwise-identical; a NaN running score fails
        // both compares and keeps the example active.
        simd::accumulate_keep_mask(&mut g[..m], &scores[..m], &mut keep[..m], ep, en);
        // Pass 2: record outcomes and stream-compact active/g by the
        // mask. Writing `out` for *every* active example is what removes
        // the branch: survivors' records are overwritten later, exiters'
        // last write (stop = r+1) is final.
        let stop = (r + 1) as u32;
        let mut w = 0usize;
        for j in 0..m {
            let i = active[j];
            let gi = g[j];
            out[i as usize] =
                SweepOutcome { positive: gi > ep, score: gi, stop, early: true };
            active[w] = i;
            g[w] = gi;
            w += keep[j] as usize;
        }
        active.truncate(w);
    }
    // Survivors of every position: full score known, decide by β.
    for (j, &i) in active.iter().enumerate() {
        let gi = g[j];
        out[i as usize] = SweepOutcome {
            positive: gi >= params.beta,
            score: gi,
            stop: t as u32,
            early: false,
        };
    }
    &*out
}

/// Fan [`sweep_block`] over `n` examples in blocks of `block` across the
/// pool. `make_scorer(lo, hi)` builds the scorer for examples [lo, hi)
/// — the scorer's `active` indices are block-local (relative to `lo`).
/// Outcomes come back in example order, so results are bit-identical at
/// every thread count.
pub fn sweep_batched<S, F>(
    params: &SweepParams<'_>,
    n: usize,
    block: usize,
    pool: &Pool,
    make_scorer: F,
) -> Vec<SweepOutcome>
where
    F: Fn(usize, usize) -> S + Sync,
    S: FnMut(usize, &[u32], &mut [f32]),
{
    let block = block.max(1);
    let blocks = pool.par_map_indexed(n.div_ceil(block), 1, |b| {
        let lo = b * block;
        let hi = ((b + 1) * block).min(n);
        sweep_block(params, hi - lo, make_scorer(lo, hi))
    });
    blocks.concat()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 2 positions over 4 examples; position scores indexed [pos][example].
    const COLS: [[f32; 4]; 2] = [[2.0, -2.0, 0.1, -0.1], [1.0, -1.0, 1.0, -1.0]];

    fn scorer(lo: usize) -> impl FnMut(usize, &[u32], &mut [f32]) {
        move |r: usize, active: &[u32], out: &mut [f32]| {
            for (slot, &i) in out.iter_mut().zip(active.iter()) {
                *slot = COLS[r][lo + i as usize];
            }
        }
    }

    #[test]
    fn thresholds_retire_examples_and_beta_decides_survivors() {
        let params = SweepParams {
            eps_pos: &[1.5, f32::INFINITY],
            eps_neg: &[-1.5, f32::NEG_INFINITY],
            bias: 0.0,
            beta: 0.0,
        };
        let out = sweep_block(&params, 4, scorer(0));
        // Examples 0/1 exit at position 1 (|2| > 1.5); 2/3 survive to β.
        assert_eq!(out[0].stop, 1);
        assert!(out[0].positive && out[0].early);
        assert_eq!(out[1].stop, 1);
        assert!(!out[1].positive && out[1].early);
        assert_eq!(out[2].stop, 2);
        assert!(out[2].positive && !out[2].early);
        assert!((out[2].score - 1.1).abs() < 1e-6);
        assert_eq!(out[3].stop, 2);
        assert!(!out[3].positive && !out[3].early);
    }

    #[test]
    fn batched_matches_single_block_at_any_thread_count() {
        let params = SweepParams {
            eps_pos: &[1.5, f32::INFINITY],
            eps_neg: &[-1.5, f32::NEG_INFINITY],
            bias: 0.25,
            beta: 0.0,
        };
        let whole = sweep_block(&params, 4, scorer(0));
        for threads in [1, 3] {
            let blocked = sweep_batched(&params, 4, 1, &Pool::new(threads), |lo, _hi| scorer(lo));
            assert_eq!(blocked.len(), 4);
            for (a, b) in whole.iter().zip(blocked.iter()) {
                assert_eq!(a.positive, b.positive);
                assert_eq!(a.stop, b.stop);
                assert_eq!(a.early, b.early);
                assert_eq!(a.score.to_bits(), b.score.to_bits());
            }
        }
    }

    #[test]
    fn zero_positions_and_zero_examples() {
        let params =
            SweepParams { eps_pos: &[], eps_neg: &[], bias: 0.5, beta: 0.0 };
        let out = sweep_block(&params, 2, |_, _, _| unreachable!("no positions"));
        assert!(out.iter().all(|o| o.positive && !o.early && o.stop == 0));
        let none = sweep_batched(&params, 0, 8, &Pool::new(2), |_, _| {
            |_: usize, _: &[u32], _: &mut [f32]| {}
        });
        assert!(none.is_empty());
    }

    /// The branchy per-example sweep this module used before the
    /// branchless rework — kept verbatim as the semantic reference the
    /// equivalence tests pin the production kernel against.
    fn reference_sweep<S>(
        params: &SweepParams<'_>,
        nb: usize,
        mut score_position: S,
    ) -> Vec<SweepOutcome>
    where
        S: FnMut(usize, &[u32], &mut [f32]),
    {
        let t = params.t();
        let mut out = vec![
            SweepOutcome { positive: false, score: 0.0, stop: t as u32, early: false };
            nb
        ];
        let mut g = vec![params.bias; nb];
        let mut scores = vec![0f32; nb];
        let mut active: Vec<u32> = (0..nb as u32).collect();
        for r in 0..t {
            if active.is_empty() {
                break;
            }
            let scores = &mut scores[..active.len()];
            score_position(r, &active, scores);
            let (ep, en) = (params.eps_pos[r], params.eps_neg[r]);
            let mut w = 0usize;
            for j in 0..active.len() {
                let i = active[j] as usize;
                let gi = g[i] + scores[j];
                g[i] = gi;
                if gi > ep || gi < en {
                    let stop = (r + 1) as u32;
                    out[i] = SweepOutcome { positive: gi > ep, score: gi, stop, early: true };
                } else {
                    active[w] = i as u32;
                    w += 1;
                }
            }
            active.truncate(w);
        }
        for &i in &active {
            let i = i as usize;
            out[i] = SweepOutcome {
                positive: g[i] >= params.beta,
                score: g[i],
                stop: t as u32,
                early: false,
            };
        }
        out
    }

    fn assert_same(a: &[SweepOutcome], b: &[SweepOutcome]) {
        assert_eq!(a.len(), b.len());
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.positive, y.positive, "example {k}: positive");
            assert_eq!(x.stop, y.stop, "example {k}: stop");
            assert_eq!(x.early, y.early, "example {k}: early");
            assert_eq!(x.score.to_bits(), y.score.to_bits(), "example {k}: score bits");
        }
    }

    /// Deterministic pseudo-random position scores (same for both sweeps).
    fn synth_score(r: usize, i: usize) -> f32 {
        let h = (r as u32).wrapping_mul(2654435761).wrapping_add(i as u32).wrapping_mul(40503);
        ((h >> 16) as f32 / 65536.0) - 0.5
    }

    fn synth_scorer(lo: usize) -> impl FnMut(usize, &[u32], &mut [f32]) {
        move |r: usize, active: &[u32], out: &mut [f32]| {
            for (slot, &i) in out.iter_mut().zip(active.iter()) {
                *slot = synth_score(r, lo + i as usize);
            }
        }
    }

    /// Branchless kernel vs the reference on adversarial exit patterns:
    /// every example exits at position 0, nobody ever exits, and
    /// alternating thresholds that retire roughly half the actives at
    /// every position.
    #[test]
    fn branchless_sweep_matches_reference_on_adversarial_patterns() {
        let t = 13;
        let nb = 97; // not a multiple of any lane width
        let all_exit_pos: Vec<f32> = vec![-10.0; t]; // g > -10 everywhere ⇒ exit at 0
        let all_exit_neg: Vec<f32> = vec![f32::NEG_INFINITY; t];
        let none_pos: Vec<f32> = vec![f32::INFINITY; t];
        let none_neg: Vec<f32> = vec![f32::NEG_INFINITY; t];
        let alt_pos: Vec<f32> =
            (0..t).map(|r| if r % 2 == 0 { 0.05 } else { f32::INFINITY }).collect();
        let alt_neg: Vec<f32> =
            (0..t).map(|r| if r % 2 == 1 { -0.05 } else { f32::NEG_INFINITY }).collect();
        for (name, ep, en) in [
            ("all-exit-at-0", &all_exit_pos, &all_exit_neg),
            ("none-exit", &none_pos, &none_neg),
            ("alternating", &alt_pos, &alt_neg),
        ] {
            let params = SweepParams { eps_pos: ep, eps_neg: en, bias: 0.125, beta: 0.0 };
            let got = sweep_block(&params, nb, synth_scorer(0));
            let want = reference_sweep(&params, nb, synth_scorer(0));
            assert_same(&got, &want);
            // Sanity on the pattern itself.
            match name {
                "all-exit-at-0" => assert!(got.iter().all(|o| o.early && o.stop == 1)),
                "none-exit" => assert!(got.iter().all(|o| !o.early && o.stop == t as u32)),
                _ => assert!(got.iter().any(|o| o.early) && got.iter().any(|o| !o.early)),
            }
        }
    }

    /// Reusing one `SweepScratch` across calls of varying size — growing,
    /// shrinking, and after a prior call left retired-example state in
    /// the buffers — is bitwise-identical to a fresh `sweep_block`.
    #[test]
    fn scratch_reuse_matches_fresh_allocation_at_every_size() {
        let t = 7;
        let pos: Vec<f32> = (0..t).map(|r| if r % 3 == 0 { 0.2 } else { f32::INFINITY }).collect();
        let neg: Vec<f32> =
            (0..t).map(|r| if r % 3 == 1 { -0.2 } else { f32::NEG_INFINITY }).collect();
        let params = SweepParams { eps_pos: &pos, eps_neg: &neg, bias: 0.0, beta: 0.0 };
        let mut scratch = SweepScratch::default();
        for nb in [64usize, 5, 33, 0, 64] {
            let got = sweep_block_with(&params, nb, synth_scorer(0), &mut scratch);
            let want = sweep_block(&params, nb, synth_scorer(0));
            assert_same(got, &want);
            assert_eq!(scratch.outcomes().len(), nb);
        }
    }

    /// A NaN running score compares false against both thresholds, so the
    /// example stays active to the end and survives with `positive =
    /// false` (NaN ≥ β is false): pin the branchless keep mask against
    /// the reference's `if gi > ep || gi < en` on that path.
    #[test]
    fn branchless_sweep_matches_reference_on_nan_scores() {
        let params = SweepParams {
            eps_pos: &[1.0, 1.0],
            eps_neg: &[-1.0, -1.0],
            bias: 0.0,
            beta: 0.0,
        };
        let nan_scorer = |r: usize, active: &[u32], out: &mut [f32]| {
            for (slot, &i) in out.iter_mut().zip(active.iter()) {
                *slot = if (i as usize + r) % 2 == 0 { f32::NAN } else { 0.5 };
            }
        };
        let got = sweep_block(&params, 8, nan_scorer);
        let want = reference_sweep(&params, 8, nan_scorer);
        assert_same(&got, &want);
    }
}
