//! Precomputed score matrix `F[i][t] = f_t(x_i)`.
//!
//! Every optimizer in this repo (QWYC Algorithms 1-2, the fixed-ordering
//! baselines, Fan et al. calibration) and every tradeoff simulation
//! consumes this matrix rather than the ensemble itself — making the
//! optimization ensemble-agnostic and turning the inner loops into dense
//! column scans. Storage is column-major (one contiguous slice per base
//! model) because the optimizers sweep one model over all active examples.

/// N×T score matrix with the ensemble's bias/β/costs carried along.
#[derive(Clone, Debug)]
pub struct ScoreMatrix {
    pub n: usize,
    pub t: usize,
    /// Column-major: `cols[t*n + i]` = f_t(x_i).
    cols: Vec<f32>,
    pub bias: f32,
    pub beta: f32,
    pub costs: Vec<f32>,
    /// Cached full scores f(x_i) = bias + Σ_t F[i][t].
    full: Vec<f32>,
}

impl ScoreMatrix {
    pub fn new(n: usize, t: usize, cols: Vec<f32>, bias: f32, beta: f32, costs: Vec<f32>) -> Self {
        assert_eq!(cols.len(), n * t);
        assert_eq!(costs.len(), t);
        let mut full = vec![bias; n];
        for ti in 0..t {
            let col = &cols[ti * n..(ti + 1) * n];
            for (f, &s) in full.iter_mut().zip(col.iter()) {
                *f += s;
            }
        }
        ScoreMatrix { n, t, cols, bias, beta, costs, full }
    }

    #[inline]
    pub fn score(&self, i: usize, t: usize) -> f32 {
        self.cols[t * self.n + i]
    }

    /// Contiguous column for base model t (all examples).
    #[inline]
    pub fn col(&self, t: usize) -> &[f32] {
        &self.cols[t * self.n..(t + 1) * self.n]
    }

    #[inline]
    pub fn full_score(&self, i: usize) -> f32 {
        self.full[i]
    }

    #[inline]
    pub fn full_scores(&self) -> &[f32] {
        &self.full
    }

    /// Full-classifier decision for example i: f(x_i) ≥ β.
    #[inline]
    pub fn full_positive(&self, i: usize) -> bool {
        self.full[i] >= self.beta
    }

    /// Restrict to a subset of example indices (e.g. the optimization
    /// subsample used to keep Algorithm 1 tractable at T=500).
    pub fn select_examples(&self, idx: &[usize]) -> ScoreMatrix {
        let n2 = idx.len();
        let mut cols = vec![0f32; n2 * self.t];
        for t in 0..self.t {
            let src = self.col(t);
            let dst = &mut cols[t * n2..(t + 1) * n2];
            for (slot, &i) in dst.iter_mut().zip(idx.iter()) {
                *slot = src[i];
            }
        }
        ScoreMatrix::new(n2, self.t, cols, self.bias, self.beta, self.costs.clone())
    }

    /// Total cost of full evaluation (Σ c_t) — the denominator in
    /// cost-based speedup numbers.
    pub fn total_cost(&self) -> f64 {
        self.costs.iter().map(|&c| c as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ScoreMatrix {
        // n=3 examples, t=2 models.
        // model 0 scores: [1, -1, 0.5]; model 1 scores: [0.5, -0.5, -1].
        let cols = vec![1.0, -1.0, 0.5, 0.5, -0.5, -1.0];
        ScoreMatrix::new(3, 2, cols, 0.25, 0.0, vec![1.0, 2.0])
    }

    #[test]
    fn full_scores_cached() {
        let sm = toy();
        assert!((sm.full_score(0) - 1.75).abs() < 1e-6);
        assert!((sm.full_score(1) + 1.25).abs() < 1e-6);
        assert!((sm.full_score(2) + 0.25).abs() < 1e-6);
        assert!(sm.full_positive(0));
        assert!(!sm.full_positive(1));
        assert!(!sm.full_positive(2));
    }

    #[test]
    fn column_access() {
        let sm = toy();
        assert_eq!(sm.col(1), &[0.5, -0.5, -1.0]);
        assert_eq!(sm.score(2, 0), 0.5);
    }

    #[test]
    fn select_examples_subsets() {
        let sm = toy();
        let sub = sm.select_examples(&[2, 0]);
        assert_eq!(sub.n, 2);
        assert_eq!(sub.col(0), &[0.5, 1.0]);
        assert!((sub.full_score(1) - 1.75).abs() < 1e-6);
    }

    #[test]
    fn total_cost() {
        assert!((toy().total_cost() - 3.0).abs() < 1e-12);
    }
}
