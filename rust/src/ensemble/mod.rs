//! Additive ensembles `f(x) = bias + Σ_t f_t(x)` — the object QWYC
//! operates on. Base models are regression trees (benchmark experiments)
//! or lattices (real-world experiments); both expose per-example scalar
//! scores and a constant evaluation cost `c_t` (the paper models c_t = 1
//! for both families; arbitrary costs are supported throughout).

pub mod scores;

use crate::data::Dataset;
use crate::error::QwycError;
use crate::gbt::tree::{Tree, TreeSoa};
use crate::lattice::model::Lattice;
use crate::util::json::Json;
use crate::util::pool::Pool;

/// Example-block width for blocked scoring: a 512-row window of features
/// (512 · d · 4 bytes, ≈128 KiB at d = 64) stays L2-resident while every
/// base model sweeps it, instead of re-streaming the whole feature matrix
/// once per model.
const SCORE_BLOCK: usize = 512;

pub use scores::ScoreMatrix;

/// A single base model.
#[derive(Clone, Debug)]
pub enum BaseModel {
    Tree(Tree),
    Lattice(Lattice),
}

impl BaseModel {
    #[inline]
    pub fn eval(&self, x: &[f32]) -> f32 {
        match self {
            BaseModel::Tree(t) => t.eval(x),
            BaseModel::Lattice(l) => l.eval(x),
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            BaseModel::Tree(_) => "tree",
            BaseModel::Lattice(_) => "lattice",
        }
    }

    fn to_json(&self) -> Json {
        match self {
            BaseModel::Tree(t) => {
                Json::obj(vec![("kind", Json::str("tree")), ("model", t.to_json())])
            }
            BaseModel::Lattice(l) => {
                Json::obj(vec![("kind", Json::str("lattice")), ("model", l.to_json())])
            }
        }
    }

    fn from_json(v: &Json) -> Result<BaseModel, QwycError> {
        match v.req("kind")?.as_str()? {
            "tree" => Ok(BaseModel::Tree(Tree::from_json(v.req("model")?)?)),
            "lattice" => Ok(BaseModel::Lattice(Lattice::from_json(v.req("model")?)?)),
            other => Err(QwycError::Schema(format!("unknown base model kind '{other}'"))),
        }
    }
}

/// An additive ensemble with a decision threshold β: classify positive iff
/// `f(x) ≥ β` (the paper's convention in §3.1: P_full = {x | f(x) ≥ β}).
#[derive(Clone, Debug)]
pub struct Ensemble {
    pub name: String,
    pub models: Vec<BaseModel>,
    /// Additive bias (GBT base score); folded into the running sum at t=0.
    pub bias: f32,
    /// Decision threshold β.
    pub beta: f32,
    /// Evaluation cost c_t per base model (paper: 1.0 for all).
    pub costs: Vec<f32>,
}

impl Ensemble {
    pub fn new(name: &str, models: Vec<BaseModel>, bias: f32, beta: f32) -> Self {
        let costs = vec![1.0; models.len()];
        Ensemble { name: name.to_string(), models, bias, beta, costs }
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Full-ensemble score.
    pub fn eval_full(&self, x: &[f32]) -> f32 {
        self.bias + self.models.iter().map(|m| m.eval(x)).sum::<f32>()
    }

    /// Full-ensemble classification decision.
    #[inline]
    pub fn classify_full(&self, x: &[f32]) -> bool {
        self.eval_full(x) >= self.beta
    }

    /// Accuracy of the full ensemble on a dataset.
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        for i in 0..ds.n {
            let pred = self.classify_full(ds.row(i));
            if pred == (ds.y[i] > 0.5) {
                correct += 1;
            }
        }
        correct as f64 / ds.n.max(1) as f64
    }

    /// Smallest feature width that covers every feature index any base
    /// model reads (max referenced index + 1; 0 when nothing is read).
    /// Plan compilation uses this for the feature-count agreement check.
    pub fn feature_count(&self) -> usize {
        let mut d = 0usize;
        for m in &self.models {
            match m {
                BaseModel::Lattice(l) => {
                    for &f in &l.features {
                        d = d.max(f + 1);
                    }
                }
                BaseModel::Tree(t) => {
                    for n in &t.nodes {
                        if !n.is_leaf() {
                            d = d.max(n.feature as usize + 1);
                        }
                    }
                }
            }
        }
        d
    }

    /// SoA mirrors of the tree base models, index-aligned with `models`
    /// (None for lattices). Shared by the blocked score-matrix build and
    /// `NativeEngine` so mirror construction lives in one place.
    pub fn soa_mirrors(&self) -> Vec<Option<TreeSoa>> {
        self.models
            .iter()
            .map(|m| match m {
                BaseModel::Tree(tr) => Some(tr.to_soa()),
                BaseModel::Lattice(_) => None,
            })
            .collect()
    }

    /// Precompute the N×T score matrix `F[i][t] = f_t(x_i)` that all
    /// ordering/threshold optimizers and simulators consume, using the
    /// pool implied by `QWYC_THREADS` (or all available cores).
    pub fn score_matrix(&self, ds: &Dataset) -> ScoreMatrix {
        self.score_matrix_par(ds, &Pool::from_env())
    }

    /// Blocked, parallel score-matrix build: examples are swept in
    /// cache-sized blocks fanned across `pool`; inside a block every base
    /// model scores the same L2-resident window of rows (trees through
    /// the [`TreeSoa`] batch kernel, lattices through
    /// `Lattice::eval_block`). Model evaluations are pure per example,
    /// so the result is identical to the serial row-at-a-time build at
    /// every thread count.
    pub fn score_matrix_par(&self, ds: &Dataset, pool: &Pool) -> ScoreMatrix {
        let t = self.models.len();
        let n = ds.n;
        let d = ds.d;
        // SoA mirrors built once, shared read-only by every block task.
        let soa = self.soa_mirrors();
        // Blocks are scored in bounded waves and scattered (then dropped)
        // between waves, so the transient block-major copies hold
        // O(threads · SCORE_BLOCK · T) floats — not a second full N×T
        // matrix, which at T=500 / N≈1M would double a ~2 GB build.
        let mut cols = vec![0f32; n * t];
        let n_blocks = n.div_ceil(SCORE_BLOCK);
        let wave = (pool.n_threads() * 4).max(1);
        let mut b0 = 0usize;
        while b0 < n_blocks {
            let b1 = (b0 + wave).min(n_blocks);
            let blocks = pool.par_map_indexed(b1 - b0, 1, |bi| {
                let lo = (b0 + bi) * SCORE_BLOCK;
                let hi = (lo + SCORE_BLOCK).min(n);
                let bn = hi - lo;
                let xblk = &ds.x[lo * d..hi * d];
                // Model-major scores for this block's rows.
                let mut out = vec![0f32; t * bn];
                for (ti, m) in self.models.iter().enumerate() {
                    let dst = &mut out[ti * bn..(ti + 1) * bn];
                    match (&soa[ti], m) {
                        (Some(s), _) => s.eval_batch(xblk, d, dst),
                        (None, BaseModel::Lattice(l)) => l.eval_block(xblk, d, dst),
                        (None, BaseModel::Tree(_)) => {
                            unreachable!("trees always have a SoA mirror")
                        }
                    }
                }
                (lo, bn, out)
            });
            // Scatter this wave into column-major storage.
            for (lo, bn, out) in blocks {
                for ti in 0..t {
                    cols[ti * n + lo..ti * n + lo + bn]
                        .copy_from_slice(&out[ti * bn..(ti + 1) * bn]);
                }
            }
            b0 = b1;
        }
        ScoreMatrix::new(n, t, cols, self.bias, self.beta, self.costs.clone())
    }

    /// Truncated ensemble containing only the first `k` models (used by the
    /// "train a smaller ensemble" baseline in Figure 1 for GBTs, whose
    /// prefix is itself a valid boosted model).
    pub fn prefix(&self, k: usize) -> Ensemble {
        Ensemble {
            name: format!("{}-first{k}", self.name),
            models: self.models[..k.min(self.models.len())].to_vec(),
            bias: self.bias,
            beta: self.beta,
            costs: self.costs[..k.min(self.costs.len())].to_vec(),
        }
    }

    // ---- serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("bias", Json::Num(self.bias as f64)),
            ("beta", Json::Num(self.beta as f64)),
            ("costs", Json::arr_f32(&self.costs)),
            (
                "models",
                Json::Arr(self.models.iter().map(|m| m.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Ensemble, QwycError> {
        let models = v
            .req("models")?
            .as_arr()?
            .iter()
            .map(BaseModel::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let costs = v.req("costs")?.as_vec_f32()?;
        if costs.len() != models.len() {
            return Err(QwycError::Schema("costs/models length mismatch".into()));
        }
        Ok(Ensemble {
            name: v.req("name")?.as_str()?.to_string(),
            models,
            bias: v.req("bias")?.as_f64()? as f32,
            beta: v.req("beta")?.as_f64()? as f32,
            costs,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &std::path::Path) -> Result<Ensemble, QwycError> {
        Ensemble::from_json(&crate::util::json::read_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::model::Lattice;

    fn toy_ensemble() -> Ensemble {
        // Two 1-feature lattices: f0(x)=x0 (θ=[0,1]), f1(x)=1-x1 (θ=[1,0]).
        let l0 = Lattice::from_params(vec![0], vec![0.0, 1.0]);
        let l1 = Lattice::from_params(vec![1], vec![1.0, 0.0]);
        Ensemble::new(
            "toy",
            vec![BaseModel::Lattice(l0), BaseModel::Lattice(l1)],
            0.0,
            1.0,
        )
    }

    #[test]
    fn eval_full_sums_models() {
        let e = toy_ensemble();
        let x = [0.25f32, 0.5];
        // 0.25 + (1 - 0.5) = 0.75
        assert!((e.eval_full(&x) - 0.75).abs() < 1e-6);
        assert!(!e.classify_full(&x));
        assert!(e.classify_full(&[1.0, 0.0]));
    }

    #[test]
    fn score_matrix_matches_eval() {
        let e = toy_ensemble();
        let mut ds = Dataset::new("t", 2);
        ds.push(&[0.1, 0.9], 0.0);
        ds.push(&[0.8, 0.2], 1.0);
        let sm = e.score_matrix(&ds);
        for i in 0..ds.n {
            for t in 0..e.len() {
                assert!((sm.score(i, t) - e.models[t].eval(ds.row(i))).abs() < 1e-6);
            }
            assert!((sm.full_score(i) - e.eval_full(ds.row(i))).abs() < 1e-5);
        }
    }

    #[test]
    fn json_roundtrip() {
        let e = toy_ensemble();
        let back = Ensemble::from_json(&e.to_json()).unwrap();
        assert_eq!(back.len(), 2);
        let x = [0.3f32, 0.6];
        assert!((back.eval_full(&x) - e.eval_full(&x)).abs() < 1e-6);
        assert_eq!(back.beta, e.beta);
    }

    #[test]
    fn prefix_truncates() {
        let e = toy_ensemble();
        let p = e.prefix(1);
        assert_eq!(p.len(), 1);
        assert!((p.eval_full(&[0.5, 0.5]) - 0.5).abs() < 1e-6);
    }
}
