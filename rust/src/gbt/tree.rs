//! Regression tree: the base model of the benchmark-experiment ensembles.
//! Trees are stored as flat node arrays; evaluation is a simple root-to-leaf
//! walk on raw feature values (split thresholds are stored in feature units,
//! so no binning is needed at serving time).
//!
//! Batched evaluation goes through [`TreeSoa`], a structure-of-arrays
//! mirror of the node table that advances a group of [`SOA_LANES`]
//! examples one tree level per step — the independent root-to-leaf walks
//! interleave, so the out-of-order core overlaps their pointer-chasing
//! loads instead of stalling on one chain at a time (the blocked-traversal
//! idea behind QuickScorer-family tree servers). The walk is branchless:
//! leaves are self-loop sentinels and every lane runs exactly `depth`
//! steps, so the inner loop is a fixed-trip-count compare+select chain.

use crate::error::QwycError;
use crate::util::json::Json;
use crate::util::simd;

// The quantized walk stages per-lane node fields into fixed arrays for
// the SIMD select; its lane width must match the walk's.
const _: () = assert!(SOA_LANES == simd::SELECT_LANES);

/// One node. Leaves have `feature == u32::MAX` and carry `value`.
///
/// `#[repr(C)]` because this exact 16-byte record is what the
/// `qwyc-plan-bin-v1` artifact stores for tree payloads (see
/// `plan/binary.rs`); the field order is part of the on-disk format and
/// is pinned by const assertions in `plan/compiled.rs`.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct Node {
    /// Split feature, or `u32::MAX` for a leaf.
    pub feature: u32,
    /// Go left iff `x[feature] <= threshold`.
    pub threshold: f32,
    /// Index of left child; right child is `left + 1`.
    pub left: u32,
    /// Leaf value (0.0 on internal nodes).
    pub value: f32,
}

const LEAF: u32 = u32::MAX;

impl Node {
    pub fn leaf(value: f32) -> Node {
        Node { feature: LEAF, threshold: 0.0, left: 0, value }
    }

    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.feature == LEAF
    }
}

/// A binary regression tree.
#[derive(Clone, Debug)]
pub struct Tree {
    /// Flat node array. Invariant (checked by [`Tree::validate`], upheld
    /// by the trainer and enforced at [`Tree::from_json`]): every
    /// internal node's children are in bounds and strictly after the
    /// node. Code that mutates this field directly must preserve it —
    /// [`Tree::eval`]'s unchecked walk relies on it.
    pub nodes: Vec<Node>,
}

impl Tree {
    pub fn single_leaf(value: f32) -> Tree {
        Tree { nodes: vec![Node::leaf(value)] }
    }

    /// Evaluate on one example.
    ///
    /// The unchecked child access relies on the [`Tree::validate`]
    /// invariant (children exist and sit strictly after their parent, so
    /// the walk is in-bounds and terminating). Trainer-built trees hold
    /// it by construction and deserialized trees are rejected at
    /// [`Tree::from_json`] if they violate it; code mutating the pub
    /// `nodes` field directly is responsible for preserving it (see the
    /// field docs).
    #[inline]
    pub fn eval(&self, x: &[f32]) -> f32 {
        let mut idx = 0usize;
        loop {
            let node = unsafe { self.nodes.get_unchecked(idx) };
            if node.is_leaf() {
                return node.value;
            }
            let v = x[node.feature as usize];
            idx = if v <= node.threshold { node.left as usize } else { node.left as usize + 1 };
        }
    }

    /// Structural soundness check for the flat node array: the tree is
    /// non-empty and every internal node's children are in bounds and
    /// strictly after the node itself (⇒ the eval walk terminates and
    /// never indexes out of range, which is what makes the
    /// `get_unchecked` in [`Tree::eval`] sound). Feature indices cannot
    /// be range-checked here — the tree does not know the feature count —
    /// but feature lookups in eval are checked slice accesses.
    pub fn validate(&self) -> Result<(), QwycError> {
        if self.nodes.is_empty() {
            return Err(QwycError::Validate("empty tree".into()));
        }
        for (i, node) in self.nodes.iter().enumerate() {
            if node.is_leaf() {
                continue;
            }
            let l = node.left as usize;
            if l <= i {
                return Err(QwycError::Validate(format!(
                    "node {i}: left child {l} does not follow its parent"
                )));
            }
            if l + 1 >= self.nodes.len() {
                return Err(QwycError::Validate(format!(
                    "node {i}: children {l},{} out of bounds ({} nodes)",
                    l + 1,
                    self.nodes.len()
                )));
            }
        }
        Ok(())
    }

    /// Build the structure-of-arrays mirror for batched evaluation.
    ///
    /// Leaves become *self-loop sentinels*: `left == right == self`, with
    /// feature index 0 (an always-in-bounds fetch whose value is unused).
    /// A lane that reaches a leaf before the fixed-depth walk ends just
    /// keeps re-selecting the same node, so the batched walk needs no
    /// per-lane done flags or data-dependent exits.
    pub fn to_soa(&self) -> TreeSoa {
        let min_features = self
            .nodes
            .iter()
            .filter(|n| !n.is_leaf())
            .map(|n| n.feature as usize + 1)
            .max()
            .unwrap_or(0);
        let n = self.nodes.len();
        let mut feature = Vec::with_capacity(n);
        let mut threshold = Vec::with_capacity(n);
        let mut left = Vec::with_capacity(n);
        let mut right = Vec::with_capacity(n);
        let mut value = Vec::with_capacity(n);
        for (i, nd) in self.nodes.iter().enumerate() {
            if nd.is_leaf() {
                feature.push(0);
                threshold.push(0.0);
                left.push(i as u32);
                right.push(i as u32);
            } else {
                feature.push(nd.feature);
                threshold.push(nd.threshold);
                left.push(nd.left);
                right.push(nd.left + 1);
            }
            value.push(nd.value);
        }
        TreeSoa {
            feature,
            threshold,
            left,
            right,
            value,
            qthreshold: Vec::new(),
            depth: self.depth(),
            min_features,
        }
    }

    /// Batched evaluation of `out.len()` consecutive examples from the
    /// row-major feature block `x` (`x[i*d..][..d]` is example i).
    /// Convenience wrapper that builds the SoA mirror per call; hot paths
    /// should build [`TreeSoa`] once and reuse it.
    pub fn eval_batch(&self, x: &[f32], d: usize, out: &mut [f32]) {
        self.to_soa().eval_batch(x, d, out);
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], idx: usize) -> usize {
            let n = &nodes[idx];
            if n.is_leaf() {
                0
            } else {
                1 + rec(nodes, n.left as usize).max(rec(nodes, n.left as usize + 1))
            }
        }
        rec(&self.nodes, 0)
    }

    /// Scale all leaf values (used to apply the boosting learning rate once
    /// at the end of tree construction).
    pub fn scale_leaves(&mut self, factor: f32) {
        for n in self.nodes.iter_mut() {
            if n.is_leaf() {
                n.value *= factor;
            }
        }
    }

    pub fn to_json(&self) -> Json {
        // Compact parallel-array encoding.
        let feats: Vec<f64> = self.nodes.iter().map(|n| n.feature as f64).collect();
        let thr: Vec<f32> = self.nodes.iter().map(|n| n.threshold).collect();
        let left: Vec<f64> = self.nodes.iter().map(|n| n.left as f64).collect();
        let val: Vec<f32> = self.nodes.iter().map(|n| n.value).collect();
        Json::obj(vec![
            ("feature", Json::arr_f64(&feats)),
            ("threshold", Json::arr_f32(&thr)),
            ("left", Json::arr_f64(&left)),
            ("value", Json::arr_f32(&val)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Tree, QwycError> {
        let feats = v.req("feature")?.as_arr()?;
        let thr = v.req("threshold")?.as_vec_f32()?;
        let left = v.req("left")?.as_arr()?;
        let val = v.req("value")?.as_vec_f32()?;
        if feats.len() != thr.len() || thr.len() != left.len() || left.len() != val.len() {
            return Err(QwycError::Schema("tree arrays length mismatch".into()));
        }
        let mut nodes = Vec::with_capacity(feats.len());
        for i in 0..feats.len() {
            nodes.push(Node {
                feature: feats[i].as_f64()? as u32,
                threshold: thr[i],
                left: left[i].as_f64()? as u32,
                value: val[i],
            });
        }
        let tree = Tree { nodes };
        tree.validate()?;
        Ok(tree)
    }
}

/// Number of independent root-to-leaf walks advanced together by the SoA
/// kernel. 16 in-flight loads cover the L2 latency of a depth-5 walk
/// without spilling the lane state out of registers/L1.
pub const SOA_LANES: usize = 16;

/// Structure-of-arrays node table: one parallel array per field, so the
/// batched walk touches only the fields it needs per step and the lane
/// state stays dense.
///
/// Unlike the AoS [`Node`] table, the SoA bank stores an explicit
/// `right` array and encodes leaves as self-loop sentinels
/// (`left == right == self`, feature 0). Together with the recorded max
/// `depth`, the walk becomes a fixed-trip-count select chain with no
/// data-dependent branches: every lane runs exactly `depth` steps and
/// early-arriving lanes idle harmlessly on their leaf.
#[derive(Clone, Debug)]
pub struct TreeSoa {
    feature: Vec<u32>,
    threshold: Vec<f32>,
    left: Vec<u32>,
    right: Vec<u32>,
    value: Vec<f32>,
    /// Quantized thresholds: `qthreshold[i]` is the bin index k of
    /// `threshold[i]` in its feature's edge table, chosen so that
    /// `bin(x) <= k ⟺ x <= threshold[i]` (0 on leaf sentinels, whose
    /// compares never change the walk). Empty until
    /// [`TreeSoa::quantize_with`] succeeds — the raw f32 walk is always
    /// available.
    qthreshold: Vec<u16>,
    /// Maximum root-to-leaf depth: the fixed trip count of the walk.
    depth: usize,
    /// 1 + the largest split-feature index (0 for all-leaf trees): the
    /// narrowest feature vector this tree can be evaluated on. Checked
    /// once per batch so an out-of-range feature fails loudly — the
    /// scalar `Tree::eval` path panics on `x[feature]`, and a silent
    /// neighbor-row read here would diverge from it.
    min_features: usize,
}

impl TreeSoa {
    /// Evaluate `out.len()` consecutive examples from the row-major block
    /// `x` (`x[i*d..][..d]` is example i): `out[i] = tree(x_i)`.
    pub fn eval_batch(&self, x: &[f32], d: usize, out: &mut [f32]) {
        let n = out.len();
        assert!(d >= self.min_features, "tree needs {} features, rows have {d}", self.min_features);
        debug_assert!(x.len() >= n * d);
        let mut base = 0usize;
        let mut rows = [0u32; SOA_LANES];
        while base + SOA_LANES <= n {
            for (lane, r) in rows.iter_mut().enumerate() {
                *r = (base + lane) as u32;
            }
            let chunk: &mut [f32; SOA_LANES] =
                (&mut out[base..base + SOA_LANES]).try_into().unwrap();
            self.walk16(x, d, &rows, chunk);
            base += SOA_LANES;
        }
        for (i, slot) in out.iter_mut().enumerate().skip(base) {
            *slot = self.walk_one(x, d, i as u32);
        }
    }

    /// Evaluate the gathered examples `rows` (indices into the row-major
    /// block `x`): `out[j] = tree(x_{rows[j]})`. This is the early-exit
    /// engine's shape — the active set shrinks position by position, so
    /// rows are scattered.
    pub fn eval_indexed(&self, x: &[f32], d: usize, rows: &[u32], out: &mut [f32]) {
        assert_eq!(rows.len(), out.len());
        assert!(d >= self.min_features, "tree needs {} features, rows have {d}", self.min_features);
        let mut base = 0usize;
        while base + SOA_LANES <= rows.len() {
            let lanes: &[u32; SOA_LANES] = rows[base..base + SOA_LANES].try_into().unwrap();
            let chunk: &mut [f32; SOA_LANES] =
                (&mut out[base..base + SOA_LANES]).try_into().unwrap();
            self.walk16(x, d, lanes, chunk);
            base += SOA_LANES;
        }
        for (slot, &row) in out.iter_mut().zip(rows.iter()).skip(base) {
            *slot = self.walk_one(x, d, row);
        }
    }

    /// Install quantized thresholds: `bin_of_threshold(feature, t)`
    /// must return the bin k of threshold t in feature's edge table
    /// (`bin(x) <= k ⟺ x <= t`), or `None` if t is unquantizable. On
    /// any `None` the bank is left unquantized and `false` is returned;
    /// leaf sentinels (self-loops) take bin 0, which is never acted on.
    pub fn quantize_with(
        &mut self,
        bin_of_threshold: impl Fn(usize, f32) -> Option<u16>,
    ) -> bool {
        let mut q = Vec::with_capacity(self.left.len());
        for (i, &l) in self.left.iter().enumerate() {
            if l as usize == i {
                q.push(0); // leaf self-loop: compare result is ignored
            } else {
                match bin_of_threshold(self.feature[i] as usize, self.threshold[i]) {
                    Some(k) => q.push(k),
                    None => {
                        self.qthreshold.clear();
                        return false;
                    }
                }
            }
        }
        self.qthreshold = q;
        true
    }

    /// Has [`TreeSoa::quantize_with`] installed a quantized bank?
    pub fn is_quantized(&self) -> bool {
        !self.qthreshold.is_empty()
    }

    /// The quantized threshold bank (empty when unquantized) — the
    /// `quant_nodes` payload of the binary artifact.
    pub fn qthresholds(&self) -> &[u16] {
        &self.qthreshold
    }

    /// [`TreeSoa::eval_indexed`] over pre-quantized feature rows: the
    /// gathered examples `rows` index the row-major u16 bin block `qx`
    /// (same `n × d` layout as the raw rows, quantized once per
    /// request). Requires [`TreeSoa::is_quantized`]. Outcomes are
    /// bitwise-identical to the raw walk: the per-node compare
    /// `bin(x) <= qthreshold` routes exactly like `x <= threshold`
    /// (NaN carries the `NAN_BIN` sentinel and routes right), and leaf
    /// values are the same f32s.
    pub fn eval_indexed_quant(&self, qx: &[u16], d: usize, rows: &[u32], out: &mut [f32]) {
        assert_eq!(rows.len(), out.len());
        assert!(self.is_quantized(), "eval_indexed_quant on an unquantized bank");
        assert!(d >= self.min_features, "tree needs {} features, rows have {d}", self.min_features);
        let mut base = 0usize;
        while base + SOA_LANES <= rows.len() {
            let lanes: &[u32; SOA_LANES] = rows[base..base + SOA_LANES].try_into().unwrap();
            let chunk: &mut [f32; SOA_LANES] =
                (&mut out[base..base + SOA_LANES]).try_into().unwrap();
            self.walk16q(qx, d, lanes, chunk);
            base += SOA_LANES;
        }
        for (slot, &row) in out.iter_mut().zip(rows.iter()).skip(base) {
            *slot = self.walk_one_q(qx, d, row);
        }
    }

    /// Quantized [`TreeSoa::walk16`]: per level, the per-lane node
    /// fields are staged into stack arrays with scalar loads (the
    /// addresses are data-dependent; see `util/simd.rs` on why there
    /// are no gathers) and the compare+select chain runs as one SIMD
    /// [`simd::select16`] call.
    #[inline]
    fn walk16q(&self, qx: &[u16], d: usize, rows: &[u32; SOA_LANES], out: &mut [f32; SOA_LANES]) {
        let mut idx = [0u32; SOA_LANES];
        let mut qv = [0u32; SOA_LANES];
        let mut qt = [0u32; SOA_LANES];
        let mut lf = [0u32; SOA_LANES];
        let mut rt = [0u32; SOA_LANES];
        for _ in 0..self.depth {
            for lane in 0..SOA_LANES {
                let node = idx[lane] as usize;
                qv[lane] = qx[rows[lane] as usize * d + self.feature[node] as usize] as u32;
                qt[lane] = self.qthreshold[node] as u32;
                lf[lane] = self.left[node];
                rt[lane] = self.right[node];
            }
            simd::select16(&qv, &qt, &lf, &rt, &mut idx);
        }
        for lane in 0..SOA_LANES {
            out[lane] = self.value[idx[lane] as usize];
        }
    }

    /// Scalar quantized walk for tail lanes — the integer twin of
    /// [`TreeSoa::walk_one`].
    #[inline]
    fn walk_one_q(&self, qx: &[u16], d: usize, row: u32) -> f32 {
        let mut idx = 0u32;
        for _ in 0..self.depth {
            let node = idx as usize;
            let qv = qx[row as usize * d + self.feature[node] as usize];
            idx = if qv <= self.qthreshold[node] { self.left[node] } else { self.right[node] };
        }
        self.value[idx as usize]
    }

    /// Advance [`SOA_LANES`] root-to-leaf walks in lockstep for exactly
    /// `depth` levels. There are no per-lane done flags and no
    /// data-dependent exits: leaves are self-loop sentinels (see
    /// [`Tree::to_soa`]), so a lane that lands early keeps re-selecting
    /// the same node. The inner loop is a fixed-trip-count compare+select
    /// chain over parallel arrays — branchless, so the compiler can turn
    /// it into gathers + blends where the target supports them, and the
    /// independent lanes keep the out-of-order core's loads overlapped.
    #[inline]
    fn walk16(&self, x: &[f32], d: usize, rows: &[u32; SOA_LANES], out: &mut [f32; SOA_LANES]) {
        let mut idx = [0u32; SOA_LANES];
        for _ in 0..self.depth {
            for lane in 0..SOA_LANES {
                let node = idx[lane] as usize;
                let v = x[rows[lane] as usize * d + self.feature[node] as usize];
                // NaN compares false ⇒ goes right, matching `Tree::eval`.
                idx[lane] =
                    if v <= self.threshold[node] { self.left[node] } else { self.right[node] };
            }
        }
        for lane in 0..SOA_LANES {
            out[lane] = self.value[idx[lane] as usize];
        }
    }

    /// Scalar fixed-depth walk for the tail lanes of a partial group —
    /// the same select chain as [`TreeSoa::walk16`], one lane wide, so
    /// small active sets don't pay for padded lanes they don't use.
    #[inline]
    fn walk_one(&self, x: &[f32], d: usize, row: u32) -> f32 {
        let mut idx = 0u32;
        for _ in 0..self.depth {
            let node = idx as usize;
            let v = x[row as usize * d + self.feature[node] as usize];
            idx = if v <= self.threshold[node] { self.left[node] } else { self.right[node] };
        }
        self.value[idx as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x0 <= 0.5 ? (x1 <= 0.3 ? 1.0 : 2.0) : 3.0
    fn stump2() -> Tree {
        Tree {
            nodes: vec![
                Node { feature: 0, threshold: 0.5, left: 1, value: 0.0 },
                Node { feature: 1, threshold: 0.3, left: 3, value: 0.0 },
                Node::leaf(3.0),
                Node::leaf(1.0),
                Node::leaf(2.0),
            ],
        }
    }

    #[test]
    fn eval_walks_correctly() {
        let t = stump2();
        assert_eq!(t.eval(&[0.4, 0.2]), 1.0);
        assert_eq!(t.eval(&[0.4, 0.9]), 2.0);
        assert_eq!(t.eval(&[0.9, 0.0]), 3.0);
        // Boundary: <= goes left.
        assert_eq!(t.eval(&[0.5, 0.3]), 1.0);
    }

    #[test]
    fn depth_and_leaves() {
        let t = stump2();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(Tree::single_leaf(1.0).depth(), 0);
    }

    #[test]
    fn scale_leaves_only() {
        let mut t = stump2();
        t.scale_leaves(0.1);
        assert!((t.eval(&[0.9, 0.0]) - 0.3).abs() < 1e-7);
        assert_eq!(t.nodes[0].threshold, 0.5); // split untouched
    }

    #[test]
    fn json_roundtrip() {
        let t = stump2();
        let back = Tree::from_json(&t.to_json()).unwrap();
        for x in [[0.1f32, 0.1], [0.4, 0.9], [0.9, 0.5]] {
            assert_eq!(t.eval(&x), back.eval(&x));
        }
    }

    #[test]
    fn malformed_json_tree_is_rejected_not_ub() {
        // Children out of bounds: left = 7 in a 5-node tree. Without
        // validation this would make eval's get_unchecked UB.
        let mut t = stump2();
        t.nodes[1].left = 7;
        assert!(t.validate().is_err());
        assert!(Tree::from_json(&t.to_json()).is_err());
        // Child index not strictly after its parent: a 0-cycle at the root.
        let mut t = stump2();
        t.nodes[0].left = 0;
        assert!(t.validate().is_err());
        assert!(Tree::from_json(&t.to_json()).is_err());
        // The well-formed original still validates and round-trips.
        assert!(stump2().validate().is_ok());
        assert!(Tree::from_json(&stump2().to_json()).is_ok());
    }

    #[test]
    fn soa_batch_matches_scalar_eval() {
        let t = stump2();
        let soa = t.to_soa();
        // 37 rows (exercises the partial final lane group), d = 2.
        let mut x = Vec::new();
        for i in 0..37 {
            x.push((i as f32 * 0.037) % 1.0);
            x.push((i as f32 * 0.101) % 1.0);
        }
        let mut out = vec![0f32; 37];
        soa.eval_batch(&x, 2, &mut out);
        for i in 0..37 {
            assert_eq!(out[i], t.eval(&x[i * 2..(i + 1) * 2]), "row {i}");
        }
        // Indexed (gathered) variant on a scattered subset.
        let rows: Vec<u32> = vec![36, 0, 17, 17, 5, 30, 2];
        let mut out2 = vec![0f32; rows.len()];
        soa.eval_indexed(&x, 2, &rows, &mut out2);
        for (j, &i) in rows.iter().enumerate() {
            let i = i as usize;
            assert_eq!(out2[j], t.eval(&x[i * 2..(i + 1) * 2]), "gathered row {i}");
        }
        // Convenience wrapper agrees too.
        let mut out3 = vec![0f32; 37];
        t.eval_batch(&x, 2, &mut out3);
        assert_eq!(out, out3);
    }

    #[test]
    fn soa_handles_leaf_only_trees_and_nan_features() {
        // Depth-0 tree: the fixed-depth walk runs zero steps and must
        // never fetch a feature (d = 0 rows are legal here).
        let leaf = Tree::single_leaf(7.5).to_soa();
        let mut out = vec![0f32; 19];
        leaf.eval_batch(&[], 0, &mut out);
        assert!(out.iter().all(|&v| v == 7.5));
        // NaN feature values: the select chain's `v <= thr` compares
        // false, so NaN routes right — exactly like the scalar walk.
        let t = stump2();
        let soa = t.to_soa();
        let x = [f32::NAN, 0.2, 0.4, f32::NAN, 0.4, 0.2];
        let mut got = vec![0f32; 3];
        soa.eval_batch(&x, 2, &mut got);
        for i in 0..3 {
            assert_eq!(got[i], t.eval(&x[i * 2..(i + 1) * 2]), "row {i}");
        }
    }

    /// Quantized walk vs raw walk, bit for bit, on rows that include
    /// threshold-equal values, NaN (sentinel bin, routes right), and
    /// ±∞ — across full 16-lane groups and the scalar tail.
    #[test]
    fn quantized_walk_matches_raw_walk_bitwise() {
        let t = stump2();
        let mut soa = t.to_soa();
        assert!(!soa.is_quantized());
        let edges: [Vec<f32>; 2] = [vec![0.5], vec![0.3]];
        assert!(soa.quantize_with(|f, thr| {
            edges[f].iter().position(|&e| e == thr).map(|k| k as u16)
        }));
        assert!(soa.is_quantized());
        assert_eq!(soa.qthresholds().len(), 5);
        let mut x = Vec::new();
        for i in 0..37 {
            x.push(match i % 5 {
                0 => 0.5,
                1 => f32::NAN,
                2 => f32::INFINITY,
                _ => (i as f32 * 0.037) % 1.0,
            });
            x.push(match i % 4 {
                0 => 0.3,
                1 => f32::NEG_INFINITY,
                _ => (i as f32 * 0.101) % 1.0,
            });
        }
        // bin(x) = #{e < x}, NaN ⇒ sentinel — quant::FeatureQuant's rule.
        let bin = |es: &[f32], v: f32| -> u16 {
            if v.is_nan() {
                u16::MAX
            } else {
                es.iter().filter(|&&e| e < v).count() as u16
            }
        };
        let qx: Vec<u16> =
            x.iter().enumerate().map(|(p, &v)| bin(&edges[p % 2], v)).collect();
        // Scattered rows: two full lane groups plus a tail.
        let rows: Vec<u32> = (0..37u32).map(|i| 36 - i).collect();
        let mut raw = vec![0f32; rows.len()];
        let mut qnt = vec![0f32; rows.len()];
        soa.eval_indexed(&x, 2, &rows, &mut raw);
        soa.eval_indexed_quant(&qx, 2, &rows, &mut qnt);
        for j in 0..rows.len() {
            assert_eq!(raw[j].to_bits(), qnt[j].to_bits(), "gathered lane {j}");
        }
        // A failed quantization leaves the bank raw.
        let mut soa2 = t.to_soa();
        assert!(!soa2.quantize_with(|_, _| None));
        assert!(!soa2.is_quantized());
    }

    #[test]
    #[should_panic(expected = "features")]
    fn soa_rejects_too_narrow_rows() {
        // stump2 splits on feature 1; d = 1 rows must fail loudly (the
        // scalar path would panic indexing x[1]) instead of silently
        // reading a neighboring row's value.
        let soa = stump2().to_soa();
        let x = vec![0.4f32; 8];
        let mut out = vec![0f32; 8];
        soa.eval_batch(&x, 1, &mut out);
    }
}
