//! Regression tree: the base model of the benchmark-experiment ensembles.
//! Trees are stored as flat node arrays; evaluation is a simple root-to-leaf
//! walk on raw feature values (split thresholds are stored in feature units,
//! so no binning is needed at serving time).

use crate::util::json::Json;

/// One node. Leaves have `feature == u32::MAX` and carry `value`.
#[derive(Clone, Copy, Debug)]
pub struct Node {
    /// Split feature, or `u32::MAX` for a leaf.
    pub feature: u32,
    /// Go left iff `x[feature] <= threshold`.
    pub threshold: f32,
    /// Index of left child; right child is `left + 1`.
    pub left: u32,
    /// Leaf value (0.0 on internal nodes).
    pub value: f32,
}

const LEAF: u32 = u32::MAX;

impl Node {
    pub fn leaf(value: f32) -> Node {
        Node { feature: LEAF, threshold: 0.0, left: 0, value }
    }

    #[inline]
    pub fn is_leaf(&self) -> bool {
        self.feature == LEAF
    }
}

/// A binary regression tree.
#[derive(Clone, Debug)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

impl Tree {
    pub fn single_leaf(value: f32) -> Tree {
        Tree { nodes: vec![Node::leaf(value)] }
    }

    /// Evaluate on one example.
    #[inline]
    pub fn eval(&self, x: &[f32]) -> f32 {
        let mut idx = 0usize;
        loop {
            let node = unsafe { self.nodes.get_unchecked(idx) };
            if node.is_leaf() {
                return node.value;
            }
            let v = x[node.feature as usize];
            idx = if v <= node.threshold { node.left as usize } else { node.left as usize + 1 };
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], idx: usize) -> usize {
            let n = &nodes[idx];
            if n.is_leaf() {
                0
            } else {
                1 + rec(nodes, n.left as usize).max(rec(nodes, n.left as usize + 1))
            }
        }
        rec(&self.nodes, 0)
    }

    /// Scale all leaf values (used to apply the boosting learning rate once
    /// at the end of tree construction).
    pub fn scale_leaves(&mut self, factor: f32) {
        for n in self.nodes.iter_mut() {
            if n.is_leaf() {
                n.value *= factor;
            }
        }
    }

    pub fn to_json(&self) -> Json {
        // Compact parallel-array encoding.
        let feats: Vec<f64> = self.nodes.iter().map(|n| n.feature as f64).collect();
        let thr: Vec<f32> = self.nodes.iter().map(|n| n.threshold).collect();
        let left: Vec<f64> = self.nodes.iter().map(|n| n.left as f64).collect();
        let val: Vec<f32> = self.nodes.iter().map(|n| n.value).collect();
        Json::obj(vec![
            ("feature", Json::arr_f64(&feats)),
            ("threshold", Json::arr_f32(&thr)),
            ("left", Json::arr_f64(&left)),
            ("value", Json::arr_f32(&val)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Tree, String> {
        let feats = v.req("feature")?.as_arr()?;
        let thr = v.req("threshold")?.as_vec_f32()?;
        let left = v.req("left")?.as_arr()?;
        let val = v.req("value")?.as_vec_f32()?;
        if feats.len() != thr.len() || thr.len() != left.len() || left.len() != val.len() {
            return Err("tree arrays length mismatch".into());
        }
        let mut nodes = Vec::with_capacity(feats.len());
        for i in 0..feats.len() {
            nodes.push(Node {
                feature: feats[i].as_f64()? as u32,
                threshold: thr[i],
                left: left[i].as_f64()? as u32,
                value: val[i],
            });
        }
        if nodes.is_empty() {
            return Err("empty tree".into());
        }
        Ok(Tree { nodes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x0 <= 0.5 ? (x1 <= 0.3 ? 1.0 : 2.0) : 3.0
    fn stump2() -> Tree {
        Tree {
            nodes: vec![
                Node { feature: 0, threshold: 0.5, left: 1, value: 0.0 },
                Node { feature: 1, threshold: 0.3, left: 3, value: 0.0 },
                Node::leaf(3.0),
                Node::leaf(1.0),
                Node::leaf(2.0),
            ],
        }
    }

    #[test]
    fn eval_walks_correctly() {
        let t = stump2();
        assert_eq!(t.eval(&[0.4, 0.2]), 1.0);
        assert_eq!(t.eval(&[0.4, 0.9]), 2.0);
        assert_eq!(t.eval(&[0.9, 0.0]), 3.0);
        // Boundary: <= goes left.
        assert_eq!(t.eval(&[0.5, 0.3]), 1.0);
    }

    #[test]
    fn depth_and_leaves() {
        let t = stump2();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(Tree::single_leaf(1.0).depth(), 0);
    }

    #[test]
    fn scale_leaves_only() {
        let mut t = stump2();
        t.scale_leaves(0.1);
        assert!((t.eval(&[0.9, 0.0]) - 0.3).abs() < 1e-7);
        assert_eq!(t.nodes[0].threshold, 0.5); // split untouched
    }

    #[test]
    fn json_roundtrip() {
        let t = stump2();
        let back = Tree::from_json(&t.to_json()).unwrap();
        for x in [[0.1f32, 0.1], [0.4, 0.9], [0.9, 0.5]] {
            assert_eq!(t.eval(&x), back.eval(&x));
        }
    }
}
