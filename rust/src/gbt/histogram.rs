//! Feature binning for histogram-based tree growth (the standard
//! LightGBM/XGBoost-hist approach): each feature is quantized once into at
//! most `max_bins` quantile bins; split finding then scans bin histograms
//! of gradient/hessian sums instead of sorted raw values.

use crate::data::Dataset;

/// Per-feature quantile binner.
#[derive(Clone, Debug)]
pub struct Binner {
    /// `edges[j]` = ascending upper-edge values for feature j; bin b covers
    /// (edges[b-1], edges[b]]. Values above the last edge go in the last bin.
    pub edges: Vec<Vec<f32>>,
    pub max_bins: usize,
}

impl Binner {
    /// Fit quantile bin edges on (a sample of) the dataset.
    pub fn fit(ds: &Dataset, max_bins: usize) -> Binner {
        assert!((2..=256).contains(&max_bins));
        let sample_cap = 100_000usize;
        let stride = (ds.n / sample_cap).max(1);
        let mut edges = Vec::with_capacity(ds.d);
        let mut vals: Vec<f32> = Vec::with_capacity(ds.n.min(sample_cap) + 1);
        for j in 0..ds.d {
            vals.clear();
            let mut i = 0;
            while i < ds.n {
                vals.push(ds.row(i)[j]);
                i += stride;
            }
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            let mut ej: Vec<f32> = if vals.len() <= max_bins {
                // Few distinct values (categorical-ish): one bin per value.
                vals.clone()
            } else {
                (1..=max_bins)
                    .map(|b| {
                        let q = b as f64 / max_bins as f64;
                        let idx = ((vals.len() - 1) as f64 * q).round() as usize;
                        vals[idx]
                    })
                    .collect()
            };
            ej.dedup();
            edges.push(ej);
        }
        Binner { edges, max_bins }
    }

    /// Number of bins for feature j.
    #[inline]
    pub fn n_bins(&self, j: usize) -> usize {
        self.edges[j].len()
    }

    /// Bin index of value v for feature j (branchless binary search).
    #[inline]
    pub fn bin(&self, j: usize, v: f32) -> u8 {
        let e = &self.edges[j];
        // partition_point: first edge >= v.
        let idx = e.partition_point(|&edge| edge < v);
        idx.min(e.len() - 1) as u8
    }

    /// Raw threshold corresponding to "bin <= b" for feature j — stored in
    /// the tree so serving needs no binner.
    #[inline]
    pub fn upper_value(&self, j: usize, b: usize) -> f32 {
        self.edges[j][b]
    }

    /// Pre-bin the whole dataset: row-major n×d bin codes.
    pub fn bin_dataset(&self, ds: &Dataset) -> Vec<u8> {
        let mut out = vec![0u8; ds.n * ds.d];
        for i in 0..ds.n {
            let row = ds.row(i);
            let dst = &mut out[i * ds.d..(i + 1) * ds.d];
            for (j, (&v, slot)) in row.iter().zip(dst.iter_mut()).enumerate() {
                *slot = self.bin(j, v);
            }
        }
        out
    }
}

/// Gradient/hessian histogram for one feature at one node.
#[derive(Clone, Debug, Default)]
pub struct FeatureHist {
    pub grad: Vec<f64>,
    pub hess: Vec<f64>,
    pub count: Vec<u32>,
}

impl FeatureHist {
    pub fn zeros(bins: usize) -> FeatureHist {
        FeatureHist { grad: vec![0.0; bins], hess: vec![0.0; bins], count: vec![0; bins] }
    }

    pub fn clear(&mut self) {
        self.grad.iter_mut().for_each(|v| *v = 0.0);
        self.hess.iter_mut().for_each(|v| *v = 0.0);
        self.count.iter_mut().for_each(|v| *v = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn uniform_ds(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut ds = Dataset::new("u", d);
        let mut row = vec![0f32; d];
        for _ in 0..n {
            for r in row.iter_mut() {
                *r = rng.f32();
            }
            ds.push(&row, 0.0);
        }
        ds
    }

    #[test]
    fn bins_are_monotone_and_bounded() {
        let ds = uniform_ds(5000, 3, 1);
        let b = Binner::fit(&ds, 64);
        for j in 0..3 {
            assert!(b.n_bins(j) <= 64);
            let b1 = b.bin(j, 0.1);
            let b2 = b.bin(j, 0.5);
            let b3 = b.bin(j, 0.9);
            assert!(b1 <= b2 && b2 <= b3);
            // Quantile bins on uniform data: roughly linear mapping.
            assert!((b.bin(j, 0.5) as f64 - 32.0).abs() < 8.0);
        }
    }

    #[test]
    fn categorical_features_get_exact_bins() {
        let mut ds = Dataset::new("c", 1);
        for i in 0..100 {
            ds.push(&[(i % 4) as f32], 0.0);
        }
        let b = Binner::fit(&ds, 64);
        assert_eq!(b.n_bins(0), 4);
        for v in 0..4 {
            assert_eq!(b.bin(0, v as f32) as usize, v);
        }
    }

    #[test]
    fn upper_value_consistent_with_bin() {
        let ds = uniform_ds(2000, 1, 2);
        let b = Binner::fit(&ds, 32);
        for bin_idx in 0..b.n_bins(0) {
            let edge = b.upper_value(0, bin_idx);
            assert!(b.bin(0, edge) as usize <= bin_idx);
            // Just above the edge must land in a later bin (except the last).
            if bin_idx + 1 < b.n_bins(0) {
                assert!(b.bin(0, edge + 1e-4) as usize > bin_idx);
            }
        }
    }

    #[test]
    fn bin_dataset_shape() {
        let ds = uniform_ds(10, 4, 3);
        let b = Binner::fit(&ds, 16);
        let codes = b.bin_dataset(&ds);
        assert_eq!(codes.len(), 40);
    }
}
