//! Gradient-boosted trees: the base-model substrate for the benchmark
//! experiments (UCI Adult / Nomao analogues, T=500 trees). Implements
//! histogram-based second-order boosting from scratch — no GBT library is
//! available offline (DESIGN.md §4).

pub mod histogram;
pub mod trainer;
pub mod tree;

pub use trainer::{train, GbtParams};
pub use tree::{Tree, TreeSoa};
