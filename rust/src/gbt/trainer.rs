//! Gradient-boosted-tree trainer (Friedman 2001) with second-order
//! (Newton) leaf values and histogram split finding — the substrate for
//! the paper's benchmark experiments (T=500 trees on Adult/Nomao-like
//! data). The sequential construction order is preserved in the returned
//! ensemble: it IS the "GBT ordering" baseline of Appendix B.

use super::histogram::{Binner, FeatureHist};
use super::tree::{Node, Tree};
use crate::data::Dataset;
use crate::ensemble::{BaseModel, Ensemble};

/// Training hyperparameters (paper: tuned over trees/depth/learning-rate;
/// defaults here are the tuned values used in EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct GbtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f32,
    /// L2 regularization on leaf values.
    pub lambda: f64,
    /// Minimum hessian mass per child.
    pub min_child_weight: f64,
    pub max_bins: usize,
    /// Minimum loss reduction to accept a split.
    pub min_gain: f64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams {
            n_trees: 500,
            max_depth: 5,
            learning_rate: 0.1,
            lambda: 1.0,
            min_child_weight: 1.0,
            max_bins: 64,
            min_gain: 1e-6,
        }
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Train a boosted ensemble with logistic loss. Returns the ensemble with
/// β = 0 (decision at probability 0.5) and the per-round train log-loss.
pub fn train(ds: &Dataset, params: &GbtParams) -> (Ensemble, Vec<f64>) {
    assert!(ds.n > 1, "need data");
    let binner = Binner::fit(ds, params.max_bins);
    let codes = binner.bin_dataset(ds);

    // Base score: log-odds of the prior.
    let p = (ds.positive_rate().clamp(1e-6, 1.0 - 1e-6)) as f32;
    let bias = (p / (1.0 - p)).ln();

    let mut margin = vec![bias; ds.n];
    let mut grad = vec![0f32; ds.n];
    let mut hess = vec![0f32; ds.n];
    let mut trees: Vec<BaseModel> = Vec::with_capacity(params.n_trees);
    let mut losses = Vec::with_capacity(params.n_trees);
    let mut builder = TreeBuilder::new(ds, &binner, &codes, params);

    for _round in 0..params.n_trees {
        // Logistic gradients: g = p - y, h = p(1-p).
        let mut loss = 0.0f64;
        for i in 0..ds.n {
            let pi = sigmoid(margin[i]);
            grad[i] = pi - ds.y[i];
            hess[i] = (pi * (1.0 - pi)).max(1e-6);
            let yi = ds.y[i];
            let pc = pi.clamp(1e-7, 1.0 - 1e-7);
            loss -= (yi * pc.ln() + (1.0 - yi) * (1.0 - pc).ln()) as f64;
        }
        losses.push(loss / ds.n as f64);

        let mut tree = builder.build(&grad, &hess);
        tree.scale_leaves(params.learning_rate);
        // Update margins using the builder's final leaf assignment (avoids
        // re-walking the tree for every example).
        builder.apply_leaf_outputs(&tree, &mut margin);
        trees.push(BaseModel::Tree(tree));
    }

    let ens = Ensemble::new(&format!("gbt-{}", ds.name), trees, bias, 0.0);
    (ens, losses)
}

/// Depth-wise histogram tree grower. Reused across rounds to avoid
/// reallocating index/histogram buffers 500 times.
struct TreeBuilder<'a> {
    ds: &'a Dataset,
    binner: &'a Binner,
    /// Row-major n×d bin codes.
    codes: &'a [u8],
    params: &'a GbtParams,
    /// Example indices, partitioned contiguously by node.
    order: Vec<u32>,
    /// Per-node (start, end) ranges into `order` for the current level.
    /// After build(), leaf ranges remain valid for apply_leaf_outputs.
    leaf_ranges: Vec<(usize, usize, usize)>, // (node_idx, start, end)
    hist: Vec<FeatureHist>,
}

#[derive(Clone, Copy)]
struct SplitCand {
    gain: f64,
    feature: usize,
    bin: usize,
    left_grad: f64,
    left_hess: f64,
}

impl<'a> TreeBuilder<'a> {
    fn new(ds: &'a Dataset, binner: &'a Binner, codes: &'a [u8], params: &'a GbtParams) -> Self {
        let hist = (0..ds.d).map(|j| FeatureHist::zeros(binner.n_bins(j))).collect();
        TreeBuilder {
            ds,
            binner,
            codes,
            params,
            order: (0..ds.n as u32).collect(),
            leaf_ranges: Vec::new(),
            hist,
        }
    }

    fn build(&mut self, grad: &[f32], hess: &[f32]) -> Tree {
        let n = self.ds.n;
        for (i, o) in self.order.iter_mut().enumerate() {
            *o = i as u32;
        }
        self.leaf_ranges.clear();

        let mut nodes: Vec<Node> = vec![Node::leaf(0.0)];
        // Frontier of (node_idx, start, end, sum_grad, sum_hess).
        let (g0, h0) = sum_gh(grad, hess, &self.order[0..n]);
        let mut frontier: Vec<(usize, usize, usize, f64, f64)> = vec![(0, 0, n, g0, h0)];

        for _depth in 0..self.params.max_depth {
            let mut next = Vec::with_capacity(frontier.len() * 2);
            for &(node_idx, start, end, sg, sh) in frontier.iter() {
                let cand = self.best_split(grad, hess, start, end, sg, sh);
                match cand {
                    Some(c) if c.gain > self.params.min_gain => {
                        // Materialize the split.
                        let mid = self.partition(start, end, c.feature, c.bin);
                        let left_idx = nodes.len();
                        nodes[node_idx] = Node {
                            feature: c.feature as u32,
                            threshold: self.binner.upper_value(c.feature, c.bin),
                            left: left_idx as u32,
                            value: 0.0,
                        };
                        nodes.push(Node::leaf(0.0));
                        nodes.push(Node::leaf(0.0));
                        next.push((left_idx, start, mid, c.left_grad, c.left_hess));
                        next.push((left_idx + 1, mid, end, sg - c.left_grad, sh - c.left_hess));
                    }
                    _ => {
                        // Finalize as a leaf.
                        nodes[node_idx].value = leaf_value(sg, sh, self.params.lambda);
                        self.leaf_ranges.push((node_idx, start, end));
                    }
                }
            }
            frontier = next;
            if frontier.is_empty() {
                break;
            }
        }
        // Remaining frontier nodes at max depth become leaves.
        for &(node_idx, start, end, sg, sh) in frontier.iter() {
            nodes[node_idx].value = leaf_value(sg, sh, self.params.lambda);
            self.leaf_ranges.push((node_idx, start, end));
        }
        Tree { nodes }
    }

    /// Add each example's leaf output (post-scaling) to `margin`, using the
    /// leaf ranges computed during build.
    fn apply_leaf_outputs(&self, tree: &Tree, margin: &mut [f32]) {
        for &(node_idx, start, end) in &self.leaf_ranges {
            let v = tree.nodes[node_idx].value;
            for &i in &self.order[start..end] {
                margin[i as usize] += v;
            }
        }
    }

    fn best_split(
        &mut self,
        grad: &[f32],
        hess: &[f32],
        start: usize,
        end: usize,
        sum_grad: f64,
        sum_hess: f64,
    ) -> Option<SplitCand> {
        if end - start < 2 || sum_hess < 2.0 * self.params.min_child_weight {
            return None;
        }
        let d = self.ds.d;
        // Build histograms for all features in one pass over the node's rows.
        for h in self.hist.iter_mut() {
            h.clear();
        }
        for &i in &self.order[start..end] {
            let i = i as usize;
            let row = &self.codes[i * d..(i + 1) * d];
            let (g, h) = (grad[i] as f64, hess[i] as f64);
            for (j, &b) in row.iter().enumerate() {
                let fh = &mut self.hist[j];
                let b = b as usize;
                fh.grad[b] += g;
                fh.hess[b] += h;
                fh.count[b] += 1;
            }
        }
        let lambda = self.params.lambda;
        let parent_score = sum_grad * sum_grad / (sum_hess + lambda);
        let mut best: Option<SplitCand> = None;
        for j in 0..d {
            let fh = &self.hist[j];
            let nb = fh.grad.len();
            let (mut lg, mut lh) = (0.0f64, 0.0f64);
            for b in 0..nb.saturating_sub(1) {
                lg += fh.grad[b];
                lh += fh.hess[b];
                let (rg, rh) = (sum_grad - lg, sum_hess - lh);
                if lh < self.params.min_child_weight || rh < self.params.min_child_weight {
                    continue;
                }
                let gain =
                    lg * lg / (lh + lambda) + rg * rg / (rh + lambda) - parent_score;
                if best.map(|c| gain > c.gain).unwrap_or(gain > 0.0) {
                    best =
                        Some(SplitCand { gain, feature: j, bin: b, left_grad: lg, left_hess: lh });
                }
            }
        }
        best
    }

    /// Stable in-place partition of order[start..end] by bin <= split_bin.
    /// Returns the boundary index.
    fn partition(&mut self, start: usize, end: usize, feature: usize, split_bin: usize) -> usize {
        let d = self.ds.d;
        let mut left: Vec<u32> = Vec::with_capacity(end - start);
        let mut right: Vec<u32> = Vec::with_capacity(end - start);
        for &i in &self.order[start..end] {
            let b = self.codes[i as usize * d + feature] as usize;
            if b <= split_bin {
                left.push(i);
            } else {
                right.push(i);
            }
        }
        let mid = start + left.len();
        self.order[start..mid].copy_from_slice(&left);
        self.order[mid..end].copy_from_slice(&right);
        mid
    }
}

#[inline]
fn leaf_value(sum_grad: f64, sum_hess: f64, lambda: f64) -> f32 {
    (-sum_grad / (sum_hess + lambda)) as f32
}

fn sum_gh(grad: &[f32], hess: &[f32], idx: &[u32]) -> (f64, f64) {
    let mut g = 0.0f64;
    let mut h = 0.0f64;
    for &i in idx {
        g += grad[i as usize] as f64;
        h += hess[i as usize] as f64;
    }
    (g, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};

    fn quick_params(n_trees: usize, depth: usize) -> GbtParams {
        GbtParams { n_trees, max_depth: depth, ..Default::default() }
    }

    #[test]
    fn loss_decreases_monotonically_early() {
        let (train_ds, _) = generate(Which::AdultLike, 1, 0.05);
        let (_, losses) = train(&train_ds, &quick_params(30, 4));
        assert!(losses.len() == 30);
        assert!(
            losses[29] < losses[0] * 0.9,
            "boosting did not reduce loss: {} -> {}",
            losses[0],
            losses[29]
        );
        // First rounds strictly improve.
        for w in losses[..10].windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "loss increased early: {w:?}");
        }
    }

    #[test]
    fn beats_majority_class_baseline() {
        let (train_ds, test_ds) = generate(Which::AdultLike, 2, 0.05);
        let (ens, _) = train(&train_ds, &quick_params(60, 4));
        let acc = ens.accuracy(&test_ds);
        let majority = 1.0 - test_ds.positive_rate();
        assert!(
            acc > majority + 0.03,
            "acc {acc:.4} vs majority {majority:.4}"
        );
    }

    #[test]
    fn trees_respect_max_depth() {
        let (train_ds, _) = generate(Which::NomaoLike, 3, 0.02);
        let (ens, _) = train(&train_ds, &quick_params(10, 3));
        for m in &ens.models {
            if let BaseModel::Tree(t) = m {
                assert!(t.depth() <= 3, "depth {}", t.depth());
            }
        }
    }

    #[test]
    fn nomao_like_is_high_accuracy() {
        let (train_ds, test_ds) = generate(Which::NomaoLike, 4, 0.1);
        let (ens, _) = train(&train_ds, &quick_params(80, 5));
        let acc = ens.accuracy(&test_ds);
        assert!(acc > 0.90, "nomao-like acc {acc:.4}");
    }

    #[test]
    fn ensemble_roundtrips_through_json() {
        let (train_ds, test_ds) = generate(Which::AdultLike, 5, 0.02);
        let (ens, _) = train(&train_ds, &quick_params(5, 3));
        let back = Ensemble::from_json(&ens.to_json()).unwrap();
        for i in 0..20.min(test_ds.n) {
            let x = test_ds.row(i);
            assert!((ens.eval_full(x) - back.eval_full(x)).abs() < 1e-6);
        }
    }
}
