//! Lattice-ensemble training: **joint** (all lattices updated together on
//! the shared logistic loss — the paper's given production models) and
//! **independent** (each lattice fit alone to the labels, then summed —
//! the paper's re-trained comparison, Experiments 5-6). Minibatch Adam.

use super::model::Lattice;
use crate::data::Dataset;
use crate::ensemble::{BaseModel, Ensemble};
use crate::util::rng::Rng;

/// Training hyperparameters.
#[derive(Clone, Debug)]
pub struct LatticeParams {
    /// Number of lattices T.
    pub n_lattices: usize,
    /// Features per lattice (RW1: 13 of 16; RW2: 8 of 30).
    pub dim: usize,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    pub l2: f64,
    pub seed: u64,
}

impl Default for LatticeParams {
    fn default() -> Self {
        LatticeParams {
            n_lattices: 5,
            dim: 13,
            steps: 400,
            batch: 128,
            lr: 0.05,
            l2: 1e-5,
            seed: 7,
        }
    }
}

/// Draw the feature subsets: distinct-seeded random k-of-D subsets (RW2's
/// "randomly generated" subsets; for RW1 the paper picks subsets maximizing
/// feature interactions — random distinct subsets exercise the same code).
pub fn make_subsets(
    n_lattices: usize,
    dim: usize,
    n_features: usize,
    seed: u64,
) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed ^ 0x5b5e75);
    (0..n_lattices)
        .map(|_| {
            let mut s = rng.choose_k(n_features, dim);
            s.sort_unstable();
            s
        })
        .collect()
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Adam state for one parameter vector.
struct Adam {
    m: Vec<f64>,
    v: Vec<f64>,
    t: i32,
    lr: f64,
}

impl Adam {
    fn new(n: usize, lr: f64) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0, lr }
    }

    fn step(&mut self, theta: &mut [f32], grad: &[f64]) {
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t);
        let bc2 = 1.0 - B2.powi(self.t);
        for i in 0..theta.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * grad[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * grad[i] * grad[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            theta[i] -= (self.lr * mh / (vh.sqrt() + EPS)) as f32;
        }
    }
}

/// Jointly train an ensemble of lattices with logistic loss on the summed
/// score. Returns (ensemble, per-eval-interval train losses).
pub fn train_joint(ds: &Dataset, params: &LatticeParams) -> (Ensemble, Vec<f64>) {
    let subsets = make_subsets(params.n_lattices, params.dim, ds.d, params.seed);
    train_with_subsets(ds, params, &subsets, true)
}

/// Independently train each lattice against the labels, then assemble the
/// additive ensemble (β scaled accordingly; see below).
pub fn train_independent(ds: &Dataset, params: &LatticeParams) -> (Ensemble, Vec<f64>) {
    let subsets = make_subsets(params.n_lattices, params.dim, ds.d, params.seed);
    train_with_subsets(ds, params, &subsets, false)
}

fn train_with_subsets(
    ds: &Dataset,
    params: &LatticeParams,
    subsets: &[Vec<usize>],
    joint: bool,
) -> (Ensemble, Vec<f64>) {
    let t_models = subsets.len();
    let prior = ds.positive_rate().clamp(1e-6, 1.0 - 1e-6) as f32;
    let logit_prior = (prior / (1.0 - prior)).ln();
    // Initialize each lattice flat at its share of the prior log-odds so
    // the untrained ensemble already matches the base rate.
    let mut lattices: Vec<Lattice> = subsets
        .iter()
        .map(|s| {
            let mut l = Lattice::zeros(s.clone());
            let init = logit_prior / t_models as f32;
            l.theta.iter_mut().for_each(|v| *v = init);
            l
        })
        .collect();

    let mut rng = Rng::new(params.seed ^ 0xada3);
    let mut adams: Vec<Adam> =
        lattices.iter().map(|l| Adam::new(l.n_vertices(), params.lr)).collect();
    let mut losses = Vec::new();
    let max_v = lattices.iter().map(|l| l.n_vertices()).max().unwrap();
    let mut w = vec![0f32; max_v];
    let mut grads: Vec<Vec<f64>> = lattices.iter().map(|l| vec![0.0; l.n_vertices()]).collect();
    let mut scratch = vec![0f32; max_v];

    // For independent training each lattice sees its own logistic loss on a
    // scaled target; we run all T in the same minibatch loop.
    for step in 0..params.steps {
        for g in grads.iter_mut() {
            g.iter_mut().for_each(|v| *v = 0.0);
        }
        let mut loss = 0.0f64;
        for _ in 0..params.batch {
            let i = rng.below(ds.n);
            let x = ds.row(i);
            let y = ds.y[i];
            if joint {
                // Shared residual: g = σ(Σ f_t) − y, dθ_tv = g · w_tv.
                let score: f32 = lattices
                    .iter()
                    .map(|l| l.eval_with_scratch(x, &mut scratch))
                    .sum();
                let p = sigmoid(score).clamp(1e-7, 1.0 - 1e-7);
                loss -= (y * p.ln() + (1.0 - y) * (1.0 - p).ln()) as f64;
                let g = (p - y) as f64;
                for (l, gl) in lattices.iter().zip(grads.iter_mut()) {
                    l.weights_into(x, &mut w);
                    for (gv, &wv) in gl.iter_mut().zip(w.iter()) {
                        *gv += g * wv as f64;
                    }
                }
            } else {
                // Per-lattice logistic fit: each f_t individually predicts
                // the label (scaled so the T-sum stays in logit range).
                for (l, gl) in lattices.iter().zip(grads.iter_mut()) {
                    let s = l.eval_with_scratch(x, &mut scratch) * t_models as f32;
                    let p = sigmoid(s).clamp(1e-7, 1.0 - 1e-7);
                    loss -= ((y * p.ln() + (1.0 - y) * (1.0 - p).ln()) / t_models as f32) as f64;
                    let g = (p - y) as f64;
                    l.weights_into(x, &mut w);
                    for (gv, &wv) in gl.iter_mut().zip(w.iter()) {
                        *gv += g * wv as f64;
                    }
                }
            }
        }
        let inv_b = 1.0 / params.batch as f64;
        for ((l, adam), gl) in lattices.iter_mut().zip(adams.iter_mut()).zip(grads.iter_mut()) {
            for (gv, &tv) in gl.iter_mut().zip(l.theta.iter()) {
                *gv = *gv * inv_b + params.l2 * tv as f64;
            }
            adam.step(&mut l.theta, gl);
        }
        if step % 20 == 0 || step + 1 == params.steps {
            losses.push(loss * inv_b);
        }
    }

    let models: Vec<BaseModel> = lattices.into_iter().map(BaseModel::Lattice).collect();
    let kind = if joint { "joint" } else { "indep" };
    // β = 0: logistic training centers the decision at score 0.
    let ens = Ensemble::new(&format!("lattice-{kind}-{}", ds.name), models, 0.0, 0.0);
    (ens, losses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};

    fn quick(n_lattices: usize, dim: usize, steps: usize) -> LatticeParams {
        LatticeParams { n_lattices, dim, steps, batch: 64, lr: 0.08, l2: 1e-5, seed: 3 }
    }

    #[test]
    fn subsets_distinct_sorted_in_range() {
        let ss = make_subsets(500, 8, 30, 1);
        assert_eq!(ss.len(), 500);
        for s in &ss {
            assert_eq!(s.len(), 8);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&f| f < 30));
        }
        // Not all identical.
        assert!(ss.iter().any(|s| s != &ss[0]));
    }

    #[test]
    fn joint_training_reduces_loss() {
        let (tr, _) = generate(Which::Rw2Like, 1, 0.02);
        let (_, losses) = train_joint(&tr, &quick(8, 4, 150));
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.98),
            "loss {:?}",
            (losses.first(), losses.last())
        );
    }

    #[test]
    fn joint_beats_prior_baseline() {
        let (tr, te) = generate(Which::Rw2Like, 2, 0.03);
        let (ens, _) = train_joint(&tr, &quick(10, 5, 300));
        let acc = ens.accuracy(&te);
        let majority = (1.0 - te.positive_rate()).max(te.positive_rate());
        assert!(acc > majority + 0.02, "acc {acc} vs majority {majority}");
    }

    #[test]
    fn independent_training_learns_signal() {
        let (tr, te) = generate(Which::Rw2Like, 3, 0.03);
        let (ens, _) = train_independent(&tr, &quick(6, 5, 300));
        let acc = ens.accuracy(&te);
        let majority = (1.0 - te.positive_rate()).max(te.positive_rate());
        assert!(acc > majority, "acc {acc} vs majority {majority}");
    }

    #[test]
    fn independent_base_models_correlate_with_full() {
        // The paper's Exp 5-6 discussion: independently trained base models
        // each correlate strongly with the full ensemble score.
        let (tr, _) = generate(Which::Rw2Like, 4, 0.02);
        let (ens, _) = train_independent(&tr, &quick(5, 5, 250));
        let sm = ens.score_matrix(&tr.take(500));
        for t in 0..ens.len() {
            let col = sm.col(t);
            let full = sm.full_scores();
            let corr = correlation(col, full);
            assert!(corr > 0.3, "model {t} corr {corr}");
        }
    }

    fn correlation(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (&x, &y) in a.iter().zip(b.iter()) {
            cov += (x as f64 - ma) * (y as f64 - mb);
            va += (x as f64 - ma).powi(2);
            vb += (y as f64 - mb).powi(2);
        }
        cov / (va.sqrt() * vb.sqrt()).max(1e-12)
    }
}
