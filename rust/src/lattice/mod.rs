//! Lattice-ensemble substrate for the real-world experiments (Exps 3-6):
//! interpolated look-up tables (Canini et al. 2016) with joint and
//! independent training. The same multilinear-interpolation schedule is
//! implemented as the L1 Pallas kernel for the AOT serving path.

pub mod model;
pub mod train;

pub use model::Lattice;
pub use train::{make_subsets, train_independent, train_joint, LatticeParams};
