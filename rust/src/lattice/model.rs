//! Lattice base models (Canini et al. 2016): multilinear interpolated
//! look-up tables over a subset of the input features. A lattice with
//! d_sub features has 2^d_sub vertex parameters θ_v; its output is
//!
//!   f(x) = Σ_v θ_v · Π_j ( x_j if v_j = 1 else 1 - x_j )
//!
//! with x restricted to the lattice's feature subset and clamped to [0,1].
//! Evaluation uses the standard iterative contraction (d_sub successive
//! linear interpolations halving the parameter buffer) — O(2^{d_sub+1})
//! FMAs — which is also exactly the schedule the L1 Pallas kernel
//! implements on the TPU side (python/compile/kernels/lattice.py).

use crate::data::Dataset;
use crate::error::QwycError;
use crate::util::json::Json;

/// A single lattice over a feature subset.
#[derive(Clone, Debug)]
pub struct Lattice {
    /// Indices into the full feature vector; `features[j]` is the feature
    /// controlling bit j of the vertex index (bit 0 = LSB).
    pub features: Vec<usize>,
    /// 2^{features.len()} vertex parameters.
    pub theta: Vec<f32>,
}

impl Lattice {
    /// Zero-initialized lattice on the given subset.
    pub fn zeros(features: Vec<usize>) -> Lattice {
        assert!(features.len() <= MAX_DIM, "lattice dim {} > MAX_DIM {MAX_DIM}", features.len());
        let v = 1usize << features.len();
        Lattice { features, theta: vec![0.0; v] }
    }

    /// Construct from explicit parameters (tests, serialization).
    pub fn from_params(features: Vec<usize>, theta: Vec<f32>) -> Lattice {
        assert!(features.len() <= MAX_DIM, "lattice dim {} > MAX_DIM {MAX_DIM}", features.len());
        assert_eq!(theta.len(), 1 << features.len(), "theta must have 2^d entries");
        Lattice { features, theta }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.features.len()
    }

    #[inline]
    pub fn n_vertices(&self) -> usize {
        self.theta.len()
    }

    /// Evaluate on a full feature vector (gathers the subset internally).
    pub fn eval(&self, x: &[f32]) -> f32 {
        let mut buf = [0f32; 1 << MAX_DIM];
        self.eval_with_scratch(x, &mut buf)
    }

    /// Evaluate using caller-provided scratch (hot path; avoids zeroing).
    #[inline]
    pub fn eval_with_scratch(&self, x: &[f32], buf: &mut [f32]) -> f32 {
        let d = self.dim();
        let v = 1usize << d;
        debug_assert!(buf.len() >= v);
        buf[..v].copy_from_slice(&self.theta);
        let mut half = v >> 1;
        // Contract from the most-significant bit down: at each step,
        // buf[i] <- lerp(buf[i], buf[i + half], x_j).
        for j in (0..d).rev() {
            let xj = x[self.features[j]].clamp(0.0, 1.0);
            let (lo, hi) = buf.split_at_mut(half);
            for (l, &h) in lo[..half].iter_mut().zip(hi[..half].iter()) {
                *l += xj * (h - *l);
            }
            half >>= 1;
        }
        buf[0]
    }

    /// Batched evaluation over a dataset into `out[i] = f(x_i)`.
    pub fn eval_batch(&self, ds: &Dataset, out: &mut [f32]) {
        assert_eq!(out.len(), ds.n);
        self.eval_block(&ds.x, ds.d, out);
    }

    /// Batched evaluation of `out.len()` consecutive rows of the
    /// row-major feature block `x` (`x[i*d..][..d]` is example i) — the
    /// shape the blocked score-matrix builder feeds.
    pub fn eval_block(&self, x: &[f32], d: usize, out: &mut [f32]) {
        debug_assert!(x.len() >= out.len() * d);
        let mut buf = vec![0f32; self.n_vertices()];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = self.eval_with_scratch(&x[i * d..(i + 1) * d], &mut buf);
        }
    }

    /// Interpolation weights w_v(x) for all vertices — the gradient of the
    /// output w.r.t. θ. Built by Kronecker doubling: O(2^{d+1}).
    /// `w` must have length ≥ 2^d.
    pub fn weights_into(&self, x: &[f32], w: &mut [f32]) {
        let d = self.dim();
        w[0] = 1.0;
        let mut len = 1usize;
        for j in 0..d {
            let xj = x[self.features[j]].clamp(0.0, 1.0);
            // Bit j set ⇒ multiply by x_j; clear ⇒ by (1 - x_j).
            let (lo, hi) = w.split_at_mut(len);
            for (h, l) in hi[..len].iter_mut().zip(lo[..len].iter_mut()) {
                *h = *l * xj;
                *l *= 1.0 - xj;
            }
            len <<= 1;
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("features", Json::arr_usize(&self.features)),
            ("theta", Json::arr_f32(&self.theta)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Lattice, QwycError> {
        let features = v.req("features")?.as_vec_usize()?;
        let theta = v.req("theta")?.as_vec_f32()?;
        if theta.len() != 1 << features.len() {
            return Err(QwycError::Schema(format!(
                "lattice theta len {} != 2^{}",
                theta.len(),
                features.len()
            )));
        }
        Ok(Lattice { features, theta })
    }
}

/// Maximum supported lattice dimensionality (RW1 uses 13).
pub const MAX_DIM: usize = 14;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identity_1d() {
        // θ = [0, 1] ⇒ f(x) = x0.
        let l = Lattice::from_params(vec![0], vec![0.0, 1.0]);
        for x in [0.0f32, 0.25, 0.5, 1.0] {
            assert!((l.eval(&[x]) - x).abs() < 1e-6);
        }
    }

    #[test]
    fn corners_reproduce_theta() {
        // On hypercube corners, interpolation returns the vertex value.
        let mut rng = Rng::new(1);
        let d = 4;
        let feats: Vec<usize> = (0..d).collect();
        let theta: Vec<f32> = (0..1 << d).map(|_| rng.normal() as f32).collect();
        let l = Lattice::from_params(feats, theta.clone());
        for v in 0..1usize << d {
            let x: Vec<f32> = (0..d).map(|j| ((v >> j) & 1) as f32).collect();
            assert!(
                (l.eval(&x) - theta[v]).abs() < 1e-5,
                "corner {v}: {} vs {}",
                l.eval(&x),
                theta[v]
            );
        }
    }

    #[test]
    fn matches_bruteforce_interpolation() {
        let mut rng = Rng::new(2);
        let d = 5;
        let feats = vec![3, 0, 4, 1, 2]; // scrambled subset mapping
        let theta: Vec<f32> = (0..1 << d).map(|_| rng.normal() as f32).collect();
        let l = Lattice::from_params(feats.clone(), theta.clone());
        for _ in 0..50 {
            let x: Vec<f32> = (0..5).map(|_| rng.f32()).collect();
            // Brute force: Σ_v θ_v Π_j w_j.
            let mut expect = 0f64;
            for v in 0..1usize << d {
                let mut w = 1f64;
                for (j, &fj) in feats.iter().enumerate() {
                    let xj = x[fj] as f64;
                    w *= if (v >> j) & 1 == 1 { xj } else { 1.0 - xj };
                }
                expect += w * theta[v] as f64;
            }
            assert!((l.eval(&x) as f64 - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn weights_sum_to_one_and_match_eval() {
        let mut rng = Rng::new(3);
        let d = 6;
        let theta: Vec<f32> = (0..1 << d).map(|_| rng.normal() as f32).collect();
        let l = Lattice::from_params((0..d).collect(), theta.clone());
        let mut w = vec![0f32; 1 << d];
        for _ in 0..20 {
            let x: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
            l.weights_into(&x, &mut w);
            let sum: f64 = w.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-5, "weights sum {sum}");
            let dot: f64 = w
                .iter()
                .zip(theta.iter())
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!((dot - l.eval(&x) as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn input_clamping() {
        let l = Lattice::from_params(vec![0], vec![0.0, 1.0]);
        assert!((l.eval(&[-0.5]) - 0.0).abs() < 1e-6);
        assert!((l.eval(&[1.5]) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Rng::new(4);
        let theta: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        let l = Lattice::from_params(vec![0, 1, 2], theta);
        let mut ds = Dataset::new("b", 3);
        for _ in 0..40 {
            ds.push(&[rng.f32(), rng.f32(), rng.f32()], 0.0);
        }
        let mut out = vec![0f32; ds.n];
        l.eval_batch(&ds, &mut out);
        for i in 0..ds.n {
            assert_eq!(out[i], l.eval(ds.row(i)));
        }
    }

    #[test]
    fn json_roundtrip() {
        let l = Lattice::from_params(vec![2, 0], vec![1.0, -2.0, 3.5, 0.25]);
        let back = Lattice::from_json(&l.to_json()).unwrap();
        assert_eq!(back.features, l.features);
        assert_eq!(back.theta, l.theta);
    }
}
