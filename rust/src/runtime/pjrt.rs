//! PJRT runtime: loads the AOT artifacts emitted by `python/compile/aot.py`
//! (`make artifacts`) and executes them from the serving hot path.
//!
//! Artifacts are HLO **text** — the interchange format that survives the
//! jax≥0.5 / xla_extension 0.5.1 proto-id mismatch (see aot.py). Each
//! artifact is compiled once at load time into a `PjRtLoadedExecutable`
//! keyed by name; shapes are validated against `manifest.json` before any
//! execution, so a stale artifact directory fails loudly at startup
//! instead of corrupting results.

use crate::error::QwycError;
use crate::util::json::{self, Json};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<TensorSpec, QwycError> {
        Ok(TensorSpec {
            shape: v.req("shape")?.as_vec_usize()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Geometry of one artifact (mirrors aot.py CONFIGS).
#[derive(Clone, Debug)]
pub struct ArtifactConfig {
    /// Total input features D.
    pub d_features: usize,
    /// Ensemble size T.
    pub t: usize,
    /// Per-lattice dimensionality d (V = 2^d).
    pub dim: usize,
    /// Compiled batch size B.
    pub b: usize,
    /// Stage width K.
    pub k: usize,
}

/// Manifest entry for one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub fn_name: String,
    pub config: ArtifactConfig,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// One input tensor for execution.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// One output tensor.
#[derive(Clone, Debug)]
pub enum Output {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Output {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Output::F32(v) => v,
            Output::I32(_) => panic!("expected f32 output"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Output::I32(v) => v,
            Output::F32(_) => panic!("expected i32 output"),
        }
    }
}

impl LoadedArtifact {
    /// Execute with pre-staged device buffers (hot path: constant inputs
    /// like model parameters are uploaded once via `Runtime::upload_*`
    /// and reused across calls — see §Perf in EXPERIMENTS.md).
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Output>, QwycError> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(QwycError::Config(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .map_err(|e| QwycError::Io(format!("{}: execute_b: {e:?}", self.spec.name)))?;
        self.decode_outputs(&result[0][0])
    }

    fn decode_outputs(&self, out: &xla::PjRtBuffer) -> Result<Vec<Output>, QwycError> {
        let tuple = out
            .to_literal_sync()
            .map_err(|e| QwycError::Io(format!("{}: to_literal: {e:?}", self.spec.name)))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let elems = tuple
            .to_tuple()
            .map_err(|e| QwycError::Io(format!("{}: to_tuple: {e:?}", self.spec.name)))?;
        if elems.len() != self.spec.outputs.len() {
            return Err(QwycError::Schema(format!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                elems.len()
            )));
        }
        elems
            .into_iter()
            .zip(self.spec.outputs.iter())
            .map(|(lit, spec)| match spec.dtype.as_str() {
                "float32" => lit
                    .to_vec::<f32>()
                    .map(Output::F32)
                    .map_err(|e| QwycError::Io(format!("output to_vec f32: {e:?}"))),
                "int32" => lit
                    .to_vec::<i32>()
                    .map(Output::I32)
                    .map_err(|e| QwycError::Io(format!("output to_vec i32: {e:?}"))),
                other => Err(QwycError::Schema(format!("unsupported output dtype {other}"))),
            })
            .collect()
    }

    /// Execute with shape/dtype validation. Inputs must match the
    /// manifest order exactly.
    pub fn execute(&self, inputs: &[Input]) -> Result<Vec<Output>, QwycError> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(QwycError::Config(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (idx, (inp, spec)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            let dims: Vec<i64> = spec.shape.iter().map(|&s| s as i64).collect();
            let lit = match inp {
                Input::F32(data) => {
                    if spec.dtype != "float32" {
                        return Err(QwycError::Config(format!(
                            "{} input {idx}: expected {}, got f32",
                            self.spec.name, spec.dtype
                        )));
                    }
                    if data.len() != spec.elements() {
                        return Err(QwycError::Config(format!(
                            "{} input {idx}: {} elements != shape {:?}",
                            self.spec.name,
                            data.len(),
                            spec.shape
                        )));
                    }
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| QwycError::Io(format!("reshape input {idx}: {e:?}")))?
                }
                Input::I32(data) => {
                    if spec.dtype != "int32" {
                        return Err(QwycError::Config(format!(
                            "{} input {idx}: expected {}, got i32",
                            self.spec.name, spec.dtype
                        )));
                    }
                    if data.len() != spec.elements() {
                        return Err(QwycError::Config(format!(
                            "{} input {idx}: {} elements != shape {:?}",
                            self.spec.name,
                            data.len(),
                            spec.shape
                        )));
                    }
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| QwycError::Io(format!("reshape input {idx}: {e:?}")))?
                }
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| QwycError::Io(format!("{}: execute: {e:?}", self.spec.name)))?;
        self.decode_outputs(&result[0][0])
    }
}

/// The artifact registry: one PJRT client, many compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    specs: HashMap<String, ArtifactSpec>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client and parse the manifest; artifacts compile
    /// lazily on first use (`get`).
    pub fn open(dir: &Path) -> Result<Runtime, QwycError> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| QwycError::Io(format!("PjRtClient::cpu: {e:?}")))?;
        let manifest = json::read_file(&dir.join("manifest.json"))?;
        let specs = parse_manifest(&manifest, dir)?;
        Ok(Runtime { client, artifacts: HashMap::new(), specs, dir: dir.to_path_buf() })
    }

    /// Names available in the manifest.
    pub fn names(&self) -> Vec<String> {
        self.specs.keys().cloned().collect()
    }

    /// Spec lookup without compiling.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Compile (if needed) and return an artifact by name.
    // Not the entry API: compilation is fallible and must not hold a
    // vacant-entry borrow across the `?` early returns.
    #[allow(clippy::map_entry)]
    pub fn get(&mut self, name: &str) -> Result<&LoadedArtifact, QwycError> {
        if !self.artifacts.contains_key(name) {
            let spec = self
                .specs
                .get(name)
                .ok_or_else(|| {
                    let have = self.names();
                    QwycError::Config(format!("unknown artifact '{name}' (have: {have:?})"))
                })?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(&spec.path).map_err(|e| {
                QwycError::Compile(format!("parse {}: {e:?}", spec.path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| QwycError::Compile(format!("compile {name}: {e:?}")))?;
            self.artifacts.insert(name.to_string(), LoadedArtifact { spec, exe });
        }
        Ok(&self.artifacts[name])
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Upload an f32 tensor to the device once; reuse across executions.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer, QwycError> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| QwycError::Io(format!("upload f32: {e:?}")))
    }

    /// Upload an i32 tensor to the device once; reuse across executions.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer, QwycError> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| QwycError::Io(format!("upload i32: {e:?}")))
    }
}

fn parse_manifest(
    manifest: &Json,
    dir: &Path,
) -> Result<HashMap<String, ArtifactSpec>, QwycError> {
    let arts = manifest.req("artifacts")?;
    let map = match arts {
        Json::Obj(m) => m,
        _ => return Err(QwycError::Schema("manifest.artifacts must be an object".into())),
    };
    let mut out = HashMap::new();
    for (name, v) in map.iter() {
        let cfgv = v.req("config")?;
        let config = ArtifactConfig {
            d_features: cfgv.req("D")?.as_usize()?,
            t: cfgv.req("T")?.as_usize()?,
            dim: cfgv.req("d")?.as_usize()?,
            b: cfgv.req("B")?.as_usize()?,
            k: cfgv.req("K")?.as_usize()?,
        };
        let inputs = v
            .req("inputs")?
            .as_arr()?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let outputs = v
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        out.insert(
            name.clone(),
            ArtifactSpec {
                name: name.clone(),
                path: dir.join(v.req("path")?.as_str()?),
                fn_name: v.req("fn")?.as_str()?.to_string(),
                config,
                inputs,
                outputs,
            },
        );
    }
    Ok(out)
}
