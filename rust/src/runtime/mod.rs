//! Execution runtimes for the serving hot path.
//!
//! [`engine`] holds the backend abstraction ([`engine::Engine`]) and the
//! always-available pure-Rust backend ([`engine::NativeEngine`]).
//!
//! The PJRT path — loading the AOT artifacts emitted by
//! `python/compile/aot.py` (`make artifacts`) and executing them from the
//! serving hot path — lives behind the non-default `pjrt` feature: the
//! default build is pure Rust with no XLA dependency, while
//! `--features pjrt` compiles `Runtime` and `engine::PjrtEngine`
//! against the `xla` bindings (the offline tree vendors a stub; see
//! rust/vendor/xla-stub).

pub mod engine;

#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactConfig, ArtifactSpec, Input, LoadedArtifact, Output, Runtime, TensorSpec};
