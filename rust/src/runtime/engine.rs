//! Batched early-exit engines — the bridge between a trained ensemble +
//! optimized [`FastClassifier`](crate::qwyc::FastClassifier) and the
//! serving scheduler.
//!
//! Two interchangeable backends:
//!
//! - [`NativeEngine`]: pure-rust early-exit evaluation over a
//!   [`CompiledPlan`] — models pre-permuted into π order with their SoA
//!   banks and invariants checked once at compile time. Batches are
//!   split into cache-sized blocks fanned across the `QWYC_THREADS`
//!   pool; each block runs the crate-wide sweep core
//!   (`qwyc::sweep`). Outcomes are identical to per-example
//!   `FastClassifier::eval_single` (asserted in
//!   rust/tests/parallel_equiv.rs and rust/tests/plan_equiv.rs).
//! - `PjrtEngine` (behind the `pjrt` feature): drives the AOT
//!   `qwyc_stage` artifact — the batch walks the optimized order in
//!   stages of K base models; after each PJRT call decided examples are
//!   retired and survivors are compacted into the next stage's fixed-B
//!   batch (padding the tail). This is the dense lattice path: Python
//!   authored the kernel, but only compiled HLO runs here.

#[cfg(feature = "pjrt")]
use super::Runtime;
#[cfg(feature = "pjrt")]
use crate::ensemble::BaseModel;
#[cfg(feature = "pjrt")]
use crate::ensemble::Ensemble;
use crate::error::QwycError;
use crate::plan::CompiledPlan;
use crate::qwyc::sweep::{SweepOutcome, SweepScratch};
#[cfg(feature = "pjrt")]
use crate::qwyc::FastClassifier;
use crate::qwyc::SingleResult;
use crate::util::pool::Pool;
use std::sync::Arc;

/// Example-block width for batched serving: small enough that a block's
/// feature rows and running scores stay cache-resident through the whole
/// position sweep, large enough to fill the SoA kernel's lanes as the
/// active set shrinks.
pub const ENGINE_BLOCK: usize = 256;

/// Classification outcome for one request.
#[derive(Clone, Copy, Debug)]
pub struct Outcome {
    pub positive: bool,
    pub score: f32,
    pub models_evaluated: u32,
    pub early: bool,
}

impl From<SingleResult> for Outcome {
    fn from(r: SingleResult) -> Outcome {
        Outcome {
            positive: r.positive,
            score: r.score,
            models_evaluated: r.models_evaluated as u32,
            early: r.early,
        }
    }
}

impl From<SweepOutcome> for Outcome {
    fn from(o: SweepOutcome) -> Outcome {
        Outcome { positive: o.positive, score: o.score, models_evaluated: o.stop, early: o.early }
    }
}

/// Engine abstraction used by the coordinator. Engines are constructed
/// inside the shard worker thread that owns them (see `Server::start`'s
/// per-shard factory parameter) because PJRT handles are not `Send` —
/// only the immutable `Arc<CompiledPlan>` crosses threads.
pub trait Engine {
    /// Number of input features expected per example.
    fn n_features(&self) -> usize;
    /// Classify a batch of examples (row-major `n × n_features`).
    fn classify_batch(&mut self, x: &[f32], n: usize) -> Result<Vec<Outcome>, QwycError>;
    /// Classify a batch into a caller-owned outcome buffer (cleared and
    /// refilled). The serving hot path uses this so a warmed shard
    /// worker performs no per-batch allocation; results are identical to
    /// [`Engine::classify_batch`] — the default simply delegates, and
    /// backends that override it must preserve bitwise-equal outcomes.
    fn classify_into(
        &mut self,
        x: &[f32],
        n: usize,
        out: &mut Vec<Outcome>,
    ) -> Result<(), QwycError> {
        let outcomes = self.classify_batch(x, n)?;
        out.clear();
        out.extend(outcomes);
        Ok(())
    }
    /// Human-readable backend name (metrics/logs).
    fn backend(&self) -> &'static str;
    /// Atomically adopt a new compiled plan (the serving `RELOAD` path).
    /// Called by a shard worker at a batch boundary, never mid-batch.
    /// Backends whose device state is baked at construction (PJRT's
    /// staged uploads) keep the default and decline the swap.
    fn swap_plan(&mut self, _plan: Arc<CompiledPlan>) -> Result<(), QwycError> {
        Err(QwycError::Config(format!(
            "backend '{}' does not support plan hot-reload",
            self.backend()
        )))
    }
    /// May this SAME engine value keep serving after a panic unwound out
    /// of [`Engine::classify_batch`]? Only sound when a half-finished
    /// call cannot leave observable state behind (no interior
    /// mutability, no device session to wedge). The default declines, so
    /// the shard supervisor drops the engine and rebuilds it from the
    /// factory; backends with mutable or external state (PJRT device
    /// buffers) must keep the default.
    fn reusable_after_panic(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------- native

/// Pure-rust early-exit evaluation: a shared immutable [`CompiledPlan`]
/// plus the worker pool that fans its blocked sweep. N serving shards
/// hold N `Arc` handles to ONE compiled plan — per-evaluation scratch
/// is either allocated inside the sweep call ([`Engine::classify_batch`])
/// or owned by this engine and recycled ([`Engine::classify_into`]), so
/// sharing the plan is free and safe.
pub struct NativeEngine {
    plan: Arc<CompiledPlan>,
    pool: Pool,
    /// Recycled sweep working set for the single-block
    /// [`Engine::classify_into`] path. Fully rewritten at the start of
    /// every sweep, so reuse after an unwound call stays sound (see the
    /// unwind-safety assertion below).
    scratch: SweepScratch,
    lat_scratch: Vec<f32>,
    /// Recycled quantized-feature block for `classify_into` (one u16 bin
    /// per feature value; see `plan/quant.rs`). Lives on the engine
    /// rather than in `SweepScratch` because the sweep's scorer closure
    /// reads it while the sweep holds the scratch mutably. Fully
    /// rewritten per call, like the rest of the scratch.
    qx: Vec<u16>,
}

impl NativeEngine {
    /// Serve a compiled plan with the pool implied by `QWYC_THREADS`.
    pub fn from_plan(plan: CompiledPlan) -> NativeEngine {
        NativeEngine::from_plan_with_pool(plan, Pool::from_env())
    }

    pub fn from_plan_with_pool(plan: CompiledPlan, pool: Pool) -> NativeEngine {
        NativeEngine::from_shared(Arc::new(plan), pool)
    }

    /// Share an already-compiled plan (the sharded-server path: compile
    /// once, hand every shard a handle).
    pub fn from_shared(plan: Arc<CompiledPlan>, pool: Pool) -> NativeEngine {
        NativeEngine {
            plan,
            pool,
            scratch: SweepScratch::default(),
            lat_scratch: Vec::new(),
            qx: Vec::new(),
        }
    }

    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }
}

impl Engine for NativeEngine {
    fn n_features(&self) -> usize {
        self.plan.n_features()
    }

    fn classify_batch(&mut self, x: &[f32], n: usize) -> Result<Vec<Outcome>, QwycError> {
        let d = self.plan.n_features();
        let outcomes = self.plan.sweep_features(x, n, d, ENGINE_BLOCK, &self.pool);
        Ok(outcomes.into_iter().map(Outcome::from).collect())
    }

    /// Allocation-free once warmed: batches up to [`ENGINE_BLOCK`] run
    /// one quantized sweep over the engine-owned scratch (the feature
    /// block is binned once into `qx`, then every tree walk is integer
    /// compare+select) — bitwise-identical to `classify_batch`, which
    /// fans the same batch as exactly one block over the same scorer.
    /// Larger batches fall back to the pooled allocating path (the
    /// serving coordinator's `max_batch` never exceeds a block on the
    /// hot path, so this is the cold case).
    fn classify_into(
        &mut self,
        x: &[f32],
        n: usize,
        out: &mut Vec<Outcome>,
    ) -> Result<(), QwycError> {
        if n > ENGINE_BLOCK {
            let outcomes = self.classify_batch(x, n)?;
            out.clear();
            out.extend(outcomes);
            return Ok(());
        }
        let d = self.plan.n_features();
        let swept = self.plan.sweep_features_quant_into(
            x,
            n,
            d,
            &mut self.scratch,
            &mut self.lat_scratch,
            &mut self.qx,
        );
        out.clear();
        out.extend(swept.iter().map(|&o| Outcome::from(o)));
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "native"
    }

    fn swap_plan(&mut self, plan: Arc<CompiledPlan>) -> Result<(), QwycError> {
        // The old Arc stays alive for any reader still holding it; this
        // engine's next batch sweeps the new plan.
        self.plan = plan;
        Ok(())
    }

    fn reusable_after_panic(&self) -> bool {
        // Sound because of the unwind-safety shape asserted below: an
        // immutable shared plan, a stateless pool, and owned sweep
        // scratch that every call clears and fully rewrites before
        // reading. An unwound call can leave stale bytes in the scratch
        // buffers, but no later call observes them.
        true
    }
}

// `reusable_after_panic` above relies on NativeEngine carrying no
// interior mutability (`Arc<CompiledPlan>` of plain data + a stateless
// pool descriptor + plain-`Vec` sweep scratch with no cross-call
// reads). Assert that shape at compile time so a future shared-state
// cache on the engine breaks this line instead of silently un-sounding
// the supervisor's engine reuse. (The response cache deliberately lives
// in the shard worker, outside the engine, for exactly this reason.)
const _: () = {
    const fn assert_unwind_safe<T: std::panic::UnwindSafe + std::panic::RefUnwindSafe>() {}
    assert_unwind_safe::<NativeEngine>()
};

// ----------------------------------------------------------------- pjrt

/// Pre-packed parameters for one stage of the optimized order.
/// Model parameters and thresholds are constant across requests, so they
/// are uploaded to the PJRT device ONCE at engine construction and reused
/// by every `execute_b` call — only the per-batch `x`/`g_in` tensors are
/// transferred per request (§Perf iteration 1 in EXPERIMENTS.md).
#[cfg(feature = "pjrt")]
struct StageParams {
    subsets: xla::PjRtBuffer,
    theta: xla::PjRtBuffer,
    eps_pos: xla::PjRtBuffer,
    eps_neg: xla::PjRtBuffer,
    /// Number of REAL positions in this stage (≤ K; the rest is padding
    /// with zero-lattices and ±∞ thresholds).
    real_k: usize,
}

/// PJRT-backed staged engine for lattice ensembles.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    rt: Runtime,
    artifact: String,
    stages: Vec<StageParams>,
    b: usize,
    /// Stage width of the compiled artifact.
    pub k: usize,
    d_features: usize,
    bias: f32,
    beta: f32,
    t: usize,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Build from a lattice ensemble and its optimized fast classifier.
    /// `artifact` names a `*_stage` manifest entry whose geometry (D, d)
    /// must match the ensemble; T is staged in blocks of the artifact's K.
    pub fn new(
        mut rt: Runtime,
        artifact: &str,
        ensemble: &Ensemble,
        fc: &FastClassifier,
    ) -> Result<PjrtEngine, QwycError> {
        let spec = rt
            .spec(artifact)
            .ok_or_else(|| QwycError::Config(format!("unknown artifact '{artifact}'")))?
            .clone();
        if spec.fn_name != "qwyc_stage" {
            return Err(QwycError::Config(format!(
                "artifact '{artifact}' is not a qwyc_stage artifact"
            )));
        }
        let cfg = &spec.config;
        let (b, k, dim, v) = (cfg.b, cfg.k, cfg.dim, 1usize << cfg.dim);
        let t = ensemble.len();
        assert_eq!(fc.t(), t);

        // Pre-pack per-stage parameter tensors in π order and upload them
        // to the device once (constant across requests).
        let mut stages = Vec::new();
        let mut r = 0usize;
        while r < t {
            let real_k = k.min(t - r);
            let mut subsets = vec![0i32; k * dim];
            let mut theta = vec![0f32; k * v];
            // Padding positions keep ±∞ thresholds and zero lattices (add
            // 0 to the running score, never trigger an exit).
            let mut eps_pos = vec![f32::INFINITY; k];
            let mut eps_neg = vec![f32::NEG_INFINITY; k];
            for j in 0..real_k {
                let m = fc.order[r + j];
                let lat = match &ensemble.models[m] {
                    BaseModel::Lattice(l) => l,
                    other => {
                        return Err(QwycError::Config(format!(
                            "PjrtEngine requires lattice models, found {}",
                            other.kind()
                        )))
                    }
                };
                if lat.dim() != dim {
                    return Err(QwycError::Config(format!(
                        "lattice dim {} != artifact dim {dim}",
                        lat.dim()
                    )));
                }
                for (jj, &f) in lat.features.iter().enumerate() {
                    subsets[j * dim + jj] = f as i32;
                }
                theta[j * v..(j + 1) * v].copy_from_slice(&lat.theta);
                eps_pos[j] = fc.eps_pos[r + j];
                eps_neg[j] = fc.eps_neg[r + j];
            }
            stages.push(StageParams {
                subsets: rt.upload_i32(&subsets, &[k, dim])?,
                theta: rt.upload_f32(&theta, &[k, v])?,
                eps_pos: rt.upload_f32(&eps_pos, &[k])?,
                eps_neg: rt.upload_f32(&eps_neg, &[k])?,
                real_k,
            });
            r += real_k;
        }

        // Eager-compile the artifact so serving never hits compile latency.
        rt.get(artifact)?;
        Ok(PjrtEngine {
            rt,
            artifact: artifact.to_string(),
            stages,
            b,
            k,
            d_features: cfg.d_features,
            bias: fc.bias,
            beta: fc.beta,
            t,
        })
    }

    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }
}

#[cfg(feature = "pjrt")]
impl Engine for PjrtEngine {
    fn n_features(&self) -> usize {
        self.d_features
    }

    fn classify_batch(&mut self, x: &[f32], n: usize) -> Result<Vec<Outcome>, QwycError> {
        let d = self.d_features;
        assert_eq!(x.len(), n * d);
        let b = self.b;

        let mut outcomes = vec![
            Outcome { positive: false, score: 0.0, models_evaluated: 0, early: false };
            n
        ];
        // Active example indices and their running scores.
        let mut active: Vec<u32> = (0..n as u32).collect();
        let mut g: Vec<f32> = vec![self.bias; n];
        let mut models: Vec<u32> = vec![0; n];

        let mut xbuf = vec![0f32; b * d];
        let mut gbuf = vec![0f32; b];

        let mut done_positions = 0usize;
        for stage in &self.stages {
            if active.is_empty() {
                break;
            }
            let mut survivors: Vec<u32> = Vec::with_capacity(active.len());
            // Process actives in chunks of the compiled batch size B.
            for chunk in active.chunks(b) {
                let nc = chunk.len();
                for (slot, &i) in chunk.iter().enumerate() {
                    let i = i as usize;
                    xbuf[slot * d..(slot + 1) * d].copy_from_slice(&x[i * d..(i + 1) * d]);
                    gbuf[slot] = g[i];
                }
                // Pad the tail with the last row (harmless: results are
                // discarded) and huge g so padding exits immediately-ish;
                // simplest is zero rows with neutral g = 0.
                for slot in nc..b {
                    xbuf[slot * d..(slot + 1) * d].iter_mut().for_each(|v| *v = 0.0);
                    gbuf[slot] = 0.0;
                }
                // Per-call uploads: only the batch tensors. Stage params
                // live on-device already.
                let xb = self.rt.upload_f32(&xbuf, &[b, d])?;
                let gb = self.rt.upload_f32(&gbuf, &[b])?;
                let art = self.rt.get(&self.artifact)?;
                let out = art.execute_buffers(&[
                    &xb,
                    &gb,
                    &stage.subsets,
                    &stage.theta,
                    &stage.eps_pos,
                    &stage.eps_neg,
                ])?;
                let g_out = out[0].as_f32();
                let decided = out[1].as_i32();
                let used = out[2].as_i32();
                for (slot, &i) in chunk.iter().enumerate() {
                    let iu = i as usize;
                    g[iu] = g_out[slot];
                    // `used` counts padded positions too if the example ran
                    // past the real positions; clamp to the stage's real K.
                    models[iu] += (used[slot] as u32).min(stage.real_k as u32);
                    match decided[slot] {
                        1 => {
                            outcomes[iu] = Outcome {
                                positive: true,
                                score: g[iu],
                                models_evaluated: models[iu],
                                early: true,
                            };
                        }
                        2 => {
                            outcomes[iu] = Outcome {
                                positive: false,
                                score: g[iu],
                                models_evaluated: models[iu],
                                early: true,
                            };
                        }
                        _ => survivors.push(i),
                    }
                }
            }
            active = survivors;
            done_positions += stage.real_k;
        }
        debug_assert!(done_positions <= self.t || self.stages.is_empty());
        // Survivors of all stages: full evaluation happened; decide by β.
        for &i in &active {
            let iu = i as usize;
            outcomes[iu] = Outcome {
                positive: g[iu] >= self.beta,
                score: g[iu],
                models_evaluated: self.t as u32,
                early: false,
            };
        }
        Ok(outcomes)
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    // PJRT engine integration tests live in rust/tests/runtime_pjrt.rs —
    // they need `make artifacts` to have run. The native engine is the
    // shared sweep over a CompiledPlan, covered by plan::compiled tests
    // plus rust/tests/{parallel_equiv,plan_equiv}.rs.
}
