//! The Fan et al. (2002) "dynamic scheduling" baseline, implemented
//! faithfully to the paper's Appendix C.
//!
//! For a fixed base-model ordering, calibration computes — per position r
//! and per bin of the running score g_r — the empirical mean μ_B and
//! std σ_B of the remaining mass Δ = f(x) − g_r(x) over a representative
//! set. At serving time the final score is estimated as g_r + μ_B and an
//! early decision is made when the estimate clears the decision threshold
//! β by a confidence margin γσ_B:
//!
//! ```text
//! g_r > β − μ_B + γσ_B  ⇒ classify positive, stop
//! g_r < β − μ_B − γσ_B  ⇒ classify negative, stop
//! ```
//!
//! (the statistically-coherent reading of Appendix C's thresholds
//! ε±_{r,B} = μ_B ± γσ_B around β). Bins are `floor(g_r / λ)` with the
//! knob λ controlling bin width; unseen bins at evaluation time fall back
//! to full evaluation, exactly as Fan et al. prescribe.

use crate::ensemble::ScoreMatrix;
use std::collections::HashMap;

/// Calibrated Fan classifier for one ordering and one λ.
#[derive(Clone, Debug)]
pub struct FanClassifier {
    pub order: Vec<usize>,
    pub lambda: f64,
    /// Per position r: bin id → (μ_B, σ_B).
    pub bins: Vec<HashMap<i64, (f32, f32)>>,
    pub bias: f32,
    pub beta: f32,
}

#[inline]
fn bin_of(g: f32, lambda: f64) -> i64 {
    (g as f64 / lambda).floor() as i64
}

impl FanClassifier {
    /// Calibrate per-bin statistics on a representative (unlabeled) set.
    pub fn calibrate(sm: &ScoreMatrix, order: &[usize], lambda: f64) -> FanClassifier {
        assert_eq!(order.len(), sm.t);
        let n = sm.n;
        let t = sm.t;
        let mut g: Vec<f32> = vec![sm.bias; n];
        let mut bins: Vec<HashMap<i64, (f32, f32)>> = Vec::with_capacity(t);
        for r in 0..t {
            let col = sm.col(order[r]);
            // Accumulate (count, Σδ, Σδ²) per bin.
            let mut acc: HashMap<i64, (u32, f64, f64)> = HashMap::new();
            for i in 0..n {
                g[i] += col[i];
                let delta = (sm.full_score(i) - g[i]) as f64;
                let e = acc.entry(bin_of(g[i], lambda)).or_insert((0, 0.0, 0.0));
                e.0 += 1;
                e.1 += delta;
                e.2 += delta * delta;
            }
            let stats: HashMap<i64, (f32, f32)> = acc
                .into_iter()
                .map(|(b, (c, s, s2))| {
                    let mu = s / c as f64;
                    let var = (s2 / c as f64 - mu * mu).max(0.0);
                    // Floor σ: singleton bins have zero empirical variance
                    // but are NOT infinitely confident — without a floor
                    // any γ would stop on them.
                    (b, (mu as f32, var.sqrt().max(1e-6) as f32))
                })
                .collect();
            bins.push(stats);
        }
        FanClassifier { order: order.to_vec(), lambda, bins, bias: sm.bias, beta: sm.beta }
    }

    /// Mean number of bins per position (the paper reports 10-400 as λ
    /// sweeps 0.1 → 0.001).
    pub fn mean_bins(&self) -> f64 {
        let total: usize = self.bins.iter().map(|b| b.len()).sum();
        total as f64 / self.bins.len().max(1) as f64
    }

    /// Simulate over a score matrix with confidence `gamma`; returns the
    /// same aggregate as `qwyc::simulate`. `neg_only` restricts to early
    /// negatives (Filter-and-Score experiments).
    pub fn simulate(&self, sm: &ScoreMatrix, gamma: f64, neg_only: bool) -> crate::qwyc::SimResult {
        let n = sm.n;
        let t = self.order.len();
        assert_eq!(t, sm.t);
        let mut g = vec![self.bias; n];
        let mut decisions = vec![false; n];
        let mut stops = vec![t as u32; n];
        let mut active: Vec<u32> = (0..n as u32).collect();
        let mut n_early = 0usize;
        let mut models_sum = 0f64;
        let mut cost_sum = 0f64;
        let mut cum_cost = 0f64;

        for r in 0..t {
            let col = sm.col(self.order[r]);
            cum_cost += sm.costs[self.order[r]] as f64;
            let stats = &self.bins[r];
            let mut w = 0usize;
            for idx in 0..active.len() {
                let i = active[idx] as usize;
                let gi = g[i] + col[i];
                g[i] = gi;
                let mut decided = false;
                if r + 1 < t {
                    if let Some(&(mu, sigma)) = stats.get(&bin_of(gi, self.lambda)) {
                        let margin = gamma as f32 * sigma;
                        let est = gi + mu; // estimated full score
                        if !neg_only && est - margin > self.beta {
                            decisions[i] = true;
                            decided = true;
                        } else if est + margin < self.beta {
                            decisions[i] = false;
                            decided = true;
                        }
                    }
                    // Unseen bin ⇒ no early stop at this position (the
                    // example proceeds toward full evaluation).
                }
                if decided {
                    stops[i] = (r + 1) as u32;
                    models_sum += (r + 1) as f64;
                    cost_sum += cum_cost;
                    n_early += 1;
                } else {
                    active[w] = i as u32;
                    w += 1;
                }
            }
            active.truncate(w);
            if active.is_empty() {
                break;
            }
        }
        for &i in &active {
            let i = i as usize;
            decisions[i] = g[i] >= sm.beta;
            stops[i] = t as u32;
            models_sum += t as f64;
            cost_sum += sm.total_cost();
        }
        let diffs = (0..n).filter(|&i| decisions[i] != sm.full_positive(i)).count();
        crate::qwyc::SimResult {
            mean_models: models_sum / n.max(1) as f64,
            mean_cost: cost_sum / n.max(1) as f64,
            pct_diff: diffs as f64 / n.max(1) as f64,
            decisions,
            stops,
            n_early,
        }
    }

    /// True early-exit single-example evaluation (timing path).
    pub fn eval_single(
        &self,
        ens: &crate::ensemble::Ensemble,
        x: &[f32],
        gamma: f64,
        neg_only: bool,
    ) -> crate::qwyc::SingleResult {
        let t = self.order.len();
        let mut g = self.bias;
        for (r, &m) in self.order.iter().enumerate() {
            g += ens.models[m].eval(x);
            if r + 1 < t {
                if let Some(&(mu, sigma)) = self.bins[r].get(&bin_of(g, self.lambda)) {
                    let margin = gamma as f32 * sigma;
                    let est = g + mu;
                    if !neg_only && est - margin > self.beta {
                        return crate::qwyc::SingleResult {
                            positive: true,
                            score: g,
                            models_evaluated: r + 1,
                            early: true,
                        };
                    }
                    if est + margin < self.beta {
                        return crate::qwyc::SingleResult {
                            positive: false,
                            score: g,
                            models_evaluated: r + 1,
                            early: true,
                        };
                    }
                }
            }
        }
        crate::qwyc::SingleResult {
            positive: g >= self.beta,
            score: g,
            models_evaluated: t,
            early: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, Which};
    use crate::gbt::{train, GbtParams};

    fn small_setup() -> (crate::ensemble::Ensemble, ScoreMatrix, ScoreMatrix) {
        let (tr, te) = generate(Which::AdultLike, 31, 0.02);
        let (ens, _) = train(&tr, &GbtParams { n_trees: 30, max_depth: 3, ..Default::default() });
        let sm_tr = ens.score_matrix(&tr);
        let sm_te = ens.score_matrix(&te);
        (ens, sm_tr, sm_te)
    }

    #[test]
    fn huge_gamma_never_stops_early() {
        let (_, sm_tr, _) = small_setup();
        let order: Vec<usize> = (0..sm_tr.t).collect();
        let fan = FanClassifier::calibrate(&sm_tr, &order, 0.01);
        let sim = fan.simulate(&sm_tr, 1e9, false);
        assert_eq!(sim.n_early, 0);
        assert_eq!(sim.pct_diff, 0.0);
        assert_eq!(sim.mean_models, sm_tr.t as f64);
    }

    #[test]
    fn gamma_tradeoff_monotone() {
        let (_, sm_tr, sm_te) = small_setup();
        let order: Vec<usize> = (0..sm_tr.t).collect();
        let fan = FanClassifier::calibrate(&sm_tr, &order, 0.01);
        let mut prev_models = 0.0;
        for &gamma in &[4.0, 2.0, 1.0, 0.5] {
            let sim = fan.simulate(&sm_te, gamma, false);
            assert!(
                sim.mean_models >= prev_models - 1e2 * f64::EPSILON
                    || sim.mean_models <= prev_models,
                "sanity"
            );
            // Lower gamma ⇒ fewer models evaluated (weakly).
            if prev_models > 0.0 {
                assert!(sim.mean_models <= prev_models + 1e-9, "gamma={gamma}");
            }
            prev_models = sim.mean_models;
        }
    }

    #[test]
    fn early_stopping_happens_and_tracks_full_decisions() {
        let (_, sm_tr, sm_te) = small_setup();
        let order: Vec<usize> = (0..sm_tr.t).collect();
        let fan = FanClassifier::calibrate(&sm_tr, &order, 0.01);
        let sim = fan.simulate(&sm_te, 2.5, false);
        assert!(sim.n_early > 0, "no early exits");
        assert!(sim.mean_models < sm_te.t as f64);
        assert!(sim.pct_diff < 0.05, "diff {}", sim.pct_diff);
    }

    #[test]
    fn lambda_controls_bin_count() {
        let (_, sm_tr, _) = small_setup();
        let order: Vec<usize> = (0..sm_tr.t).collect();
        let coarse = FanClassifier::calibrate(&sm_tr, &order, 0.1);
        let fine = FanClassifier::calibrate(&sm_tr, &order, 0.001);
        assert!(
            fine.mean_bins() > 4.0 * coarse.mean_bins(),
            "bins: coarse {} fine {}",
            coarse.mean_bins(),
            fine.mean_bins()
        );
    }

    #[test]
    fn simulate_agrees_with_eval_single() {
        let (ens, sm_tr, sm_te) = small_setup();
        let (_, te) = generate(Which::AdultLike, 31, 0.02);
        let order: Vec<usize> = (0..sm_tr.t).collect();
        let fan = FanClassifier::calibrate(&sm_tr, &order, 0.01);
        let sim = fan.simulate(&sm_te, 1.5, false);
        for i in (0..te.n).step_by(29) {
            let single = fan.eval_single(&ens, te.row(i), 1.5, false);
            assert_eq!(single.positive, sim.decisions[i], "example {i}");
            assert_eq!(single.models_evaluated as u32, sim.stops[i], "example {i}");
        }
    }

    #[test]
    fn neg_only_mode_produces_no_early_positives() {
        let (_, sm_tr, sm_te) = small_setup();
        let order: Vec<usize> = (0..sm_tr.t).collect();
        let fan = FanClassifier::calibrate(&sm_tr, &order, 0.01);
        let sim = fan.simulate(&sm_te, 1.0, true);
        for i in 0..sm_te.n {
            if sim.stops[i] < sm_te.t as u32 {
                assert!(!sim.decisions[i], "early positive in neg_only mode");
            }
        }
    }
}
