//! Pre-selected base-model orderings (paper Appendix B) — the baselines
//! QWYC*'s joint optimization is compared against. Each produces a
//! permutation that is then combined with either Algorithm-2 thresholds
//! (`qwyc::optimize_thresholds_for_order`) or the Fan et al. early-stop
//! mechanism (`fan::`).

use crate::ensemble::ScoreMatrix;
use crate::util::rng::Rng;

/// Natural training order (for GBTs this is the boosting order — each tree
/// was fit to the residual of the trees before it).
pub fn natural(t: usize) -> Vec<usize> {
    (0..t).collect()
}

/// Uniformly random permutation; the paper reports mean ± std over 5 such
/// orderings.
pub fn random(t: usize, seed: u64) -> Vec<usize> {
    Rng::new(seed ^ 0x0d0e0f).permutation(t)
}

/// Order by Individual MSE (ascending): each base model's mean squared
/// error as a standalone predictor of the ±1 label margin — Fan et al.'s
/// suggested "total benefits" metric. Requires labels.
pub fn individual_mse(sm: &ScoreMatrix, labels: &[f32]) -> Vec<usize> {
    assert_eq!(labels.len(), sm.n);
    let z: Vec<f32> = labels.iter().map(|&y| 2.0 * y - 1.0).collect();
    let mut mses: Vec<(f64, usize)> = (0..sm.t)
        .map(|t| {
            let col = sm.col(t);
            let mse = col
                .iter()
                .zip(z.iter())
                .map(|(&s, &zi)| ((s - zi) as f64).powi(2))
                .sum::<f64>()
                / sm.n as f64;
            (mse, t)
        })
        .collect();
    mses.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    mses.into_iter().map(|(_, t)| t).collect()
}

/// Order by Greedy MSE: first the best individual model, then repeatedly
/// the model that minimizes the MSE of the accumulated partial ensemble
/// against the ±1 margin (Appendix B; analogous to ordered-bagging
/// pruning). O(T²N) — pass a subsampled matrix for large T.
pub fn greedy_mse(sm: &ScoreMatrix, labels: &[f32]) -> Vec<usize> {
    assert_eq!(labels.len(), sm.n);
    let z: Vec<f32> = labels.iter().map(|&y| 2.0 * y - 1.0).collect();
    let n = sm.n;
    let mut g: Vec<f32> = vec![sm.bias; n];
    let mut remaining: Vec<usize> = (0..sm.t).collect();
    let mut order = Vec::with_capacity(sm.t);
    while !remaining.is_empty() {
        let mut best = (f64::INFINITY, usize::MAX, 0usize);
        for (pos, &t) in remaining.iter().enumerate() {
            let col = sm.col(t);
            let mut mse = 0f64;
            for i in 0..n {
                let e = (g[i] + col[i] - z[i]) as f64;
                mse += e * e;
            }
            if mse < best.0 || (mse == best.0 && t < best.1) {
                best = (mse, t, pos);
            }
        }
        let (_, t, pos) = best;
        let col = sm.col(t);
        for i in 0..n {
            g[i] += col[i];
        }
        remaining.swap_remove(pos);
        order.push(t);
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ensemble::ScoreMatrix;

    /// Matrix where model 1 is a perfect predictor, model 0 is noise, and
    /// model 2 is anti-correlated.
    fn toy() -> (ScoreMatrix, Vec<f32>) {
        let labels = vec![1.0, 0.0, 1.0, 0.0];
        let z: Vec<f32> = vec![1.0, -1.0, 1.0, -1.0];
        let n = 4;
        let mut cols = vec![0f32; n * 3];
        // model 0: noise
        cols[..n].copy_from_slice(&[0.1, 0.1, -0.1, -0.1]);
        // model 1: perfect
        cols[n..2 * n].copy_from_slice(&z);
        // model 2: inverted
        for i in 0..n {
            cols[2 * n + i] = -z[i];
        }
        (ScoreMatrix::new(n, 3, cols, 0.0, 0.0, vec![1.0; 3]), labels)
    }

    #[test]
    fn individual_mse_ranks_perfect_model_first() {
        let (sm, labels) = toy();
        let ord = individual_mse(&sm, &labels);
        assert_eq!(ord[0], 1);
        assert_eq!(ord[2], 2); // anti-correlated model last
    }

    #[test]
    fn greedy_mse_starts_with_best_and_is_permutation() {
        let (sm, labels) = toy();
        let ord = greedy_mse(&sm, &labels);
        assert_eq!(ord[0], 1);
        let mut s = ord.clone();
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2]);
    }

    #[test]
    fn greedy_differs_from_individual_with_correlated_models() {
        // Two identical good models + one complementary model: individual
        // MSE ranks the twins 1st and 2nd; greedy picks a twin then the
        // complementary model (adding the second twin over-shoots).
        let labels = vec![1.0, 0.0, 1.0, 0.0];
        let z = [1.0f32, -1.0, 1.0, -1.0];
        let n = 4;
        let mut cols = vec![0f32; n * 3];
        for i in 0..n {
            cols[i] = z[i] * 0.9; // twin A
            cols[n + i] = z[i] * 0.9; // twin B
            cols[2 * n + i] = z[i] * 0.2; // small complement
        }
        let sm = ScoreMatrix::new(n, 3, cols, 0.0, 0.0, vec![1.0; 3]);
        let ind = individual_mse(&sm, &labels);
        let gre = greedy_mse(&sm, &labels);
        assert_eq!(&ind[..2], &[0, 1]);
        assert_eq!(gre[0], 0);
        assert_eq!(gre[1], 2, "greedy should pick the complement: {gre:?}");
    }

    #[test]
    fn random_orders_are_permutations_and_differ() {
        let a = random(100, 1);
        let b = random(100, 2);
        assert_ne!(a, b);
        let mut s = a.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn natural_is_identity() {
        assert_eq!(natural(4), vec![0, 1, 2, 3]);
    }
}
