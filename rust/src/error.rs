//! Typed errors for the plan artifact lifecycle.
//!
//! The plan layer used to thread `Result<_, String>` through load /
//! validate / compile, which made it impossible for callers (the CLI,
//! the serving `RELOAD` handler) to tell a missing file from a corrupt
//! document from a structurally invalid plan without string matching.
//! [`PlanError`] names the four failure stages explicitly; `Display`
//! keeps the old human-readable messages, and `From<PlanError> for
//! String` keeps `?` working in the many `Result<_, String>` call sites
//! (CLI arms, `FilterPipeline`, engine factories) without churn.

use std::fmt;

/// What went wrong while loading, validating, or compiling a
/// [`QwycPlan`](crate::plan::QwycPlan).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// The artifact file could not be read or written.
    Io(String),
    /// The document parsed but is not a well-formed `qwyc-plan-v1`
    /// payload (wrong schema tag, missing keys, bad JSON shapes).
    Schema(String),
    /// The plan parsed but violates a structural invariant (classifier
    /// structure, ensemble/classifier size or bias/β agreement,
    /// derived-metadata drift).
    Validate(String),
    /// Compilation into the serving-ready [`CompiledPlan`]
    /// (crate::plan::CompiledPlan) failed: tree structure, feature-count
    /// agreement, or declared-width checks.
    Compile(String),
}

impl PlanError {
    /// The failure stage as a short lowercase tag (log/metrics friendly).
    pub fn stage(&self) -> &'static str {
        match self {
            PlanError::Io(_) => "io",
            PlanError::Schema(_) => "schema",
            PlanError::Validate(_) => "validate",
            PlanError::Compile(_) => "compile",
        }
    }
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::Io(m) => write!(f, "plan io error: {m}"),
            PlanError::Schema(m) => write!(f, "plan schema error: {m}"),
            PlanError::Validate(m) => write!(f, "plan validation error: {m}"),
            PlanError::Compile(m) => write!(f, "plan compile error: {m}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// Interop with the crate's `Result<_, String>` substrate: `?` on a
/// plan-layer call keeps working inside CLI arms and pipelines.
impl From<PlanError> for String {
    fn from(e: PlanError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_stage_and_message() {
        let e = PlanError::Schema("expected schema 'qwyc-plan-v1'".into());
        assert_eq!(e.stage(), "schema");
        let s: String = e.clone().into();
        assert!(s.contains("schema"));
        assert!(s.contains("qwyc-plan-v1"));
        assert_eq!(s, e.to_string());
    }

    #[test]
    fn question_mark_converts_into_string_results() {
        fn inner() -> Result<(), PlanError> {
            Err(PlanError::Io("no such file".into()))
        }
        fn outer() -> Result<(), String> {
            inner()?;
            Ok(())
        }
        let err = outer().unwrap_err();
        assert!(err.contains("io error"), "{err}");
    }
}
