//! The crate-wide error type.
//!
//! Every fallible operation in this crate — artifact IO, JSON
//! (de)serialization, structural validation, plan compilation, ensemble
//! training, and CLI/configuration parsing — reports a [`QwycError`].
//! The variant names the pipeline *stage* that failed, so callers (the
//! CLI's `error[stage]: message` lines, the serving `RELOAD` handler,
//! metrics) can route on [`QwycError::stage`] without string matching.
//!
//! Until PR 5 only the plan layer was typed (`PlanError` with four
//! variants and a shim converting into the stringly-typed error
//! substrate everywhere else). The shim is gone: every public API
//! returns `QwycError` directly.

#![warn(missing_docs)]

use std::fmt;

/// What went wrong, named by the pipeline stage that failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QwycError {
    /// A file, device, or remote peer could not be read, written, or
    /// driven (artifact files, CSV datasets, PJRT client/upload/execute
    /// failures, serving-protocol errors reported by a server).
    Io(String),
    /// A document parsed but is not well-formed for its schema (JSON
    /// syntax, missing keys, wrong shapes, bad `qwyc-plan-v1` payloads).
    Schema(String),
    /// A structural invariant is violated (classifier thresholds, tree
    /// node layout, ensemble/classifier agreement, derived-metadata
    /// drift).
    Validate(String),
    /// Compilation into a serving-ready form failed (feature-count
    /// agreement, declared-width checks, artifact compilation).
    Compile(String),
    /// Ensemble training could not run (degenerate dataset, impossible
    /// hyperparameters).
    Train(String),
    /// Configuration is unusable (CLI flags, dataset names, builder
    /// arguments out of range).
    Config(String),
}

impl QwycError {
    /// The failure stage as a short lowercase tag (log/metrics friendly,
    /// and the `[stage]` in the CLI's `error[stage]: message` lines).
    pub fn stage(&self) -> &'static str {
        match self {
            QwycError::Io(_) => "io",
            QwycError::Schema(_) => "schema",
            QwycError::Validate(_) => "validate",
            QwycError::Compile(_) => "compile",
            QwycError::Train(_) => "train",
            QwycError::Config(_) => "config",
        }
    }

    /// The bare message, without the stage prefix `Display` adds.
    pub fn message(&self) -> &str {
        match self {
            QwycError::Io(m)
            | QwycError::Schema(m)
            | QwycError::Validate(m)
            | QwycError::Compile(m)
            | QwycError::Train(m)
            | QwycError::Config(m) => m,
        }
    }

    /// Prefix the message with a context label, keeping the stage (e.g.
    /// `"ensemble"` while deserializing the ensemble part of a plan).
    pub fn context(self, ctx: &str) -> QwycError {
        let wrap = |m: String| format!("{ctx}: {m}");
        match self {
            QwycError::Io(m) => QwycError::Io(wrap(m)),
            QwycError::Schema(m) => QwycError::Schema(wrap(m)),
            QwycError::Validate(m) => QwycError::Validate(wrap(m)),
            QwycError::Compile(m) => QwycError::Compile(wrap(m)),
            QwycError::Train(m) => QwycError::Train(wrap(m)),
            QwycError::Config(m) => QwycError::Config(wrap(m)),
        }
    }
}

impl fmt::Display for QwycError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} error: {}", self.stage(), self.message())
    }
}

impl std::error::Error for QwycError {}

/// File-system failures fold into the `Io` stage, so `?` works on
/// `std::io::Result` inside functions returning `QwycError`.
impl From<std::io::Error> for QwycError {
    fn from(e: std::io::Error) -> QwycError {
        QwycError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_stage_and_message() {
        let e = QwycError::Schema("expected schema 'qwyc-plan-v1'".into());
        assert_eq!(e.stage(), "schema");
        assert_eq!(e.message(), "expected schema 'qwyc-plan-v1'");
        let s = e.to_string();
        assert!(s.contains("schema error"), "{s}");
        assert!(s.contains("qwyc-plan-v1"), "{s}");
    }

    #[test]
    fn every_variant_maps_to_its_stage() {
        let cases = [
            (QwycError::Io("a".into()), "io"),
            (QwycError::Schema("b".into()), "schema"),
            (QwycError::Validate("c".into()), "validate"),
            (QwycError::Compile("d".into()), "compile"),
            (QwycError::Train("e".into()), "train"),
            (QwycError::Config("f".into()), "config"),
        ];
        for (e, stage) in cases {
            assert_eq!(e.stage(), stage);
            assert!(e.to_string().starts_with(stage), "{e}");
        }
    }

    #[test]
    fn context_prefixes_without_changing_stage() {
        let e = QwycError::Validate("bias drift".into()).context("plan 'demo'");
        assert_eq!(e.stage(), "validate");
        assert_eq!(e.message(), "plan 'demo': bias drift");
    }

    #[test]
    fn question_mark_converts_io_errors() {
        fn inner() -> std::io::Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"))
        }
        fn outer() -> Result<(), QwycError> {
            inner()?;
            Ok(())
        }
        let err = outer().unwrap_err();
        assert_eq!(err.stage(), "io");
        assert!(err.message().contains("no such file"), "{err}");
    }
}
