//! Environment substrates: deterministic PRNG, JSON, argv parsing, timing,
//! statistics, and a mini property-testing driver. These exist because the
//! offline image has no `rand`/`serde_json`/`clap`/`criterion`/`proptest`;
//! see DESIGN.md §4 (Substitutions).

pub mod cli;
pub mod failpoints;
pub mod json;
pub mod lineio;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod timer;

/// Value of the k-th smallest element (0-based) of `xs` — the threshold
/// optimizer's order-statistic primitive, the innermost loop of
/// Algorithm 1 (see qwyc/thresholds.rs).
///
/// Two strategies (§Perf iteration 2 in EXPERIMENTS.md): for small k a
/// single sequential pass with a bounded max-heap (O(n log k), cache
/// friendly — and k = remaining α-budget is almost always small); for
/// large k, three-way quickselect (average O(n)).
pub fn kth_smallest(xs: &mut [f32], k: usize) -> f32 {
    assert!(k < xs.len(), "kth_smallest: k={k} len={}", xs.len());
    if k < 64 {
        return kth_smallest_heap(xs, k);
    }
    let (mut lo, mut hi) = (0usize, xs.len() - 1);
    // Deterministic pivot mixing to dodge adversarial patterns.
    let mut salt = 0x9e3779b97f4a7c15u64;
    loop {
        if lo == hi {
            return xs[lo];
        }
        // Median-of-three-ish pivot choice with a rotating salt.
        salt = salt.wrapping_mul(6364136223846793005).wrapping_add(1);
        let pivot_idx = lo + (salt as usize) % (hi - lo + 1);
        let pivot = xs[pivot_idx];
        // Three-way partition (Dutch national flag) — robust to duplicates.
        // After the loop: xs[lo..i] < pivot, xs[i..=j] == pivot, xs[j+1..=hi] > pivot.
        let (mut i, mut j, mut p) = (lo, hi, lo);
        while p <= j {
            if xs[p] < pivot {
                xs.swap(i, p);
                i += 1;
                p += 1;
            } else if xs[p] > pivot {
                xs.swap(p, j);
                if j == 0 {
                    break;
                }
                j -= 1;
            } else {
                p += 1;
            }
        }
        if k < i {
            hi = i - 1;
        } else if k <= j {
            return pivot;
        } else {
            lo = j + 1;
        }
    }
}

/// Value of the k-th LARGEST element (0-based). Negates in place so the
/// small-k heap path applies symmetrically (ε⁺ search uses small k too).
pub fn kth_largest(xs: &mut [f32], k: usize) -> f32 {
    for v in xs.iter_mut() {
        *v = -*v;
    }
    let r = kth_smallest(xs, k);
    // Restore (callers reuse the scratch buffer contents only as a bag of
    // values, but keep the contract clean anyway).
    for v in xs.iter_mut() {
        *v = -*v;
    }
    -r
}

/// Small-k path: keep the k+1 smallest seen so far in a max-heap; the
/// heap root is the answer after one sequential pass.
fn kth_smallest_heap(xs: &[f32], k: usize) -> f32 {
    // f32 is not Ord; totally ordered here because callers never pass NaN
    // (scores are finite). Compare via total_cmp for safety.
    let mut heap: Vec<f32> = Vec::with_capacity(k + 1);
    for &v in xs {
        if heap.len() <= k {
            heap.push(v);
            if heap.len() == k + 1 {
                // Heapify once full.
                for i in (0..=(k / 2)).rev() {
                    sift_down(&mut heap, i);
                }
            }
        } else if v.total_cmp(&heap[0]) == std::cmp::Ordering::Less {
            heap[0] = v;
            sift_down(&mut heap, 0);
        }
    }
    if heap.len() <= k {
        unreachable!("caller guarantees k < xs.len()");
    }
    heap[0]
}

#[inline]
fn sift_down(heap: &mut [f32], mut i: usize) {
    let n = heap.len();
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut largest = i;
        if l < n && heap[l] > heap[largest] {
            largest = l;
        }
        if r < n && heap[r] > heap[largest] {
            largest = r;
        }
        if largest == i {
            return;
        }
        heap.swap(i, largest);
        i = largest;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kth_matches_sort() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let n = 1 + rng.below(100);
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() * 10.0).round()).collect();
            let k = rng.below(n);
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut work = xs.clone();
            assert_eq!(kth_smallest(&mut work, k), sorted[k], "n={n} k={k} xs={xs:?}");
        }
    }

    #[test]
    fn kth_all_duplicates() {
        let mut xs = vec![2.0f32; 17];
        assert_eq!(kth_smallest(&mut xs, 0), 2.0);
        assert_eq!(kth_smallest(&mut xs, 16), 2.0);
    }
}
