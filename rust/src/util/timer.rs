//! Benchmark timing substrate (criterion is unavailable offline).
//!
//! `bench_fn` runs a closure with warmup, repeats it for a wall-clock
//! budget, and reports mean/std per-iteration nanoseconds — enough to
//! regenerate the paper's μs-per-example timing tables with ± spreads
//! (Tables 2-5 report mean ± % over 100 runs; we do the same).

use std::time::{Duration, Instant};

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Mean ns per iteration across measurement runs.
    pub mean_ns: f64,
    /// Std dev of per-run means (the paper's ±%).
    pub std_ns: f64,
    pub runs: usize,
    pub iters_per_run: u64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn rel_std_pct(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            self.std_ns / self.mean_ns * 100.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.3} us/iter  ±{:>4.1}%  ({} runs x {} iters)",
            self.name,
            self.mean_us(),
            self.rel_std_pct(),
            self.runs,
            self.iters_per_run
        )
    }
}

/// Time `f` (which performs ONE logical iteration) with `runs` measurement
/// runs of `iters` iterations each, after `warmup` iterations.
pub fn bench_fn<F: FnMut()>(
    name: &str,
    warmup: u64,
    runs: usize,
    iters: u64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut per_run_ns = Vec::with_capacity(runs);
    for _ in 0..runs {
        let start = Instant::now();
        for _ in 0..iters {
            f();
        }
        let ns = start.elapsed().as_nanos() as f64 / iters as f64;
        per_run_ns.push(ns);
    }
    BenchResult {
        name: name.to_string(),
        mean_ns: crate::util::stats::mean(&per_run_ns),
        std_ns: crate::util::stats::std(&per_run_ns),
        runs,
        iters_per_run: iters,
    }
}

/// Time `f` adaptively: pick an iteration count that makes one run take
/// about `target` wall time, then do `runs` runs.
pub fn bench_auto<F: FnMut()>(name: &str, target: Duration, runs: usize, mut f: F) -> BenchResult {
    // Calibrate.
    let mut iters = 1u64;
    loop {
        let start = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = start.elapsed();
        if el >= Duration::from_millis(5) || iters >= 1 << 24 {
            let per = el.as_nanos().max(1) as f64 / iters as f64;
            iters = ((target.as_nanos() as f64 / per).ceil() as u64).clamp(1, 1 << 28);
            break;
        }
        iters *= 4;
    }
    bench_fn(name, iters / 4, runs, iters, f)
}

/// Simple stopwatch for phase timing in experiment logs.
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Prevent the optimizer from discarding a computed value (std::hint-based).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let mut acc = 0u64;
        let r = bench_fn("spin", 10, 3, 100, || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        black_box(acc);
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.runs, 3);
    }

    #[test]
    fn auto_calibration_runs() {
        let r = bench_auto("noop", Duration::from_millis(10), 2, || {
            black_box(1 + 1);
        });
        assert!(r.iters_per_run >= 1);
    }
}
