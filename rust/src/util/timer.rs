//! Benchmark timing substrate (criterion is unavailable offline).
//!
//! `bench_fn` runs a closure with warmup, repeats it for a wall-clock
//! budget, and reports mean/std per-iteration nanoseconds — enough to
//! regenerate the paper's μs-per-example timing tables with ± spreads
//! (Tables 2-5 report mean ± % over 100 runs; we do the same).

use std::time::{Duration, Instant};

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Mean ns per iteration across measurement runs.
    pub mean_ns: f64,
    /// Std dev of per-run means (the paper's ±%).
    pub std_ns: f64,
    /// Median / 99th percentile of the per-run means (ns per iteration).
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub runs: usize,
    pub iters_per_run: u64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn rel_std_pct(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            self.std_ns / self.mean_ns * 100.0
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.3} us/iter  ±{:>4.1}%  ({} runs x {} iters)",
            self.name,
            self.mean_us(),
            self.rel_std_pct(),
            self.runs,
            self.iters_per_run
        )
    }
}

/// Time `f` (which performs ONE logical iteration) with `runs` measurement
/// runs of `iters` iterations each, after `warmup` iterations.
pub fn bench_fn<F: FnMut()>(
    name: &str,
    warmup: u64,
    runs: usize,
    iters: u64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut per_run_ns = Vec::with_capacity(runs);
    for _ in 0..runs {
        let start = Instant::now();
        for _ in 0..iters {
            f();
        }
        let ns = start.elapsed().as_nanos() as f64 / iters as f64;
        per_run_ns.push(ns);
    }
    BenchResult {
        name: name.to_string(),
        mean_ns: crate::util::stats::mean(&per_run_ns),
        std_ns: crate::util::stats::std(&per_run_ns),
        p50_ns: crate::util::stats::percentile(&per_run_ns, 50.0),
        p99_ns: crate::util::stats::percentile(&per_run_ns, 99.0),
        runs,
        iters_per_run: iters,
    }
}

/// Accumulates [`BenchResult`]s (optionally paired with a serial
/// baseline) and writes the machine-readable `BENCH.json` that tracks
/// the perf trajectory across PRs.
///
/// Schema (`"schema": "qwyc-bench-v1"`):
///
/// ```json
/// {
///   "schema": "qwyc-bench-v1",
///   "threads": 8,
///   "targets": [
///     {"name": "...", "mean_ns": 0.0, "p50_ns": 0.0, "p99_ns": 0.0,
///      "std_ns": 0.0, "runs": 5, "iters_per_run": 100,
///      "speedup_vs_serial": 3.7}   // null when no serial baseline
///   ]
/// }
/// ```
pub struct BenchReport {
    threads: usize,
    targets: Vec<(BenchResult, Option<f64>)>,
}

impl BenchReport {
    pub fn new(threads: usize) -> BenchReport {
        BenchReport { threads, targets: Vec::new() }
    }

    /// Record a standalone target.
    pub fn push(&mut self, r: &BenchResult) {
        self.targets.push((r.clone(), None));
    }

    /// Record a parallel target with its serial baseline; the baseline is
    /// stored as its own target and the parallel one carries
    /// `speedup_vs_serial = serial.mean_ns / parallel.mean_ns` (null if
    /// the parallel measurement is degenerate — a 0.0 ratio would read
    /// as an infinite slowdown to trend tooling, not as "invalid").
    pub fn push_pair(&mut self, serial: &BenchResult, parallel: &BenchResult) {
        self.targets.push((serial.clone(), None));
        let speedup = if parallel.mean_ns > 0.0 {
            Some(serial.mean_ns / parallel.mean_ns)
        } else {
            None
        };
        self.targets.push((parallel.clone(), speedup));
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let targets = self
            .targets
            .iter()
            .map(|(r, speedup)| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("mean_ns", Json::Num(r.mean_ns)),
                    ("p50_ns", Json::Num(r.p50_ns)),
                    ("p99_ns", Json::Num(r.p99_ns)),
                    ("std_ns", Json::Num(r.std_ns)),
                    ("runs", Json::Num(r.runs as f64)),
                    ("iters_per_run", Json::Num(r.iters_per_run as f64)),
                    ("speedup_vs_serial", speedup.map_or(Json::Null, Json::Num)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("qwyc-bench-v1")),
            ("threads", Json::Num(self.threads as f64)),
            ("targets", Json::Arr(targets)),
        ])
    }

    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::json::write_file(path, &self.to_json())
    }
}

/// Time `f` adaptively: pick an iteration count that makes one run take
/// about `target` wall time, then do `runs` runs.
pub fn bench_auto<F: FnMut()>(name: &str, target: Duration, runs: usize, mut f: F) -> BenchResult {
    // Calibrate.
    let mut iters = 1u64;
    loop {
        let start = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = start.elapsed();
        if el >= Duration::from_millis(5) || iters >= 1 << 24 {
            let per = el.as_nanos().max(1) as f64 / iters as f64;
            iters = ((target.as_nanos() as f64 / per).ceil() as u64).clamp(1, 1 << 28);
            break;
        }
        iters *= 4;
    }
    bench_fn(name, iters / 4, runs, iters, f)
}

/// Simple stopwatch for phase timing in experiment logs.
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Prevent the optimizer from discarding a computed value (std::hint-based).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let mut acc = 0u64;
        let r = bench_fn("spin", 10, 3, 100, || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        black_box(acc);
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.runs, 3);
    }

    #[test]
    fn bench_report_json_schema() {
        let r = BenchResult {
            name: "serial".into(),
            mean_ns: 100.0,
            std_ns: 1.0,
            p50_ns: 99.0,
            p99_ns: 110.0,
            runs: 4,
            iters_per_run: 10,
        };
        let mut p = r.clone();
        p.name = "parallel".into();
        p.mean_ns = r.mean_ns / 2.0;
        let mut report = BenchReport::new(4);
        report.push(&r);
        report.push_pair(&r, &p);
        let j = report.to_json();
        assert_eq!(j.req("schema").unwrap().as_str().unwrap(), "qwyc-bench-v1");
        assert_eq!(j.req("threads").unwrap().as_f64().unwrap(), 4.0);
        let targets = j.req("targets").unwrap().as_arr().unwrap();
        assert_eq!(targets.len(), 3);
        // Standalone + serial-baseline entries carry a null speedup.
        assert_eq!(targets[0].req("speedup_vs_serial").unwrap(), &crate::util::json::Json::Null);
        let sp = targets[2].req("speedup_vs_serial").unwrap().as_f64().unwrap();
        assert!((sp - 2.0).abs() < 1e-9, "speedup {sp}");
        assert!(targets[0].req("p50_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(targets[0].req("p99_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn auto_calibration_runs() {
        let r = bench_auto("noop", Duration::from_millis(10), 2, || {
            black_box(1 + 1);
        });
        assert!(r.iters_per_run >= 1);
    }
}
