//! Minimal JSON substrate (serde_json is unavailable offline).
//!
//! Covers exactly what this repo needs: model/artifact serialization,
//! experiment result dumps, and the AOT `manifest.json`. The parser is a
//! straightforward recursive-descent over the full JSON grammar; the writer
//! emits compact or pretty output. Numbers are kept as f64 (all our payloads
//! are f32 tensors, counts, and ratios — well within f64's exact range).

use crate::error::QwycError;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Every malformed-document failure in this module is a `Schema` error.
fn schema(msg: String) -> QwycError {
    QwycError::Schema(msg)
}

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Field access that reports the missing key — models fail loudly.
    pub fn req(&self, key: &str) -> Result<&Json, QwycError> {
        self.get(key).ok_or_else(|| schema(format!("missing JSON field '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64, QwycError> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(schema(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, QwycError> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            return Err(schema(format!("expected non-negative integer, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> Result<&str, QwycError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(schema(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool, QwycError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(schema(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], QwycError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(schema(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_vec_f32(&self) -> Result<Vec<f32>, QwycError> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    pub fn as_vec_usize(&self) -> Result<Vec<usize>, QwycError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer ------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    // JSON has no NaN/Inf; encode as null (read back as such).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parser ------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, QwycError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(schema(format!("trailing characters at byte {}", p.i)));
        }
        Ok(v)
    }
}

/// Compact single-line rendering (the pretty writer is `to_string_pretty`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), QwycError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(schema(format!("expected '{}' at byte {}", c as char, self.i)))
        }
    }

    fn value(&mut self) -> Result<Json, QwycError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => {
                let c = other.map(|c| c as char);
                Err(schema(format!("unexpected {c:?} at byte {}", self.i)))
            }
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, QwycError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(schema(format!("invalid literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json, QwycError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| schema(format!("bad number at byte {start}")))
    }

    fn string(&mut self) -> Result<String, QwycError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(schema("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(schema("bad \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| schema("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| schema("bad \\u escape".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(schema(format!("bad escape {other:?}"))),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes at once.
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| schema("invalid utf8 in string".into()))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, QwycError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => return Err(schema(format!("expected ',' or ']', got {other:?}"))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, QwycError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(schema(format!("expected ',' or '}}', got {other:?}"))),
            }
        }
    }
}

/// Write a JSON value to a file, creating parent dirs.
pub fn write_file(path: &std::path::Path, v: &Json) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, v.to_string_pretty())
}

/// Read and parse a JSON file. A file-system failure is an `Io` error;
/// unparseable bytes are a `Schema` error — callers can tell a missing
/// artifact from a corrupt one without string matching.
pub fn read_file(path: &std::path::Path) -> Result<Json, QwycError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| QwycError::Io(format!("read {path:?}: {e}")))?;
    Json::parse(&text).map_err(|e| e.context(&format!("parse {path:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3e4", "\"hi\\n\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::str("qwyc")),
            ("t", Json::Num(500.0)),
            ("thresholds", Json::arr_f32(&[1.5, -2.25, 0.0])),
            (
                "nested",
                Json::obj(vec![("a", Json::Arr(vec![Json::Null, Json::Bool(true)]))]),
            ),
        ]);
        let s = v.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), v);
        let s2 = v.to_string();
        assert_eq!(Json::parse(&s2).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"a": [1, 2, 3], "b": "x", "c": true}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_vec_usize().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.req("b").unwrap().as_str().unwrap(), "x");
        assert!(v.req("c").unwrap().as_bool().unwrap());
        assert!(v.req("zz").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn big_float_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 * 0.137).sin() * 1e3).collect();
        let v = Json::arr_f32(&xs);
        let back = Json::parse(&v.to_string()).unwrap().as_vec_f32().unwrap();
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= a.abs() * 1e-6 + 1e-6);
        }
    }
}
