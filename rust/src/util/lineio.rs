//! Capped line reading over any [`BufRead`] — the oversized-input
//! hardening shared by every text front-end. Both protocol surfaces
//! parse with it:
//!
//! - the line protocol (`coordinator::server`, one request per line,
//!   capped at `MAX_LINE_BYTES`), and
//! - the HTTP/1.1 request parser (`http::parse`, request line and each
//!   header line capped independently),
//!
//! so "a hostile peer streams an endless line" costs O(cap) memory in
//! one audited place instead of per-protocol copies drifting apart.

use std::io::BufRead;

/// One line read with a hard byte cap. The bytes land in the caller's
/// reusable buffer; `Line` just flags that it holds a complete line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineRead {
    /// The buffer holds one complete line (terminator stripped).
    Line,
    /// The line exceeded the cap; it has been consumed from the stream.
    TooLong,
    /// Clean end of stream with no pending bytes.
    Eof,
}

/// Read one `\n`-terminated line of at most `cap` bytes into `buf`
/// (cleared first) via `fill_buf`/`consume` — unlike
/// `BufRead::read_line`, an oversized (or maliciously endless) line is
/// discarded as it streams in instead of being accumulated, so one bad
/// client line costs O(cap) memory, and the reused buffer means a
/// steady request stream stops allocating here after warmup. A final
/// unterminated line (client half-wrote then shut down its write side)
/// is returned as a normal line at EOF. Decoding stays lossy at the
/// call site (`String::from_utf8_lossy`) — binary garbage turns into a
/// line the protocol parser rejects, which is the per-line error
/// behavior we want. Only the trailing `\n` is stripped; a `\r` before
/// it is the caller's to trim (the line protocol trims whitespace, the
/// HTTP parser strips the single optional `\r`).
pub fn read_line_capped<R: BufRead>(
    reader: &mut R,
    cap: usize,
    buf: &mut Vec<u8>,
) -> std::io::Result<LineRead> {
    buf.clear();
    let mut discarding = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF.
            if discarding {
                return Ok(LineRead::TooLong);
            }
            if buf.is_empty() {
                return Ok(LineRead::Eof);
            }
            return Ok(LineRead::Line);
        }
        let (take, found_newline) = match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => (i + 1, true),
            None => (chunk.len(), false),
        };
        if !discarding {
            let keep = take - usize::from(found_newline);
            if buf.len() + keep > cap {
                discarding = true;
                buf.clear();
            } else {
                buf.extend_from_slice(&chunk[..keep]);
            }
        }
        reader.consume(take);
        if found_newline {
            if discarding {
                return Ok(LineRead::TooLong);
            }
            return Ok(LineRead::Line);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capped_reader_handles_long_partial_and_binary_lines() {
        use std::io::Cursor;
        let cap = 16;
        let mut buf: Vec<u8> = Vec::new();
        // Normal short lines pass through, CRLF and all. The buffer is
        // reused across reads (cleared each time, never reallocated).
        let mut r = Cursor::new(b"hello\nworld\r\n".to_vec());
        match read_line_capped(&mut r, cap, &mut buf).unwrap() {
            LineRead::Line => assert_eq!(String::from_utf8_lossy(&buf), "hello"),
            _ => panic!("expected line"),
        }
        match read_line_capped(&mut r, cap, &mut buf).unwrap() {
            LineRead::Line => assert_eq!(String::from_utf8_lossy(&buf), "world\r"),
            _ => panic!("expected line"),
        }
        assert!(matches!(read_line_capped(&mut r, cap, &mut buf).unwrap(), LineRead::Eof));
        // An oversized line is consumed (not buffered) and the stream
        // stays usable for the next line.
        let mut big = vec![b'x'; 100];
        big.push(b'\n');
        big.extend_from_slice(b"next\n");
        let mut r = Cursor::new(big);
        assert!(matches!(read_line_capped(&mut r, cap, &mut buf).unwrap(), LineRead::TooLong));
        match read_line_capped(&mut r, cap, &mut buf).unwrap() {
            LineRead::Line => assert_eq!(String::from_utf8_lossy(&buf), "next"),
            _ => panic!("expected line"),
        }
        // A half-written final line (no newline before EOF) is returned
        // as a line; binary garbage is replaced lossily, not fatal.
        let mut r = Cursor::new(b"\xff\xfepartial".to_vec());
        match read_line_capped(&mut r, cap, &mut buf).unwrap() {
            LineRead::Line => {
                let l = String::from_utf8_lossy(&buf);
                assert!(l.contains("partial"));
            }
            _ => panic!("expected line"),
        }
        // An oversized line that never terminates before EOF is TooLong.
        let mut r = Cursor::new(vec![b'y'; 50]);
        assert!(matches!(read_line_capped(&mut r, cap, &mut buf).unwrap(), LineRead::TooLong));
    }
}
