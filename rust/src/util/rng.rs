//! Deterministic PRNG substrate.
//!
//! The offline crate set has no `rand` (only `rand_core`), so we carry our
//! own generator: PCG-XSH-RR 64/32 state with a 64-bit output mix — fast,
//! statistically solid for simulation workloads, and fully reproducible.
//! Every experiment in this repo threads explicit seeds through this type so
//! that figures and tables regenerate bit-identically.

/// Splittable deterministic PRNG (PCG64-style).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second normal variate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1, spare_normal: None };
        r.next_u64();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u64();
        r
    }

    /// Derive an independent stream; used to give each dataset column /
    /// base model / trial its own generator without coupling.
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Rng::new(s)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // Two rounds of a 64-bit PCG-like LCG + xorshift mix.
        self.state = self
            .state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let mut x = self.state;
        x ^= x >> 33;
        x = x.wrapping_mul(0xff51afd7ed558ccd);
        x ^= x >> 33;
        x = x.wrapping_mul(0xc4ceb9fe1a85ec53);
        x ^= x >> 33;
        x
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k ≤ n), order randomized.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        // Partial Fisher-Yates over an index vector.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(5);
        let ks = r.choose_k(30, 8);
        assert_eq!(ks.len(), 8);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
        assert!(s.iter().all(|&i| i < 30));
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.split(1);
        let mut b = r.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
