//! Runtime-dispatched SIMD kernels for the two sweep inner loops.
//!
//! Exactly two loops dominate the serving hot path, and both live here
//! as explicitly vectorized kernels with a scalar twin:
//!
//! - [`accumulate_keep_mask`] — the sweep core's pass 1
//!   (`qwyc/sweep.rs`): `g[j] += scores[j]` plus the branchless exit
//!   mask `keep = !((g > ε⁺) | (g < ε⁻))` over the compacted active
//!   block.
//! - [`select16`] — one level of the quantized 16-lane tree walk
//!   (`gbt/tree.rs`): `idx = if qv <= qt { left } else { right }`, an
//!   integer compare+select over u16 bin indices widened to u32 lanes.
//!
//! Dispatch is decided **once per process** by [`tier`]:
//! `is_x86_feature_detected!` picks AVX2 where available, SSE2
//! otherwise (baseline on x86-64), and the scalar twins everywhere else
//! — or everywhere, when the `QWYC_FORCE_SCALAR=1` override is set (CI
//! runs the whole test suite once per tier this way). The scalar twins
//! are public so equivalence tests can pin `dispatched == scalar`
//! in-process without mutating the environment.
//!
//! Bitwise contract: every tier computes the *same* IEEE-754 result.
//! The accumulate kernel performs the identical per-element `f32` add
//! (no reassociation, no FMA contraction — `std::arch` intrinsics map
//! to fixed instructions), and the compares are ordered/quiet, so a NaN
//! running score fails both threshold compares and stays active exactly
//! as in the scalar code. The select kernel is pure integer lane math.
//!
//! Design note — no gathers: the quantized walk's per-lane node fetches
//! stay scalar (stack-array staging in `gbt/tree.rs`) and only the
//! compare+select is vectorized. AVX2 `vpgatherdd` over u16 banks would
//! need 2-byte-past-the-end reads or widened banks, is microcoded on
//! common cores, and buys little when the fetch addresses are
//! data-dependent anyway; the select chain is where the lane-parallel
//! work is.

use std::sync::atomic::{AtomicU8, Ordering};

/// Lane count of [`select16`]; must equal the tree walk's
/// `SOA_LANES` (asserted at compile time in `gbt/tree.rs`).
pub const SELECT_LANES: usize = 16;

/// Instruction-set tier selected at runtime for the sweep kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// 256-bit `std::arch` AVX2 paths.
    Avx2,
    /// 128-bit SSE2 paths (baseline on x86-64).
    Sse2,
    /// The portable scalar twins (non-x86 targets, or
    /// `QWYC_FORCE_SCALAR=1`).
    Scalar,
}

impl SimdTier {
    /// Stable name for logs and bench labels.
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Avx2 => "avx2",
            SimdTier::Sse2 => "sse2",
            SimdTier::Scalar => "scalar",
        }
    }
}

// 0 = not yet detected; otherwise SimdTier discriminant + 1.
static TIER: AtomicU8 = AtomicU8::new(0);

fn detect() -> SimdTier {
    if std::env::var("QWYC_FORCE_SCALAR").map(|v| v.trim() == "1").unwrap_or(false) {
        return SimdTier::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdTier::Avx2;
        }
        if std::arch::is_x86_feature_detected!("sse2") {
            return SimdTier::Sse2;
        }
    }
    SimdTier::Scalar
}

/// The process-wide kernel tier: detected once (honoring
/// `QWYC_FORCE_SCALAR=1`), then cached. Every dispatched kernel call
/// pays one relaxed atomic load.
pub fn tier() -> SimdTier {
    match TIER.load(Ordering::Relaxed) {
        1 => SimdTier::Avx2,
        2 => SimdTier::Sse2,
        3 => SimdTier::Scalar,
        _ => {
            let t = detect();
            let code = match t {
                SimdTier::Avx2 => 1,
                SimdTier::Sse2 => 2,
                SimdTier::Scalar => 3,
            };
            TIER.store(code, Ordering::Relaxed);
            t
        }
    }
}

// ---- accumulate + keep mask ---------------------------------------------

/// Sweep pass 1 over one active block: `g[j] += scores[j]`, then
/// `keep[j] = !((g[j] > ep) | (g[j] < en))` as 0/1 bytes. All three
/// slices must have equal length. Bitwise-identical across tiers (see
/// the module docs); a NaN sum fails both compares and keeps the
/// example active.
pub fn accumulate_keep_mask(g: &mut [f32], scores: &[f32], keep: &mut [u8], ep: f32, en: f32) {
    assert_eq!(g.len(), scores.len());
    assert_eq!(g.len(), keep.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => {
            // SAFETY: tier() returned Avx2 only after
            // is_x86_feature_detected!("avx2") succeeded.
            unsafe { accumulate_keep_mask_avx2(g, scores, keep, ep, en) }
        }
        #[cfg(target_arch = "x86_64")]
        SimdTier::Sse2 => {
            // SAFETY: SSE2 is baseline on x86-64 and was detected.
            unsafe { accumulate_keep_mask_sse2(g, scores, keep, ep, en) }
        }
        _ => accumulate_keep_mask_scalar(g, scores, keep, ep, en),
    }
}

/// Scalar twin of [`accumulate_keep_mask`] — the reference semantics,
/// kept public so tests can pin the dispatched kernel against it.
pub fn accumulate_keep_mask_scalar(
    g: &mut [f32],
    scores: &[f32],
    keep: &mut [u8],
    ep: f32,
    en: f32,
) {
    for ((gi, &s), k) in g.iter_mut().zip(scores.iter()).zip(keep.iter_mut()) {
        let v = *gi + s;
        *gi = v;
        *k = u8::from(!((v > ep) | (v < en)));
    }
}

/// # Safety
/// Caller must have verified AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_keep_mask_avx2(
    g: &mut [f32],
    scores: &[f32],
    keep: &mut [u8],
    ep: f32,
    en: f32,
) {
    use std::arch::x86_64::*;
    let m = g.len();
    let vep = _mm256_set1_ps(ep);
    let ven = _mm256_set1_ps(en);
    let mut j = 0usize;
    while j + 8 <= m {
        let gv = _mm256_loadu_ps(g.as_ptr().add(j));
        let sv = _mm256_loadu_ps(scores.as_ptr().add(j));
        // One f32 add per element, same operand order as the scalar twin.
        let sum = _mm256_add_ps(gv, sv);
        _mm256_storeu_ps(g.as_mut_ptr().add(j), sum);
        // Ordered/quiet compares: NaN ⇒ false on both, so NaN keeps.
        let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(sum, vep);
        let lt = _mm256_cmp_ps::<_CMP_LT_OQ>(sum, ven);
        let bits = _mm256_movemask_ps(_mm256_or_ps(gt, lt)) as u32;
        for (lane, k) in keep[j..j + 8].iter_mut().enumerate() {
            *k = ((!bits >> lane) & 1) as u8;
        }
        j += 8;
    }
    accumulate_keep_mask_scalar(&mut g[j..], &scores[j..], &mut keep[j..], ep, en);
}

/// # Safety
/// Caller must have verified SSE2 support (baseline on x86-64).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn accumulate_keep_mask_sse2(
    g: &mut [f32],
    scores: &[f32],
    keep: &mut [u8],
    ep: f32,
    en: f32,
) {
    use std::arch::x86_64::*;
    let m = g.len();
    let vep = _mm_set1_ps(ep);
    let ven = _mm_set1_ps(en);
    let mut j = 0usize;
    while j + 4 <= m {
        let gv = _mm_loadu_ps(g.as_ptr().add(j));
        let sv = _mm_loadu_ps(scores.as_ptr().add(j));
        let sum = _mm_add_ps(gv, sv);
        _mm_storeu_ps(g.as_mut_ptr().add(j), sum);
        // CMPPS with NaN operands compares false on both predicates.
        let gt = _mm_cmpgt_ps(sum, vep);
        let lt = _mm_cmplt_ps(sum, ven);
        let bits = _mm_movemask_ps(_mm_or_ps(gt, lt)) as u32;
        for (lane, k) in keep[j..j + 4].iter_mut().enumerate() {
            *k = ((!bits >> lane) & 1) as u8;
        }
        j += 4;
    }
    accumulate_keep_mask_scalar(&mut g[j..], &scores[j..], &mut keep[j..], ep, en);
}

// ---- 16-lane quantized select -------------------------------------------

/// One level of the quantized tree walk, [`SELECT_LANES`] lanes wide:
/// `idx[lane] = if qv[lane] <= qt[lane] { left[lane] } else
/// { right[lane] }`. Values are u16 bin indices (plus the `u16::MAX`
/// NaN sentinel) widened to u32 by the caller, so the x86 paths'
/// signed 32-bit compares are exact.
pub fn select16(
    qv: &[u32; SELECT_LANES],
    qt: &[u32; SELECT_LANES],
    left: &[u32; SELECT_LANES],
    right: &[u32; SELECT_LANES],
    idx: &mut [u32; SELECT_LANES],
) {
    match tier() {
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => {
            // SAFETY: tier() returned Avx2 only after detection.
            unsafe { select16_avx2(qv, qt, left, right, idx) }
        }
        #[cfg(target_arch = "x86_64")]
        SimdTier::Sse2 => {
            // SAFETY: SSE2 is baseline on x86-64 and was detected.
            unsafe { select16_sse2(qv, qt, left, right, idx) }
        }
        _ => select16_scalar(qv, qt, left, right, idx),
    }
}

/// Scalar twin of [`select16`] — reference semantics, public for tests
/// and for the forced-scalar tier.
pub fn select16_scalar(
    qv: &[u32; SELECT_LANES],
    qt: &[u32; SELECT_LANES],
    left: &[u32; SELECT_LANES],
    right: &[u32; SELECT_LANES],
    idx: &mut [u32; SELECT_LANES],
) {
    for lane in 0..SELECT_LANES {
        idx[lane] = if qv[lane] <= qt[lane] { left[lane] } else { right[lane] };
    }
}

/// # Safety
/// Caller must have verified AVX2 support.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn select16_avx2(
    qv: &[u32; SELECT_LANES],
    qt: &[u32; SELECT_LANES],
    left: &[u32; SELECT_LANES],
    right: &[u32; SELECT_LANES],
    idx: &mut [u32; SELECT_LANES],
) {
    use std::arch::x86_64::*;
    for half in 0..2 {
        let o = half * 8;
        let v = _mm256_loadu_si256(qv.as_ptr().add(o).cast());
        let t = _mm256_loadu_si256(qt.as_ptr().add(o).cast());
        let lv = _mm256_loadu_si256(left.as_ptr().add(o).cast());
        let rv = _mm256_loadu_si256(right.as_ptr().add(o).cast());
        // Values fit in 16 bits, so the signed epi32 compare is exact:
        // qv > qt ⇒ all-ones lane ⇒ pick right (`<=` goes left).
        let gt = _mm256_cmpgt_epi32(v, t);
        let sel = _mm256_blendv_epi8(lv, rv, gt);
        _mm256_storeu_si256(idx.as_mut_ptr().add(o).cast(), sel);
    }
}

/// # Safety
/// Caller must have verified SSE2 support (baseline on x86-64).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn select16_sse2(
    qv: &[u32; SELECT_LANES],
    qt: &[u32; SELECT_LANES],
    left: &[u32; SELECT_LANES],
    right: &[u32; SELECT_LANES],
    idx: &mut [u32; SELECT_LANES],
) {
    use std::arch::x86_64::*;
    for quarter in 0..4 {
        let o = quarter * 4;
        let v = _mm_loadu_si128(qv.as_ptr().add(o).cast());
        let t = _mm_loadu_si128(qt.as_ptr().add(o).cast());
        let lv = _mm_loadu_si128(left.as_ptr().add(o).cast());
        let rv = _mm_loadu_si128(right.as_ptr().add(o).cast());
        let gt = _mm_cmpgt_epi32(v, t);
        // SSE2 has no blendv: (gt & right) | (!gt & left).
        let sel = _mm_or_si128(_mm_and_si128(gt, rv), _mm_andnot_si128(gt, lv));
        _mm_storeu_si128(idx.as_mut_ptr().add(o).cast(), sel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(i: usize, salt: u32) -> f32 {
        let h = (i as u32).wrapping_mul(2654435761).wrapping_add(salt).wrapping_mul(40503);
        ((h >> 16) as f32 / 65536.0) - 0.5
    }

    /// Dispatched kernel vs the scalar twin, bit for bit, across sizes
    /// that cover the vector body and the scalar tail — including NaN,
    /// ±∞, and threshold-equal sums.
    #[test]
    fn accumulate_matches_scalar_bitwise() {
        for m in [0usize, 1, 3, 4, 7, 8, 9, 16, 31, 97] {
            let mut g1: Vec<f32> = (0..m).map(|i| synth(i, 1)).collect();
            let mut s: Vec<f32> = (0..m).map(|i| synth(i, 2)).collect();
            // Adversarial values in fixed slots.
            if m > 4 {
                g1[0] = f32::NAN;
                g1[1] = f32::INFINITY;
                s[2] = f32::NEG_INFINITY;
                g1[3] = 0.25;
                s[3] = 0.0; // sum exactly equal to ep below: > is false ⇒ keep
                s[4] = f32::NAN;
            }
            let mut g2 = g1.clone();
            let mut k1 = vec![9u8; m];
            let mut k2 = vec![7u8; m];
            accumulate_keep_mask(&mut g1, &s, &mut k1, 0.25, -0.25);
            accumulate_keep_mask_scalar(&mut g2, &s, &mut k2, 0.25, -0.25);
            for j in 0..m {
                assert_eq!(g1[j].to_bits(), g2[j].to_bits(), "m={m} j={j}: g bits");
                assert_eq!(k1[j], k2[j], "m={m} j={j}: keep");
            }
        }
    }

    /// NaN sums keep the example active on every tier, and an exactly
    /// threshold-equal sum does not exit (strict compares).
    #[test]
    fn keep_mask_contract_nan_and_edges() {
        let mut g = [f32::NAN, 1.0, -1.0, 0.5, -0.5, 0.0, 2.0, -2.0];
        let s = [0.0f32; 8];
        let mut keep = [0u8; 8];
        accumulate_keep_mask(&mut g, &s, &mut keep, 0.5, -0.5);
        // NaN keeps; ±1 exit; ±0.5 are == thresholds ⇒ keep; 0 keeps;
        // ±2 exit.
        assert_eq!(keep, [1, 0, 0, 1, 1, 1, 0, 0]);
    }

    #[test]
    fn select16_matches_scalar_on_sentinels_and_edges() {
        // qv covers: below, equal, above, NaN sentinel, max finite bin.
        let qv: [u32; 16] = [
            0, 5, 6, 65535, 65534, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11,
        ];
        let qt: [u32; 16] = [5; 16];
        let left: [u32; 16] = core::array::from_fn(|i| 100 + i as u32);
        let right: [u32; 16] = core::array::from_fn(|i| 200 + i as u32);
        let mut got = [0u32; 16];
        let mut want = [0u32; 16];
        select16(&qv, &qt, &left, &right, &mut got);
        select16_scalar(&qv, &qt, &left, &right, &mut want);
        assert_eq!(got, want);
        // Spot-check the contract itself: <= goes left.
        assert_eq!(want[0], 100); // 0 <= 5
        assert_eq!(want[1], 101); // 5 <= 5
        assert_eq!(want[2], 202); // 6 > 5
        assert_eq!(want[3], 203); // NaN sentinel routes right
    }

    #[test]
    fn tier_is_cached_and_named() {
        let t1 = tier();
        let t2 = tier();
        assert_eq!(t1, t2);
        assert!(["avx2", "sse2", "scalar"].contains(&t1.name()));
    }
}
