//! Miniature property-testing substrate (proptest is unavailable offline).
//!
//! `check` runs a property over many seeded random cases; on failure it
//! reports the case index and seed so the exact case replays with
//! `Gen::new(seed)`. No shrinking — failures print their inputs instead
//! (properties in this repo construct small human-readable cases).

use crate::util::rng::Rng;

/// A property failure: the human-readable description of the violated
/// case. `Err("message".into())` and `Err(format!(...).into())` both
/// construct it.
#[derive(Debug)]
pub struct PropFail(pub String);

impl From<String> for PropFail {
    fn from(s: String) -> PropFail {
        PropFail(s)
    }
}

impl From<&str> for PropFail {
    fn from(s: &str) -> PropFail {
        PropFail(s.to_string())
    }
}

impl std::fmt::Display for PropFail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// Case generator handed to properties — a thin veneer over [`Rng`] with
/// generators commonly needed by the QWYC invariants.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.range_f64(lo as f64, hi as f64) as f32).collect()
    }

    /// Random score matrix values in roughly unit scale with outliers.
    pub fn score(&mut self) -> f32 {
        let base = self.rng.normal() as f32;
        if self.rng.bool(0.05) {
            base * 10.0
        } else {
            base
        }
    }
}

/// Run `cases` random cases of the property. Property returns
/// `Err(description)` to fail. Panics with seed info on first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), PropFail>,
{
    // Fixed base seed: reproducible CI. Vary per-case deterministically.
    let base = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (replay with Gen::new({seed:#x})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sort is idempotent", 50, |g| {
            let n = g.usize_in(0, 50);
            let mut v = g.vec_f32(n, -5.0, 5.0);
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let v2 = {
                let mut w = v.clone();
                w.sort_by(|a, b| a.partial_cmp(b).unwrap());
                w
            };
            if v == v2 {
                Ok(())
            } else {
                Err("not idempotent".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 5, |_| Err("boom".into()));
    }
}
