//! Std-only scoped worker pool (no external crates — the build image is
//! offline, so rayon/crossbeam are unavailable; DESIGN.md §4).
//!
//! The pool is a *thread-count policy*, not a set of persistent workers:
//! each `par_*` call opens a `std::thread::scope`, spawns up to
//! `n_threads` workers that pull chunks of the index space off a shared
//! atomic counter (dynamic scheduling, so uneven chunks — e.g. Algorithm
//! 1 candidates over a shrinking active set — still balance), and joins
//! before returning. Spawn cost is a few tens of microseconds per call,
//! negligible against the O(T·N̄) / O(N·T) loops this parallelizes; for
//! small inputs every primitive falls back to a plain inline loop.
//!
//! Determinism contract: results are returned **in index order** no
//! matter how chunks were interleaved across workers, and the worker
//! closures receive disjoint index ranges — so any caller whose closure
//! is a pure function of its indices gets bit-identical output at every
//! thread count. The QWYC optimizers rely on this (see qwyc/order.rs and
//! rust/tests/parallel_equiv.rs).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker-count handle shared by every parallel hot path.
#[derive(Clone, Debug)]
pub struct Pool {
    n_threads: usize,
}

/// Thread count from the `QWYC_THREADS` env var. `0`, unset, and
/// unparseable all mean *auto*: use `std::thread::available_parallelism`
/// (so `QWYC_THREADS=0` matches the common "0 = all cores" convention
/// instead of silently pinning the pool to one worker).
pub fn threads_from_env() -> usize {
    let raw = std::env::var("QWYC_THREADS").ok();
    let available = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    parse_threads(raw.as_deref(), available)
}

/// Pure core of [`threads_from_env`], separated so the policy is unit-
/// testable without mutating process-global env state (tests run in
/// parallel threads).
fn parse_threads(raw: Option<&str>, available: usize) -> usize {
    match raw.and_then(|s| s.trim().parse::<usize>().ok()) {
        Some(0) | None => available.max(1),
        Some(v) => v,
    }
}

impl Pool {
    pub fn new(n_threads: usize) -> Pool {
        Pool { n_threads: n_threads.max(1) }
    }

    /// Pool sized by `QWYC_THREADS` / available parallelism.
    pub fn from_env() -> Pool {
        Pool::new(threads_from_env())
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// `(0..n).map(f)` with chunks of `chunk` indices scheduled across
    /// the pool; results are in index order. Runs inline when the pool
    /// has one thread or the whole range fits a single chunk.
    pub fn par_map_indexed<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let chunk = chunk.max(1);
        if self.n_threads == 1 || n <= chunk {
            return (0..n).map(f).collect();
        }
        let n_chunks = n.div_ceil(chunk);
        let parts = self.run_chunked(n_chunks, |c, out: &mut Vec<R>| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            out.extend((lo..hi).map(&f));
        });
        concat_in_order(parts, n)
    }

    /// Apply `f` to disjoint consecutive chunks of `items` (chunk index,
    /// chunk slice) and return one result per chunk, in chunk order.
    /// Workers reuse whatever per-chunk state `f` builds internally —
    /// this is the primitive for loops that want thread-local scratch.
    pub fn par_chunks<T, R, F>(&self, items: &[T], chunk: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &[T]) -> R + Sync,
    {
        let chunk = chunk.max(1);
        if self.n_threads == 1 || items.len() <= chunk {
            return items.chunks(chunk).enumerate().map(|(c, s)| f(c, s)).collect();
        }
        let n_chunks = items.len().div_ceil(chunk);
        let parts = self.run_chunked(n_chunks, |c, out: &mut Vec<R>| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(items.len());
            out.push(f(c, &items[lo..hi]));
        });
        concat_in_order(parts, n_chunks)
    }

    /// Shared scheduling core: workers pull chunk ids off an atomic
    /// counter and append `(chunk_id, results)` pairs to a shared bag.
    fn run_chunked<R, G>(&self, n_chunks: usize, work: G) -> Vec<(usize, Vec<R>)>
    where
        R: Send,
        G: Fn(usize, &mut Vec<R>) + Sync,
    {
        let next = AtomicUsize::new(0);
        let bag: Mutex<Vec<(usize, Vec<R>)>> = Mutex::new(Vec::with_capacity(n_chunks));
        let workers = self.n_threads.min(n_chunks);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    let mut local: Vec<(usize, Vec<R>)> = Vec::new();
                    loop {
                        let c = next.fetch_add(1, Ordering::Relaxed);
                        if c >= n_chunks {
                            break;
                        }
                        let mut out = Vec::new();
                        work(c, &mut out);
                        local.push((c, out));
                    }
                    if !local.is_empty() {
                        bag.lock().unwrap().extend(local);
                    }
                });
            }
        });
        bag.into_inner().unwrap()
    }
}

/// Restore index order after dynamic scheduling.
fn concat_in_order<R>(mut parts: Vec<(usize, Vec<R>)>, size_hint: usize) -> Vec<R> {
    parts.sort_unstable_by_key(|(c, _)| *c);
    let mut out = Vec::with_capacity(size_hint);
    for (_, v) in parts {
        out.extend(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_preserves_order() {
        for threads in [1, 2, 4, 7] {
            let pool = Pool::new(threads);
            let got = pool.par_map_indexed(1000, 16, |i| i * i);
            let want: Vec<usize> = (0..1000).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn chunks_cover_everything_once() {
        let items: Vec<u32> = (0..513).collect();
        for threads in [1, 3, 8] {
            let pool = Pool::new(threads);
            let sums = pool.par_chunks(&items, 64, |c, s| (c, s.iter().sum::<u32>()));
            // One result per chunk, in chunk order.
            assert_eq!(sums.len(), 513usize.div_ceil(64));
            for (i, &(c, _)) in sums.iter().enumerate() {
                assert_eq!(c, i);
            }
            let total: u32 = sums.iter().map(|&(_, s)| s).sum();
            assert_eq!(total, items.iter().sum::<u32>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let pool = Pool::new(4);
        assert!(pool.par_map_indexed(0, 8, |i| i).is_empty());
        assert!(pool.par_chunks(&[] as &[u8], 8, |_, s| s.len()).is_empty());
        assert_eq!(pool.par_map_indexed(1, 8, |i| i + 1), vec![1]);
    }

    #[test]
    fn uneven_work_balances() {
        // Chunks with wildly different costs must still come back ordered.
        let pool = Pool::new(4);
        let got = pool.par_map_indexed(64, 1, |i| {
            let spins = if i % 7 == 0 { 20_000 } else { 10 };
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_add(std::hint::black_box(k));
            }
            (i, acc > 0)
        });
        for (i, &(idx, _)) in got.iter().enumerate() {
            assert_eq!(idx, i);
        }
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(Pool::new(0).n_threads(), 1);
    }

    #[test]
    fn env_thread_policy() {
        // QWYC_THREADS=0 means auto (all available cores), not serial.
        assert_eq!(parse_threads(Some("0"), 8), 8);
        assert_eq!(parse_threads(Some(" 0 "), 8), 8);
        // Explicit counts pass through untouched, even oversubscribed.
        assert_eq!(parse_threads(Some("3"), 8), 3);
        assert_eq!(parse_threads(Some("16"), 8), 16);
        // Unset or garbage falls back to auto; auto itself clamps to ≥ 1.
        assert_eq!(parse_threads(None, 8), 8);
        assert_eq!(parse_threads(Some("lots"), 8), 8);
        assert_eq!(parse_threads(Some("0"), 0), 1);
        assert_eq!(parse_threads(None, 0), 1);
    }
}
