//! Tiny argv parser substrate (clap is unavailable offline).
//!
//! Supports the patterns this repo's binaries use:
//!   `qwyc <subcommand> [positionals] --key value --flag`
//! with typed getters and defaults. Unknown-flag detection is explicit so
//! typos fail loudly instead of silently using a default.

use crate::error::QwycError;
use std::collections::BTreeMap;

/// Every CLI-parse failure is a `Config` error.
fn config(msg: String) -> QwycError {
    QwycError::Config(msg)
}

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags actually consumed by getters, for unknown-flag detection.
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args, QwycError> {
        let mut a = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(config("bare '--' not supported".into()));
                }
                // --key=value or --key value or boolean --key
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    a.flags.insert(name.to_string(), v);
                } else {
                    a.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(arg);
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args, QwycError> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, QwycError> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| config(format!("--{key}: {e}"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, QwycError> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| config(format!("--{key}: {e}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, QwycError> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| config(format!("--{key}: {e}"))),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, QwycError> {
        self.mark(key);
        match self.flags.get(key).map(|s| s.as_str()) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(config(format!("--{key}: expected bool, got '{v}'"))),
        }
    }

    /// Comma-separated f64 list, e.g. `--alphas 0.001,0.005,0.01`.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, QwycError> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<f64>().map_err(|e| config(format!("--{key}: {e}"))))
                .collect(),
        }
    }

    /// Error if any provided flag was never consumed by a getter.
    pub fn check_unknown(&self) -> Result<(), QwycError> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !seen.contains(k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(config(format!("unknown flag(s): {unknown:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("train fig1 --dataset adult --trees 500 --verbose");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.positional, vec!["train", "fig1"]);
        assert_eq!(a.get_str("dataset", "x"), "adult");
        assert_eq!(a.get_usize("trees", 1).unwrap(), 500);
        assert!(a.get_bool("verbose", false).unwrap());
    }

    #[test]
    fn eq_form_and_lists() {
        let a = parse("x --alpha=0.01 --alphas 0.1,0.2,0.3");
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.01);
        assert_eq!(a.get_f64_list("alphas", &[]).unwrap(), vec![0.1, 0.2, 0.3]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_str("s", "d"), "d");
        assert!(!a.get_bool("b", false).unwrap());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --known 1 --typo 2");
        let _ = a.get_usize("known", 0);
        assert!(a.check_unknown().is_err());
        let _ = a.get_usize("typo", 0);
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn bad_values_error() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n", 0).is_err());
    }
}
