//! Deterministic failpoint layer for chaos testing the serving runtime.
//!
//! A failpoint is a named hook compiled into production code paths
//! (`failpoints::fire("shard_panic")`) that stays dormant unless the
//! process opts in — either through the `QWYC_FAILPOINTS` environment
//! variable or programmatically via [`configure`]. When dormant the cost
//! is one relaxed atomic load, so the hooks can live on the batch hot
//! path of the coordinator without a feature gate.
//!
//! # Grammar
//!
//! ```text
//! QWYC_FAILPOINTS = entry [';' entry]*
//! entry           = name ['@' key '=' value [',' key '=' value]*]
//! ```
//!
//! e.g. `shard_panic@at=3;slow_batch@shard=1,ms=50;reload_corrupt`.
//!
//! Recognised keys (all values are unsigned integers):
//!
//! | key       | meaning                                                   |
//! |-----------|-----------------------------------------------------------|
//! | `at`      | fire exactly on the Nth hit (1-based), never again        |
//! | `batch`   | alias for `at` — reads naturally for per-batch hooks      |
//! | `every`   | fire on every Nth hit                                     |
//! | `shard`   | only hits reported from this shard index count            |
//! | `ms`      | payload for sleep-style failpoints (see [`sleep_ms`])     |
//! | `p`       | fire with probability p% per hit, seeded-deterministic    |
//! | `seed`    | seed for `p` (default `0x5eed`)                           |
//!
//! A bare `name` with no args fires on every hit. Unknown names never
//! fire; unknown keys are ignored so specs stay forward-compatible.
//!
//! # Determinism
//!
//! All triggers are functions of the per-failpoint hit counter (and, for
//! `p`, a SplitMix64 hash of `seed ^ hit`), never of wall-clock time or
//! global RNG state — the same spec against the same request sequence
//! reproduces the same faults.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once};

use crate::error::QwycError;

/// Environment variable read (once, lazily) for the process-wide spec.
pub const ENV_VAR: &str = "QWYC_FAILPOINTS";

/// One configured failpoint: its parsed `key=value` args plus a
/// monotonically increasing hit counter.
struct Spec {
    args: Vec<(String, u64)>,
    hits: AtomicU64,
}

impl Spec {
    fn arg(&self, key: &str) -> Option<u64> {
        self.args.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }
}

static INIT: Once = Once::new();
static ENABLED: AtomicBool = AtomicBool::new(false);
// A Vec rather than a HashMap because `Mutex::new(Vec::new())` is const;
// specs hold a handful of entries, so linear lookup is fine.
static TABLE: Mutex<Vec<(String, Arc<Spec>)>> = Mutex::new(Vec::new());

fn table() -> std::sync::MutexGuard<'static, Vec<(String, Arc<Spec>)>> {
    // A panic while holding the table lock leaves consistent data (we
    // only ever replace or read the Vec), so poisoning is ignorable.
    TABLE.lock().unwrap_or_else(|e| e.into_inner())
}

fn install(parsed: Vec<(String, Arc<Spec>)>) {
    let enabled = !parsed.is_empty();
    *table() = parsed;
    ENABLED.store(enabled, Ordering::SeqCst);
}

fn ensure_init() {
    INIT.call_once(|| {
        if let Ok(spec) = std::env::var(ENV_VAR) {
            match parse(&spec) {
                Ok(parsed) => install(parsed),
                Err(e) => eprintln!("{ENV_VAR} ignored: {}", e.message()),
            }
        }
    });
}

/// Cheap global check: are ANY failpoints configured? This is the only
/// cost production pays when chaos is off — guard non-trivial hook
/// work behind it.
pub fn enabled() -> bool {
    ensure_init();
    ENABLED.load(Ordering::Relaxed)
}

/// Install a failpoint spec programmatically, replacing any previous
/// configuration (including one loaded from the environment). An empty
/// spec disables all failpoints. Tests use this — it claims the
/// one-time env read, so explicit configuration always wins.
pub fn configure(spec: &str) -> Result<(), QwycError> {
    INIT.call_once(|| {});
    let parsed = parse(spec)?;
    install(parsed);
    Ok(())
}

fn parse(spec: &str) -> Result<Vec<(String, Arc<Spec>)>, QwycError> {
    let mut out: Vec<(String, Arc<Spec>)> = Vec::new();
    for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, args_str) = match entry.split_once('@') {
            Some((n, a)) => (n.trim(), a),
            None => (entry, ""),
        };
        if name.is_empty() {
            return Err(QwycError::Config(format!("failpoint entry '{entry}' has no name")));
        }
        let mut args = Vec::new();
        for kv in args_str.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = kv.split_once('=').ok_or_else(|| {
                QwycError::Config(format!("failpoint arg '{kv}' is not key=value (in '{entry}')"))
            })?;
            let v: u64 = v.trim().parse().map_err(|_| {
                QwycError::Config(format!("failpoint arg '{kv}' has a non-integer value"))
            })?;
            args.push((k.trim().to_string(), v));
        }
        out.push((name.to_string(), Arc::new(Spec { args, hits: AtomicU64::new(0) })));
    }
    Ok(out)
}

fn lookup(name: &str) -> Option<Arc<Spec>> {
    table().iter().find(|(n, _)| n == name).map(|(_, s)| s.clone())
}

/// Report a hit on `name` with no shard affinity; returns whether the
/// failpoint should trigger.
pub fn fire(name: &str) -> bool {
    fire_at(name, None)
}

/// Report a hit on `name` from shard `shard`. Entries carrying a
/// `shard=` filter only count hits from that shard.
pub fn fire_on_shard(name: &str, shard: u64) -> bool {
    fire_at(name, Some(shard))
}

fn fire_at(name: &str, shard: Option<u64>) -> bool {
    if !enabled() {
        return false;
    }
    let Some(spec) = lookup(name) else { return false };
    if let Some(want) = spec.arg("shard") {
        if shard != Some(want) {
            return false;
        }
    }
    let hit = spec.hits.fetch_add(1, Ordering::SeqCst) + 1;
    if let Some(at) = spec.arg("at").or_else(|| spec.arg("batch")) {
        return hit == at;
    }
    if let Some(every) = spec.arg("every") {
        return every > 0 && hit % every == 0;
    }
    if let Some(p) = spec.arg("p") {
        let seed = spec.arg("seed").unwrap_or(0x5eed);
        return splitmix64(seed ^ hit) % 100 < p;
    }
    true
}

/// The configured value of `key` for failpoint `name`, if any. Used by
/// payload-carrying hooks (e.g. `ms` for [`sleep_ms`]).
pub fn arg(name: &str, key: &str) -> Option<u64> {
    if !enabled() {
        return None;
    }
    lookup(name).and_then(|s| s.arg(key))
}

/// Sleep hook: if `name` fires for `shard`, sleep its `ms=` payload
/// (default 10ms) and return true.
pub fn sleep_ms(name: &str, shard: u64) -> bool {
    if !fire_on_shard(name, shard) {
        return false;
    }
    let ms = arg(name, "ms").unwrap_or(10);
    std::thread::sleep(std::time::Duration::from_millis(ms));
    true
}

/// Panic hook: if `name` fires for `shard`, panic with a recognizable
/// message. The supervisor's `catch_unwind` is expected to absorb it.
pub fn maybe_panic(name: &str, shard: u64) {
    if fire_on_shard(name, shard) {
        panic!("injected failpoint '{name}'");
    }
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The failpoint table is process-global and lib unit tests run in
    // parallel threads, so every test in this module serializes on one
    // lock and clears the table before releasing it.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    struct Guard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

    impl Drop for Guard<'_> {
        fn drop(&mut self) {
            configure("").unwrap();
        }
    }

    fn guard(spec: &str) -> Guard<'_> {
        let g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        configure(spec).unwrap();
        Guard(g)
    }

    #[test]
    fn disabled_by_default_and_after_clear() {
        let _g = guard("");
        assert!(!enabled());
        assert!(!fire("anything"));
        assert_eq!(arg("anything", "ms"), None);
    }

    #[test]
    fn bare_name_fires_every_hit_and_unknown_names_never_fire() {
        let _g = guard("always_on");
        assert!(enabled());
        assert!(fire("always_on"));
        assert!(fire("always_on"));
        assert!(!fire("never_configured"));
    }

    #[test]
    fn at_fires_exactly_once_on_the_nth_hit() {
        let _g = guard("boom@at=3");
        let fired: Vec<bool> = (0..5).map(|_| fire("boom")).collect();
        assert_eq!(fired, vec![false, false, true, false, false]);
    }

    #[test]
    fn batch_is_an_alias_for_at() {
        let _g = guard("boom@batch=2");
        assert!(!fire("boom"));
        assert!(fire("boom"));
        assert!(!fire("boom"));
    }

    #[test]
    fn every_fires_periodically() {
        let _g = guard("tick@every=2");
        let fired: Vec<bool> = (0..6).map(|_| fire("tick")).collect();
        assert_eq!(fired, vec![false, true, false, true, false, true]);
    }

    #[test]
    fn shard_filter_ignores_other_shards() {
        let _g = guard("boom@shard=1,at=1");
        // Hits from shard 0 don't even advance the counter.
        assert!(!fire_on_shard("boom", 0));
        assert!(!fire_on_shard("boom", 0));
        assert!(fire_on_shard("boom", 1));
        assert!(!fire_on_shard("boom", 1));
    }

    #[test]
    fn probabilistic_trigger_is_deterministic_for_a_seed() {
        let _g = guard("flaky@p=50,seed=7");
        let first: Vec<bool> = (0..32).map(|_| fire("flaky")).collect();
        configure("flaky@p=50,seed=7").unwrap();
        let second: Vec<bool> = (0..32).map(|_| fire("flaky")).collect();
        assert_eq!(first, second);
        assert!(first.iter().any(|&b| b) && first.iter().any(|&b| !b));
    }

    #[test]
    fn args_are_queryable_and_multiple_entries_coexist() {
        let _g = guard("slow_batch@shard=1,ms=50; reload_corrupt");
        assert_eq!(arg("slow_batch", "ms"), Some(50));
        assert_eq!(arg("slow_batch", "shard"), Some(1));
        assert_eq!(arg("slow_batch", "missing"), None);
        assert!(fire("reload_corrupt"));
        assert!(!fire_on_shard("slow_batch", 0));
        assert!(fire_on_shard("slow_batch", 1));
    }

    #[test]
    fn malformed_specs_are_rejected() {
        let _g = guard("");
        assert!(configure("boom@at").is_err());
        assert!(configure("boom@at=notanum").is_err());
        assert!(configure("@at=1").is_err());
    }
}
