//! Small statistics helpers shared by the optimizer, metrics, and the
//! experiment harness: moments, percentiles, histograms, and a fixed-bucket
//! latency histogram for the serving path.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on a *sorted* slice; p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Fixed-width histogram over [lo, hi] with `bins` buckets; values outside
/// the range clamp to the edge buckets. Used for Figures 5-6.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn add(&mut self, v: f64) {
        let bins = self.counts.len();
        let t = ((v - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let idx = (t.max(0.0) as usize).min(bins - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Bucket midpoints for rendering.
    pub fn midpoints(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len()).map(|i| self.lo + w * (i as f64 + 0.5)).collect()
    }

    /// Render as an ASCII bar chart (for terminal figure output).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mids = self.midpoints();
        let mut s = String::new();
        for (m, &c) in mids.iter().zip(self.counts.iter()) {
            let bar = "#".repeat((c as usize * width).div_ceil(max as usize).min(width));
            s.push_str(&format!("{m:>10.1} | {bar} {c}\n"));
        }
        s
    }
}

/// Log-bucketed latency recorder (nanoseconds); cheap enough for the
/// serving hot path. Buckets are powers of √2 from 100ns to ~100s.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    counts: Vec<u64>,
    total: u64,
    sum_ns: u128,
    max_ns: u64,
}

const LAT_BUCKETS: usize = 64;
const LAT_BASE_NS: f64 = 100.0;

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { counts: vec![0; LAT_BUCKETS], total: 0, sum_ns: 0, max_ns: 0 }
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        let idx = if ns as f64 <= LAT_BASE_NS {
            0
        } else {
            (((ns as f64 / LAT_BASE_NS).log2() * 2.0) as usize).min(LAT_BUCKETS - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.total as f64
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate percentile from bucket boundaries.
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return LAT_BASE_NS * 2f64.powf(i as f64 / 2.0);
            }
        }
        self.max_ns as f64
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 50.5).abs() < 1.0, "p50={p50}");
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for v in [0.5, 1.5, 1.6, 9.9, -3.0, 42.0] {
            h.add(v);
        }
        assert_eq!(h.total, 6);
        assert_eq!(h.counts[0], 2); // 0.5 and clamped -3.0
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 2); // 9.9 and clamped 42.0
    }

    #[test]
    fn latency_hist_percentiles_ordered() {
        let mut h = LatencyHist::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 100);
        }
        let p50 = h.percentile_ns(50.0);
        let p99 = h.percentile_ns(99.0);
        assert!(p50 < p99);
        assert!(h.mean_ns() > 0.0);
        assert_eq!(h.count(), 10_000);
    }
}
