//! Request/response body codecs for the scoring data plane: JSON
//! (`[1,2,3]` or `[[...],[...]]`) and CSV (one row per line) decoded
//! into feature vectors drawn from the connection's [`BufPool`] — the
//! same recycled buffers the line protocol parses into, so the warmed
//! HTTP path allocates nothing per row either. The JSON decoder is a
//! purpose-built scanner (rows are arrays of numbers, nothing else)
//! rather than a trip through `util::json`, which would allocate a
//! `Json` tree per row.

use crate::coordinator::server::BufPool;
use super::parse::BodyKind;

/// Decode the rows of a scoring request into pooled feature vectors,
/// appended to `rows` (caller recycles them after replying). Errors
/// name the offending row/token; any partial rows are returned to the
/// pool before erroring so a bad batch leaks nothing.
pub(crate) fn parse_rows(
    text: &str,
    kind: BodyKind,
    pool: &BufPool,
    rows: &mut Vec<Vec<f32>>,
) -> Result<(), String> {
    let start = rows.len();
    let result = match kind {
        BodyKind::Json => parse_json_rows(text, pool, rows),
        BodyKind::Csv => parse_csv_rows(text, pool, rows),
    };
    match result {
        Ok(()) if rows.len() == start => Err("no rows in body".to_string()),
        Ok(()) => Ok(()),
        Err(e) => {
            for row in rows.drain(start..) {
                pool.put_feats(row);
            }
            Err(e)
        }
    }
}

/// `[1,2,3]` (one row) or `[[1,2],[3,4]]` (a batch). Numbers only —
/// the feature space is f32 by contract.
fn parse_json_rows(text: &str, pool: &BufPool, rows: &mut Vec<Vec<f32>>) -> Result<(), String> {
    let mut s = Scanner { b: text.as_bytes(), i: 0 };
    s.skip_ws();
    s.expect(b'[').map_err(|e| format!("body: {e}"))?;
    s.skip_ws();
    if s.peek() == Some(b'[') {
        // Batch: [[...],[...],...]
        loop {
            let mut row = pool.get_feats();
            if let Err(e) = parse_json_row(&mut s, &mut row) {
                pool.put_feats(row);
                return Err(format!("row {}: {e}", rows.len()));
            }
            rows.push(row);
            s.skip_ws();
            match s.next() {
                Some(b',') => s.skip_ws(),
                Some(b']') => break,
                _ => return Err(format!("row {}: expected ',' or ']'", rows.len())),
            }
        }
    } else {
        // Single row: the '[' already consumed is the row's own.
        s.i -= 1;
        let mut row = pool.get_feats();
        if let Err(e) = parse_json_row(&mut s, &mut row) {
            pool.put_feats(row);
            return Err(format!("row 0: {e}"));
        }
        rows.push(row);
    }
    s.skip_ws();
    if s.i != s.b.len() {
        return Err("trailing bytes after rows".to_string());
    }
    Ok(())
}

/// One `[n, n, ...]` into a pooled buffer.
fn parse_json_row(s: &mut Scanner<'_>, row: &mut Vec<f32>) -> Result<(), String> {
    s.expect(b'[')?;
    s.skip_ws();
    if s.peek() == Some(b']') {
        s.i += 1;
        return Err("empty row".to_string());
    }
    loop {
        let v = s.number()?;
        row.push(v);
        s.skip_ws();
        match s.next() {
            Some(b',') => s.skip_ws(),
            Some(b']') => return Ok(()),
            _ => return Err("expected ',' or ']'".to_string()),
        }
    }
}

/// One row per non-empty line, comma-separated f32s.
fn parse_csv_rows(text: &str, pool: &BufPool, rows: &mut Vec<Vec<f32>>) -> Result<(), String> {
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let mut row = pool.get_feats();
        for token in line.split(',') {
            match token.trim().parse::<f32>() {
                Ok(v) => row.push(v),
                Err(_) => {
                    pool.put_feats(row);
                    return Err(format!("row {}: bad number '{}'", rows.len(), token.trim()));
                }
            }
        }
        rows.push(row);
    }
    Ok(())
}

/// Byte scanner for the row decoder.
struct Scanner<'a> {
    b: &'a [u8],
    i: usize,
}

impl Scanner<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        Some(c)
    }

    fn expect(&mut self, want: u8) -> Result<(), String> {
        match self.next() {
            Some(c) if c == want => Ok(()),
            Some(c) => Err(format!("expected '{}', found '{}'", want as char, c as char)),
            None => Err(format!("expected '{}', found end of body", want as char)),
        }
    }

    /// Scan one JSON number token and parse it as f32.
    fn number(&mut self) -> Result<f32, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let token = &self.b[start..self.i];
        // Valid UTF-8 by construction (ASCII digits/signs only).
        std::str::from_utf8(token)
            .ok()
            .and_then(|t| t.parse::<f32>().ok())
            .ok_or_else(|| "expected a number".to_string())
    }
}

/// Append `s` to `out` as a JSON string literal (quotes included).
/// Covers the control characters the encoder in `util::json` covers;
/// lives here so the zero-alloc data plane can write error bodies into
/// its reused buffer without building a `Json` tree.
pub(crate) fn write_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows_of(text: &str, kind: BodyKind) -> Result<Vec<Vec<f32>>, String> {
        let pool = BufPool::new();
        let mut rows = Vec::new();
        parse_rows(text, kind, &pool, &mut rows)?;
        Ok(rows)
    }

    #[test]
    fn json_single_row_and_batch() {
        assert_eq!(rows_of("[1, 2.5, -3e1]", BodyKind::Json).unwrap(), vec![vec![
            1.0, 2.5, -30.0
        ]]);
        assert_eq!(
            rows_of(" [[1,2],[3,4]] ", BodyKind::Json).unwrap(),
            vec![vec![1.0, 2.0], vec![3.0, 4.0]]
        );
    }

    #[test]
    fn json_rejects_garbage_and_returns_buffers() {
        for bad in ["", "[]", "[[]]", "[1,2", "[[1],[x]]", "[1,2]trail", "{\"a\":1}", "[[1],2]"] {
            assert!(rows_of(bad, BodyKind::Json).is_err(), "{bad:?} should fail");
        }
        // Errors name the failing row.
        let e = rows_of("[[1],[2],[bad]]", BodyKind::Json).unwrap_err();
        assert!(e.starts_with("row 2:"), "{e}");
    }

    #[test]
    fn csv_rows() {
        assert_eq!(
            rows_of("1,2\n\n3.5, 4\n", BodyKind::Csv).unwrap(),
            vec![vec![1.0, 2.0], vec![3.5, 4.0]]
        );
        assert!(rows_of("1,zap", BodyKind::Csv).is_err());
        assert!(rows_of("\n\n", BodyKind::Csv).is_err());
    }

    #[test]
    fn json_string_escaping() {
        let mut out = String::new();
        write_json_str(&mut out, "a\"b\\c\nd\u{1}");
        assert_eq!(out, "\"a\\\"b\\\\c\\nd\\u0001\"");
    }
}
