//! Std-only HTTP/1.1 front-end over the sharded serving runtime — the
//! second protocol surface of one serving stack, NOT a parallel path.
//! [`crate::coordinator::Server::attach_http`] binds this listener over
//! the SAME least-queued dispatcher, per-shard [`BatchQueue`] set,
//! response cache, deadlines, and [`PlanSlot`] as the line protocol, so
//! a `/v1/score` response is bitwise-identical to the `EVAL` reply for
//! the same row (rust/tests/http_api.rs pins this at 1 and 4 shards).
//!
//! Data plane (keep-alive + pipelining, per-connection recycled
//! buffers through the coordinator's [`BufPool`]):
//!
//! | route             | body                              | reply |
//! |-------------------|-----------------------------------|-------|
//! | `POST /v1/score`  | one row (JSON array or CSV line)  | `{"id","label","score","models","latency_us"}` |
//! | `POST /v1/score-batch` | rows (JSON array-of-arrays or CSV lines) | `{"results":[...],"ok","busy","timeout","error"}` |
//!
//! An `X-Deadline-Ms` header bounds queueing latency exactly like the
//! line protocol's `DEADLINE_MS=` token (`0` opts out of the server
//! default). Admission verdicts map onto status codes: queue-full
//! `BUSY` → 503, deadline `TIMEOUT` → 504, per-row engine errors →
//! 422; the JSON body carries the per-row detail either way.
//!
//! Admin plane, all behind per-route latency middleware
//! ([`metrics::HttpMetrics`]) whose p50/p99 surface in the metrics it
//! serves:
//!
//! - `GET /healthz` — liveness (503 once draining)
//! - `GET /stats` — [`Snapshot::to_json`] + per-route HTTP latency
//! - `GET /metrics` — Prometheus text exposition (shard counters,
//!   exit-position histogram, flush/cache/ops counters, HTTP routes)
//! - `GET /plan` — live [`ArtifactInfo`] (section table + quantization)
//! - `POST /reload` — validated hot-swap; staged rejection on 409
//! - `POST /drain` — stop admission, wait for shard queues to empty
//!
//! Request heads are parsed with the same capped reader as the line
//! protocol (`util::lineio`), headers and body are bounded
//! ([`parse::MAX_HEADER_LINE`], [`parse::MAX_BODY_BYTES`]), and a
//! framing-safe bad request (bad body, unknown route) errors that
//! request only — the connection survives (rust/tests/http_api.rs).
//!
//! [`BatchQueue`]: crate::coordinator::BatchQueue
//! [`PlanSlot`]: crate::plan::PlanSlot
//! [`BufPool`]: crate::coordinator::server::BufPool
//! [`Snapshot::to_json`]: crate::coordinator::Snapshot::to_json
//! [`ArtifactInfo`]: crate::plan::ArtifactInfo

mod body;
mod client;
mod conn;
mod metrics;
mod parse;

pub use client::{read_response_from, HttpClient, HttpResponse};

pub(crate) use conn::serve_conn;

use crate::coordinator::server::ConnShared;
use std::sync::Arc;

/// Shared state for every HTTP connection: the same dispatcher/metrics
/// context the line protocol's connections use, plus the per-route
/// latency middleware sinks (one instance per listener).
pub(crate) struct HttpState {
    pub(crate) ctx: Arc<ConnShared>,
    pub(crate) routes: metrics::HttpMetrics,
}

impl HttpState {
    pub(crate) fn new(ctx: Arc<ConnShared>) -> HttpState {
        HttpState { ctx, routes: metrics::HttpMetrics::new() }
    }
}
