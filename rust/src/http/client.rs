//! Minimal blocking HTTP/1.1 client for tests, the CI smoke driver,
//! and `bench-client --http`. Send and read are split so a load
//! generator can pipeline: issue several `send` calls back-to-back,
//! then drain the responses in order (the server answers FIFO per
//! connection).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

/// One parsed HTTP response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// Header (name, value) pairs in arrival order; names as sent.
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    /// First header value matching `name` (ASCII case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Blocking keep-alive client over one connection.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl HttpClient {
    pub fn connect(addr: &SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let writer = stream.try_clone()?;
        Ok(HttpClient { reader: BufReader::new(stream), writer })
    }

    /// Write one request (does not wait for the response). `headers`
    /// are extra headers; `Host` and `Content-Length` are always sent.
    pub fn send(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> std::io::Result<()> {
        write!(self.writer, "{method} {path} HTTP/1.1\r\nHost: qwyc\r\n")?;
        for (name, value) in headers {
            write!(self.writer, "{name}: {value}\r\n")?;
        }
        write!(self.writer, "Content-Length: {}\r\n\r\n", body.len())?;
        self.writer.write_all(body)?;
        self.writer.flush()
    }

    /// Read one response (blocking). Interim `100 Continue` responses
    /// are skipped transparently; the body is framed by the server's
    /// `Content-Length`.
    pub fn read_response(&mut self) -> std::io::Result<HttpResponse> {
        read_response_from(&mut self.reader)
    }

    /// Convenience: send one request and wait for its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> std::io::Result<HttpResponse> {
        self.send(method, path, headers, body)?;
        self.read_response()
    }

}

/// Read one response from any buffered reader. Shared by
/// [`HttpClient::read_response`] and load generators that split the
/// stream into a writer half and a dedicated reader thread.
pub fn read_response_from<R: BufRead>(reader: &mut R) -> std::io::Result<HttpResponse> {
    loop {
        let status_line = read_line_from(reader)?;
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|t| t.parse::<u16>().ok())
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad status line '{status_line}'"),
                )
            })?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let line = read_line_from(reader)?;
            if line.is_empty() {
                break;
            }
            if let Some((name, value)) = line.split_once(':') {
                let value = value.trim().to_string();
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.parse().unwrap_or(0);
                }
                headers.push((name.to_string(), value));
            }
        }
        if status == 100 {
            continue;
        }
        let mut body = vec![0u8; content_length];
        reader.read_exact(&mut body)?;
        let body = String::from_utf8_lossy(&body).into_owned();
        return Ok(HttpResponse { status, headers, body });
    }
}

fn read_line_from<R: BufRead>(reader: &mut R) -> std::io::Result<String> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "server closed the connection",
        ));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}
