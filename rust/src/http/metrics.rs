//! Per-route latency middleware and the Prometheus text exposition.
//!
//! Every HTTP request is timed around its handler and recorded under a
//! fixed route label ([`ROUTE_LABELS`]); the recorded p50/p99 surface
//! in the very `/metrics` and `/stats` responses the middleware wraps,
//! so the admin plane observes itself. The engine-side families render
//! from the same [`Snapshot`] that backs the line protocol's `STATS`
//! (single formatting authority — see [`Snapshot::to_json`]).
//!
//! [`Snapshot`]: crate::coordinator::Snapshot
//! [`Snapshot::to_json`]: crate::coordinator::Snapshot::to_json

use crate::coordinator::Snapshot;
use crate::util::json::Json;
use crate::util::stats::LatencyHist;
use std::fmt::Write as _;
use std::sync::Mutex;

/// Route labels for the middleware, in dispatch order. Unknown paths
/// fold into `"other"` so an attacker probing random URLs cannot grow
/// the label set (Prometheus cardinality stays fixed).
pub(crate) const ROUTE_LABELS: [&str; 9] = [
    "/v1/score",
    "/v1/score-batch",
    "/healthz",
    "/stats",
    "/metrics",
    "/plan",
    "/reload",
    "/drain",
    "other",
];

/// Index into [`ROUTE_LABELS`] for a request path.
pub(crate) fn route_index(path: &str) -> usize {
    ROUTE_LABELS.iter().position(|&r| r == path).unwrap_or(ROUTE_LABELS.len() - 1)
}

#[derive(Default)]
struct RouteStat {
    lat: LatencyHist,
    /// (status, count) pairs; a route answers with a handful of distinct
    /// statuses, so a tiny linear-scan vec beats a map here.
    statuses: Vec<(u16, u64)>,
}

/// One latency/status sink per route label. Each route has its own
/// mutex so `/metrics` scrapes don't contend with `/v1/score` traffic.
pub(crate) struct HttpMetrics {
    routes: Vec<Mutex<RouteStat>>,
}

impl HttpMetrics {
    pub(crate) fn new() -> HttpMetrics {
        let routes = ROUTE_LABELS.iter().map(|_| Mutex::new(RouteStat::default())).collect();
        HttpMetrics { routes }
    }

    /// Record one completed request (the middleware's single call site).
    pub(crate) fn record(&self, route: usize, status: u16, latency_ns: u64) {
        let mut r = self.routes[route].lock().unwrap();
        r.lat.record_ns(latency_ns);
        match r.statuses.iter_mut().find(|(s, _)| *s == status) {
            Some((_, c)) => *c += 1,
            None => r.statuses.push((status, 1)),
        }
    }

    /// Per-route request counts, latency percentiles, and status
    /// breakdown — the `"http"` section of `GET /stats`. Routes with no
    /// traffic are omitted.
    pub(crate) fn to_json(&self) -> Json {
        let mut routes = Vec::new();
        for (label, stat) in ROUTE_LABELS.iter().zip(self.routes.iter()) {
            let r = stat.lock().unwrap();
            if r.lat.count() == 0 {
                continue;
            }
            let statuses =
                r.statuses.iter().map(|&(s, c)| (s.to_string(), Json::Num(c as f64))).collect();
            routes.push((
                *label,
                Json::obj(vec![
                    ("requests", Json::Num(r.lat.count() as f64)),
                    ("p50_us", Json::Num(r.lat.percentile_ns(50.0) / 1e3)),
                    ("p99_us", Json::Num(r.lat.percentile_ns(99.0) / 1e3)),
                    ("status", Json::Obj(statuses)),
                ]),
            ));
        }
        Json::obj(routes)
    }

    /// The HTTP-side Prometheus families: request counts by
    /// route × status and a latency summary (p50/p99 quantiles) by
    /// route.
    pub(crate) fn render_prometheus(&self, out: &mut String) {
        out.push_str("# HELP qwyc_http_requests_total HTTP requests by route and status.\n");
        out.push_str("# TYPE qwyc_http_requests_total counter\n");
        for (label, stat) in ROUTE_LABELS.iter().zip(self.routes.iter()) {
            let r = stat.lock().unwrap();
            for &(status, count) in &r.statuses {
                let _ = writeln!(
                    out,
                    "qwyc_http_requests_total{{route=\"{label}\",status=\"{status}\"}} {count}"
                );
            }
        }
        out.push_str("# HELP qwyc_http_request_latency_us HTTP request latency by route.\n");
        out.push_str("# TYPE qwyc_http_request_latency_us summary\n");
        for (label, stat) in ROUTE_LABELS.iter().zip(self.routes.iter()) {
            let r = stat.lock().unwrap();
            let n = r.lat.count();
            if n == 0 {
                continue;
            }
            let p50 = r.lat.percentile_ns(50.0) / 1e3;
            let p99 = r.lat.percentile_ns(99.0) / 1e3;
            let sum = r.lat.mean_ns() * n as f64 / 1e3;
            let _ = writeln!(
                out,
                "qwyc_http_request_latency_us{{route=\"{label}\",quantile=\"0.5\"}} {p50:.1}"
            );
            let _ = writeln!(
                out,
                "qwyc_http_request_latency_us{{route=\"{label}\",quantile=\"0.99\"}} {p99:.1}"
            );
            let _ = writeln!(out, "qwyc_http_request_latency_us_sum{{route=\"{label}\"}} {sum:.1}");
            let _ = writeln!(out, "qwyc_http_request_latency_us_count{{route=\"{label}\"}} {n}");
        }
    }
}

/// The engine-side Prometheus families, rendered from the aggregated
/// serving [`Snapshot`]: per-shard request counters, the exit-position
/// histogram (the serving-side view of the paper's Figures 5-6),
/// batch-flush/cache/ops counters, and the end-to-end latency summary.
pub(crate) fn render_engine_prometheus(snap: &Snapshot, out: &mut String) {
    out.push_str("# HELP qwyc_requests_total Requests scored across all shards.\n");
    out.push_str("# TYPE qwyc_requests_total counter\n");
    let _ = writeln!(out, "qwyc_requests_total {}", snap.requests);

    out.push_str("# HELP qwyc_shard_requests_total Requests scored per shard.\n");
    out.push_str("# TYPE qwyc_shard_requests_total counter\n");
    for (i, &n) in snap.shard_requests.iter().enumerate() {
        let _ = writeln!(out, "qwyc_shard_requests_total{{shard=\"{i}\"}} {n}");
    }

    out.push_str("# HELP qwyc_request_latency_us End-to-end scoring latency.\n");
    out.push_str("# TYPE qwyc_request_latency_us summary\n");
    let _ = writeln!(out, "qwyc_request_latency_us{{quantile=\"0.5\"}} {:.1}", snap.p50_latency_us);
    let _ = writeln!(
        out,
        "qwyc_request_latency_us{{quantile=\"0.99\"}} {:.1}",
        snap.p99_latency_us
    );
    let _ = writeln!(
        out,
        "qwyc_request_latency_us_sum {:.1}",
        snap.mean_latency_us * snap.requests as f64
    );
    let _ = writeln!(out, "qwyc_request_latency_us_count {}", snap.requests);

    out.push_str("# HELP qwyc_mean_models Mean base models evaluated per request.\n");
    out.push_str("# TYPE qwyc_mean_models gauge\n");
    let _ = writeln!(out, "qwyc_mean_models {:.4}", snap.mean_models);
    out.push_str("# HELP qwyc_early_exit_fraction Fraction of requests that quit early.\n");
    out.push_str("# TYPE qwyc_early_exit_fraction gauge\n");
    let _ = writeln!(out, "qwyc_early_exit_fraction {:.4}", snap.early_frac);

    // Exit positions as a classic cumulative histogram: one bucket per
    // position that actually saw an exit (bounded by the engine's
    // position cap, so cardinality cannot run away).
    out.push_str("# HELP qwyc_exit_position Base models evaluated before the ensemble quit.\n");
    out.push_str("# TYPE qwyc_exit_position histogram\n");
    let mut acc = 0u64;
    let mut models_sum = 0u64;
    for (pos, &c) in snap.stop_counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        acc += c;
        models_sum += c * pos as u64;
        let _ = writeln!(out, "qwyc_exit_position_bucket{{le=\"{pos}\"}} {acc}");
    }
    let _ = writeln!(out, "qwyc_exit_position_bucket{{le=\"+Inf\"}} {acc}");
    let _ = writeln!(out, "qwyc_exit_position_sum {models_sum}");
    let _ = writeln!(out, "qwyc_exit_position_count {acc}");

    out.push_str("# HELP qwyc_batch_flush_total Batch flushes by reason.\n");
    out.push_str("# TYPE qwyc_batch_flush_total counter\n");
    let _ = writeln!(out, "qwyc_batch_flush_total{{reason=\"idle\"}} {}", snap.flush_idle);
    let _ = writeln!(out, "qwyc_batch_flush_total{{reason=\"full\"}} {}", snap.flush_full);
    let _ = writeln!(out, "qwyc_batch_flush_total{{reason=\"deadline\"}} {}", snap.flush_deadline);

    let o = &snap.ops;
    out.push_str("# HELP qwyc_cache_events_total Response-cache events.\n");
    out.push_str("# TYPE qwyc_cache_events_total counter\n");
    let _ = writeln!(out, "qwyc_cache_events_total{{event=\"hit\"}} {}", o.cache_hits);
    let _ = writeln!(out, "qwyc_cache_events_total{{event=\"miss\"}} {}", o.cache_misses);
    let _ = writeln!(out, "qwyc_cache_events_total{{event=\"eviction\"}} {}", o.cache_evictions);

    out.push_str("# HELP qwyc_busy_shed_total Requests refused at admission (all queues full).\n");
    out.push_str("# TYPE qwyc_busy_shed_total counter\n");
    let _ = writeln!(out, "qwyc_busy_shed_total {}", o.busy_shed);
    out.push_str("# HELP qwyc_timeouts_total Requests shed after their deadline expired.\n");
    out.push_str("# TYPE qwyc_timeouts_total counter\n");
    let _ = writeln!(out, "qwyc_timeouts_total {}", o.timeouts);
    out.push_str("# HELP qwyc_shard_restarts_total Shard workers restarted after a panic.\n");
    out.push_str("# TYPE qwyc_shard_restarts_total counter\n");
    let _ = writeln!(out, "qwyc_shard_restarts_total {}", o.shard_restarts);
    out.push_str("# HELP qwyc_reload_total Plan hot-reload attempts by outcome.\n");
    out.push_str("# TYPE qwyc_reload_total counter\n");
    let _ = writeln!(out, "qwyc_reload_total{{result=\"ok\"}} {}", o.reload_ok);
    let _ = writeln!(out, "qwyc_reload_total{{result=\"rejected\"}} {}", o.reload_rejected);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Metrics, ShardedMetrics};

    #[test]
    fn routes_fold_unknown_paths_into_other() {
        assert_eq!(route_index("/v1/score"), 0);
        assert_eq!(route_index("/drain"), 7);
        assert_eq!(route_index("/.git/config"), ROUTE_LABELS.len() - 1);
        assert_eq!(ROUTE_LABELS[route_index("/nope")], "other");
    }

    #[test]
    fn record_surfaces_in_json_and_prometheus() {
        let m = HttpMetrics::new();
        m.record(route_index("/v1/score"), 200, 50_000);
        m.record(route_index("/v1/score"), 200, 70_000);
        m.record(route_index("/v1/score"), 503, 10_000);
        m.record(route_index("/healthz"), 200, 5_000);
        let j = m.to_json();
        let score = j.req("/v1/score").unwrap();
        assert_eq!(score.req("requests").unwrap().as_usize().unwrap(), 3);
        assert_eq!(score.req("status").unwrap().req("200").unwrap().as_usize().unwrap(), 2);
        assert_eq!(score.req("status").unwrap().req("503").unwrap().as_usize().unwrap(), 1);
        assert!(score.req("p99_us").unwrap().as_f64().unwrap() > 0.0);
        // Untouched routes are omitted from the JSON view.
        assert!(j.get("/drain").is_none());
        let mut out = String::new();
        m.render_prometheus(&mut out);
        assert!(
            out.contains("qwyc_http_requests_total{route=\"/v1/score\",status=\"200\"} 2"),
            "{out}"
        );
        assert!(
            out.contains("qwyc_http_requests_total{route=\"/healthz\",status=\"200\"} 1"),
            "{out}"
        );
        assert!(
            out.contains("qwyc_http_request_latency_us{route=\"/v1/score\",quantile=\"0.99\"}"),
            "{out}"
        );
        assert!(out.contains("qwyc_http_request_latency_us_count{route=\"/v1/score\"} 3"), "{out}");
    }

    #[test]
    fn engine_families_render_from_a_snapshot() {
        let sm = ShardedMetrics::new(2);
        sm.shard(0).record_request(10_000, 2, true);
        sm.shard(0).record_request(12_000, 2, true);
        sm.shard(1).record_request(20_000, 7, false);
        sm.ops().cache_hits.fetch_add(4, std::sync::atomic::Ordering::Relaxed);
        let mut out = String::new();
        render_engine_prometheus(&sm.snapshot(), &mut out);
        assert!(out.contains("qwyc_requests_total 3"), "{out}");
        assert!(out.contains("qwyc_shard_requests_total{shard=\"0\"} 2"), "{out}");
        assert!(out.contains("qwyc_shard_requests_total{shard=\"1\"} 1"), "{out}");
        // Cumulative histogram: 2 exits at position 2, all 3 by 7.
        assert!(out.contains("qwyc_exit_position_bucket{le=\"2\"} 2"), "{out}");
        assert!(out.contains("qwyc_exit_position_bucket{le=\"7\"} 3"), "{out}");
        assert!(out.contains("qwyc_exit_position_bucket{le=\"+Inf\"} 3"), "{out}");
        assert!(out.contains("qwyc_exit_position_sum 11"), "{out}");
        assert!(out.contains("qwyc_exit_position_count 3"), "{out}");
        assert!(out.contains("qwyc_cache_events_total{event=\"hit\"} 4"), "{out}");
        assert!(out.contains("qwyc_reload_total{result=\"ok\"} 0"), "{out}");
    }

    #[test]
    fn bare_sink_snapshot_renders_without_shards() {
        let m = Metrics::new();
        m.record_request(1_000, 1, true);
        let mut out = String::new();
        render_engine_prometheus(&m.snapshot(), &mut out);
        assert!(out.contains("qwyc_requests_total 1"), "{out}");
        assert!(!out.contains("shard=\""), "{out}");
    }
}
