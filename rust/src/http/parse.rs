//! HTTP/1.1 request-head parsing over the shared capped line reader
//! (`util::lineio`) — the same oversized-input hardening the line
//! protocol uses, applied per header line. Everything is bounded:
//! one line ([`MAX_HEADER_LINE`]), the header count ([`MAX_HEADERS`]),
//! and the declared body ([`MAX_BODY_BYTES`]). The head lands in a
//! caller-owned [`RequestHead`] whose `String` fields are reused across
//! requests on a keep-alive connection.

use crate::util::lineio::{read_line_capped, LineRead};
use std::io::BufRead;

/// Hard cap on the request line and each header line. 8 KiB matches
/// the de-facto server default; an oversized line answers 431 and
/// closes (framing is unrecoverable once a line is discarded).
pub(crate) const MAX_HEADER_LINE: usize = 8 * 1024;

/// Cap on header count per request.
pub(crate) const MAX_HEADERS: usize = 64;

/// Cap on a request body (`Content-Length`). Scoring batches are rows
/// of f32 text — 1 MiB is thousands of rows.
pub(crate) const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Request body encoding, from `Content-Type`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum BodyKind {
    /// `application/json` (the default when absent).
    Json,
    /// Any `Content-Type` mentioning `csv` (e.g. `text/csv`).
    Csv,
}

/// Request method; only the two the router serves are distinguished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Method {
    Get,
    Post,
    Other,
}

/// One parsed request head: the request line plus the few headers the
/// server acts on. Reused across requests on a connection (the
/// `target` buffer is cleared and refilled, not reallocated).
#[derive(Debug)]
pub(crate) struct RequestHead {
    pub(crate) method: Method,
    pub(crate) target: String,
    /// HTTP/1.1 defaults to keep-alive; `Connection: close` (or an
    /// HTTP/1.0 peer without `keep-alive`) turns it off.
    pub(crate) keep_alive: bool,
    pub(crate) content_length: usize,
    pub(crate) content_type: BodyKind,
    /// `X-Deadline-Ms` header (0 = explicit opt-out of the server
    /// default, like the line protocol's `DEADLINE_MS=0`).
    pub(crate) deadline_ms: Option<u64>,
    /// Peer sent `Expect: 100-continue` and is waiting for the interim
    /// response before streaming the body (curl does this for larger
    /// POSTs).
    pub(crate) expect_continue: bool,
}

impl Default for RequestHead {
    fn default() -> Self {
        RequestHead {
            method: Method::Other,
            target: String::new(),
            keep_alive: true,
            content_length: 0,
            content_type: BodyKind::Json,
            deadline_ms: None,
            expect_continue: false,
        }
    }
}

/// Why a request head could not be produced.
pub(crate) enum HeadError {
    /// Clean end of the connection between requests (or plain I/O
    /// failure) — nothing to answer.
    Closed,
    /// Malformed or oversized head. Framing is lost, so the caller
    /// answers `status`/`message` once and closes the connection.
    Fatal { status: u16, message: String },
}

/// Read and parse one request head. Blank lines before the request
/// line are skipped (robustness; RFC 9112 §2.2). On `Fatal` the
/// connection must close after the error response: an unparseable or
/// discarded line means the next request boundary is unknown.
pub(crate) fn read_head<R: BufRead>(
    reader: &mut R,
    line_buf: &mut Vec<u8>,
    head: &mut RequestHead,
) -> Result<(), HeadError> {
    *head = RequestHead { target: std::mem::take(&mut head.target), ..RequestHead::default() };
    head.target.clear();
    // Request line (skipping interstitial blank lines).
    loop {
        match read_line_capped(reader, MAX_HEADER_LINE, line_buf) {
            Err(_) | Ok(LineRead::Eof) => return Err(HeadError::Closed),
            Ok(LineRead::TooLong) => {
                return Err(HeadError::Fatal {
                    status: 431,
                    message: format!("request line exceeds {MAX_HEADER_LINE} bytes"),
                })
            }
            Ok(LineRead::Line) => {}
        }
        let line = trim_crlf(line_buf);
        if line.is_empty() {
            continue;
        }
        parse_request_line(line, head)?;
        break;
    }
    // Header lines until the blank separator.
    for _ in 0..=MAX_HEADERS {
        match read_line_capped(reader, MAX_HEADER_LINE, line_buf) {
            Err(_) | Ok(LineRead::Eof) => {
                return Err(HeadError::Fatal {
                    status: 400,
                    message: "truncated request head".to_string(),
                })
            }
            Ok(LineRead::TooLong) => {
                return Err(HeadError::Fatal {
                    status: 431,
                    message: format!("header line exceeds {MAX_HEADER_LINE} bytes"),
                })
            }
            Ok(LineRead::Line) => {}
        }
        let line = trim_crlf(line_buf);
        if line.is_empty() {
            return Ok(());
        }
        parse_header_line(line, head)?;
    }
    Err(HeadError::Fatal {
        status: 431,
        message: format!("more than {MAX_HEADERS} headers"),
    })
}

/// Strip one trailing `\r` (the reader already stripped the `\n`) and
/// decode lossily — garbage bytes become characters the parser rejects.
fn trim_crlf(buf: &[u8]) -> std::borrow::Cow<'_, str> {
    let b = buf.strip_suffix(b"\r").unwrap_or(buf);
    String::from_utf8_lossy(b)
}

fn parse_request_line(line: &str, head: &mut RequestHead) -> Result<(), HeadError> {
    let bad = || HeadError::Fatal {
        status: 400,
        message: "malformed request line (want: METHOD TARGET HTTP/1.x)".to_string(),
    };
    let mut parts = line.split(' ');
    let method = parts.next().ok_or_else(bad)?;
    let target = parts.next().ok_or_else(bad)?;
    let version = parts.next().ok_or_else(bad)?;
    if parts.next().is_some() || method.is_empty() || target.is_empty() {
        return Err(bad());
    }
    head.method = match method {
        "GET" => Method::Get,
        "POST" => Method::Post,
        _ => Method::Other,
    };
    // Ignore any query string: the API carries parameters in headers
    // and bodies.
    let path = target.split('?').next().unwrap_or(target);
    head.target.push_str(path);
    match version {
        "HTTP/1.1" => head.keep_alive = true,
        "HTTP/1.0" => head.keep_alive = false,
        _ => {
            return Err(HeadError::Fatal {
                status: 505,
                message: format!("unsupported protocol version '{version}'"),
            })
        }
    }
    Ok(())
}

fn parse_header_line(line: &str, head: &mut RequestHead) -> Result<(), HeadError> {
    let Some((name, value)) = line.split_once(':') else {
        return Err(HeadError::Fatal {
            status: 400,
            message: format!("malformed header line '{line}'"),
        });
    };
    let value = value.trim();
    // Header names are ASCII; eq_ignore_ascii_case avoids allocating a
    // lowercased copy per header.
    if name.eq_ignore_ascii_case("content-length") {
        let n = value.parse::<usize>().map_err(|_| HeadError::Fatal {
            status: 400,
            message: format!("bad Content-Length '{value}'"),
        })?;
        if n > MAX_BODY_BYTES {
            return Err(HeadError::Fatal {
                status: 413,
                message: format!("body of {n} bytes exceeds cap {MAX_BODY_BYTES}"),
            });
        }
        head.content_length = n;
    } else if name.eq_ignore_ascii_case("connection") {
        if value.eq_ignore_ascii_case("close") {
            head.keep_alive = false;
        } else if value.eq_ignore_ascii_case("keep-alive") {
            head.keep_alive = true;
        }
    } else if name.eq_ignore_ascii_case("content-type") {
        if value.to_ascii_lowercase().contains("csv") {
            head.content_type = BodyKind::Csv;
        }
    } else if name.eq_ignore_ascii_case("x-deadline-ms") {
        let ms = value.parse::<u64>().map_err(|_| HeadError::Fatal {
            status: 400,
            message: format!("bad X-Deadline-Ms '{value}'"),
        })?;
        head.deadline_ms = Some(ms);
    } else if name.eq_ignore_ascii_case("transfer-encoding") {
        // Content-Length framing only: a chunked body we cannot frame
        // is fatal by definition.
        return Err(HeadError::Fatal {
            status: 501,
            message: "Transfer-Encoding is not supported (use Content-Length)".to_string(),
        });
    } else if name.eq_ignore_ascii_case("expect") {
        if value.eq_ignore_ascii_case("100-continue") {
            head.expect_continue = true;
        }
    }
    // Every other header is ignored.
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &[u8]) -> Result<RequestHead, HeadError> {
        let mut head = RequestHead::default();
        let mut buf = Vec::new();
        read_head(&mut Cursor::new(raw.to_vec()), &mut buf, &mut head)?;
        Ok(head)
    }

    #[test]
    fn parses_a_full_head() {
        let head = parse(
            b"POST /v1/score?x=1 HTTP/1.1\r\nHost: a\r\nContent-Type: text/csv\r\n\
              Content-Length: 12\r\nX-Deadline-Ms: 250\r\nConnection: close\r\n\r\n",
        )
        .unwrap_or_else(|_| panic!("head should parse"));
        assert_eq!(head.method, Method::Post);
        assert_eq!(head.target, "/v1/score");
        assert_eq!(head.content_length, 12);
        assert_eq!(head.content_type, BodyKind::Csv);
        assert_eq!(head.deadline_ms, Some(250));
        assert!(!head.keep_alive);
    }

    #[test]
    fn defaults_and_blank_line_skip() {
        let head = parse(b"\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n")
            .unwrap_or_else(|_| panic!("head should parse"));
        assert_eq!(head.method, Method::Get);
        assert_eq!(head.target, "/healthz");
        assert!(head.keep_alive);
        assert_eq!(head.content_length, 0);
        assert_eq!(head.content_type, BodyKind::Json);
        assert_eq!(head.deadline_ms, None);
    }

    #[test]
    fn rejects_malformed_and_oversized_input() {
        match parse(b"GARBAGE\r\n\r\n") {
            Err(HeadError::Fatal { status: 400, .. }) => {}
            _ => panic!("expected 400"),
        }
        match parse(b"GET / HTTP/2.0\r\n\r\n") {
            Err(HeadError::Fatal { status: 505, .. }) => {}
            _ => panic!("expected 505"),
        }
        let mut big = b"GET / HTTP/1.1\r\nX-Big: ".to_vec();
        let target = big.len() + MAX_HEADER_LINE + 10;
        big.resize(target, b'a');
        big.extend_from_slice(b"\r\n\r\n");
        match parse(&big) {
            Err(HeadError::Fatal { status: 431, .. }) => {}
            _ => panic!("expected 431"),
        }
        match parse(b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n") {
            Err(HeadError::Fatal { status: 413, .. }) => {}
            _ => panic!("expected 413"),
        }
        match parse(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n") {
            Err(HeadError::Fatal { status: 501, .. }) => {}
            _ => panic!("expected 501"),
        }
        match parse(b"GET / HTTP/1.1\r\nContent-Length") {
            Err(HeadError::Fatal { status: 400, .. }) => {}
            _ => panic!("expected 400 for truncated head"),
        }
    }

    #[test]
    fn head_buffer_is_reused_across_requests() {
        let raw = b"GET /stats HTTP/1.1\r\n\r\nGET /healthz HTTP/1.0\r\n\r\n";
        let mut r = Cursor::new(raw.to_vec());
        let mut head = RequestHead::default();
        let mut buf = Vec::new();
        assert!(read_head(&mut r, &mut buf, &mut head).is_ok());
        assert_eq!(head.target, "/stats");
        assert!(read_head(&mut r, &mut buf, &mut head).is_ok());
        // The second parse fully resets the first request's state.
        assert_eq!(head.target, "/healthz");
        assert!(!head.keep_alive);
        assert!(matches!(
            read_head(&mut r, &mut buf, &mut head),
            Err(HeadError::Closed)
        ));
    }
}
